"""Quickstart: the write-free CLT-GRNG and the Bayesian head in 60 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clt_grng as grng
from repro.core.sampling import (BayesHeadConfig, logit_samples,
                                 prepare_serving_head)
from repro.core.uncertainty import predictive_stats

# ----------------------------------------------------------------------
# 1. The CLT-GRNG: Gaussian samples from subset sums of fixed "devices".
# ----------------------------------------------------------------------
cfg = grng.GRNGConfig()          # 16 virtual FeFETs/cell, select 8
eps = grng.eps(cfg, n_rows=64, n_cols=64, num_samples=256)  # [256, 64, 64]
print(f"ε mean={float(eps.mean()):+.4f}  std={float(eps.std()):.4f} "
      f"(write-free: no stored randomness, no RNG state)")

mean, std = cfg.analytic_sum_stats()
print(f"raw-sum statistics: {mean:.2f} µA / {std:.3f} µA "
      "(paper Fig. 9: 10.1 / 0.993)")

# ----------------------------------------------------------------------
# 2. A Bayesian output head: w = µ + σ·ε, deployed with offset
#    compensation and sampled three different ways.
# ----------------------------------------------------------------------
key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)
d_in, n_classes = 128, 10
mu = jax.random.normal(k1, (d_in, n_classes)) * 0.1
sigma = jax.nn.softplus(jax.random.normal(k2, (d_in, n_classes)) - 2) * 0.1

hcfg = BayesHeadConfig(num_samples=20, mode="rank16",  # R-independent cost
                       grng=cfg, compute_dtype=jnp.float32)
head = prepare_serving_head(mu, sigma, hcfg)   # µ' = µ − σ·Δε (one-time)

x = jax.random.normal(k3, (4, d_in))
samples = logit_samples(head, x, hcfg)          # [20, 4, 10]
stats = predictive_stats(samples)
print("\nper-input uncertainty-aware predictions:")
for i in range(4):
    print(f"  input {i}: class={int(stats['prediction'][i])} "
          f"conf={float(stats['confidence'][i]):.3f} "
          f"epistemic={float(stats['mutual_information'][i]):.4f}")

# paper vs rank16 modes produce IDENTICAL samples (exact factorization)
paper = logit_samples(head, x, BayesHeadConfig(
    num_samples=20, mode="paper", grng=cfg, compute_dtype=jnp.float32))
print("\nrank16 ≡ paper-mode samples:",
      bool(np.allclose(np.asarray(samples), np.asarray(paper), atol=1e-4)))
