"""End-to-end SAR driver: train the paper's application model and
evaluate uncertainty-aware detection (paper §V-B).

Trains the deterministic CNN and the Bayesian-last-layer BNN on the
synthetic SARD task, then prints the paper's metric suite (accuracy,
AURC, AECE, AMCE) for CNN vs ideal-Gaussian BNN vs this work's CLT-GRNG
path, plus a risk–coverage table — the "skip the verification dive"
decision curve from Fig. 1/16.

Run: PYTHONPATH=src python examples/train_sar_bnn.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.sar_train import (R_SAMPLES, model_cfg, test_batches,
                                  trained_models)
from repro.core.uncertainty import (predictive_stats, risk_coverage_curve,
                                    uq_report)
from repro.models.sar_cnn import logit_samples_ideal, logit_samples_serve


def main() -> None:
    print("=== training (cached under artifacts/sar_models) ===")
    cnn, bnn = trained_models()

    batches = list(test_batches())
    images = jnp.concatenate([b["images"] for b in batches])
    labels = jnp.concatenate([b["labels"] for b in batches])

    rows = {}
    rows["CNN (deterministic)"] = logit_samples_serve(
        cnn, images, model_cfg(False), 1)
    rows["BNN (ideal Gaussian)"] = logit_samples_ideal(
        bnn, images, model_cfg(True), R_SAMPLES, jax.random.PRNGKey(9))
    clt_cfg = dataclasses.replace(model_cfg(True), cim_execution=True)
    rows["This work (CLT-GRNG + CIM)"] = logit_samples_serve(
        bnn, images, clt_cfg, R_SAMPLES, mode="rank16")

    print("\n=== paper §V-B metric suite (synthetic SARD) ===")
    print(f"{'model':<28}{'acc':>8}{'AURC':>8}{'AECE':>8}{'AMCE':>8}")
    for name, samples in rows.items():
        r = uq_report(samples, labels)
        print(f"{name:<28}{float(r['accuracy']):8.4f}"
              f"{float(r['aurc']):8.4f}{float(r['aece']):8.4f}"
              f"{float(r['amce']):8.4f}")

    print("\n=== risk–coverage (This work) — the SAR decision curve ===")
    stats = predictive_stats(rows["This work (CLT-GRNG + CIM)"])
    correct = stats["prediction"] == labels
    cov, risk = risk_coverage_curve(stats["confidence"], correct)
    cov, risk = np.asarray(cov), np.asarray(risk)
    for c in (0.5, 0.7, 0.9, 1.0):
        i = min(int(c * len(cov)) - 1, len(cov) - 1)
        print(f"  keep top {100*c:3.0f}% most-confident detections "
              f"-> miss risk {100*risk[i]:5.2f}%")


if __name__ == "__main__":
    main()
