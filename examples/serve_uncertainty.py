"""Uncertainty-aware LM serving through the continuous-batching engine.

Adaptive fidelity vs the paper's fixed R = 20: every token decision
starts at a small GRNG sample count and escalates only while the
accept / flag-for-verification triage (paper Fig. 1) is statistically
ambiguous.  Prints per-request verdicts with confidence, mutual
information, and the samples actually spent.

Run: PYTHONPATH=src python examples/serve_uncertainty.py [--arch qwen3-0.6b]
"""

import argparse

from repro.launch.serve import serve
from repro.serving import TriagePolicy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=6)
    args = ap.parse_args()

    policy = TriagePolicy(conf_threshold=0.05, mi_threshold=1.0)
    for adaptive in (True, False):
        out = serve(args.arch, smoke=True, batch=args.batch,
                    prompt_len=16, gen_len=args.gen, adaptive=adaptive,
                    n_requests=2 * args.batch, policy=policy)
        name = "adaptive" if adaptive else "fixed-R20"
        print(f"mode={name:9s} {out['tokens_per_s']:8.2f} tok/s  "
              f"samples/token: {out['mean_samples_per_decision']:5.1f}  "
              f"flagged-for-verification: {100*out['flagged_fraction']:.1f}%")
        if adaptive:
            for v in out["verdicts"][:4]:
                print(f"   req {v['rid']}: conf={v['confidence']:.2f} "
                      f"mi={v['mutual_information']:.3f} "
                      f"samples={v['n_samples']} tokens={v['n_tokens']}")


if __name__ == "__main__":
    main()
