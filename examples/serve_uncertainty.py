"""Uncertainty-aware LM serving: batched prefill + decode with the
Bayesian head sampling R CLT-GRNG draws per token.

Every generated token comes with predictive confidence and mutual
information (epistemic uncertainty); tokens above the MI threshold are
flagged "needs verification" — the paper's SAR decision (Fig. 1) at the
token level.  Compares the three head execution modes.

Run: PYTHONPATH=src python examples/serve_uncertainty.py [--arch qwen3-0.6b]
"""

import argparse

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=6)
    args = ap.parse_args()

    for mode in ("paper", "rank16", "moment"):
        out = serve(args.arch, smoke=True, batch=args.batch,
                    prompt_len=16, gen_len=args.gen, mode=mode)
        print(f"mode={mode:7s} {out['tokens_per_s']:8.2f} tok/s  "
              f"flagged-for-verification: {100*out['flagged_fraction']:.1f}%")
        if mode == "paper":
            v = out["verdicts"][0]
            print("   first-token verdicts:",
                  [f"conf={float(c):.2f}/mi={float(m):.3f}"
                   for c, m in zip(v["confidence"],
                                   v["mutual_information"])])


if __name__ == "__main__":
    main()
