"""Pretraining driver for a ~100M-parameter LM with a Bayesian head.

This is the full launcher path (data pipeline → sharded train step →
async checkpoints → straggler monitor) on whatever devices exist.  The
default invocation uses a reduced model/steps so it completes on a CPU
dev box; pass --dim/--layers/--steps to scale up (on a real TPU slice
the same script trains the assigned full configs via --arch X --full).

Run: PYTHONPATH=src python examples/pretrain_lm.py --steps 120
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="use the full (paper-scale) architecture config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    out = train(args.arch, smoke=not args.full, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                metrics_path=None)
    first = out["history"][0]["loss"]
    print(f"\nloss {first:.3f} -> {out['final_loss']:.3f} over "
          f"{args.steps} steps "
          f"({100*(first-out['final_loss'])/first:.1f}% reduction)")


if __name__ == "__main__":
    main()
