"""Fault-tolerance walkthrough: crash → restart → bit-exact continuation,
plus compressed gradients and straggler policy.

1. trains an LM with async checkpointing, simulating a node failure;
2. restarts from the last checkpoint and verifies the loss trajectory
   matches a never-failed run (stateless data pipeline ⇒ exact replay);
3. repeats training with int8 + error-feedback gradient compression
   (the cross-pod reduction mode) and compares final loss.

Run: PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

import tempfile
from pathlib import Path

from repro.launch.train import train

ARCH, STEPS, BATCH, SEQ = "qwen3-1.7b", 40, 4, 32


def main() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        ck = Path(tmp) / "ckpt"

        print("=== reference run (no failure) ===")
        ref = train(ARCH, smoke=True, steps=STEPS, batch=BATCH, seq=SEQ)

        print("\n=== run with simulated failure at step 25 ===")
        try:
            train(ARCH, smoke=True, steps=STEPS, batch=BATCH, seq=SEQ,
                  ckpt_dir=str(ck), ckpt_every=10, fail_at=25)
        except SystemExit as e:
            print(e)

        print("\n=== restart: resumes from step 20 automatically ===")
        resumed = train(ARCH, smoke=True, steps=STEPS, batch=BATCH, seq=SEQ,
                        ckpt_dir=str(ck), ckpt_every=10)
        print(f"\nfinal loss — reference {ref['final_loss']:.4f} vs "
              f"crash+resume {resumed['final_loss']:.4f} "
              f"(Δ={abs(ref['final_loss']-resumed['final_loss']):.2e})")

        print("\n=== int8 + error-feedback compressed gradients ===")
        comp = train(ARCH, smoke=True, steps=STEPS, batch=BATCH, seq=SEQ,
                     compress=True)
        print(f"compressed-reduction final loss {comp['final_loss']:.4f} "
              f"(exact {ref['final_loss']:.4f}) — 4× fewer cross-pod bytes")


if __name__ == "__main__":
    main()
