"""Paper Fig. 16 / Table II (SARD rows): accuracy + UQ comparison.

Evaluates three model variants on held-out synthetic SARD:
  * CNN        — deterministic baseline,
  * BNN        — Bayesian head with *ideal* Gaussian sampling,
  * This work  — Bayesian head with CLT-GRNG samples (rank16 ≡ paper
                 distribution) and the deterministic trunk on the
                 quantized CIM path (im2col + 6-bit chunked ADC).

Reported: accuracy (mAP-50 stand-in), AURC, AECE, AMCE — the paper's
§V-B2 metric suite.  Claims validated downstream in EXPERIMENTS.md:
BNN improves AURC/calibration at matched accuracy; the imperfect
CLT-GRNG distribution costs ≈nothing.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.sar_train import (R_SAMPLES, model_cfg, test_batches,
                                  trained_models)
from repro.core.uncertainty import uq_report
from repro.models.sar_cnn import (logit_samples_ideal, logit_samples_serve)


def _eval(params, cfg, variant: str, batches, key) -> dict:
    all_logits, all_labels = [], []
    for batch in batches:
        if variant == "cnn":
            s = logit_samples_serve(params, batch["images"], cfg, 1)
        elif variant == "ideal":
            key, k = jax.random.split(key)
            s = logit_samples_ideal(params, batch["images"], cfg,
                                    R_SAMPLES, k)
        elif variant == "clt":
            s = logit_samples_serve(params, batch["images"], cfg, R_SAMPLES,
                                    mode="rank16")
        elif variant == "clt_paper":
            s = logit_samples_serve(params, batch["images"], cfg, R_SAMPLES,
                                    mode="paper")
        else:
            raise ValueError(variant)
        all_logits.append(np.asarray(s, np.float32))
        all_labels.append(np.asarray(batch["labels"]))
    logits = jnp.asarray(np.concatenate(all_logits, axis=1))
    labels = jnp.asarray(np.concatenate(all_labels))
    rep = uq_report(logits, labels)
    return {k: float(v) for k, v in rep.items()}


def run(corruption: str | None = None, severity: float = 1.0) -> dict:
    cnn_params, bnn_params = trained_models()
    key = jax.random.PRNGKey(11)
    rows = {}
    batches = list(test_batches(corruption, severity))
    rows["cnn"] = _eval(cnn_params, model_cfg(False), "cnn", batches, key)
    rows["bnn_ideal"] = _eval(bnn_params, model_cfg(True), "ideal",
                              batches, key)
    clt_cfg = dataclasses.replace(model_cfg(True), cim_execution=True)
    rows["this_clt"] = _eval(bnn_params, clt_cfg, "clt", batches, key)
    return rows


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    rows = run()
    dt_us = (time.time() - t0) * 1e6
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/fig16_uq.json").write_text(json.dumps(rows, indent=2))
    out = []
    for name, r in rows.items():
        out.append((f"fig16_{name}", dt_us / 3,
                    f"acc={r['accuracy']:.4f};aurc={r['aurc']:.4f};"
                    f"aece={r['aece']:.4f};amce={r['amce']:.4f}"))
    # The paper's central fig16 claim: the imperfect CLT distribution is
    # ≈free relative to an ideal Gaussian sampler (ΔAURC +0.49%, Δacc
    # +0.2%).  (CNN-vs-BNN AURC gaps only open up under distribution
    # shift — see table2; on the clean set both sit at ceiling.)
    d_acc = rows["this_clt"]["accuracy"] - rows["bnn_ideal"]["accuracy"]
    out.append(("fig16_clt_vs_ideal_acc_delta", dt_us / 3,
                f"{100*d_acc:+.2f}%_vs_paper_+0.2%"))
    d_aurc = rows["this_clt"]["aurc"] - rows["bnn_ideal"]["aurc"]
    out.append(("fig16_clt_vs_ideal_aurc_delta", dt_us / 3,
                f"{d_aurc:+.4f}_abs(paper_+0.49%_rel)"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
