"""Mesh-of-pools fleet-serving scaling benchmark (serving/fleet.py).

Workload: the serving_bench SARD triage stream (same trained CNN, same
triage policy, 25% fog-corrupted), served through ``serve_sar_fleet``
at ``P`` pools × ``SLOTS_PER_POOL`` slots for P in (1, 2, 4, 8) on a
simulated 8-device host mesh.  8 × 64 = 512 concurrent decision slots
— 16× the single-pool serving_bench workload.

Weak scaling: the request count grows with P (``REQS_PER_POOL`` per
pool), so every sweep point runs the same per-pool workload.

Two throughput views per sweep point, for the same reason
serving_bench reports ``model_decisions_per_s`` next to wall clock:

  * WALL  (``decisions_per_s_cold`` / ``_warm``) — measured aggregate
    wall-clock throughput of THIS host.  The CI/dev host is a single
    physical CPU core, so the "8 simulated devices" of
    ``--xla_force_host_platform_device_count`` time-slice one core:
    every shard program of a gang dispatch runs serially and per-pool
    admission (featurize) is serial host work.  Wall scaling is
    therefore ~flat by construction — it measures the simulator, not
    the design — and is reported honestly but NOT gated.
  * MESH  (``decisions_per_s_mesh``) — the §V-A-style latency-model
    throughput on a real P-device mesh, calibrated from measurement.
    The fleet records per tick ``{"wall_s", "trips": [P]}`` where
    ``trips[p]`` is pool p's OWN while-loop trip count (its device-side
    work this tick).  From the P = 1 warm run we fit the per-pool tick
    cost ``t = a + b·trips`` by least squares (a = per-pool host work:
    admission/featurize, dispatch, retirement — all per-pool state
    that lives with its device on a real mesh; b = cost per escalation
    round).  On a mesh the pools run concurrently and the gang
    dispatch is a barrier, so a tick's critical path is its slowest
    pool: ``T_mesh(P) = Σ_ticks (a + b · max_p trips[p])``.  This
    keeps every genuinely serial effect — straggler pools, router
    imbalance, escalation skew — and removes only the one-core
    time-slicing artifact.  ``speedup``/``scaling_efficiency`` are
    computed from the mesh view (P = 1 via the same model, so the
    comparison is model-vs-model, not model-vs-wall).

Also reported per P: ``host_syncs_per_decision`` (fleet syncs — ONE
gang pull serves all P pools per tick) and ``per_pool_syncs_per_
decision`` (= fleet syncs/decision · P), the per-pool structural cost
that must stay at the single-engine ~0.05 budget or better.

The 4-pool point carries the ROADMAP item-1 acceptance gates (enforced
by ``regress.py --baseline benchmarks/baseline_fleet.json``): mesh
speedup ≥ 3× over one pool and scaling efficiency ≥ 0.7.

Device bootstrap: the sweep needs 8 devices; when the process has
fewer (the default CPU process exposes one) the bench re-runs itself
in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count
=8`` and reads the report back — so ``python -m benchmarks.run --only
fleet_bench`` works from any process.

Outputs: repo-root ``BENCH_fleet.json`` (full report), a ``fleet`` key
merged into ``BENCH_serving.json`` (kept across serving_bench rewrites)
and one ``fleet_bench`` record in ``BENCH_history.jsonl``.

Run: PYTHONPATH=src python -m benchmarks.run --only fleet_bench
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BENCH_JSON = ROOT / "BENCH_fleet.json"
SERVING_JSON = ROOT / "BENCH_serving.json"

POOLS = (1, 2, 4, 8)
SLOTS_PER_POOL = 64
REQS_PER_POOL = 384
N_DEVICES = 8
CORRUPT_FRAC = 0.25


def _policy():
    from repro.serving import TriagePolicy
    return TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                        r_min=4, r_max=20, z=1.0)


def _fit_tick_model(tick_log: list[dict]) -> tuple[float, float]:
    """Least-squares fit of per-pool tick cost ``t = a + b·trips``
    from a P = 1 tick log (trips is then that pool's scalar count)."""
    pts = [(float(sum(t["trips"])), float(t["wall_s"]))
           for t in tick_log]
    n = len(pts)
    if n == 0:
        return 0.0, 0.0
    sx = sum(x for x, _ in pts)
    sy = sum(y for _, y in pts)
    sxx = sum(x * x for x, _ in pts)
    sxy = sum(x * y for x, y in pts)
    denom = n * sxx - sx * sx
    if abs(denom) < 1e-12:                  # every tick same trip count
        return sy / n, 0.0
    b = (n * sxy - sx * sy) / denom
    a = (sy - b * sx) / n
    if b < 0.0 or a < 0.0:
        # noisy fit crossed an axis: fall back to the mean-tick model
        # (pessimistic — no trip-count credit)
        return sy / n, 0.0
    return a, b


def _mesh_time_s(tick_log: list[dict], a: float, b: float) -> float:
    """Modelled wall time on a real mesh: pools run concurrently, the
    gang dispatch is a barrier, so each tick costs its slowest pool."""
    return sum(a + b * max(t["trips"]) for t in tick_log)


def _persist_ticks(n_pools: int, tick_log: list[dict]) -> None:
    """Raw per-tick records -> artifacts/fleet/ticks.jsonl (one line
    per tick, tagged with the sweep point) so straggler analysis can
    rerun offline without redoing the sweep."""
    d = Path(__file__).parent.parent / "artifacts" / "fleet"
    d.mkdir(parents=True, exist_ok=True)
    mode = "w" if n_pools == POOLS[0] else "a"
    with open(d / "ticks.jsonl", mode) as f:
        for i, t in enumerate(tick_log):
            f.write(json.dumps({"pools": n_pools, "tick": i,
                                "wall_s": t["wall_s"],
                                "trips": list(t["trips"])}) + "\n")


def _measure(params, cfg, n_pools: int) -> dict:
    from repro.launch.serve import serve_sar_fleet
    kw = dict(n_requests=REQS_PER_POOL * n_pools, n_pools=n_pools,
              slots_per_pool=SLOTS_PER_POOL, policy=_policy(),
              corrupt_frac=CORRUPT_FRAC, corruption="fog",
              params=params, cfg=cfg)
    t0 = time.time()
    cold = serve_sar_fleet(**kw)
    cold_wall = time.time() - t0
    warm = serve_sar_fleet(**kw)          # compiled gang fn reuse
    return {
        "n_pools": n_pools,
        "slots_per_pool": SLOTS_PER_POOL,
        "gang": warm["gang"],
        "requests": warm["requests"],
        "decisions": warm["decisions"],
        "ticks": warm["ticks"],
        "tick_log": warm["tick_log"],
        "cold_wall_s": cold_wall,
        "decisions_per_s_cold": cold["decisions_per_s"],
        "decisions_per_s_warm": warm["decisions_per_s"],
        "mean_samples_per_decision": warm["mean_samples_per_decision"],
        "flag_fraction": warm.get("flag_fraction", float("nan")),
        "host_syncs": warm["host_syncs"],
        "host_syncs_per_decision": warm["host_syncs_per_decision"],
        # the per-POOL structural cost: one gang sync serves P pools
        "per_pool_syncs_per_decision":
            warm["host_syncs_per_decision"] * n_pools,
        "backlog_peak": warm["backlog_peak"],
        "routed_per_pool": warm["routed_per_pool"],
        "energy_total_J": warm.get("energy_total_J"),
    }


def _report() -> dict:
    from repro.launch.serve import sar_layer_shapes  # noqa: F401
    from repro.models.sar_cnn import SarCnnConfig
    from benchmarks.serving_bench import trained_params
    cfg = SarCnnConfig()
    params = trained_params(cfg)
    sweep = {str(p): _measure(params, cfg, p) for p in POOLS}

    # calibrate the per-pool tick-cost model on the 1-pool warm run,
    # then evaluate every sweep point's tick log under it (see module
    # docstring — critical path per tick is the slowest pool)
    a, b = _fit_tick_model(sweep["1"]["tick_log"])
    base_wall = sweep["1"]["decisions_per_s_warm"]
    base_mesh = None
    for p in POOLS:
        rec = sweep[str(p)]
        t_mesh = _mesh_time_s(rec["tick_log"], a, b)
        rec["mesh_time_s"] = t_mesh
        rec["decisions_per_s_mesh"] = (
            rec["decisions"] / t_mesh if t_mesh > 0 else float("nan"))
        if base_mesh is None:               # P = 1: self-consistency
            base_mesh = rec["decisions_per_s_mesh"]
        rec["speedup"] = rec["decisions_per_s_mesh"] / base_mesh
        rec["scaling_efficiency"] = rec["speedup"] / p
        rec["speedup_wall"] = rec["decisions_per_s_warm"] / base_wall
        rec["scaling_efficiency_wall"] = rec["speedup_wall"] / p
        # straggler share: fraction of the mesh critical path that is
        # waiting on the slowest pool vs the mean — 0 when every pool
        # runs the same trip count every tick
        mean_trips = sum(sum(t["trips"]) / len(t["trips"])
                         for t in rec["tick_log"])
        max_trips = sum(float(max(t["trips"])) for t in rec["tick_log"])
        rec["straggler_share"] = (1.0 - mean_trips / max_trips
                                  if max_trips > 0 else 0.0)
        _persist_ticks(p, rec["tick_log"])
        del rec["tick_log"]                 # raw log stays out of JSON
    return {
        "workload": {
            "pools": list(POOLS),
            "slots_per_pool": SLOTS_PER_POOL,
            "requests_per_pool": REQS_PER_POOL,
            "corrupt_frac": CORRUPT_FRAC,
            "n_devices": N_DEVICES,
            "scaling": "weak (requests grow with P)",
        },
        "latency_model": {
            "a_s_per_pool_tick": a,
            "b_s_per_trip": b,
            "fit_ticks": sweep["1"]["ticks"],
            "source": "least squares on the P=1 warm tick log; "
                      "T_mesh(P) = sum over ticks of "
                      "(a + b * max_p trips[p])",
        },
        "pools": sweep,
        "speedup_4pools": sweep["4"]["speedup"],
        "scaling_efficiency_4pools": sweep["4"]["scaling_efficiency"],
        "speedup_8pools": sweep["8"]["speedup"],
        "scaling_efficiency_8pools": sweep["8"]["scaling_efficiency"],
        "straggler_share_8pools": sweep["8"]["straggler_share"],
    }


def _rows(report: dict) -> list[tuple[str, float, str]]:
    out = []
    for p in POOLS:
        rec = report["pools"][str(p)]
        us = rec["cold_wall_s"] * 1e6 / max(rec["decisions"], 1)
        out.append((f"fleet_sar_{p}pool", us,
                    f"mesh_dps={rec['decisions_per_s_mesh']:.1f};"
                    f"speedup={rec['speedup']:.2f}x;"
                    f"eff={rec['scaling_efficiency']:.2f};"
                    f"wall_dps={rec['decisions_per_s_warm']:.1f};"
                    f"cold_dps={rec['decisions_per_s_cold']:.1f};"
                    f"syncs_per_dec={rec['host_syncs_per_decision']:.4f};"
                    f"pool_syncs_per_dec="
                    f"{rec['per_pool_syncs_per_decision']:.4f};"
                    f"samples={rec['mean_samples_per_decision']:.2f};"
                    f"flagged={rec['flag_fraction']:.3f};"
                    f"gang={rec['gang']}"))
    out.append(("fleet_sar_scaling", 0.0,
                f"speedup_4pools={report['speedup_4pools']:.2f}x;"
                f"eff_4pools={report['scaling_efficiency_4pools']:.2f};"
                f"speedup_8pools={report['speedup_8pools']:.2f}x;"
                f"eff_8pools={report['scaling_efficiency_8pools']:.2f};"
                f"model=a+b*trips,a="
                f"{report['latency_model']['a_s_per_pool_tick']*1e3:.2f}"
                f"ms,b="
                f"{report['latency_model']['b_s_per_trip']*1e3:.2f}ms"))
    return out


def _merge_into_serving_json(report: dict) -> None:
    """Ride the ``fleet`` key into BENCH_serving.json (serving_bench
    preserves it across its own rewrites)."""
    prev = {}
    if SERVING_JSON.exists():
        try:
            prev = json.loads(SERVING_JSON.read_text())
        except json.JSONDecodeError:
            prev = {}
    prev["fleet"] = {
        "pools": {p: {k: report["pools"][p][k] for k in
                      ("decisions_per_s_warm", "decisions_per_s_mesh",
                       "speedup", "scaling_efficiency",
                       "host_syncs_per_decision",
                       "per_pool_syncs_per_decision")}
                  for p in report["pools"]},
        "latency_model": report["latency_model"],
        "speedup_4pools": report["speedup_4pools"],
        "scaling_efficiency_4pools": report["scaling_efficiency_4pools"],
    }
    SERVING_JSON.write_text(json.dumps(prev, indent=2, sort_keys=True))


def _bench_here() -> list[tuple[str, float, str]]:
    report = _report()
    BENCH_JSON.write_text(json.dumps(report, indent=2, sort_keys=True))
    _merge_into_serving_json(report)
    from benchmarks import history
    history.record("fleet_bench",
                   {"pools": report["pools"],
                    "latency_model": report["latency_model"],
                    "speedup_4pools": report["speedup_4pools"],
                    "scaling_efficiency_4pools":
                        report["scaling_efficiency_4pools"]},
                   path=ROOT / "BENCH_history.jsonl")
    return _rows(report)


def _bench_subprocess() -> list[tuple[str, float, str]]:
    """Re-run the sweep in a child with 8 forced host devices."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count"
            f"={N_DEVICES}").strip()
    src = str(ROOT / "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fleet_bench"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=3000)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fleet_bench subprocess failed:\n{proc.stdout}\n"
            f"{proc.stderr}")
    return _rows(json.loads(BENCH_JSON.read_text()))


def bench() -> list[tuple[str, float, str]]:
    import jax
    if len(jax.devices()) < N_DEVICES:
        return _bench_subprocess()
    return _bench_here()


if __name__ == "__main__":
    import jax
    if len(jax.devices()) < N_DEVICES:
        rows = _bench_subprocess()
    else:
        rows = _bench_here()
    for row in rows:
        print(",".join(str(x) for x in row))
