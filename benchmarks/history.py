"""Append-only benchmark history: every bench run leaves a record.

BENCH_*.json are overwritten each run, which makes them snapshots, not
a trajectory.  This module gives every bench run a durable line in
repo-root ``BENCH_history.jsonl``: one schema-versioned JSON record per
(bench module, run) carrying the git SHA, a backend fingerprint (the
honesty bit: CPU interpret-mode numbers must never be compared against
compiled-backend numbers), and the run's key metrics.  ``regress.py``
reads the same flat metric namespace to gate regressions;
``benchmarks/run.py`` appends a record per module automatically.

Record schema (v1):
    {"schema": 1, "bench": "<module>", "ts": "<iso8601 utc>",
     "git_sha": "<sha or null>",
     "fingerprint": {"backend", "device_kind", "jax", "python",
                     "interpret_mode"},
     "metrics": {...}}
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import Any

SCHEMA_VERSION = 1
HISTORY_PATH = Path("BENCH_history.jsonl")


def git_sha() -> str | None:
    """Current commit SHA, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True,
            text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() if out.returncode == 0 else None
    except Exception:  # noqa: BLE001 — no git binary
        return None


def backend_fingerprint() -> dict[str, Any]:
    """What hardware/software produced these numbers.

    ``interpret_mode`` is the load-bearing flag: Pallas kernels run
    interpreted on CPU (kernels/backend.py), so wall-clock numbers from
    different fingerprints are not comparable and regress.py refuses to
    hard-gate across them."""
    import jax
    from repro.kernels.backend import interpret_default
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "jax": jax.__version__,
        "python": "%d.%d" % sys.version_info[:2],
        "interpret_mode": bool(interpret_default()),
    }


def record(bench: str, metrics: dict[str, Any], *,
           path: Path | str | None = None,
           extra: dict[str, Any] | None = None) -> dict[str, Any]:
    """Append one history record; returns the record written."""
    rec = {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": git_sha(),
        "fingerprint": backend_fingerprint(),
        "metrics": metrics,
    }
    if extra:
        rec.update(extra)
    p = Path(path) if path is not None else HISTORY_PATH
    with open(p, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec


def record_rows(bench: str, rows, *,
                path: Path | str | None = None) -> dict[str, Any]:
    """Record a bench module's ``(name, us_per_call, derived)`` rows.

    The runner's CSV rows become ``{name: {"us_per_call": float,
    "derived": str}}`` — coarse but uniform, so EVERY module gets a
    history line without bespoke extraction; regress.py gates on the
    richer BENCH_*.json metrics instead."""
    metrics = {name: {"us_per_call": float(us), "derived": str(derived)}
               for name, us, derived in rows}
    return record(bench, metrics, path=path)


def load(path: Path | str | None = None) -> list[dict[str, Any]]:
    """All history records, oldest first (empty list if no file)."""
    p = Path(path) if path is not None else HISTORY_PATH
    if not p.exists():
        return []
    out = []
    for line in p.read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


def latest(bench: str,
           path: Path | str | None = None) -> dict[str, Any] | None:
    """Most recent record for one bench module, or None."""
    for rec in reversed(load(path)):
        if rec.get("bench") == bench:
            return rec
    return None
