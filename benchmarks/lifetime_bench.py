"""Die-lifetime benchmark: aging, drift advisories, self-healing heal.

The paper characterizes a die at birth; a deployed FeFET die drifts —
retention loss walks the programmed currents and an accumulating
per-device Vth imprint decorrelates the cell offsets the §III-B1
calibration measured (hw/aging.py).  This benchmark pins the PR 2
characterization die (chip seed 11, severity 2.5) and measures the
whole lifetime story on it:

  * static arms: the die aged LIFETIME_BENCH_AGE_DAYS in the field,
    served three ways — ``stale`` (birth calibration on aged physics:
    what an unmonitored fleet degrades to), ``healed`` (hw/redeploy
    recalibration against the aged die), and the birth-time ``cal0``
    reference.  Deviations are |accuracy − golden| on the clean and
    fog SARD eval batches through the die's nonideal CIM trunk.
  * closed-loop serve arms: launch/serve.serve_sar_lifetime compresses
    the same field time into one request stream cut into segments; the
    drift monitor watches the live telemetry and — in the ``healed``
    arm — recalibrate-and-redeploy hot-swaps the head mid-stream.  A
    ``fresh`` arm runs the identical segmented loop at negligible age
    as the false-positive control.

Structural gates (enforced at the pinned default scale; env-overridden
smoke scales record, not enforce):

  * healed serve arm raised ≥ 1 advisory and healed ≥ 1 time,
  * stale serve arm raised advisories but healed 0 times,
  * fresh arm raised 0 advisories (no false positives),
  * static healed clean acc-dev ≤ 0.014 (2× the PR 2 calibrated
    bound) while the stale arm sits above it.

Env knobs (CI smoke): LIFETIME_BENCH_AGE_DAYS (default 30),
LIFETIME_BENCH_REQUESTS (default 96), LIFETIME_BENCH_EPOCHS (4).

Run: PYTHONPATH=src python -m benchmarks.run --only lifetime_bench
Writes repo-root BENCH_lifetime.json + artifacts/lifetime/report.json
(uploaded as CI artifacts; benchmarks/regress.py gates on the former).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

BENCH_JSON = Path("BENCH_lifetime.json")
ART = Path("artifacts/lifetime")

CHIP_SEED = 11          # the PR 2 characterization die
SEVERITY = 2.5
HEALED_BOUND = 0.014    # 2x the PR 2 calibrated acc-dev bound (0.007)
UNCAL_BOUND = 0.183     # PR 2 uncalibrated acc-dev at severity 2.5
DEFAULTS = {"AGE_DAYS": 30.0, "REQUESTS": 96, "EPOCHS": 4}


def _knobs() -> tuple[dict, bool]:
    knobs, overridden = {}, False
    for name, default in DEFAULTS.items():
        raw = os.environ.get(f"LIFETIME_BENCH_{name}")
        if raw is None:
            knobs[name] = default
        else:
            overridden = True
            knobs[name] = type(default)(raw)
    return knobs, overridden


def _static_arms(chip, params, cfg, age_s: float) -> dict:
    """Stale vs healed vs birth-cal acc-dev on the aged die."""
    import jax.numpy as jnp

    from benchmarks.hw_variation import (R_SAMPLES, _chip_features,
                                         _eval_head, _eval_images)
    from repro.core.bayes_layer import sigma_of
    from repro.core.sampling import BayesHeadConfig, prepare_serving_head
    from repro.hw import golden_instance, prepare_instance_head
    from repro.hw.redeploy import aged_belief_view, recalibrate

    base_hcfg = BayesHeadConfig(num_samples=R_SAMPLES, mode="rank16",
                                grng=cfg.grng, compute_dtype=jnp.float32)
    mu, sg = params["head"]["mu"], sigma_of(params["head"])
    images = _eval_images(cfg)
    eval_sets = _chip_features(params, cfg, images, chip)
    gold_sets = _chip_features(params, cfg, images,
                               golden_instance(cfg.grng))
    gold = prepare_serving_head(mu, sg, base_hcfg)
    golden = {n: _eval_head(gold, base_hcfg, f, l) for n, f, l in gold_sets}

    cal_head, cal_cfg = prepare_instance_head(mu, sg, base_hcfg, chip,
                                              calibrated=True)
    aged = chip.at_age(age_s)
    arms = {
        "cal0": (cal_head, cal_cfg),
        "stale": aged_belief_view(cal_head, cal_cfg, aged, cfg.grng),
        "healed": recalibrate(mu, sg, base_hcfg, aged, epoch=1),
    }
    out = {"age_s": age_s, "imprint": float(aged.imprint), "arms": {}}
    for arm, (head, scfg) in arms.items():
        m = {}
        for name, feats, labels in eval_sets:
            e = _eval_head(head, scfg, feats, labels)
            m[name] = dict(e, acc_dev=abs(e["accuracy"]
                                          - golden[name]["accuracy"]))
        out["arms"][arm] = m
    return out


def _serve_arms(chip, params, cfg, age_s: float, n_requests: int,
                epochs: int) -> dict:
    """Closed-loop lifetime serving: healed / stale / fresh arms."""
    from repro.hw.redeploy import LifetimeConfig
    from repro.launch.serve import serve_sar_lifetime

    rate = age_s / max(n_requests, 1)
    arms = {
        "healed": LifetimeConfig(age_rate=rate, epochs=epochs,
                                 auto_recalibrate=True),
        "stale": LifetimeConfig(age_rate=rate, epochs=epochs,
                                auto_recalibrate=False),
        # false-positive control: the same segmented loop at a
        # negligible 1 s of field time per request
        "fresh": LifetimeConfig(age_rate=1.0, epochs=epochs,
                                auto_recalibrate=True),
    }
    out = {}
    for arm, lt in arms.items():
        t0 = time.time()
        res = serve_sar_lifetime(lifetime=lt, chip_instance=chip,
                                 n_requests=n_requests, n_slots=16,
                                 params=params, cfg=cfg, seed=0)
        out[arm] = {
            "wall_s": time.time() - t0,
            "host_syncs": res["host_syncs"],
            "flagged_fraction": res["flagged_fraction"],
            "lifetime": res["lifetime"],
        }
    return out


def run(knobs: dict | None = None) -> dict:
    from benchmarks.serving_bench import trained_params
    from repro.hw import VariationSpec, sample_instances
    from repro.models.sar_cnn import SarCnnConfig

    if knobs is None:
        knobs, overridden = _knobs()
    else:
        overridden = True
    cfg = SarCnnConfig()
    params = trained_params(cfg)
    chip = sample_instances(CHIP_SEED, 1,
                            VariationSpec().scaled(SEVERITY))[0]
    age_s = knobs["AGE_DAYS"] * 86400.0

    static = _static_arms(chip, params, cfg, age_s)
    serve = _serve_arms(chip, params, cfg, age_s,
                        int(knobs["REQUESTS"]), int(knobs["EPOCHS"]))

    healed_lt = serve["healed"]["lifetime"]
    stale_lt = serve["stale"]["lifetime"]
    fresh_lt = serve["fresh"]["lifetime"]
    gates = {
        "healed_loop_closed": (healed_lt["advisories"] >= 1
                               and healed_lt["heals"] >= 1
                               and healed_lt["calib_epoch"] >= 1),
        "stale_never_heals": (stale_lt["advisories"] >= 1
                              and stale_lt["heals"] == 0),
        "fresh_no_false_positives": (fresh_lt["advisories"] == 0
                                     and fresh_lt["heals"] == 0),
        "healed_within_band": (static["arms"]["healed"]["clean"]
                               ["acc_dev"] <= HEALED_BOUND),
        "stale_degraded": (static["arms"]["stale"]["clean"]["acc_dev"]
                           > HEALED_BOUND),
    }
    report = {
        "chip_seed": CHIP_SEED, "severity": SEVERITY,
        "knobs": knobs, "scale_overridden": overridden,
        "bounds": {"healed_acc_dev": HEALED_BOUND,
                   "uncal_acc_dev": UNCAL_BOUND},
        "static": static, "serve": serve, "gates": gates,
    }
    ART.mkdir(parents=True, exist_ok=True)
    text = json.dumps(report, indent=2, sort_keys=True, default=float)
    BENCH_JSON.write_text(text)
    (ART / "report.json").write_text(text)

    if not overridden and not all(gates.values()):
        raise RuntimeError(f"lifetime acceptance regressed: {gates}")
    return report


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    report = run()
    wall = time.time() - t0
    a = report["static"]["arms"]
    out = [(
        "lifetime_static", wall * 1e6,
        f"age_days={report['knobs']['AGE_DAYS']};"
        f"imprint={report['static']['imprint']:.3f};"
        f"acc_dev_clean={a['stale']['clean']['acc_dev']:.4f}->"
        f"{a['healed']['clean']['acc_dev']:.4f};"
        f"acc_dev_fog={a['stale']['fog']['acc_dev']:.4f}->"
        f"{a['healed']['fog']['acc_dev']:.4f};"
        f"cal0_clean={a['cal0']['clean']['acc_dev']:.4f}")]
    for arm in ("healed", "stale", "fresh"):
        s = report["serve"][arm]
        lt = s["lifetime"]
        out.append((
            f"lifetime_serve_{arm}", s["wall_s"] * 1e6,
            f"advisories={lt['advisories']};heals={lt['heals']};"
            f"epoch={lt['calib_epoch']};age_s={lt['age_s']:.0f};"
            f"host_syncs={s['host_syncs']};"
            f"flagged={s['flagged_fraction']:.3f}"))
    gates = report["gates"]
    out.append(("lifetime_gates", 0.0,
                ";".join(f"{k}={v}" for k, v in sorted(gates.items()))
                + f";json={BENCH_JSON}"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
