"""Paper Fig. 9 + Fig. 10: CLT-GRNG output distribution quality and
selection-network analysis.

Paper reports: QQ correlation r = 0.9980 vs ideal Gaussian; fails
D'Agostino K² and Anderson–Darling (statistically imperfect but
BNN-tolerable); sum mean 10.1 µA, SD 0.993 µA.  We reproduce all four
statistics from the virtual-device model, time the Pallas ε kernel
(interpret mode), and add a reachability analysis of the swapper
network the paper does not report (distinct patterns out of C(16,8)).
"""

from __future__ import annotations

import time

import numpy as np
from scipy import stats

from repro.core import clt_grng as g
from repro.core.lfsr import enumerate_reachable
from repro.kernels import ops


def bench() -> list[tuple[str, float, str]]:
    cfg = g.GRNGConfig()
    out = []

    # raw-sum calibration vs paper Fig. 9 statistics
    t0 = time.time()
    mean, std = g.calibrate(cfg, 4096, 64)
    dt = (time.time() - t0) * 1e6
    out.append(("fig9_sum_mean_uA", dt,
                f"ours={float(mean):.3f};paper=10.1"))
    out.append(("fig9_sum_std_uA", dt,
                f"ours={float(std):.4f};paper=0.993"))

    # distribution-quality statistics
    eps = g.distribution_sample(cfg, 8192, 32)
    (osm, osr), _ = stats.probplot(eps[:50000], dist="norm")
    qq_r = float(np.corrcoef(osm, osr)[0, 1])
    k2, k2_p = stats.normaltest(eps[:50000])
    ad = stats.anderson(eps[:50000], dist="norm")
    out.append(("fig9_qq_r", dt, f"ours={qq_r:.4f};paper=0.9980"))
    out.append(("fig9_k2_rejected", dt,
                f"p={float(k2_p):.2e};paper=fails_K2"))
    out.append(("fig9_anderson_rejected", dt,
                f"stat={float(ad.statistic):.2f};crit5%={ad.critical_values[2]:.2f}"))

    # per-cell offset magnitude (drives §III-B1 compensation)
    d_eps = np.asarray(g.cell_mean_offset(cfg, 256, 256))
    out.append(("fig9_cell_offset_std", dt, f"{d_eps.std():.4f}sigma"))

    # Pallas kernel throughput (interpret mode — correctness platform)
    t0 = time.time()
    e = ops.grng_eps(cfg, 256, 256, 8, interpret=True)
    e.block_until_ready()
    dt_k = (time.time() - t0) * 1e6
    out.append(("fig9_grng_kernel_256x256x8", dt_k,
                f"{e.size} samples"))

    # Fig. 10 selection-network reachability (novel analysis)
    t0 = time.time()
    count, freq = enumerate_reachable()
    dt = (time.time() - t0) * 1e6
    out.append(("fig10_reachable_patterns", dt,
                f"{count}_of_12870;pos_freq={float(freq.mean()):.3f}"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
