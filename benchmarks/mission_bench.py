"""Closed-loop mission benchmark: the abstract's comparison, end to end.

Flies the SAME seeded worlds, fleet, and energy budget under three
decision systems —

  * deterministic   µ-only detector, EVERY detection triggers the
                    costly verification descent (the overconfident
                    baseline the paper opens with),
  * bayes_fixed     Fig. 1 triage at fixed R = 20 per decision,
                    flag-and-orbit before any descent,
  * bayes_adaptive  the same triage with sequential-test escalation
                    (r_min = 4 → r_max = 20) — the full system,

on the ideal chip AND a severity-2.5 sampled FeFET die (hw/ digital
twin: nonideal CIM trunk + degraded GRNG head, per-die recalibration +
mission operating-point transfer).  Reported per configuration:
time-to-first-detection, rescue delay (horizon-penalized), coverage,
false-verification rate, missed-victim rate, the battery ledger split,
and samples/decision.

The acceptance gate (enforced at the default scale, recorded under env
overrides): on both dies, Bayesian adaptive triage achieves STRICTLY
lower false-verification rate and no worse rescue delay than the
deterministic baseline, while every rollout runs device-resident (one
host sync per die group).

Lifetime arms (hw/aging + hw/redeploy): each non-ideal die is also
flown AGED — the FeFET physics drifts mid-mission at
MISSION_BENCH_AGE_DAYS of simulated field time spread over the steps —
once with the stale birth calibration (``aged_stale``) and once with
the self-healing loop recalibrating on drift advisories
(``aged_healed``).  Aged rollouts dispatch in ``epochs`` segments, so
their device-residency contract is host_syncs == epochs; the un-aged
arms keep the strict one-sync gate.

Env knobs (CI smoke): MISSION_BENCH_GRID, _VICTIMS, _DRONES, _STEPS,
_EPISODES, _BATTERY_UJ, _CHIPS ("ideal,2.5"), _TRAIN_STEPS,
_AGE_DAYS (0 skips the aged arms).

Run: PYTHONPATH=src python -m benchmarks.run --only mission_bench
Writes repo-root BENCH_mission.json (uploaded as a CI artifact).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

BENCH_JSON = Path("BENCH_mission.json")
ART = Path("artifacts/mission")

DEFAULTS = {
    "GRID": 14, "VICTIMS": 10, "DRONES": 4, "STEPS": 70, "EPISODES": 2,
    "BATTERY_UJ": 320.0, "CHIPS": "ideal,2.5", "TRAIN_STEPS": 1600,
    "AGE_DAYS": 90.0,
}
CHIP_SEED = 11
WORLD_SEED = 0
MODES = ("deterministic", "bayes_fixed", "bayes_adaptive")


def _knobs() -> tuple[dict, bool]:
    """(knobs, overridden): env-tunable scale for CI smoke runs."""
    knobs, overridden = {}, False
    for name, default in DEFAULTS.items():
        raw = os.environ.get(f"MISSION_BENCH_{name}")
        if raw is None:
            knobs[name] = default
        else:
            overridden = True
            knobs[name] = type(default)(raw)
    return knobs, overridden


def bench() -> list[tuple[str, float, str]]:
    from repro.hw import VariationSpec, sample_instances
    from repro.mission import (MissionPolicy, UavConfig, WorldConfig,
                               fly_mission, trained_detector)

    knobs, overridden = _knobs()
    params, cfg = trained_detector(steps=knobs["TRAIN_STEPS"])
    wcfg = WorldConfig(grid=knobs["GRID"], n_victims=knobs["VICTIMS"],
                       seed=WORLD_SEED)
    ucfg = UavConfig(n_drones=knobs["DRONES"],
                     battery_J=knobs["BATTERY_UJ"] * 1e-6)

    chips = {}
    for tag in knobs["CHIPS"].split(","):
        tag = tag.strip()
        if tag == "ideal":
            chips["ideal"] = None
        else:
            chips[f"sev{tag}"] = sample_instances(
                CHIP_SEED, 1, VariationSpec().scaled(float(tag)))[0]

    out, report = [], {"knobs": knobs, "chip_seed": CHIP_SEED,
                       "world_seed": WORLD_SEED, "configs": {}}
    results: dict[str, dict] = {}
    full_system = None          # ideal/bayes_adaptive, for metrics/trace
    for chip_tag, chip in chips.items():
        for mode in MODES:
            pol = MissionPolicy(mode=mode)
            t0 = time.time()
            res = fly_mission(wcfg, ucfg, pol, params=params, cfg=cfg,
                              chips=chip, n_steps=knobs["STEPS"],
                              n_episodes=knobs["EPISODES"])
            wall = time.time() - t0
            if res.host_syncs != 1:
                raise RuntimeError(
                    f"mission rollout not device-resident: "
                    f"{res.host_syncs} host syncs for one die group")
            s = dict(res.summary)
            s["wall_s"] = wall
            s["host_syncs"] = res.host_syncs
            # observability rider: per-die-group telemetry + online
            # GRNG drift verdict, pulled at the existing die-group sync
            if res.telemetry:
                s["obs"] = {
                    group: {"telemetry": t["telemetry"],
                            "drift": t["drift"]}
                    for group, t in res.telemetry.items()}
            name = f"{chip_tag}/{mode}"
            results[name] = s
            report["configs"][name] = s
            if chip_tag == "ideal" and mode == "bayes_adaptive":
                full_system = res
            out.append((
                f"mission_{chip_tag}_{mode}",
                wall * 1e6 / max(s["decisions"], 1),
                f"rescued={s['rescued']}/{s['victims']};"
                f"delay_s={s['rescue_delay_s']:.0f};"
                f"ttfd_s={s['time_to_first_detection_s']:.0f};"
                f"cov={s['coverage']:.2f};"
                f"fvr={s['false_verification_rate']:.3f};"
                f"samples={s['mean_samples_per_decision']:.1f};"
                f"e_uJ={1e6 * s['energy_total_J']:.0f}"))

    # the abstract's comparison, per die
    claims = {}
    for chip_tag in chips:
        det = results[f"{chip_tag}/deterministic"]
        ada = results[f"{chip_tag}/bayes_adaptive"]
        fix = results[f"{chip_tag}/bayes_fixed"]
        claims[chip_tag] = {
            "fvr_deterministic": det["false_verification_rate"],
            "fvr_adaptive": ada["false_verification_rate"],
            "fvr_strictly_lower": (ada["false_verification_rate"]
                                   < det["false_verification_rate"]),
            "rescue_delay_deterministic_s": det["rescue_delay_s"],
            "rescue_delay_adaptive_s": ada["rescue_delay_s"],
            "rescue_delay_no_worse": (ada["rescue_delay_s"]
                                      <= det["rescue_delay_s"]),
            "samples_saving_vs_fixed": (
                fix["mean_samples_per_decision"]
                / max(ada["mean_samples_per_decision"], 1e-9)),
        }
        out.append((f"mission_{chip_tag}_claims", 0.0,
                    f"fvr={claims[chip_tag]['fvr_adaptive']:.3f}"
                    f"_vs_det={claims[chip_tag]['fvr_deterministic']:.3f};"
                    f"delay_ok={claims[chip_tag]['rescue_delay_no_worse']};"
                    f"sample_saving="
                    f"{claims[chip_tag]['samples_saving_vs_fixed']:.2f}x"))
    report["claims"] = claims

    # Lifetime arms (hw/aging + hw/redeploy): each non-ideal die flies
    # the full system AGED — AGE_DAYS of simulated field time spread
    # over the mission — once serving the stale birth calibration and
    # once with the self-healing loop recalibrating on advisories.
    # Aged rollouts dispatch in lifetime.epochs segments, so the
    # device-residency contract there is host_syncs == epochs.
    lifetime_arms = {}
    if knobs["AGE_DAYS"] > 0:
        from repro.hw.redeploy import LifetimeConfig
        age_rate = knobs["AGE_DAYS"] * 86400.0 / max(knobs["STEPS"], 1)
        pol = MissionPolicy(mode="bayes_adaptive")
        for chip_tag, chip in chips.items():
            if chip is None:
                continue
            for arm, heal in (("aged_stale", False), ("aged_healed", True)):
                lt = LifetimeConfig(age_rate=age_rate,
                                    auto_recalibrate=heal)
                t0 = time.time()
                res = fly_mission(wcfg, ucfg, pol, params=params, cfg=cfg,
                                  chips=chip, n_steps=knobs["STEPS"],
                                  n_episodes=knobs["EPISODES"],
                                  lifetime=lt)
                wall = time.time() - t0
                if res.host_syncs != lt.epochs:
                    raise RuntimeError(
                        f"aged mission not segment-resident: "
                        f"{res.host_syncs} host syncs for "
                        f"{lt.epochs} lifetime epochs")
                s = dict(res.summary)
                s["wall_s"] = wall
                s["host_syncs"] = res.host_syncs
                ltd = next(iter((res.lifetime or {}).values()), {})
                s["lifetime"] = ltd
                name = f"{chip_tag}/{arm}"
                results[name] = s
                report["configs"][name] = s
                lifetime_arms[name] = ltd
                out.append((
                    f"mission_{chip_tag}_{arm}",
                    wall * 1e6 / max(s["decisions"], 1),
                    f"rescued={s['rescued']}/{s['victims']};"
                    f"fvr={s['false_verification_rate']:.3f};"
                    f"samples={s['mean_samples_per_decision']:.1f};"
                    f"advisories={ltd.get('advisories', 0)};"
                    f"heals={ltd.get('heals', 0)};"
                    f"epoch={ltd.get('calib_epoch', 0)}"))
        report["lifetime"] = {"age_days": knobs["AGE_DAYS"],
                              "age_rate_s_per_step": age_rate,
                              "arms": lifetime_arms}

    report["scale_overridden"] = overridden

    ART.mkdir(parents=True, exist_ok=True)
    text = json.dumps(report, indent=2, sort_keys=True, default=float)
    BENCH_JSON.write_text(text)
    (ART / "report.json").write_text(text)

    if full_system is not None:
        # metrics snapshot + per-drone Perfetto trace for the full
        # system on the ideal die (CI artifacts)
        from repro.obs.registry import mission_registry
        from repro.obs.trace import mission_trace
        reg = mission_registry(results["ideal/bayes_adaptive"],
                               telemetry=full_system.telemetry,
                               policy="bayes_adaptive", chip="ideal")
        reg.write(str(ART / "metrics"))
        (ART / "trace.json").write_text(
            json.dumps(mission_trace(full_system.logs)))

    if not overridden:
        # regression gate — only at the pinned default scale, where the
        # comparison was validated; smoke scales record, not enforce.
        for chip_tag, c in claims.items():
            if not (c["fvr_strictly_lower"] and c["rescue_delay_no_worse"]):
                raise RuntimeError(
                    f"mission acceptance regressed on {chip_tag}: {c}")
        for name, ltd in lifetime_arms.items():
            heals = ltd.get("heals", 0)
            advisories = ltd.get("advisories", 0)
            if name.endswith("aged_healed"):
                if advisories < 1 or heals < 1:
                    raise RuntimeError(
                        f"self-healing loop never closed on {name}: "
                        f"advisories={advisories} heals={heals}")
            elif heals != 0:
                raise RuntimeError(
                    f"no-heal arm healed on {name}: heals={heals}")
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
