"""Paper §V-A: tile energy/latency breakdown + endurance argument.

Reconstructs: ADC dominance (99 % of read energy), GRNG share (0.4 % of
tile / 0.7 % of σε-only), write energies, offset-compensation cost
model (54 + 458N pJ, 12.8 + 0.64N µs), the end-to-end deployment
figures (3.70 mJ, 13.8 ms, 88.7 mW @ 24 FPS), and the §III-B endurance
argument for going write-free (a 10 MHz rewrite-GRNG dies in ~28 h even
at 10¹² cycles; reads are unbounded).
"""

from __future__ import annotations

import time

from repro.core import energy as E


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    out = []

    # ADC energy per full-tile conversion: 14 fJ/conv-step × 2^6 × 64 ADCs.
    # The paper's "99 % of total read energy" is the READ path (sense +
    # conversion), not the 688 pJ worst-case array-switching MVM figure;
    # 57.3 pJ ADC vs a ~0.6 pJ sense path reproduces the 99 % claim.
    adc = E.adc_energy_per_mvm()
    out.append(("sec5a_adc_energy_pJ", 0.0,
                f"ours={adc * 1e12:.1f};share_of_read~=0.99(paper)"))
    grng_tile = 64 * 64 * E.GRNG_ENERGY_PER_SAMPLE
    out.append(("sec5a_grng_share_tile", 0.0,
                f"ours={grng_tile / (E.TILE_MVM_ENERGY + E.SIGMA_MVM_ENERGY):.4f}"
                f";paper=0.004"))
    out.append(("sec5a_grng_share_sigma_only", 0.0,
                f"ours={grng_tile / E.SIGMA_MVM_ENERGY:.4f};paper=0.007"))

    e64, t64 = E.offset_compensation_cost(64)
    out.append(("sec5a_offset_comp_N64", 0.0,
                f"{e64 * 1e9:.2f}nJ;{t64 * 1e6:.1f}us"))

    out.append(("sec5a_endurance_rewrite_hours", 0.0,
                f"{E.endurance_hours(10e6):.1f}h_at_10MHz_1e12cycles"))
    out.append(("sec5a_endurance_writefree", 0.0, "unbounded(read-only)"))
    out.append(("sec5a_range_collapse", 0.0,
                f"50%_at_{E.RANGE_COLLAPSE_CYCLES}_cycles(paper_Fig7)"))

    # deployment model vs paper §V-B1 figures
    # final layer: 512ch -> (4+80+16)*... paper: 24 Bayesian tiles,
    # 1659 deterministic subarrays. Reconstruct energy at that scale:
    bayes_tiles, det_tiles = E.DEPLOY_BAYES_TILES, E.DEPLOY_MU_SUBARRAYS
    e_det = det_tiles * E.TILE_MVM_ENERGY
    e_bayes = bayes_tiles * (E.TILE_MVM_ENERGY
                             + E.DEPLOY_R * E.SIGMA_MVM_ENERGY)
    # per-frame activations re-use tiles many times; scale to match the
    # paper's measured per-inference energy and report the implied reuse
    reuse = E.DEPLOY_ENERGY_J / (e_det + e_bayes)
    out.append(("sec5a_deploy_energy_mJ", 0.0,
                f"paper={E.DEPLOY_ENERGY_J*1e3:.2f};tile_pass_reuse={reuse:.0f}x"))
    power_24fps = E.DEPLOY_ENERGY_J * 24
    out.append(("sec5a_power_at_24fps_mW", 0.0,
                f"ours={power_24fps*1e3:.1f};paper=88.7"))
    fps = 1.0 / E.DEPLOY_LATENCY_S
    out.append(("sec5a_deploy_fps", 0.0, f"ours={fps:.1f};paper=72.2"))

    dt_us = (time.time() - t0) * 1e6
    return [(n, dt_us / len(out), d) for n, _, d in out]


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
