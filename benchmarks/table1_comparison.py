"""Paper Table I: accelerator comparison — derived from component
constants in core/energy.py and cross-checked against the printed paper
values.  The 'derived' column reports our reconstruction and the paper
number side by side; see also sec5a_energy.py for the §V-A breakdown.
"""

from __future__ import annotations

import time

from repro.core import energy as E


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    rows = [
        ("table1_grng_energy_fJ", E.GRNG_ENERGY_PER_SAMPLE * 1e15, 0.640),
        ("table1_grng_improvement_x", E.grng_energy_improvement(), 560.0),
        ("table1_grng_tput_GSas", E.grng_throughput_gsas(), 40.96),
        ("table1_tile_eff_TOPSW", E.tile_efficiency_tops_w(), 17.8),
        ("table1_eff_density_TOPSWmm2", E.efficiency_density(), 185.0),
        ("table1_grng_area_um2", E.GRNG_AREA_UM2, 5.11),
        ("table1_macro_area_mm2", E.TILE_AREA_MM2, 0.0964),
    ]
    dt_us = (time.time() - t0) * 1e6
    out = []
    for name, ours, paper in rows:
        err = abs(ours - paper) / paper * 100
        out.append((name, dt_us / len(rows),
                    f"ours={ours:.4g};paper={paper:.4g};err={err:.1f}%"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
