"""Roofline analysis: train path (dry-run artifacts) + DECISION path
(the serving hot kernels, compiled fresh — runs everywhere).

Train side — for every compiled (arch × shape × mesh) cell in
artifacts/dryrun/:

    compute term    = loop-aware HLO FLOPs / (197 TFLOP/s bf16)
    memory term     = loop-aware HBM bytes / (819 GB/s)
    collective term = ring-model wire bytes / (50 GB/s per ICI link)

(All three are per-chip; FLOPs/bytes come from launch/hlo_analysis.py —
XLA's own cost_analysis does not multiply loop bodies by trip count.)

Also reported per cell:
  * dominant term (the bottleneck),
  * MODEL_FLOPS (6·N·D train / 2·N_active serve) and the useful-compute
    ratio MODEL_FLOPS / HLO_FLOPS (remat/redundancy waste),
  * roofline fraction = useful-FLOP time ÷ bottleneck time (the score).

Serving side (ROADMAP item 5's closure) — the fused decision kernel
(kernels.ops.decision_update) across (B, N, R) points and the engine's
cached SAR round fn (serving/engine._sar_round_fn) at its deployed
shape, each charted as

    bound_us    = max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW) · 1e6
    measured_us = warm wall time per call
    fraction    = bound_us / measured_us

with ``interpret_mode`` flagged honestly: on the CPU backend Pallas
runs interpreted, so measured/fraction quantify the gap that the
compiled-backend lane must close, not a hardware claim.

Outputs artifacts/roofline.csv + .md (train, needs dryrun artifacts)
and artifacts/roofline_serving.csv + .md (always).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

PEAK_FLOPS = 197e12      # TPU v5e bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (conservative single-link)

DRYRUN_DIR = Path("artifacts/dryrun")
SERVING_CSV = Path("artifacts/roofline_serving.csv")
SERVING_MD = Path("artifacts/roofline_serving.md")


def cell_terms(rec: dict) -> dict:
    devices = rec["devices"]
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["hbm_bytes_per_device"] / HBM_BW
    # bf16 adjustment: the CPU-backend XLA promotes bf16 dots AND bf16
    # all-reduces to f32 (verified by probing an explicit bf16 psum),
    # so every f32 collective payload in these artifacts is semantically
    # bf16 on the TPU target.  We report the raw term too (roofline.csv)
    # but score against the target hardware's wire bytes.
    raw = rec["wire_bytes_per_device"]
    f32 = rec.get("wire_bytes_f32_per_device", 0.0)
    t_x_raw = raw / ICI_BW
    t_x = (raw - 0.5 * f32) / ICI_BW
    bottleneck = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    useful_t = rec["model_flops_global"] / devices / PEAK_FLOPS
    hlo_flops_global = rec["flops_per_device"] * devices
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "t_collective_raw_s": t_x_raw,
        "bottleneck": bottleneck[1],
        "model_flops": rec["model_flops_global"],
        "useful_ratio": (rec["model_flops_global"] / hlo_flops_global
                         if hlo_flops_global else 0.0),
        "roofline_fraction": useful_t / bottleneck[0] if bottleneck[0] else 0.0,
        "peak_mem_gb": rec["memory"]["peak_estimate_bytes"] / 1e9,
        "fits_16g": rec["memory"]["peak_estimate_bytes"] < 16e9,
    }


def load_cells(mesh: str | None = None, tag_filter: str = "") -> list[dict]:
    cells = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) == 4 and not tag_filter:
            continue                      # hillclimb variants excluded
        if tag_filter and (len(parts) != 4 or parts[3] != tag_filter):
            continue
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        cells.append(cell_terms(rec))
    return cells


def _table(cells, md_path, csv_path):
    cells.sort(key=lambda c: (c["arch"], c["shape"]))
    md = ["| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | "
          "bottleneck | useful | fraction | peak GB |",
          "|---|---|---|---|---|---|---|---|---|"]
    csv = ["arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
           "t_collective_raw_s,bottleneck,useful_ratio,roofline_fraction,"
           "peak_mem_gb,fits_16g"]
    for c in cells:
        md.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']:.4g} | "
            f"{c['t_memory_s']:.4g} | {c['t_collective_s']:.4g} | "
            f"{c['bottleneck']} | {c['useful_ratio']:.3f} | "
            f"{c['roofline_fraction']:.3f} | {c['peak_mem_gb']:.1f} |")
        csv.append(",".join(str(c[k]) for k in (
            "arch", "shape", "mesh", "t_compute_s", "t_memory_s",
            "t_collective_s", "t_collective_raw_s", "bottleneck",
            "useful_ratio", "roofline_fraction", "peak_mem_gb", "fits_16g")))
    Path(md_path).write_text("\n".join(md) + "\n")
    Path(csv_path).write_text("\n".join(csv) + "\n")


def write_tables() -> Path:
    _table(load_cells(mesh="pod16x16"),
           "artifacts/roofline.md", "artifacts/roofline.csv")
    opt = load_cells(mesh="pod16x16", tag_filter="opt")
    if opt:
        _table(opt, "artifacts/roofline_opt.md", "artifacts/roofline_opt.csv")
    return Path("artifacts/roofline.md")


# ----------------------------------------------------------------------
# serving-side roofline: the decision path
# ----------------------------------------------------------------------
# (B, N, R) points for the fused decision kernel: the kernel-bench
# shape at both R extremes, a wider batch, and the serving engine's
# deployed SAR shape (32 slots × 2 classes).
DECISION_POINTS = ((8, 512, 4), (8, 512, 20), (32, 512, 20), (32, 2, 4))


def _measured_us(jitted, make_args, reps: int = 10) -> float:
    """Warm wall time per call; ``make_args`` returns fresh positional
    args each call (donation-safe — donated buffers are single-use)."""
    import jax
    arg_sets = [make_args() for _ in range(reps + 1)]
    jax.block_until_ready(arg_sets)
    jax.block_until_ready(jitted(*arg_sets[0]))            # warm
    t0 = time.time()
    r = None
    for args in arg_sets[1:]:
        r = jitted(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) * 1e6 / reps


def _cell_from_compiled(name: str, txt: str, measured_us: float,
                        interpret: bool) -> dict:
    from repro.launch.hlo_analysis import analyze, \
        largest_intermediate_bytes
    walk = analyze(txt, 1)
    flops = walk["flops_per_device"]
    hbm = walk["hbm_bytes_per_device"]
    bound_us = max(flops / PEAK_FLOPS, hbm / HBM_BW) * 1e6
    return {
        "name": name, "flops": flops, "hbm_bytes": hbm,
        "peak_live_bytes": largest_intermediate_bytes(txt),
        "bound": "compute" if flops / PEAK_FLOPS >= hbm / HBM_BW
        else "memory",
        "bound_us": bound_us, "measured_us": measured_us,
        "fraction": bound_us / measured_us if measured_us else 0.0,
        "interpret_mode": bool(interpret),
    }


def serving_cells(points=DECISION_POINTS,
                  measure_reps: int = 10) -> list[dict]:
    """Roofline cells for the decision path; compiles fresh, no
    artifacts needed."""
    import jax
    import jax.numpy as jnp
    from repro.core.clt_grng import GRNGConfig
    from repro.core.sampling import BayesHeadConfig
    from repro.kernels.backend import interpret_default
    from repro.kernels.ops import decision_update
    from repro.serving import TriagePolicy, adaptive

    interp = interpret_default()
    cfg0 = GRNGConfig()
    cells = []

    def point_args(b, n, r):
        k1, k2 = jax.random.split(jax.random.PRNGKey(11))
        ab = {"y_mu": jax.random.normal(k1, (b, n)) * 0.05,
              "x_sigma": jnp.abs(jax.random.normal(k2, (b, n))) * 0.1,
              "m": jax.random.normal(k2, (b, n, 16)) * 0.05}
        zeros_u = jnp.zeros((b,), jnp.uint32)
        zeros_i = jnp.zeros((b,), jnp.int32)
        sel = jnp.asarray(adaptive.stream_selections(cfg0, zeros_u,
                                                     zeros_i, r))
        idx = adaptive.stream_indices(zeros_u, zeros_i, r)
        return ab, sel, idx

    for b, n, r in points:
        ab, sel, idx = point_args(b, n, r)
        stats0 = adaptive.init_stats(b, n)

        def fn(stats, ab, sel, idx):
            return decision_update(stats, ab, sel, cfg0,
                                   sample_idx=idx)

        jitted = jax.jit(fn)
        txt = jitted.lower(stats0, ab, sel, idx).compile().as_text()
        us = _measured_us(jitted, lambda: (stats0, ab, sel, idx),
                          reps=measure_reps)
        cells.append(_cell_from_compiled(
            f"decision_update_B{b}_N{n}_R{r}_f32", txt, us, interp))

    # the engine's cached SAR round fn at its deployed shape.  Stats
    # start at n = r_max - r_step so the device-resident while_loop
    # force-decides after EXACTLY one round — a deterministic
    # measurement that matches the HLO walk's trip estimate.
    from repro.serving.engine import _sar_round_fn
    policy = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                          r_min=4, r_max=20)
    b, n = 32, 2
    hcfg = BayesHeadConfig(num_samples=policy.r_max, mode="rank16",
                           grng=cfg0, compute_dtype=jnp.float32,
                           hoist_basis=True)
    fn = _sar_round_fn(hcfg, policy, True, policy.r_min, True, None)
    ab, _, _ = point_args(b, n, policy.r_min)

    def make_args():
        stats = adaptive.init_stats(b, n)
        stats["n"] = jnp.full((b,), policy.r_max - policy.r_min,
                              jnp.int32)
        return (ab, stats, jnp.zeros((b,), jnp.uint32),
                jnp.ones((b,), bool))

    txt = fn.lower(*make_args()).compile().as_text()
    us = _measured_us(fn, make_args, reps=measure_reps)
    cells.append(_cell_from_compiled(
        f"sar_round_B{b}_N{n}_R{policy.r_min}_f32", txt, us, interp))
    return cells


def write_serving_tables(cells: list[dict]) -> Path:
    SERVING_CSV.parent.mkdir(parents=True, exist_ok=True)
    keys = ("name", "flops", "hbm_bytes", "peak_live_bytes", "bound",
            "bound_us", "measured_us", "fraction", "interpret_mode")
    csv = [",".join(keys)]
    md = ["| cell | flops | hbm B | peak live B | bound | bound us | "
          "measured us | fraction | interp |",
          "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        csv.append(",".join(str(c[k]) for k in keys))
        md.append(
            f"| {c['name']} | {c['flops']:.3g} | {c['hbm_bytes']:.3g} "
            f"| {c['peak_live_bytes']:.0f} | {c['bound']} | "
            f"{c['bound_us']:.3g} | {c['measured_us']:.1f} | "
            f"{c['fraction']:.2e} | {c['interpret_mode']} |")
    SERVING_CSV.write_text("\n".join(csv) + "\n")
    SERVING_MD.write_text("\n".join(md) + "\n")
    return SERVING_MD


def bench() -> list[tuple[str, float, str]]:
    out = []
    # serving side first: compiles its own cells, runs everywhere
    cells = serving_cells()
    write_serving_tables(cells)
    for c in cells:
        out.append((
            f"roofline_serving_{c['name']}", c["measured_us"],
            f"bound={c['bound']};bound_us={c['bound_us']:.3g};"
            f"fraction={c['fraction']:.2e};"
            f"peak_live_B={c['peak_live_bytes']:.0f};"
            f"interpret_mode={c['interpret_mode']}"))

    t0 = time.time()
    if not DRYRUN_DIR.exists() or not list(DRYRUN_DIR.glob("*.json")):
        out.append(("roofline", 0.0, "no_dryrun_artifacts_yet"))
        return out
    write_tables()
    cells = load_cells(mesh="pod16x16", tag_filter="opt") or load_cells(
        mesh="pod16x16")
    dt_us = (time.time() - t0) * 1e6
    for c in cells:
        out.append((
            f"roofline_{c['arch']}_{c['shape']}", dt_us / max(len(cells), 1),
            f"bottleneck={c['bottleneck']};fraction={c['roofline_fraction']:.3f}"
            f";useful={c['useful_ratio']:.3f}"))
    worst = min(cells, key=lambda c: c["roofline_fraction"])
    collbound = [c for c in cells if c["bottleneck"] == "collective"]
    out.append(("roofline_worst_cell", 0.0,
                f"{worst['arch']}/{worst['shape']}="
                f"{worst['roofline_fraction']:.3f}"))
    out.append(("roofline_collective_bound_cells", 0.0, str(len(collbound))))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
