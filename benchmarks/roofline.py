"""Roofline analysis over the dry-run artifacts (deliverable g).

For every compiled (arch × shape × mesh) cell in artifacts/dryrun/:

    compute term    = loop-aware HLO FLOPs / (197 TFLOP/s bf16)
    memory term     = loop-aware HBM bytes / (819 GB/s)
    collective term = ring-model wire bytes / (50 GB/s per ICI link)

(All three are per-chip; FLOPs/bytes come from launch/hlo_analysis.py —
XLA's own cost_analysis does not multiply loop bodies by trip count.)

Also reported per cell:
  * dominant term (the bottleneck),
  * MODEL_FLOPS (6·N·D train / 2·N_active serve) and the useful-compute
    ratio MODEL_FLOPS / HLO_FLOPS (remat/redundancy waste),
  * roofline fraction = useful-FLOP time ÷ bottleneck time (the score).

Outputs artifacts/roofline.csv + artifacts/roofline.md.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

PEAK_FLOPS = 197e12      # TPU v5e bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (conservative single-link)

DRYRUN_DIR = Path("artifacts/dryrun")


def cell_terms(rec: dict) -> dict:
    devices = rec["devices"]
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["hbm_bytes_per_device"] / HBM_BW
    # bf16 adjustment: the CPU-backend XLA promotes bf16 dots AND bf16
    # all-reduces to f32 (verified by probing an explicit bf16 psum),
    # so every f32 collective payload in these artifacts is semantically
    # bf16 on the TPU target.  We report the raw term too (roofline.csv)
    # but score against the target hardware's wire bytes.
    raw = rec["wire_bytes_per_device"]
    f32 = rec.get("wire_bytes_f32_per_device", 0.0)
    t_x_raw = raw / ICI_BW
    t_x = (raw - 0.5 * f32) / ICI_BW
    bottleneck = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    useful_t = rec["model_flops_global"] / devices / PEAK_FLOPS
    hlo_flops_global = rec["flops_per_device"] * devices
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "t_collective_raw_s": t_x_raw,
        "bottleneck": bottleneck[1],
        "model_flops": rec["model_flops_global"],
        "useful_ratio": (rec["model_flops_global"] / hlo_flops_global
                         if hlo_flops_global else 0.0),
        "roofline_fraction": useful_t / bottleneck[0] if bottleneck[0] else 0.0,
        "peak_mem_gb": rec["memory"]["peak_estimate_bytes"] / 1e9,
        "fits_16g": rec["memory"]["peak_estimate_bytes"] < 16e9,
    }


def load_cells(mesh: str | None = None, tag_filter: str = "") -> list[dict]:
    cells = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) == 4 and not tag_filter:
            continue                      # hillclimb variants excluded
        if tag_filter and (len(parts) != 4 or parts[3] != tag_filter):
            continue
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        cells.append(cell_terms(rec))
    return cells


def _table(cells, md_path, csv_path):
    cells.sort(key=lambda c: (c["arch"], c["shape"]))
    md = ["| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | "
          "bottleneck | useful | fraction | peak GB |",
          "|---|---|---|---|---|---|---|---|---|"]
    csv = ["arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
           "t_collective_raw_s,bottleneck,useful_ratio,roofline_fraction,"
           "peak_mem_gb,fits_16g"]
    for c in cells:
        md.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']:.4g} | "
            f"{c['t_memory_s']:.4g} | {c['t_collective_s']:.4g} | "
            f"{c['bottleneck']} | {c['useful_ratio']:.3f} | "
            f"{c['roofline_fraction']:.3f} | {c['peak_mem_gb']:.1f} |")
        csv.append(",".join(str(c[k]) for k in (
            "arch", "shape", "mesh", "t_compute_s", "t_memory_s",
            "t_collective_s", "t_collective_raw_s", "bottleneck",
            "useful_ratio", "roofline_fraction", "peak_mem_gb", "fits_16g")))
    Path(md_path).write_text("\n".join(md) + "\n")
    Path(csv_path).write_text("\n".join(csv) + "\n")


def write_tables() -> Path:
    _table(load_cells(mesh="pod16x16"),
           "artifacts/roofline.md", "artifacts/roofline.csv")
    opt = load_cells(mesh="pod16x16", tag_filter="opt")
    if opt:
        _table(opt, "artifacts/roofline_opt.md", "artifacts/roofline_opt.csv")
    return Path("artifacts/roofline.md")


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    if not DRYRUN_DIR.exists() or not list(DRYRUN_DIR.glob("*.json")):
        return [("roofline", 0.0, "no_dryrun_artifacts_yet")]
    write_tables()
    cells = load_cells(mesh="pod16x16", tag_filter="opt") or load_cells(
        mesh="pod16x16")
    dt_us = (time.time() - t0) * 1e6
    out = []
    for c in cells:
        out.append((
            f"roofline_{c['arch']}_{c['shape']}", dt_us / max(len(cells), 1),
            f"bottleneck={c['bottleneck']};fraction={c['roofline_fraction']:.3f}"
            f";useful={c['useful_ratio']:.3f}"))
    worst = min(cells, key=lambda c: c["roofline_fraction"])
    collbound = [c for c in cells if c["bottleneck"] == "collective"]
    out.append(("roofline_worst_cell", 0.0,
                f"{worst['arch']}/{worst['shape']}="
                f"{worst['roofline_fraction']:.3f}"))
    out.append(("roofline_collective_bound_cells", 0.0, str(len(collbound))))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
