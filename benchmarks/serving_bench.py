"""Serving-engine benchmark: adaptive-R vs the paper's fixed R = 20,
fused decision kernel vs the materializing path.

Workload: the synthetic SARD victim-triage stream (clean + a corrupted
fraction), served through repro/serving's continuous-batching engine
over the SAME trained Bayesian-head CNN and the SAME accept/flag
thresholds, in three configurations:

  * adaptive       4-sample rounds with sequential-test escalation and
                   the fused Pallas decision kernel — the default
                   serving fast path,
  * adaptive_jnp   same policy through the materializing
                   ``mix_samples → update_stats`` rounds (the PR 3
                   hot path; verdict-identical, kept as the
                   perf/memory baseline),
  * fixed_r20      one 20-sample round per decision — the paper's
                   dataflow.

Because the asymptotic decision rule is identical (the adaptive policy
collapses onto the fixed rule at the R budget), flagged fractions match
up to the sequential test's early stopping; the bench reports the
delta alongside.

decisions/s is reported three ways:
  * cold  — engine wall-clock including jit compilation (what every
    run paid before the process-wide compile cache; kept so the
    PR-over-PR trajectory in BENCH_serving.json stays comparable),
  * warm  — steady-state wall-clock with compiled executables (the
    serving quantity: engines now share jitted pool functions, so a
    fleet pays compilation once per process),
  * model — the paper's §V-A latency model at the measured mean sample
    count, the deployment-side quantity the adaptive-fidelity claim is
    scored on (72.2 FPS at R̄ = 20 is the same math).

Per configuration the bench also records the tentpole memory/sync
metrics: ``peak_live_bytes_per_decision`` (largest live array in the
compiled decision round, via launch/hlo_analysis — the fused path must
not carry an R·B·N term) and ``host_syncs_per_decision`` (blocking
device→host round trips; the device-resident escalation loop syncs
once per retirement event, not once per round).

Everything is written to repo-root ``BENCH_serving.json`` (uploaded as
a CI artifact) so the perf trajectory is tracked PR over PR.

Run: PYTHONPATH=src python -m benchmarks.run --only serving_bench
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.ckpt import latest_step, restore, save
from repro.data.sard import SardConfig, batch_at
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn, train_loss
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.serving import TriagePolicy

ART = Path("artifacts/serving_bench")
BENCH_JSON = Path("BENCH_serving.json")
TRAIN_STEPS = 250
DATA_CFG = SardConfig(image_size=32, seed=7)
N_REQUESTS = 192
N_SLOTS = 32
CORRUPT_FRAC = 0.25
POLICY = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                      r_min=4, r_max=20, z=1.0)


def trained_params(cfg: SarCnnConfig):
    if latest_step(ART) is not None:
        tree, _ = restore(ART)
        return jax.tree.map(jnp.asarray, tree)
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)

    @jax.jit
    def step_fn(params, opt, batch, step):
        (loss, m), g = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, step), has_aux=True)(params)
        params, opt, _ = adamw_update(params, g, opt, opt_cfg)
        return params, opt, m

    for s in range(TRAIN_STEPS):
        params, opt, _ = step_fn(params, opt, batch_at(DATA_CFG, s, 64),
                                 jnp.int32(s))
    save(ART, TRAIN_STEPS, params)
    return params


def _run(params, cfg, adaptive: bool, fused: bool,
         n_requests: int = N_REQUESTS) -> dict:
    from repro.launch.serve import serve_sar
    return serve_sar(n_requests=n_requests, n_slots=N_SLOTS,
                     adaptive=adaptive, policy=POLICY,
                     corrupt_frac=CORRUPT_FRAC, corruption="fog",
                     params=params, cfg=cfg, fused=fused)


def _round_peak_live_bytes(cfg, adaptive: bool, fused: bool,
                           n_classes: int) -> float:
    """Largest live array in the compiled decision round (HLO walk)."""
    from repro.core.sampling import BayesHeadConfig
    from repro.launch.hlo_analysis import largest_intermediate_bytes
    from repro.serving import adaptive as ad
    from repro.serving.engine import _sar_round_fn
    hcfg = BayesHeadConfig(num_samples=POLICY.r_max, mode="rank16",
                           grng=cfg.grng, compute_dtype=jnp.float32,
                           hoist_basis=True)
    r_step = POLICY.r_min if adaptive else POLICY.r_max
    fn = _sar_round_fn(hcfg, POLICY, adaptive, r_step, fused, None)
    b, n = N_SLOTS, n_classes
    pool = {"y_mu": jnp.zeros((b, n)), "x_sigma": jnp.zeros((b, n)),
            "m": jnp.zeros((b, n, 16))}
    txt = fn.lower(pool, ad.init_stats(b, n), jnp.zeros((b,), jnp.uint32),
                   jnp.ones((b,), bool)).compile().as_text()
    return largest_intermediate_bytes(txt)


def bench() -> list[tuple[str, float, str]]:
    cfg = SarCnnConfig()
    params = trained_params(cfg)
    out = []
    results: dict[str, dict] = {}
    configs = (
        ("adaptive", True, True),
        ("adaptive_jnp", True, False),
        ("fixed_r20", False, True),
    )
    for name, adaptive, fused in configs:
        t0 = time.time()
        cold = _run(params, cfg, adaptive, fused)
        cold_wall = time.time() - t0
        warm = _run(params, cfg, adaptive, fused)     # compiled reuse
        us = cold_wall * 1e6 / max(cold["decisions"], 1)
        rec = dict(warm)
        rec["cold_wall_s"] = cold_wall
        rec["cold_decisions_per_s"] = cold["decisions_per_s"]
        rec["warm_decisions_per_s"] = warm["decisions_per_s"]
        rec["peak_live_bytes_per_decision"] = _round_peak_live_bytes(
            cfg, adaptive, fused, cfg.n_classes)
        results[name] = rec
        # wall_dps is the STEADY-STATE number (compiled executables) —
        # the serving quantity; cold_dps keeps the compile-inclusive
        # figure previous PRs reported, for trajectory continuity.
        out.append((f"serving_sar_{name}", us,
                    f"wall_dps={rec['warm_decisions_per_s']:.1f};"
                    f"cold_dps={rec['cold_decisions_per_s']:.1f};"
                    f"model_dps={rec['model_decisions_per_s']:.0f};"
                    f"samples={rec['mean_samples_per_decision']:.2f};"
                    f"flagged={rec['flag_fraction']:.3f};"
                    f"syncs_per_dec={rec['host_syncs_per_decision']:.3f};"
                    f"peak_live_B={rec['peak_live_bytes_per_decision']:.0f};"
                    f"grng_aJ={rec['grng_energy_per_decision_aJ']:.2e};"
                    # tilemap-true accounting (placed blocks, not
                    # logical tiles): deployed area/utilization and the
                    # batch's reconciled total energy
                    f"etot_J={rec['energy_total_J']:.3e};"
                    f"util={rec['tile_utilization']:.3f};"
                    f"tops_w_mm2_eff={rec['tops_w_mm2_effective']:.1f}"))

    a, f = results["adaptive"], results["fixed_r20"]
    model_speedup = (a["model_decisions_per_s"]
                     / f["model_decisions_per_s"])
    wall_speedup = (a["warm_decisions_per_s"]
                    / f["warm_decisions_per_s"])
    warm_speedup = (a["warm_decisions_per_s"]
                    / max(a["cold_decisions_per_s"], 1e-9))
    energy_saving = a["energy_saving_vs_R20"]
    flag_delta = abs(a["flag_fraction"] - f["flag_fraction"])
    out.append(("serving_sar_speedup", 0.0,
                f"model_speedup={model_speedup:.2f}x;"
                f"wall_speedup={wall_speedup:.2f}x;"
                f"warm_over_cold={warm_speedup:.2f}x;"
                f"energy_saving={energy_saving:.2f}x;"
                f"flag_delta={flag_delta:.3f};"
                f"adaptive_samples={a['mean_samples_per_decision']:.2f}"))

    report = {
        "workload": {
            "n_requests": N_REQUESTS, "n_slots": N_SLOTS,
            "corrupt_frac": CORRUPT_FRAC,
            "policy": {"conf_threshold": POLICY.conf_threshold,
                       "mi_threshold": POLICY.mi_threshold,
                       "r_min": POLICY.r_min, "r_max": POLICY.r_max},
        },
        "configs": {
            name: {
                "decisions_per_s_cold": rec["cold_decisions_per_s"],
                "decisions_per_s_warm": rec["warm_decisions_per_s"],
                "model_decisions_per_s": rec["model_decisions_per_s"],
                "placed_decisions_per_s": rec.get(
                    "placed_decisions_per_s"),
                "mean_samples_per_decision":
                    rec["mean_samples_per_decision"],
                "host_syncs_per_decision":
                    rec["host_syncs_per_decision"],
                "peak_live_bytes_per_decision":
                    rec["peak_live_bytes_per_decision"],
                "flag_fraction": rec["flag_fraction"],
                "energy_total_J": rec["energy_total_J"],
                "grng_energy_per_decision_aJ":
                    rec["grng_energy_per_decision_aJ"],
                # observability rider: device-resident telemetry pulled
                # at the engine's existing drain point + the online
                # GRNG drift verdict against the calibration reference
                "grng_probe": (rec.get("telemetry") or {}).get("grng"),
                "drift": rec.get("drift"),
            } for name, rec in results.items()
        },
        "speedups": {
            "adaptive_vs_fixed_model": model_speedup,
            "adaptive_vs_fixed_wall_warm": wall_speedup,
            "warm_over_cold": warm_speedup,
            "energy_saving_vs_R20": energy_saving,
            "flag_delta": flag_delta,
        },
    }
    if BENCH_JSON.exists():
        # fleet_bench rides its scaling summary in under "fleet" —
        # keep it across this module's snapshot rewrite
        try:
            prev = json.loads(BENCH_JSON.read_text())
        except json.JSONDecodeError:
            prev = {}
        if "fleet" in prev:
            report["fleet"] = prev["fleet"]
    BENCH_JSON.write_text(json.dumps(report, indent=2, sort_keys=True))
    # history rider: the regress.py-gated per-config metrics, one
    # schema-versioned line per run (BENCH_serving.json is a snapshot;
    # BENCH_history.jsonl is the trajectory).
    from benchmarks import history
    history.record("serving_bench",
                   {"configs": report["configs"],
                    "speedups": report["speedups"]})

    # Prometheus/JSON metrics snapshot for the fast-path config,
    # uploaded next to BENCH_serving.json as a CI artifact.
    from repro.obs.registry import serving_registry
    ada = results["adaptive"]
    reg = serving_registry(ada, telemetry=ada.get("telemetry"),
                           drift=ada.get("drift"),
                           arch="sar_cnn", config="adaptive")
    ART.mkdir(parents=True, exist_ok=True)
    reg.write(str(ART / "metrics"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
