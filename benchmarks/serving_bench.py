"""Serving-engine benchmark: adaptive-R vs the paper's fixed R = 20.

Workload: the synthetic SARD victim-triage stream (clean + a corrupted
fraction), served through repro/serving's continuous-batching engine in
two policies over the SAME trained Bayesian-head CNN and the SAME
accept/flag thresholds:

  * fixed    one 20-sample round per decision — the paper's dataflow,
  * adaptive 4-sample rounds with sequential-test escalation, per-slot
             escalation depth (serving/adaptive.py).

Because the asymptotic decision rule is identical (the adaptive policy
collapses onto the fixed rule at the R budget), flagged fractions match
up to the sequential test's early stopping; the bench reports the
delta alongside.

decisions/s is reported two ways:
  * wall  — engine wall-clock on this host (jit dispatch dominates at
    smoke scale; reported for regression tracking),
  * model — the paper's §V-A latency model at the measured mean sample
    count: trunk MVMs + (1 + R̄) serial σε re-reads.  This is the
    deployment-side quantity (the paper's own 72.2 FPS figure is the
    same math at R̄ = 20), and the one the adaptive-fidelity claim is
    scored on.

Also reports mean samples/decision and the analytic GRNG energy per
decision (640 aJ/sample, core/energy.py).

Run: PYTHONPATH=src python -m benchmarks.run --only serving_bench
"""

from __future__ import annotations

import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.ckpt import latest_step, restore, save
from repro.data.sard import SardConfig, batch_at
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn, train_loss
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.serving import TriagePolicy

ART = Path("artifacts/serving_bench")
TRAIN_STEPS = 250
DATA_CFG = SardConfig(image_size=32, seed=7)
N_REQUESTS = 192
N_SLOTS = 32
CORRUPT_FRAC = 0.25
POLICY = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                      r_min=4, r_max=20, z=1.0)


def trained_params(cfg: SarCnnConfig):
    if latest_step(ART) is not None:
        tree, _ = restore(ART)
        return jax.tree.map(jnp.asarray, tree)
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)

    @jax.jit
    def step_fn(params, opt, batch, step):
        (loss, m), g = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, step), has_aux=True)(params)
        params, opt, _ = adamw_update(params, g, opt, opt_cfg)
        return params, opt, m

    for s in range(TRAIN_STEPS):
        params, opt, _ = step_fn(params, opt, batch_at(DATA_CFG, s, 64),
                                 jnp.int32(s))
    save(ART, TRAIN_STEPS, params)
    return params


def _run(params, cfg, adaptive: bool) -> dict:
    from repro.launch.serve import serve_sar
    return serve_sar(n_requests=N_REQUESTS, n_slots=N_SLOTS,
                     adaptive=adaptive, policy=POLICY,
                     corrupt_frac=CORRUPT_FRAC, corruption="fog",
                     params=params, cfg=cfg)


def bench() -> list[tuple[str, float, str]]:
    cfg = SarCnnConfig()
    params = trained_params(cfg)
    out = []
    results = {}
    for adaptive in (True, False):
        name = "adaptive" if adaptive else "fixed_r20"
        t0 = time.time()
        summary = _run(params, cfg, adaptive)
        us = (time.time() - t0) * 1e6 / max(summary["decisions"], 1)
        results[name] = summary
        out.append((f"serving_sar_{name}", us,
                    f"wall_dps={summary['decisions_per_s']:.1f};"
                    f"model_dps={summary['model_decisions_per_s']:.0f};"
                    f"samples={summary['mean_samples_per_decision']:.2f};"
                    f"flagged={summary['flag_fraction']:.3f};"
                    f"grng_aJ={summary['grng_energy_per_decision_aJ']:.2e};"
                    # tilemap-true accounting (placed blocks, not
                    # logical tiles): deployed area/utilization and the
                    # batch's reconciled total energy
                    f"etot_J={summary['energy_total_J']:.3e};"
                    f"util={summary['tile_utilization']:.3f};"
                    f"tops_w_mm2_eff={summary['tops_w_mm2_effective']:.1f}"))

    a, f = results["adaptive"], results["fixed_r20"]
    model_speedup = (a["model_decisions_per_s"]
                     / f["model_decisions_per_s"])
    wall_speedup = a["decisions_per_s"] / f["decisions_per_s"]
    energy_saving = a["energy_saving_vs_R20"]
    flag_delta = abs(a["flag_fraction"] - f["flag_fraction"])
    out.append(("serving_sar_speedup", 0.0,
                f"model_speedup={model_speedup:.2f}x;"
                f"wall_speedup={wall_speedup:.2f}x;"
                f"energy_saving={energy_saving:.2f}x;"
                f"flag_delta={flag_delta:.3f};"
                f"adaptive_samples={a['mean_samples_per_decision']:.2f}"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
