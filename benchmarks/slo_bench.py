"""SLO benchmark: time-to-verdict latency under seeded traffic.

Every serving bench so far enqueued its whole stream up front, so
latency percentiles measured burst *absorption*, never a traffic
regime.  This bench drives the trained SAR pipeline through
serving/load.py's OPEN-LOOP harness — arrivals follow a seeded
schedule and do not wait for the system — and reports what an operator
actually runs a pager on:

  * p50/p95/p99 time-to-verdict + queue-wait share under three arrival
    patterns (steady ``poisson``, 10x ``burst``, linear ``ramp``), each
    offered at a fixed fraction of the measured closed-loop capacity,
    for the single 16-slot engine AND the 4-pool fleet (sequential
    dispatch on one device — verdict-identical to the gang path);
  * a latency-vs-offered-load curve on the engine (Poisson sweep from
    0.25x to 1.5x capacity) and its knee: the highest offered rate
    whose p99 stays within ``KNEE_FACTOR`` of the light-load p99 —
    past the knee the open-loop queue grows without bound;
  * alerting gates: the error-budget burn-rate alert must FIRE under a
    10x arrival spike against an SLO calibrated at nominal load, and
    must stay QUIET at nominal load (the CI ``slo-smoke`` job fails on
    either a missed page or a false page);
  * structural metrics for benchmarks/regress.py: queue-wait share at
    nominal load, host syncs per decision (unchanged by the SLO
    tracker — it is pure host bookkeeping), and ``gates_all_pass``.

Scale knob: ``SLO_BENCH_REQUESTS`` (default 96) requests per
configuration; the curve sweep uses half that per point.

Everything lands in repo-root ``BENCH_slo.json`` + a
``BENCH_history.jsonl`` line.

Run: PYTHONPATH=src python -m benchmarks.run --only slo_bench
"""

from __future__ import annotations

import json
import math
import os
import time
from pathlib import Path

BENCH_JSON = Path("BENCH_slo.json")
N_REQUESTS = int(os.environ.get("SLO_BENCH_REQUESTS", "96"))
N_SLOTS = 16
FLEET_POOLS = 4
FLEET_SLOTS = 8
CORRUPT_FRAC = 0.25
NOMINAL_FRAC = 0.6         # nominal offered load, as a capacity fraction
CURVE_FRACS = (0.25, 0.5, 0.75, 1.0, 1.5)
KNEE_FACTOR = 3.0          # p99 multiple over light load that ends the
                           # "before the knee" region
SPIKE_FACTOR = 10.0        # arrival-rate multiplier for the alert gate


def _slo_fields(out: dict) -> dict:
    """The per-config record BENCH_slo.json keeps."""
    snap = out.get("slo") or {}
    return {
        "requests": out["requests"],
        "decisions": out["decisions"],
        "offered_rps": (out.get("offered") or {}).get("offered_rps",
                                                      float("nan")),
        "arrival": out.get("arrival"),
        "p50_s": snap.get("p50_s", float("nan")),
        "p95_s": snap.get("p95_s", float("nan")),
        "p99_s": snap.get("p99_s", float("nan")),
        "mean_s": snap.get("mean_s", float("nan")),
        "queue_wait_share": snap.get("queue_wait_share", float("nan")),
        "by_verdict": {k: v.get("count", 0)
                       for k, v in (snap.get("by_verdict") or {}).items()},
        "host_syncs_per_decision": out.get("host_syncs_per_decision",
                                           float("nan")),
        "fleet": snap.get("fleet"),
        "slos": snap.get("slos"),
        "alerts": [a["kind"] for a in out.get("alerts", [])],
        "wall_s": out["wall_s"],
    }


def _serve_engine(params, cfg, *, n_requests, arrival, slo=()):
    from repro.launch.serve import serve_sar
    from benchmarks.serving_bench import POLICY
    return serve_sar(n_requests=n_requests, n_slots=N_SLOTS,
                     policy=POLICY, corrupt_frac=CORRUPT_FRAC,
                     params=params, cfg=cfg, telemetry=False,
                     arrival=arrival, slo=slo)


def _serve_fleet(params, cfg, *, n_requests, arrival, slo=()):
    from repro.launch.serve import serve_sar_fleet
    from benchmarks.serving_bench import POLICY
    return serve_sar_fleet(n_requests=n_requests, n_pools=FLEET_POOLS,
                           slots_per_pool=FLEET_SLOTS, policy=POLICY,
                           corrupt_frac=CORRUPT_FRAC, params=params,
                           cfg=cfg, telemetry=False, gang=False,
                           arrival=arrival, slo=slo)


def _well_formed(rec: dict) -> bool:
    p50, p95, p99 = rec["p50_s"], rec["p95_s"], rec["p99_s"]
    return (all(math.isfinite(x) and x >= 0 for x in (p50, p95, p99))
            and p50 <= p95 + 1e-12 and p95 <= p99 + 1e-12
            and rec["decisions"] >= rec["requests"] > 0)


def _report() -> dict:
    from repro.models.sar_cnn import SarCnnConfig
    from repro.serving.load import ArrivalSpec
    from benchmarks.serving_bench import trained_params
    cfg = SarCnnConfig()
    params = trained_params(cfg)

    # -- closed-loop capacity: everything enqueued up front ------------
    from repro.launch.serve import serve_sar, serve_sar_fleet
    from benchmarks.serving_bench import POLICY
    t0 = time.perf_counter()
    cold = serve_sar(n_requests=N_REQUESTS, n_slots=N_SLOTS,
                     policy=POLICY, corrupt_frac=CORRUPT_FRAC,
                     params=params, cfg=cfg, telemetry=False)
    warm = serve_sar(n_requests=N_REQUESTS, n_slots=N_SLOTS,
                     policy=POLICY, corrupt_frac=CORRUPT_FRAC,
                     params=params, cfg=cfg, telemetry=False)
    capacity_rps = warm["decisions_per_s"]
    # compile the fleet's pool shapes once so traffic runs are warm too
    serve_sar_fleet(n_requests=2 * FLEET_POOLS, n_pools=FLEET_POOLS,
                    slots_per_pool=FLEET_SLOTS, policy=POLICY,
                    params=params, cfg=cfg, telemetry=False, gang=False)
    compile_wall_s = time.perf_counter() - t0

    nominal = NOMINAL_FRAC * capacity_rps
    patterns = {
        "poisson": ArrivalSpec(kind="poisson", rate=nominal),
        "burst": ArrivalSpec(kind="burst", rate=nominal),
        "ramp": ArrivalSpec(kind="ramp", rate=0.5 * nominal,
                            rate_hi=2.0 * nominal),
    }

    # -- the 3x2 pattern grid ------------------------------------------
    configs: dict[str, dict] = {}
    for pname, spec in patterns.items():
        for tname, runner in (("engine", _serve_engine),
                              ("fleet", _serve_fleet)):
            out = runner(params, cfg, n_requests=N_REQUESTS,
                         arrival=spec)
            configs[f"{pname}_{tname}"] = _slo_fields(out)

    # -- latency vs offered load (engine, Poisson sweep) ---------------
    curve = []
    n_curve = max(N_REQUESTS // 2, 16)
    for frac in CURVE_FRACS:
        spec = ArrivalSpec(kind="poisson", rate=frac * capacity_rps)
        out = _serve_engine(params, cfg, n_requests=n_curve,
                            arrival=spec)
        snap = out["slo"] if "slo" in out else {}
        curve.append({"capacity_frac": frac,
                      "offered_rps": frac * capacity_rps,
                      "p50_s": snap.get("p50_s", float("nan")),
                      "p99_s": snap.get("p99_s", float("nan")),
                      "queue_wait_share": snap.get("queue_wait_share",
                                                   float("nan"))})
    base_p99 = curve[0]["p99_s"]
    knee_rps = curve[0]["offered_rps"]
    for pt in curve:
        if math.isfinite(pt["p99_s"]) and \
                pt["p99_s"] <= KNEE_FACTOR * base_p99:
            knee_rps = pt["offered_rps"]
        else:
            break

    # -- alerting gates -------------------------------------------------
    # SLO calibrated from the measured nominal p99 (headroom 3x, scored
    # at p95 so one straggler in a small run cannot false-page)
    nominal_p99 = configs["poisson_engine"]["p99_s"]
    target_s = 3.0 * max(nominal_p99, 1e-3)
    slo_spec = f"{target_s:.6f}:p95"
    quiet = _serve_engine(
        params, cfg, n_requests=N_REQUESTS,
        arrival=ArrivalSpec(kind="poisson", rate=nominal),
        slo=(slo_spec,))
    # The spike must be SUSTAINED overload, not an absorbable blip: in
    # an open-loop overload the queue grows with the stream, so time-
    # to-verdict for the bulk of the stream is ~stream_len/capacity —
    # size the stream so that dwarfs the target (8x), bounded for
    # pathological targets.
    spike_n = int(min(max(N_REQUESTS,
                          math.ceil(8 * capacity_rps * target_s)),
                      2048))
    spike = _serve_engine(
        params, cfg, n_requests=spike_n,
        arrival=ArrivalSpec(kind="poisson", rate=SPIKE_FACTOR * nominal),
        slo=(slo_spec,))
    quiet_slo = (quiet["slo"]["slos"] or [{}])[0]
    spike_slo = (spike["slo"]["slos"] or [{}])[0]
    gates = {
        "slo_report_well_formed": all(_well_formed(r)
                                      for r in configs.values()),
        "burn_alert_fires_under_spike": bool(spike_slo.get("breach")),
        "quiet_under_nominal": not quiet_slo.get("breach", False),
    }
    gates["gates_all_pass"] = all(gates.values())

    return {
        "workload": {
            "n_requests": N_REQUESTS,
            "n_slots": N_SLOTS,
            "fleet_pools": FLEET_POOLS,
            "fleet_slots_per_pool": FLEET_SLOTS,
            "corrupt_frac": CORRUPT_FRAC,
            "nominal_frac": NOMINAL_FRAC,
            "spike_factor": SPIKE_FACTOR,
            "seed": 0,
        },
        "capacity": {
            "closed_loop_rps_warm": capacity_rps,
            "closed_loop_rps_cold": cold["decisions_per_s"],
            "nominal_offered_rps": nominal,
            "compile_wall_s": compile_wall_s,
        },
        "configs": configs,
        "load_curve": curve,
        "knee_rps": knee_rps,
        "knee_capacity_frac": (knee_rps / capacity_rps
                               if capacity_rps > 0 else float("nan")),
        "alert_gate": {
            "slo": slo_spec,
            "spike_requests": spike_n,
            "quiet": quiet_slo,
            "spike": spike_slo,
            "quiet_alerts": [a["kind"] for a in quiet.get("alerts", [])],
            "spike_alerts": [a["kind"] for a in spike.get("alerts", [])],
        },
        "gates": gates,
    }


def bench() -> list[tuple[str, float, str]]:
    report = _report()
    BENCH_JSON.write_text(json.dumps(report, indent=2, sort_keys=True))

    from benchmarks import history
    history.record("slo_bench",
                   {"capacity": report["capacity"],
                    "configs": report["configs"],
                    "knee_rps": report["knee_rps"],
                    "gates": report["gates"]})

    rows = []
    for name, rec in report["configs"].items():
        rows.append((
            f"slo_{name}", rec["p99_s"] * 1e6,
            f"p50_s={rec['p50_s']:.4f};p99_s={rec['p99_s']:.4f};"
            f"offered_rps={rec['offered_rps']:.1f};"
            f"qshare={rec['queue_wait_share']:.3f}"))
    g = report["gates"]
    rows.append((
        "slo_gates", report["knee_rps"],
        f"knee_rps={report['knee_rps']:.1f};"
        f"well_formed={g['slo_report_well_formed']};"
        f"spike_fires={g['burn_alert_fires_under_spike']};"
        f"quiet={g['quiet_under_nominal']};"
        f"all={g['gates_all_pass']}"))
    return rows


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
