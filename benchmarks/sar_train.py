"""Shared SAR-model training for the §V-B reproduction benchmarks.

Trains the deterministic CNN and the Bayesian-last-layer BNN on the
synthetic SARD task once and caches parameters under artifacts/ — the
fig16/table2 benchmarks evaluate the cached models through the CNN /
ideal-Gaussian / CLT-GRNG serving paths.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.ckpt import latest_step, restore, save
from repro.data.sard import SardConfig, batch_at
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn, train_loss
from repro.optim import AdamWConfig, adamw_update, init_opt_state

ART = Path("artifacts/sar_models")
DATA_CFG = SardConfig(image_size=32, seed=7)
TRAIN_STEPS = 800
BATCH = 64
TEST_BATCHES = 16          # 1024 eval images, offset beyond training steps
R_SAMPLES = 20             # paper R


def model_cfg(bayesian: bool) -> SarCnnConfig:
    return SarCnnConfig(bayesian_head=bayesian)


def _train(cfg: SarCnnConfig, tag: str, steps: int = TRAIN_STEPS):
    ckpt_dir = ART / tag
    if latest_step(ckpt_dir) is not None:
        tree, _ = restore(ckpt_dir)
        return jax.tree.map(jnp.asarray, tree)
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)

    @jax.jit
    def step_fn(params, opt, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, step), has_aux=True)(params)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, metrics

    for step in range(steps):
        batch = batch_at(DATA_CFG, step, BATCH)
        params, opt, metrics = step_fn(params, opt, batch,
                                       jnp.int32(step))
        if step % 100 == 0:
            print(f"[sar:{tag}] step {step} ce={float(metrics['ce']):.4f} "
                  f"acc={float(metrics['acc']):.3f}")
    save(ckpt_dir, steps, params)
    return params


def trained_models():
    """Returns (cnn_params, bnn_params) — cached across benchmark runs."""
    cnn = _train(model_cfg(bayesian=False), "cnn")
    bnn = _train(model_cfg(bayesian=True), "bnn")
    return cnn, bnn


def test_batches(corruption: str | None = None, severity: float = 1.0):
    """Held-out evaluation batches (steps beyond the training range)."""
    from repro.data.sard import corrupted_batch
    for i in range(TEST_BATCHES):
        step = 10_000 + i
        if corruption is None:
            yield batch_at(DATA_CFG, step, BATCH)
        else:
            yield corrupted_batch(DATA_CFG, step, BATCH, corruption, severity)
