"""Performance regression gate: diff the current bench outputs against
a committed baseline with per-metric tolerance bands, exit nonzero on
regression.

ROADMAP item 5 asks for "a regression gate on compiled us/call so 'fast
as the hardware allows' becomes a measured claim" — this is that gate.
Two metric classes, because CPU interpret-mode timings are noisy while
structural metrics are exact:

  * deterministic metrics (compiled-program peak-live bytes, host syncs
    per decision, mean samples per decision, flag fraction, the §V-A
    model throughput, the fused kernel's peak-vs-R growth) get TIGHT
    machine-independent bands — these regress only when the code
    changes behaviour;
  * wall-clock metrics (warm us/call, warm decisions/s) are gated by a
    single ``--wall-ratio`` knob: the default 1.5 catches a 2×
    slowdown on a quiet machine, CI passes a generous interpret-mode
    ratio (shared runners jitter) — an honest wide band beats a tight
    band that cries wolf.

A third class, absolute FLOORS (``FLOOR_BANDS``), carries acceptance
gates that must hold regardless of the committed baseline value — the
fleet scaling-efficiency/speedup criteria from ROADMAP item 1.

Usage:
  PYTHONPATH=src python -m benchmarks.regress                 # gate
  PYTHONPATH=src python -m benchmarks.regress --write-baseline
  PYTHONPATH=src python -m benchmarks.regress --wall-ratio 5  # CI
  # fleet gate (multi-device smoke job; fleet metrics live in their
  # own baseline because they only exist when fleet_bench has run):
  PYTHONPATH=src python -m benchmarks.regress \
      --baseline benchmarks/baseline_fleet.json

The baseline (benchmarks/baseline.json) is committed; refresh it with
``--write-baseline`` whenever a PR intentionally moves a metric, so the
diff is reviewed like any other code change.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Any

BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"
FLEET_BASELINE_PATH = Path(__file__).resolve().parent \
    / "baseline_fleet.json"
SLO_BASELINE_PATH = Path(__file__).resolve().parent \
    / "baseline_slo.json"
SERVING_JSON = Path("BENCH_serving.json")
KERNELS_JSON = Path("BENCH_kernels.json")
LIFETIME_JSON = Path("BENCH_lifetime.json")
FLEET_JSON = Path("BENCH_fleet.json")
SLO_JSON = Path("BENCH_slo.json")

# metric-name suffix -> (direction, band).  "lower": regression when
# current > baseline * band; "higher": regression when
# current < baseline / band; "abs": regression when
# |current - baseline| > band.  Deterministic bands are deliberately
# tight — these numbers are properties of the compiled programs and the
# sequential test, not of the machine.
DETERMINISTIC_BANDS: dict[str, tuple[str, float]] = {
    "peak_live_bytes_per_decision": ("lower", 1.01),
    "host_syncs_per_decision": ("lower", 1.25),
    "mean_samples_per_decision": ("lower", 1.05),
    "model_decisions_per_s": ("higher", 1.10),
    "peak_vs_r_growth": ("lower", 1.01),
    # lifetime loop (BENCH_lifetime.json): the healed serve arm must
    # keep raising advisories and healing them — a half-strength band
    # tolerates count jitter but fails on zero.
    "advisories": ("higher", 2.0),
    "heals": ("higher", 2.0),
    # fleet (BENCH_fleet.json): one gang sync serves P pools, so the
    # per-POOL structural sync cost must hold the single-engine budget
    "per_pool_syncs_per_decision": ("lower", 1.25),
    # SLO bench (BENCH_slo.json): the tracker is pure host bookkeeping
    # around the existing sync points, so syncs/decision under traffic
    # must hold the same structural budget (wide band: open-loop runs
    # add idle admission ticks, never per-round syncs).
    "slo_syncs_per_decision": ("lower", 2.0),
}
# absolute floors, independent of the baseline VALUE: regression when
# current < floor.  These are the ROADMAP item-1 fleet acceptance
# gates — committing a weaker baseline must not weaken the gate.
FLOOR_BANDS: dict[str, float] = {
    "scaling_efficiency_4pools": 0.7,
    "speedup_4pools": 3.0,
}
ABS_BANDS: dict[str, float] = {
    "flag_fraction": 0.05,
    # lifetime loop: structural booleans (1.0 = pass) and the healed
    # die's clean acc-dev, which must stay inside the PR 2 band.
    "gates_all_pass": 0.0,
    "false_advisories": 0.0,
    "healed_clean_acc_dev": 0.01,
    # SLO bench: queue-wait share at nominal offered load is a
    # structural property of the arrival schedule vs capacity, but
    # scheduling jitter on shared runners moves it — wide absolute band
    "queue_wait_share": 0.45,
}
# wall-clock metrics: band comes from --wall-ratio
WALL_LOWER_SUFFIXES = ("us_per_call_warm",)
WALL_HIGHER_SUFFIXES = ("decisions_per_s_warm", "decisions_per_s_mesh")

SERVING_METRIC_KEYS = (
    "host_syncs_per_decision", "peak_live_bytes_per_decision",
    "mean_samples_per_decision", "flag_fraction",
    "model_decisions_per_s", "decisions_per_s_warm",
)


def _kernel_rows(doc: dict) -> dict[str, dict]:
    return {row["name"]: row for row in doc.get("rows", [])}


def current_metrics(serving_path: Path | str = SERVING_JSON,
                    kernels_path: Path | str = KERNELS_JSON,
                    lifetime_path: Path | str = LIFETIME_JSON,
                    fleet_path: Path | str = FLEET_JSON,
                    slo_path: Path | str = SLO_JSON,
                    ) -> dict[str, float]:
    """Flat {metric_name: value} from the BENCH_*.json snapshots.

    Missing snapshot files contribute nothing (regress then fails on
    the baseline's uncovered metrics — a silently absent bench must not
    read as a pass)."""
    out: dict[str, float] = {}
    serving_path, kernels_path = Path(serving_path), Path(kernels_path)
    lifetime_path = Path(lifetime_path)
    if serving_path.exists():
        doc = json.loads(serving_path.read_text())
        for cfg, rec in doc.get("configs", {}).items():
            for key in SERVING_METRIC_KEYS:
                v = rec.get(key)
                if isinstance(v, (int, float)) and v == v:
                    out[f"serving.{cfg}.{key}"] = float(v)
    if kernels_path.exists():
        rows = _kernel_rows(json.loads(kernels_path.read_text()))
        for name in ("kernel_decision_fused",
                     "kernel_decision_materializing"):
            row = rows.get(name)
            if row and "us_per_call_warm" in row:
                out[f"kernels.{name}.us_per_call_warm"] = float(
                    row["us_per_call_warm"])
        row = rows.get("kernel_decision_peak_vs_R_fused")
        if row:
            m = re.search(r"growth=([0-9.]+)x", row.get("derived", ""))
            if m:
                out["kernels.fused.peak_vs_r_growth"] = float(m.group(1))
    if lifetime_path.exists():
        doc = json.loads(lifetime_path.read_text())
        healed = doc.get("serve", {}).get("healed", {}).get("lifetime", {})
        fresh = doc.get("serve", {}).get("fresh", {}).get("lifetime", {})
        for key in ("advisories", "heals"):
            v = healed.get(key)
            if isinstance(v, (int, float)):
                out[f"lifetime.serve_healed.{key}"] = float(v)
        v = fresh.get("advisories")
        if isinstance(v, (int, float)):
            out["lifetime.serve_fresh.false_advisories"] = float(v)
        dev = (doc.get("static", {}).get("arms", {}).get("healed", {})
               .get("clean", {}).get("acc_dev"))
        if isinstance(dev, (int, float)):
            out["lifetime.static.healed_clean_acc_dev"] = float(dev)
        gates = doc.get("gates", {})
        if gates:
            out["lifetime.gates_all_pass"] = float(
                all(bool(v) for v in gates.values()))
    fleet_path = Path(fleet_path)
    if fleet_path.exists():
        doc = json.loads(fleet_path.read_text())
        for p, rec in doc.get("pools", {}).items():
            for key in ("decisions_per_s_warm", "decisions_per_s_mesh",
                        "host_syncs_per_decision",
                        "per_pool_syncs_per_decision"):
                v = rec.get(key)
                if isinstance(v, (int, float)) and v == v:
                    out[f"fleet.pools{p}.{key}"] = float(v)
        for key in ("speedup_4pools", "scaling_efficiency_4pools"):
            v = doc.get(key)
            if isinstance(v, (int, float)) and v == v:
                out[f"fleet.{key}"] = float(v)
    slo_path = Path(slo_path)
    if slo_path.exists():
        doc = json.loads(slo_path.read_text())
        gates = doc.get("gates", {})
        if gates:
            out["slo.gates_all_pass"] = float(
                all(bool(v) for v in gates.values()))
        rec = doc.get("configs", {}).get("poisson_engine", {})
        v = rec.get("queue_wait_share")
        if isinstance(v, (int, float)) and v == v:
            out["slo.poisson_engine.queue_wait_share"] = float(v)
        v = rec.get("host_syncs_per_decision")
        if isinstance(v, (int, float)) and v == v:
            out["slo.poisson_engine.slo_syncs_per_decision"] = float(v)
    return out


def _band_for(metric: str, wall_ratio: float):
    """(direction, band) for one metric name, by suffix."""
    tail = metric.rsplit(".", 1)[-1]
    if tail in FLOOR_BANDS:
        return "floor", FLOOR_BANDS[tail]
    if tail in ABS_BANDS:
        return "abs", ABS_BANDS[tail]
    if tail in DETERMINISTIC_BANDS:
        return DETERMINISTIC_BANDS[tail]
    if tail in WALL_LOWER_SUFFIXES:
        return "lower", wall_ratio
    if tail in WALL_HIGHER_SUFFIXES:
        return "higher", wall_ratio
    # unclassified: treat as wall-clock lower-is-better (conservative)
    return "lower", wall_ratio


def compare(current: dict[str, float], baseline: dict[str, float],
            wall_ratio: float = 1.5) -> list[dict[str, Any]]:
    """Regressions of ``current`` vs ``baseline``; empty list = pass.

    Every baseline metric must be present in ``current`` (a vanished
    metric is a regression in coverage, not a pass); metrics only in
    ``current`` are new and ignored until the baseline is refreshed."""
    failures = []
    for metric in sorted(baseline):
        base = float(baseline[metric])
        kind, band = _band_for(metric, wall_ratio)
        if metric not in current:
            failures.append({"metric": metric, "kind": "missing",
                             "baseline": base, "current": None,
                             "limit": None})
            continue
        cur = float(current[metric])
        if kind == "floor":
            # absolute acceptance floor — the baseline value is
            # informational; the committed FLOOR_BANDS constant gates
            limit = band
            ok = cur >= band
        elif kind == "abs":
            limit = band
            ok = abs(cur - base) <= band
        elif kind == "lower":
            limit = base * band
            ok = cur <= limit
        else:  # higher
            limit = base / band
            ok = cur >= limit
        if not ok:
            failures.append({"metric": metric, "kind": kind,
                             "baseline": base, "current": cur,
                             "limit": limit})
    return failures


def load_baseline(path: Path | str = BASELINE_PATH) -> dict[str, float]:
    doc = json.loads(Path(path).read_text())
    return doc["metrics"]


def write_baseline(metrics: dict[str, float],
                   path: Path | str = BASELINE_PATH) -> None:
    from benchmarks import history
    doc = {"schema": 1, "fingerprint": history.backend_fingerprint(),
           "git_sha": history.git_sha(), "metrics": metrics}
    Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True)
                          + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(BASELINE_PATH))
    ap.add_argument("--serving", default=str(SERVING_JSON))
    ap.add_argument("--kernels", default=str(KERNELS_JSON))
    ap.add_argument("--lifetime", default=str(LIFETIME_JSON))
    ap.add_argument("--fleet", default=str(FLEET_JSON))
    ap.add_argument("--slo", default=str(SLO_JSON))
    ap.add_argument("--wall-ratio", type=float, default=1.5,
                    help="tolerance ratio for wall-clock metrics "
                         "(CI interpret-mode runs pass a generous "
                         "value; deterministic metrics keep their "
                         "tight bands regardless)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the baseline with the current "
                         "metrics instead of gating")
    args = ap.parse_args(argv)

    current = current_metrics(args.serving, args.kernels,
                              args.lifetime, args.fleet, args.slo)
    if not current:
        print("regress: no BENCH_*.json snapshots found — run "
              "benchmarks first", file=sys.stderr)
        return 2
    if args.write_baseline:
        write_baseline(current, args.baseline)
        print(f"baseline written: {args.baseline} "
              f"({len(current)} metrics)")
        return 0

    baseline = load_baseline(args.baseline)
    failures = compare(current, baseline, args.wall_ratio)
    n_checked = len(baseline)
    if not failures:
        print(f"regress: PASS — {n_checked} metrics within bands "
              f"(wall_ratio={args.wall_ratio})")
        return 0
    print(f"regress: FAIL — {len(failures)}/{n_checked} metrics out of "
          f"band (wall_ratio={args.wall_ratio})", file=sys.stderr)
    for f in failures:
        if f["kind"] == "missing":
            print(f"  {f['metric']}: MISSING (baseline "
                  f"{f['baseline']:.6g})", file=sys.stderr)
        else:
            print(f"  {f['metric']}: current {f['current']:.6g} vs "
                  f"baseline {f['baseline']:.6g} "
                  f"(limit {f['limit']:.6g}, {f['kind']})",
                  file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
