"""Paper Fig. 17 / Table II (Corr rows): corruption robustness.

Fog / frost / motion / snow partitions of the synthetic SARD test set,
evaluated without retraining — validating that the BNN's OOD behaviour
(and its CLT-GRNG realization) survives the paper's adverse-weather
setting.  Paper claims to check: BNN improves mAP/AURC/AECE/AMCE on
every partition; CLT ≈ ideal.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.fig16_uq import run
from repro.data.sard import CORRUPTIONS


def bench() -> list[tuple[str, float, str]]:
    out = []
    results = {}
    for corr in CORRUPTIONS:
        t0 = time.time()
        # severity 0.5: models degraded-but-skilled (the paper's regime —
        # its corrupted mAPs sit at 0.58-0.83, well above chance)
        rows = run(corruption=corr, severity=0.5)
        dt_us = (time.time() - t0) * 1e6
        results[corr] = rows
        for name in ("cnn", "bnn_ideal", "this_clt"):
            r = rows[name]
            out.append((f"table2_{corr}_{name}", dt_us / 3,
                        f"acc={r['accuracy']:.4f};aurc={r['aurc']:.4f};"
                        f"aece={r['aece']:.4f};amce={r['amce']:.4f}"))
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/table2_corr.json").write_text(
        json.dumps(results, indent=2))
    # headline: mean AURC improvement CNN -> BNN across partitions.
    # (the paper's Table II BNN rows use ideal sampling; its "This*"
    # rows add the CLT distribution on a QAT-deployed chip.  Our CIM
    # trunk is post-training-quantized, so the BNN row is the
    # apples-to-apples robustness claim; the CLT-head-only delta is
    # checked in fig16.)
    gains = [(results[c]["cnn"]["aurc"] - results[c]["bnn_ideal"]["aurc"])
             / max(results[c]["cnn"]["aurc"], 1e-9) for c in results]
    out.append(("table2_mean_aurc_improvement_bnn", 0.0,
                f"{100 * sum(gains) / len(gains):.1f}%_vs_paper_14.4%"))
    amce = [(results[c]["cnn"]["amce"] - results[c]["bnn_ideal"]["amce"])
            / max(results[c]["cnn"]["amce"], 1e-9) for c in results]
    out.append(("table2_mean_amce_improvement_bnn", 0.0,
                f"{100 * sum(amce) / len(amce):.1f}%_vs_paper_22.1%"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
