"""Monte-Carlo chip-variation sweep: does calibration hold the fleet?

The paper characterizes ONE die.  A deployment ships a population, and
the question that decides deployed accuracy (cf. Bayes2IMC / FeBiM) is
whether per-instance calibration — the paper's own §III-B1 measurement,
re-run per chip (hw/calib.py) — recovers the golden-chip operating
point across process corner, temperature, read noise, and programming
error.  This benchmark samples ≥16 chip instances per severity level,
deploys the SAME trained SAR Bayesian-head CNN onto each twice
(golden factory transform vs per-instance recalibration), and measures
accuracy / adaptive-ECE / mutual information / flagged fraction on
clean and fog-corrupted SARD streams.

The conv trunk runs through each chip's NONIDEAL CIM arrays by default
(models/sar_cnn.features with per-column ADC gain/offset + conductance
programming error — the paper's µ-only-subarray mapping on that die);
the golden reference runs the same CIM numeric path on the golden
instance, so deviations isolate chip variation rather than CIM
quantization.  HW_VARIATION_TRUNK=ideal restores the old float-conv
trunk (features computed once, chip-independent — much cheaper).

Before sweeping the fleet the benchmark asserts, bit-for-bit, that the
GOLDEN instance (hw.golden_instance: golden hash seeds, zero variation)
reproduces the golden factory head through the whole instance plumbing
(prepare_instance_head → logit samples) — and raises RuntimeError on
any drift, so a broken twin can never masquerade as a clean fleet.

Energy/area accounting is tilemap-true: the tile compiler's placed-
block counts (padding, column splits, Bayesian replication) feed
serving/metrics.decision_energy, reported next to the logical-tile
number it replaces.

Outputs:
  * CSV rows through benchmarks/run.py (``bench()``),
  * a JSON report (per-instance rows + aggregates) at
    artifacts/hw_variation/report.json — uploaded as a CI artifact.

Env knobs (CI smoke): HW_VARIATION_INSTANCES (default 16),
HW_VARIATION_SEVERITIES (comma floats, default "1.0,2.5"),
HW_VARIATION_TRUNK ("nonideal" | "ideal"),
HW_VARIATION_AGE_S (simulated field-seconds for the aged arms,
default 30 days; 0 skips them).  The aged arms measure the die
LIFETIME story (hw/aging + hw/redeploy): ``aged_stale`` serves the
birth-calibrated head on the aged physics (what an unmonitored fleet
degrades to), ``aged_healed`` re-runs §III-B1 calibration against the
aged die (what the self-healing loop restores).

Run: PYTHONPATH=src python -m benchmarks.hw_variation [--instances N]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bayes_layer import sigma_of
from repro.core.sampling import BayesHeadConfig, logit_samples
from repro.core.uncertainty import uq_report
from repro.data.sard import SardConfig, batch_at, corrupted_batch
from repro.hw import (VariationSpec, calibration_report, compile_network,
                      golden_instance, prepare_instance_head,
                      sample_instances)
from repro.models.sar_cnn import SarCnnConfig, features
from repro.serving import TriagePolicy, decision_energy, finalize, \
    fixed_r_decide, init_stats, update_stats
from repro.serving.triage import FLAG

ART = Path("artifacts/hw_variation")
EVAL_STEP0 = 700            # past training and serving streams
EVAL_BATCH = 96
R_SAMPLES = 20
SEED = 2026
POLICY = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05)


def _n_instances() -> int:
    return int(os.environ.get("HW_VARIATION_INSTANCES", "16"))


def _severities() -> tuple[float, ...]:
    raw = os.environ.get("HW_VARIATION_SEVERITIES", "1.0,2.5")
    return tuple(float(s) for s in raw.split(","))


def _nonideal_trunk() -> bool:
    return os.environ.get("HW_VARIATION_TRUNK", "nonideal") != "ideal"


def _age_s() -> float:
    return float(os.environ.get("HW_VARIATION_AGE_S", str(30 * 86400)))


def _eval_head(head, scfg, feats, labels) -> dict:
    samples = logit_samples(head, feats, scfg, num_samples=R_SAMPLES)
    uq = uq_report(samples, labels)
    stats = init_stats(feats.shape[0], samples.shape[-1])
    fin = finalize(update_stats(stats, samples))
    flagged = float((np.asarray(fixed_r_decide(fin, POLICY)) == FLAG).mean())
    return {
        "accuracy": float(uq["accuracy"]),
        "aece": float(uq["aece"]),
        "aurc": float(uq["aurc"]),
        "mean_mutual_information": float(uq["mean_mutual_information"]),
        "flagged_fraction": flagged,
    }


def _eval_images(cfg):
    """(name, images, labels) eval sets.  Fog severity 0.3 keeps the
    corrupted stream informative (0.688 golden accuracy) rather than
    saturated at chance."""
    dcfg = SardConfig(image_size=cfg.image_size, seed=7)
    clean = batch_at(dcfg, EVAL_STEP0, EVAL_BATCH)
    fog = corrupted_batch(dcfg, EVAL_STEP0, EVAL_BATCH, "fog", 0.3)
    return [
        ("clean", clean["images"], clean["labels"]),
        ("fog", fog["images"], clean["labels"]),
    ]


def _chip_features(params, cfg, images_sets, chip):
    """(name, feats, labels) for one die's trunk.

    ``chip=None`` = the ideal-trunk mode (float convs, chip-independent
    — callers reuse one result fleet-wide).  Eager on purpose: the
    Pallas CIM kernel's jit cache keys on shapes, not the chip, so a
    fleet sweep compiles the trunk once."""
    return [(name, features(params, imgs, cfg, chip=chip), labels)
            for name, imgs, labels in images_sets]


def _assert_golden_instance_bitexact(gold_head, base_hcfg, mu, sg,
                                     golden_sets) -> None:
    """The severity-0 anchor: the GOLDEN instance (golden hash seeds,
    zero variation) pushed through the whole instance plumbing must
    reproduce the factory transform's logit samples BIT-FOR-BIT.  Any
    drift means the digital twin no longer collapses to the golden
    model at zero variation — fail the sweep loudly rather than report
    deviations against a broken reference."""
    gi = golden_instance(base_hcfg.grng)
    gi_head, gi_cfg = prepare_instance_head(mu, sg, base_hcfg, gi,
                                            calibrated=False)
    name, feats, _ = golden_sets[0]
    want = np.asarray(logit_samples(gold_head, feats, base_hcfg,
                                    num_samples=R_SAMPLES))
    got = np.asarray(logit_samples(gi_head, feats, gi_cfg,
                                   num_samples=R_SAMPLES))
    if not np.array_equal(want, got):
        raise RuntimeError(
            "golden-instance drift: prepare_instance_head on the golden "
            "die no longer reproduces the factory transform bit-for-bit "
            f"on '{name}' (max |Δ| = {np.abs(want - got).max():.3e})")


def run_sweep(n_instances: int | None = None,
              severities: tuple[float, ...] | None = None,
              calib_samples: int = 64) -> dict:
    from benchmarks.serving_bench import trained_params
    cfg = SarCnnConfig()
    params = trained_params(cfg)
    base_hcfg = BayesHeadConfig(num_samples=R_SAMPLES, mode="rank16",
                                grng=cfg.grng, compute_dtype=jnp.float32)
    n_instances = n_instances or _n_instances()
    severities = severities or _severities()
    nonideal_trunk = _nonideal_trunk()
    images_sets = _eval_images(cfg)
    mu, sg = params["head"]["mu"], sigma_of(params["head"])

    # Golden-chip reference: the characterized-die operating point every
    # deployed instance should reproduce.  "Recovery" below is measured
    # as |metric(chip) − metric(golden)| — raw ECE can accidentally dip
    # on a broken chip (a systematic logit offset deflates confidence),
    # deviation from golden cannot.  With the nonideal trunk the golden
    # trunk is the golden INSTANCE's CIM arrays (ideal gain/offset, no
    # programming error) so chip deviations isolate variation, not CIM
    # quantization.
    from repro.core.sampling import prepare_serving_head
    trunk_chip = golden_instance(base_hcfg.grng) if nonideal_trunk else None
    golden_sets = _chip_features(params, cfg, images_sets, trunk_chip)
    gold = prepare_serving_head(mu, sg, base_hcfg)
    golden = {name: _eval_head(gold, base_hcfg, f, l)
              for name, f, l in golden_sets}
    rows = [dict(severity=0.0, chip_id=-1, calibrated=True, data=name,
                 **golden[name]) for name, _, _ in golden_sets]

    _assert_golden_instance_bitexact(gold, base_hcfg, mu, sg, golden_sets)

    age_s = _age_s()
    for sev in severities:
        chips = sample_instances(SEED, n_instances,
                                 VariationSpec().scaled(sev))
        for chip in chips:
            crep = calibration_report(chip, base_hcfg.grng,
                                      n_samples=calib_samples)
            eval_sets = (_chip_features(params, cfg, images_sets, chip)
                         if nonideal_trunk else golden_sets)
            cal_head = cal_cfg = None
            for calibrated in (False, True):
                head, scfg = prepare_instance_head(
                    mu, sg, base_hcfg, chip, calibrated=calibrated,
                    n_offset_samples=calib_samples)
                if calibrated:
                    cal_head, cal_cfg = head, scfg
                for name, feats, labels in eval_sets:
                    m = _eval_head(head, scfg, feats, labels)
                    rows.append(dict(
                        severity=sev, chip_id=chip.chip_id,
                        calibrated=calibrated, data=name,
                        chip_temp_c=chip.temp_c,
                        chip_read_sigma=chip.read_sigma,
                        residual_eps=(crep.residual_eps_cal if calibrated
                                      else crep.residual_eps_uncal),
                        calib_energy_J=crep.energy_J if calibrated else 0.0,
                        acc_dev=abs(m["accuracy"]
                                    - golden[name]["accuracy"]),
                        aece_dev=abs(m["aece"] - golden[name]["aece"]),
                        flagged_dev=abs(m["flagged_fraction"]
                                        - golden[name]["flagged_fraction"]),
                        **m))
            if age_s > 0.0:
                # Lifetime arms: the same die after ``age_s`` in the
                # field (hw/aging).  The trunk is age-invariant (aging
                # scopes to the GRNG subarrays), so eval features are
                # reused; ``calibrated=None`` keeps these rows out of
                # the birth-time aggregates above.
                from repro.hw import at_age
                from repro.hw.redeploy import aged_belief_view, \
                    recalibrate
                aged = at_age(chip, age_s)
                arms = {
                    "aged_stale": aged_belief_view(
                        cal_head, cal_cfg, aged, base_hcfg.grng),
                    "aged_healed": recalibrate(
                        mu, sg, base_hcfg, aged, epoch=1,
                        n_offset_samples=calib_samples),
                }
                for arm, (head, scfg) in arms.items():
                    for name, feats, labels in eval_sets:
                        m = _eval_head(head, scfg, feats, labels)
                        rows.append(dict(
                            severity=sev, chip_id=chip.chip_id,
                            calibrated=None, arm=arm, data=name,
                            age_s=age_s, chip_imprint=aged.imprint,
                            acc_dev=abs(m["accuracy"]
                                        - golden[name]["accuracy"]),
                            aece_dev=abs(m["aece"] - golden[name]["aece"]),
                            flagged_dev=abs(
                                m["flagged_fraction"]
                                - golden[name]["flagged_fraction"]),
                            **m))

    # Aggregates: mean over instances per (severity, calibrated, data).
    agg = {}
    for sev in severities:
        for calibrated in (False, True):
            for name, _, _ in images_sets:
                sel = [r for r in rows
                       if r["severity"] == sev and r["chip_id"] >= 0
                       and r["calibrated"] == calibrated
                       and r["data"] == name]
                key = f"sev{sev}_{'cal' if calibrated else 'uncal'}_{name}"
                agg[key] = {
                    m: float(np.mean([r[m] for r in sel]))
                    for m in ("accuracy", "aece", "aurc",
                              "mean_mutual_information", "flagged_fraction",
                              "residual_eps", "acc_dev", "aece_dev",
                              "flagged_dev")}
                agg[key]["accuracy_std"] = float(
                    np.std([r["accuracy"] for r in sel]))
        if age_s > 0.0:
            for arm in ("aged_stale", "aged_healed"):
                for name, _, _ in images_sets:
                    sel = [r for r in rows
                           if r["severity"] == sev
                           and r.get("arm") == arm and r["data"] == name]
                    key = f"sev{sev}_{arm}_{name}"
                    agg[key] = {
                        m: float(np.mean([r[m] for r in sel]))
                        for m in ("accuracy", "aece", "aurc",
                                  "mean_mutual_information",
                                  "flagged_fraction", "acc_dev",
                                  "aece_dev", "flagged_dev")}
                    agg[key]["accuracy_std"] = float(
                        np.std([r["accuracy"] for r in sel]))
                    # bench()'s CSV loop reads this on every aggregate;
                    # calibration residual is meaningless for aged arms.
                    agg[key]["residual_eps"] = float("nan")

    # Deployed-area + tilemap-true per-request energy from the compiler:
    # placed blocks (padding, column splits) next to the logical-tile
    # math they replace.
    from repro.launch.serve import sar_layer_shapes
    layers = sar_layer_shapes(cfg)
    program = compile_network(layers)
    tile_report = program.report(r_samples=R_SAMPLES)
    e_placed = decision_energy(R_SAMPLES, layers, program)
    e_logical = decision_energy(R_SAMPLES, layers)
    report = {
        "n_instances": n_instances,
        "severities": list(severities),
        "eval_batch": EVAL_BATCH,
        "r_samples": R_SAMPLES,
        "trunk": "nonideal" if nonideal_trunk else "ideal",
        "age_s": age_s,
        "golden_instance_bitexact": True,
        "golden": golden,
        "tilemap": {k: v for k, v in tile_report.items()
                    if isinstance(v, (int, float))},
        "energy_per_request": {
            "placed_pJ": e_placed["energy_J"] * 1e12,
            "logical_pJ": e_logical["energy_J"] * 1e12,
            "grng_aJ": e_placed["grng_energy_aJ"],
            "area_mm2": tile_report["area_mm2"],
            "utilization": tile_report["utilization"],
            "tops_w_mm2_effective": tile_report["tops_w_mm2_effective"],
        },
        "aggregates": agg,
        "instances": rows,
    }
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "report.json").write_text(json.dumps(report, indent=1))
    return report


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    report = run_sweep()
    us = (time.time() - t0) * 1e6 / max(len(report["instances"]), 1)
    out = []
    for key, a in sorted(report["aggregates"].items()):
        out.append((f"hw_variation_{key}", us,
                    f"acc={a['accuracy']:.3f}±{a['accuracy_std']:.3f};"
                    f"aece={a['aece']:.3f};"
                    f"flagged={a['flagged_fraction']:.3f};"
                    f"acc_dev={a['acc_dev']:.3f};"
                    f"aece_dev={a['aece_dev']:.3f};"
                    f"resid_eps={a['residual_eps']:.4f}"))
    # Headline: deviation from the golden operating point that
    # per-instance calibration removes, at the top severity.
    sev = max(report["severities"])
    u = report["aggregates"][f"sev{sev}_uncal_clean"]
    c = report["aggregates"][f"sev{sev}_cal_clean"]
    out.append(("hw_variation_recovery", 0.0,
                f"sev={sev};acc_dev={u['acc_dev']:.3f}->{c['acc_dev']:.3f};"
                f"aece_dev={u['aece_dev']:.3f}->{c['aece_dev']:.3f};"
                f"flagged_dev={u['flagged_dev']:.3f}->"
                f"{c['flagged_dev']:.3f};"
                f"json={ART / 'report.json'}"))
    # Lifetime: what the self-healing loop buys back on a die aged
    # report["age_s"] in the field (stale birth calibration vs a
    # recalibrate-and-redeploy against the aged physics).
    if report["age_s"] > 0.0:
        st = report["aggregates"][f"sev{sev}_aged_stale_clean"]
        he = report["aggregates"][f"sev{sev}_aged_healed_clean"]
        out.append(("hw_variation_aged_recovery", 0.0,
                    f"sev={sev};age_s={report['age_s']:.0f};"
                    f"acc_dev={st['acc_dev']:.3f}->{he['acc_dev']:.3f};"
                    f"aece_dev={st['aece_dev']:.3f}->"
                    f"{he['aece_dev']:.3f};"
                    f"flagged_dev={st['flagged_dev']:.3f}->"
                    f"{he['flagged_dev']:.3f}"))
    e = report["energy_per_request"]
    out.append(("hw_variation_energy", 0.0,
                f"trunk={report['trunk']};"
                f"placed_pJ={e['placed_pJ']:.1f};"
                f"logical_pJ={e['logical_pJ']:.1f};"
                f"util={e['utilization']:.3f};"
                f"tops_w_mm2_eff={e['tops_w_mm2_effective']:.1f};"
                f"golden_bitexact={report['golden_instance_bitexact']}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=None)
    ap.add_argument("--severities", type=str, default=None,
                    help="comma-separated severity multipliers")
    args = ap.parse_args()
    if args.instances:
        os.environ["HW_VARIATION_INSTANCES"] = str(args.instances)
    if args.severities:
        os.environ["HW_VARIATION_SEVERITIES"] = args.severities
    for row in bench():
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
