"""Monte-Carlo chip-variation sweep: does calibration hold the fleet?

The paper characterizes ONE die.  A deployment ships a population, and
the question that decides deployed accuracy (cf. Bayes2IMC / FeBiM) is
whether per-instance calibration — the paper's own §III-B1 measurement,
re-run per chip (hw/calib.py) — recovers the golden-chip operating
point across process corner, temperature, read noise, and programming
error.  This benchmark samples ≥16 chip instances per severity level,
deploys the SAME trained SAR Bayesian-head CNN onto each twice
(golden factory transform vs per-instance recalibration), and measures
accuracy / adaptive-ECE / mutual information / flagged fraction on
clean and fog-corrupted SARD streams.

The conv trunk runs ideal (the head is the paper's Bayesian story and
the variation target); per-chip degradation enters through the GRNG
arrays, the standardization constants, and conductance programming
noise on the stored (µ', σ).

Outputs:
  * CSV rows through benchmarks/run.py (``bench()``),
  * a JSON report (per-instance rows + aggregates) at
    artifacts/hw_variation/report.json — uploaded as a CI artifact.

Env knobs (CI smoke): HW_VARIATION_INSTANCES (default 16),
HW_VARIATION_SEVERITIES (comma floats, default "1.0,2.5").

Run: PYTHONPATH=src python -m benchmarks.hw_variation [--instances N]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bayes_layer import sigma_of
from repro.core.sampling import BayesHeadConfig, logit_samples
from repro.core.uncertainty import uq_report
from repro.data.sard import SardConfig, batch_at, corrupted_batch
from repro.hw import (VariationSpec, calibration_report, compile_network,
                      prepare_instance_head, sample_instances)
from repro.models.sar_cnn import SarCnnConfig, features
from repro.serving import TriagePolicy, finalize, fixed_r_decide, init_stats, \
    update_stats
from repro.serving.triage import FLAG

ART = Path("artifacts/hw_variation")
EVAL_STEP0 = 700            # past training and serving streams
EVAL_BATCH = 96
R_SAMPLES = 20
SEED = 2026
POLICY = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05)


def _n_instances() -> int:
    return int(os.environ.get("HW_VARIATION_INSTANCES", "16"))


def _severities() -> tuple[float, ...]:
    raw = os.environ.get("HW_VARIATION_SEVERITIES", "1.0,2.5")
    return tuple(float(s) for s in raw.split(","))


def _eval_head(head, scfg, feats, labels) -> dict:
    samples = logit_samples(head, feats, scfg, num_samples=R_SAMPLES)
    uq = uq_report(samples, labels)
    stats = init_stats(feats.shape[0], samples.shape[-1])
    fin = finalize(update_stats(stats, samples))
    flagged = float((np.asarray(fixed_r_decide(fin, POLICY)) == FLAG).mean())
    return {
        "accuracy": float(uq["accuracy"]),
        "aece": float(uq["aece"]),
        "aurc": float(uq["aurc"]),
        "mean_mutual_information": float(uq["mean_mutual_information"]),
        "flagged_fraction": flagged,
    }


def _eval_sets(params, cfg):
    """(name, feats, labels) eval sets — trunk is chip-independent, so
    features are computed once and reused across the whole fleet.  Fog
    severity 0.3 keeps the corrupted stream informative (0.688 golden
    accuracy) rather than saturated at chance."""
    dcfg = SardConfig(image_size=cfg.image_size, seed=7)
    clean = batch_at(dcfg, EVAL_STEP0, EVAL_BATCH)
    fog = corrupted_batch(dcfg, EVAL_STEP0, EVAL_BATCH, "fog", 0.3)
    return [
        ("clean", features(params, clean["images"], cfg), clean["labels"]),
        ("fog", features(params, fog["images"], cfg), clean["labels"]),
    ]


def run_sweep(n_instances: int | None = None,
              severities: tuple[float, ...] | None = None,
              calib_samples: int = 64) -> dict:
    from benchmarks.serving_bench import trained_params
    cfg = SarCnnConfig()
    params = trained_params(cfg)
    base_hcfg = BayesHeadConfig(num_samples=R_SAMPLES, mode="rank16",
                                grng=cfg.grng, compute_dtype=jnp.float32)
    n_instances = n_instances or _n_instances()
    severities = severities or _severities()
    eval_sets = _eval_sets(params, cfg)
    mu, sg = params["head"]["mu"], sigma_of(params["head"])

    # Golden-chip reference: the characterized-die operating point every
    # deployed instance should reproduce.  "Recovery" below is measured
    # as |metric(chip) − metric(golden)| — raw ECE can accidentally dip
    # on a broken chip (a systematic logit offset deflates confidence),
    # deviation from golden cannot.
    from repro.core.sampling import prepare_serving_head
    gold = prepare_serving_head(mu, sg, base_hcfg)
    golden = {name: _eval_head(gold, base_hcfg, f, l)
              for name, f, l in eval_sets}
    rows = [dict(severity=0.0, chip_id=-1, calibrated=True, data=name,
                 **golden[name]) for name, _, _ in eval_sets]

    for sev in severities:
        chips = sample_instances(SEED, n_instances,
                                 VariationSpec().scaled(sev))
        for chip in chips:
            crep = calibration_report(chip, base_hcfg.grng,
                                      n_samples=calib_samples)
            for calibrated in (False, True):
                head, scfg = prepare_instance_head(
                    mu, sg, base_hcfg, chip, calibrated=calibrated,
                    n_offset_samples=calib_samples)
                for name, feats, labels in eval_sets:
                    m = _eval_head(head, scfg, feats, labels)
                    rows.append(dict(
                        severity=sev, chip_id=chip.chip_id,
                        calibrated=calibrated, data=name,
                        chip_temp_c=chip.temp_c,
                        chip_read_sigma=chip.read_sigma,
                        residual_eps=(crep.residual_eps_cal if calibrated
                                      else crep.residual_eps_uncal),
                        calib_energy_J=crep.energy_J if calibrated else 0.0,
                        acc_dev=abs(m["accuracy"]
                                    - golden[name]["accuracy"]),
                        aece_dev=abs(m["aece"] - golden[name]["aece"]),
                        flagged_dev=abs(m["flagged_fraction"]
                                        - golden[name]["flagged_fraction"]),
                        **m))

    # Aggregates: mean over instances per (severity, calibrated, data).
    agg = {}
    for sev in severities:
        for calibrated in (False, True):
            for name, _, _ in eval_sets:
                sel = [r for r in rows
                       if r["severity"] == sev and r["chip_id"] >= 0
                       and r["calibrated"] == calibrated
                       and r["data"] == name]
                key = f"sev{sev}_{'cal' if calibrated else 'uncal'}_{name}"
                agg[key] = {
                    m: float(np.mean([r[m] for r in sel]))
                    for m in ("accuracy", "aece", "aurc",
                              "mean_mutual_information", "flagged_fraction",
                              "residual_eps", "acc_dev", "aece_dev",
                              "flagged_dev")}
                agg[key]["accuracy_std"] = float(
                    np.std([r["accuracy"] for r in sel]))

    # Deployed-area context from the tile compiler.
    from repro.launch.serve import sar_layer_shapes
    tile_report = compile_network(sar_layer_shapes(cfg)).report(
        r_samples=R_SAMPLES)
    report = {
        "n_instances": n_instances,
        "severities": list(severities),
        "eval_batch": EVAL_BATCH,
        "r_samples": R_SAMPLES,
        "golden": golden,
        "tilemap": {k: v for k, v in tile_report.items()
                    if isinstance(v, (int, float))},
        "aggregates": agg,
        "instances": rows,
    }
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "report.json").write_text(json.dumps(report, indent=1))
    return report


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    report = run_sweep()
    us = (time.time() - t0) * 1e6 / max(len(report["instances"]), 1)
    out = []
    for key, a in sorted(report["aggregates"].items()):
        out.append((f"hw_variation_{key}", us,
                    f"acc={a['accuracy']:.3f}±{a['accuracy_std']:.3f};"
                    f"aece={a['aece']:.3f};"
                    f"flagged={a['flagged_fraction']:.3f};"
                    f"acc_dev={a['acc_dev']:.3f};"
                    f"aece_dev={a['aece_dev']:.3f};"
                    f"resid_eps={a['residual_eps']:.4f}"))
    # Headline: deviation from the golden operating point that
    # per-instance calibration removes, at the top severity.
    sev = max(report["severities"])
    u = report["aggregates"][f"sev{sev}_uncal_clean"]
    c = report["aggregates"][f"sev{sev}_cal_clean"]
    out.append(("hw_variation_recovery", 0.0,
                f"sev={sev};acc_dev={u['acc_dev']:.3f}->{c['acc_dev']:.3f};"
                f"aece_dev={u['aece_dev']:.3f}->{c['aece_dev']:.3f};"
                f"flagged_dev={u['flagged_dev']:.3f}->"
                f"{c['flagged_dev']:.3f};"
                f"json={ART / 'report.json'}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=None)
    ap.add_argument("--severities", type=str, default=None,
                    help="comma-separated severity multipliers")
    args = ap.parse_args()
    if args.instances:
        os.environ["HW_VARIATION_INSTANCES"] = str(args.instances)
    if args.severities:
        os.environ["HW_VARIATION_SEVERITIES"] = args.severities
    for row in bench():
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
