"""Kernel-level benchmarks: sampling-mode FLOP scaling + interpret-mode
wall time.

The headline claim of the rank16 path: logit-sample cost is independent
of R (16 basis MVMs + a rank-16 mixing matmul) versus the paper
dataflow's R σε MVMs.  We verify by compiling both modes at several R
and counting loop-aware HLO FLOPs — the crossover should sit at R≈17.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.clt_grng import GRNGConfig
from repro.core.sampling import (BayesHeadConfig, logit_samples_paper,
                                 logit_samples_rank16, prepare_serving_head)
from repro.launch.hlo_analysis import analyze

B, K, N = 8, 512, 2048


def _flops(fn, head, x) -> float:
    compiled = jax.jit(fn).lower(head, x).compile()
    return analyze(compiled.as_text(), 1)["flops_per_device"]


def bench() -> list[tuple[str, float, str]]:
    cfg0 = GRNGConfig()
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    head = {"mu_prime": jax.random.normal(k1, (K, N)) * 0.02,
            "sigma": jax.nn.softplus(jax.random.normal(k2, (K, N)) - 3) * 0.1}
    x = jax.random.normal(k3, (B, K))
    out = []
    for r in (4, 16, 20, 64):
        hcfg = BayesHeadConfig(num_samples=r, grng=cfg0,
                               compute_dtype=jnp.float32)
        t0 = time.time()
        f_paper = _flops(
            lambda h, xx: logit_samples_paper(h, xx, hcfg), head, x)
        f_rank = _flops(
            lambda h, xx: logit_samples_rank16(h, xx, hcfg), head, x)
        dt_us = (time.time() - t0) * 1e6
        out.append((f"kernel_mode_flops_R{r}", dt_us,
                    f"paper={f_paper:.3e};rank16={f_rank:.3e};"
                    f"speedup={f_paper / f_rank:.2f}x"))

    # basis hoisting: decode-loop FLOPs with the 16 σ⊙I_j matrices
    # precomputed at deployment (prepare_serving_head hoist_basis) vs
    # rehashed per call — the serving engine reuses them every step.
    import dataclasses
    hcfg = BayesHeadConfig(num_samples=8, grng=cfg0,
                           compute_dtype=jnp.float32)
    hcfg_h = dataclasses.replace(hcfg, hoist_basis=True)
    mu_r = jax.random.normal(k1, (K, N)) * 0.02
    sg_r = jax.nn.softplus(jax.random.normal(k2, (K, N)) - 3) * 0.1
    head_hoist = prepare_serving_head(mu_r, sg_r, hcfg_h)
    t0 = time.time()
    f_rehash = _flops(
        lambda h, xx: logit_samples_rank16(h, xx, hcfg), head, x)
    f_hoist = _flops(
        lambda h, xx: logit_samples_rank16(h, xx, hcfg_h), head_hoist, x)
    out.append(("kernel_basis_hoist_flops_R8", (time.time() - t0) * 1e6,
                f"rehash={f_rehash:.3e};hoisted={f_hoist:.3e};"
                f"saving={f_rehash / f_hoist:.2f}x"))

    def _wall(fn, *args, reps=20):
        fn(*args)[0].block_until_ready()   # compile + warm
        t0 = time.time()
        for _ in range(reps):
            r = fn(*args)
        jax.tree.map(lambda a: a.block_until_ready(), r)
        return (time.time() - t0) * 1e6 / reps

    j_rehash = jax.jit(lambda h, xx: logit_samples_rank16(h, xx, hcfg))
    j_hoist = jax.jit(lambda h, xx: logit_samples_rank16(h, xx, hcfg_h))
    us_rehash = _wall(j_rehash, head, x)
    us_hoist = _wall(j_hoist, head_hoist, x)
    out.append(("kernel_basis_hoist_walltime", us_hoist,
                f"rehash_us={us_rehash:.1f};hoisted_us={us_hoist:.1f};"
                f"speedup={us_rehash / us_hoist:.2f}x"))

    # interpret-mode wall time of the fused Pallas kernel vs oracle
    from repro.kernels import ops, ref
    xs = jax.random.normal(k3, (4, 256))
    mu = jax.random.normal(k1, (256, 256)) * 0.02
    sg = jax.nn.softplus(jax.random.normal(k2, (256, 256)) - 3) * 0.1
    for name, fn in (
        ("pallas_rank16", lambda: ops.bayes_head_mvm(
            xs, mu, sg, cfg0, 8, mode="rank16", interpret=True)),
        ("oracle_jnp", lambda: ref.bayes_mvm_ref(xs, mu, sg, cfg0, 8)),
    ):
        fn()  # warm
        t0 = time.time()
        fn().block_until_ready()
        out.append((f"kernel_walltime_{name}", (time.time() - t0) * 1e6,
                    "interpret_mode_cpu"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
