"""Kernel-level benchmarks: sampling-mode FLOP scaling + interpret-mode
wall time + the fused decision kernel's memory/footprint claim.

The headline claim of the rank16 path: logit-sample cost is independent
of R (16 basis MVMs + a rank-16 mixing matmul) versus the paper
dataflow's R σε MVMs.  We verify by compiling both modes at several R
and counting loop-aware HLO FLOPs — the crossover should sit at R≈17.

The decision-kernel section compiles the fused sample→statistics round
(kernels/decision_kernel.py) against the materializing
``mix_samples → update_stats`` composition and reports wall time plus
the largest live array of each compiled program — the fused path must
not carry an R·B·N term.  All rows land in repo-root
``BENCH_kernels.json`` (uploaded as a CI artifact) so the kernel perf
trajectory is tracked PR over PR.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.clt_grng import GRNGConfig
from repro.core.sampling import (BayesHeadConfig, logit_samples_paper,
                                 logit_samples_rank16, prepare_serving_head)
from repro.launch.hlo_analysis import analyze

B, K, N = 8, 512, 2048
BENCH_JSON = Path("BENCH_kernels.json")

# per-row warm/cold split, merged into BENCH_kernels.json: the CSV
# ``us_per_call`` column is the WARM (steady-state, compiled) figure;
# ``us_per_call_cold`` is the one-time compile+first-call overhead.
# History comparisons gate on warm — folding a ~550 ms compile into a
# per-call number made every run look identically slow.
_EXTRAS: dict[str, dict] = {}


def _aot(fn, *args):
    """(compiled, cold_us): AOT compile wall time is the cold cost."""
    t0 = time.time()
    compiled = jax.jit(fn).lower(*args).compile()
    return compiled, (time.time() - t0) * 1e6


def _warm_us(call, *args, reps: int = 20) -> float:
    call(*args)                                    # warm / ensure ready
    t0 = time.time()
    for _ in range(reps):
        r = call(*args)
    jax.tree.map(lambda a: a.block_until_ready(), r)
    return (time.time() - t0) * 1e6 / reps


def _flops(fn, head, x) -> float:
    compiled = jax.jit(fn).lower(head, x).compile()
    return analyze(compiled.as_text(), 1)["flops_per_device"]


def bench() -> list[tuple[str, float, str]]:
    _EXTRAS.clear()
    cfg0 = GRNGConfig()
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    head = {"mu_prime": jax.random.normal(k1, (K, N)) * 0.02,
            "sigma": jax.nn.softplus(jax.random.normal(k2, (K, N)) - 3) * 0.1}
    x = jax.random.normal(k3, (B, K))
    out = []
    for r in (4, 16, 20, 64):
        hcfg = BayesHeadConfig(num_samples=r, grng=cfg0,
                               compute_dtype=jnp.float32)
        c_paper, cold_paper = _aot(
            lambda h, xx: logit_samples_paper(h, xx, hcfg), head, x)
        c_rank, cold_rank = _aot(
            lambda h, xx: logit_samples_rank16(h, xx, hcfg), head, x)
        f_paper = analyze(c_paper.as_text(), 1)["flops_per_device"]
        f_rank = analyze(c_rank.as_text(), 1)["flops_per_device"]
        warm_us = _warm_us(c_rank, head, x)
        name = f"kernel_mode_flops_R{r}"
        _EXTRAS[name] = {"us_per_call_warm": warm_us,
                         "us_per_call_cold": cold_rank,
                         "us_compile_paper": cold_paper}
        out.append((name, warm_us,
                    f"paper={f_paper:.3e};rank16={f_rank:.3e};"
                    f"speedup={f_paper / f_rank:.2f}x;"
                    f"warm_us={warm_us:.1f};cold_us={cold_rank:.0f}"))

    # basis hoisting: decode-loop FLOPs with the 16 σ⊙I_j matrices
    # precomputed at deployment (prepare_serving_head hoist_basis) vs
    # rehashed per call — the serving engine reuses them every step.
    import dataclasses
    hcfg = BayesHeadConfig(num_samples=8, grng=cfg0,
                           compute_dtype=jnp.float32)
    hcfg_h = dataclasses.replace(hcfg, hoist_basis=True)
    mu_r = jax.random.normal(k1, (K, N)) * 0.02
    sg_r = jax.nn.softplus(jax.random.normal(k2, (K, N)) - 3) * 0.1
    head_hoist = prepare_serving_head(mu_r, sg_r, hcfg_h)
    t0 = time.time()
    f_rehash = _flops(
        lambda h, xx: logit_samples_rank16(h, xx, hcfg), head, x)
    f_hoist = _flops(
        lambda h, xx: logit_samples_rank16(h, xx, hcfg_h), head_hoist, x)
    out.append(("kernel_basis_hoist_flops_R8", (time.time() - t0) * 1e6,
                f"rehash={f_rehash:.3e};hoisted={f_hoist:.3e};"
                f"saving={f_rehash / f_hoist:.2f}x"))

    j_rehash, cold_rehash = _aot(
        lambda h, xx: logit_samples_rank16(h, xx, hcfg), head, x)
    j_hoist, cold_hoist = _aot(
        lambda h, xx: logit_samples_rank16(h, xx, hcfg_h), head_hoist, x)
    us_rehash = _warm_us(j_rehash, head, x)
    us_hoist = _warm_us(j_hoist, head_hoist, x)
    _EXTRAS["kernel_basis_hoist_walltime"] = {
        "us_per_call_warm": us_hoist, "us_per_call_cold": cold_hoist}
    out.append(("kernel_basis_hoist_walltime", us_hoist,
                f"rehash_us={us_rehash:.1f};hoisted_us={us_hoist:.1f};"
                f"speedup={us_rehash / us_hoist:.2f}x;"
                f"cold_us={cold_hoist:.0f}"))

    # interpret-mode wall time of the fused Pallas kernel vs oracle
    from repro.kernels import ops, ref
    xs = jax.random.normal(k3, (4, 256))
    mu = jax.random.normal(k1, (256, 256)) * 0.02
    sg = jax.nn.softplus(jax.random.normal(k2, (256, 256)) - 3) * 0.1
    for name, fn in (
        ("pallas_rank16", lambda: ops.bayes_head_mvm(
            xs, mu, sg, cfg0, 8, mode="rank16", interpret=True)),
        ("oracle_jnp", lambda: ref.bayes_mvm_ref(xs, mu, sg, cfg0, 8)),
    ):
        t0 = time.time()
        fn().block_until_ready()                    # compile + first call
        cold_us = (time.time() - t0) * 1e6
        t0 = time.time()
        fn().block_until_ready()
        warm_us = (time.time() - t0) * 1e6
        _EXTRAS[f"kernel_walltime_{name}"] = {
            "us_per_call_warm": warm_us, "us_per_call_cold": cold_us}
        out.append((f"kernel_walltime_{name}", warm_us,
                    f"interpret_mode_cpu;cold_us={cold_us:.0f}"))

    out.extend(_decision_kernel_rows())
    BENCH_JSON.write_text(json.dumps(
        {"rows": [dict({"name": n, "us_per_call": us, "derived": d},
                       **_EXTRAS.get(n, {}))
                  for n, us, d in out]},
        indent=2, sort_keys=True))
    return out


def _decision_kernel_rows() -> list[tuple[str, float, str]]:
    """Fused decision round vs the materializing composition: wall time
    (interpret-mode CPU) and the largest live array of each compiled
    program (the R·B·N claim, quantified)."""
    from repro.launch.hlo_analysis import largest_intermediate_bytes
    from repro.serving import adaptive
    from repro.core.sampling import (activation_basis, mix_samples,
                                     prepare_serving_head)

    b, k, n, r = 8, 128, 512, 8
    cfg0 = GRNGConfig()
    hcfg = BayesHeadConfig(num_samples=r, grng=cfg0,
                           compute_dtype=jnp.float32, hoist_basis=True)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    mu = jax.random.normal(k1, (k, n)) * 0.05
    sg = jax.nn.softplus(jax.random.normal(k2, (k, n)) - 3) * 0.1
    head = prepare_serving_head(mu, sg, hcfg)
    x = jax.random.normal(k3, (b, k))
    ab = activation_basis(head, x, hcfg)
    sel = jax.numpy.asarray(
        adaptive.stream_selections(cfg0, jnp.zeros((b,), jnp.uint32),
                                   jnp.zeros((b,), jnp.int32), r))
    idx = adaptive.stream_indices(jnp.zeros((b,), jnp.uint32),
                                  jnp.zeros((b,), jnp.int32), r)
    stats0 = adaptive.init_stats(b, n)

    from repro.kernels.ops import decision_update

    def fused(stats, ab, sel, idx):
        return decision_update(stats, ab, sel, cfg0, sample_idx=idx,
                               interpret=True)

    def materializing(stats, ab, sel, idx):
        return adaptive.update_stats(
            stats, mix_samples(ab, sel, hcfg, sample_idx=idx))

    rows = []
    for name, fn in (("fused", fused), ("materializing", materializing)):
        compiled, cold_us = _aot(fn, stats0, ab, sel, idx)
        txt = compiled.as_text()
        jf = jax.jit(fn)
        jf(stats0, ab, sel, idx)["sum_p"].block_until_ready()   # warm
        t0 = time.time()
        for _ in range(5):
            res = jf(stats0, ab, sel, idx)
        res["sum_p"].block_until_ready()
        us = (time.time() - t0) * 1e6 / 5
        row_name = f"kernel_decision_{name}"
        _EXTRAS[row_name] = {"us_per_call_warm": us,
                             "us_per_call_cold": cold_us}
        rows.append((
            row_name, us,
            f"B={b};N={n};R={r};interpret_mode_cpu;"
            f"peak_live_bytes={largest_intermediate_bytes(txt):.0f};"
            f"warm_us={us:.1f};cold_us={cold_us:.0f}"))

    # the memory claim, quantified: sweep R and watch the largest live
    # array — the fused round is R-INDEPENDENT (bounded by the B·N·16
    # basis), the materializing round grows linearly with its [R, B, N]
    # sample tensor.
    for name, fn in (("fused", fused), ("materializing", materializing)):
        peaks = []
        for r_k in (8, 32, 64):
            sel_k = adaptive.stream_selections(
                cfg0, jnp.zeros((b,), jnp.uint32),
                jnp.zeros((b,), jnp.int32), r_k)
            idx_k = adaptive.stream_indices(
                jnp.zeros((b,), jnp.uint32), jnp.zeros((b,), jnp.int32),
                r_k)
            txt = jax.jit(fn).lower(stats0, ab, sel_k,
                                    idx_k).compile().as_text()
            peaks.append(largest_intermediate_bytes(txt))
        rows.append((
            f"kernel_decision_peak_vs_R_{name}", 0.0,
            ";".join(f"R{r_k}={p:.0f}B"
                     for r_k, p in zip((8, 32, 64), peaks))
            + f";growth={peaks[-1] / max(peaks[0], 1):.2f}x"))
    return rows


if __name__ == "__main__":
    rows = bench()
    for row in rows:
        print(",".join(str(x) for x in row))
    from benchmarks import history
    history.record_rows("kernel_bench", rows)
