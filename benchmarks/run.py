"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Modules:
  table1_comparison  Table I   accelerator metrics (derived vs paper)
  fig2_overhead      Fig. 2    BNN energy overhead vs R
  fig9_distribution  Fig. 9/10 GRNG distribution quality + selection net
  sec5a_energy       SecV-A    tile energy/latency/endurance breakdown
  fig16_uq           Fig.16    SARD accuracy + UQ (CNN vs BNN vs CLT)
  table2_corr        Fig.17/II corruption robustness
  kernel_bench       --        rank16-vs-paper FLOP scaling, kernels
  serving_bench      --        adaptive-R vs fixed-R serving engine
  fleet_bench        --        mesh-of-pools fleet scaling sweep
                               (BENCH_fleet, 8 simulated devices)
  hw_variation       --        chip-instance MC sweep, cal vs uncal
  mission_bench      --        closed-loop SAR mission (BENCH_mission)
  lifetime_bench     --        FeFET aging + self-healing redeploy
                               (BENCH_lifetime)
  roofline           --        decision-path roofline (always) +
                               3-term roofline over dry-run artifacts

Run:   PYTHONPATH=src python -m benchmarks.run [--only <m>] [--fast|--all]
(or:   PYTHONPATH=src python benchmarks/run.py ... — both entry forms
register the whole suite).  The default run skips nothing but honours
historical behaviour; ``--fast`` skips the model-training benches,
``--all`` forces every registered module even under ``--fast``.

Every module's rows are also appended as one schema-versioned record
(git SHA + backend fingerprint) to repo-root ``BENCH_history.jsonl``
(benchmarks/history.py); ``--no-history`` suppresses that.
"""

from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path

if __package__ in (None, ""):                    # `python benchmarks/run.py`
    _root = Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))       # repro.* without PYTHONPATH

MODULES = [
    "table1_comparison",
    "fig2_overhead",
    "fig9_distribution",
    "sec5a_energy",
    "kernel_bench",
    "serving_bench",
    "slo_bench",
    "fleet_bench",
    "hw_variation",
    "fig16_uq",
    "table2_corr",
    "mission_bench",
    "lifetime_bench",
    "roofline",
]
FAST_SKIP = {"fig16_uq", "table2_corr", "serving_bench",
             "slo_bench", "fleet_bench", "hw_variation",
             "mission_bench", "lifetime_bench"}  # SAR training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=MODULES)
    ap.add_argument("--fast", action="store_true",
                    help="skip benchmarks that train models")
    ap.add_argument("--all", action="store_true",
                    help="run every registered module (overrides --fast)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip appending run records to "
                         "BENCH_history.jsonl")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        if args.only and mod_name != args.only:
            continue
        if args.fast and not args.all and mod_name in FAST_SKIP:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["bench"])
            rows = list(mod.bench())
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            if not args.no_history:
                from benchmarks import history
                history.record_rows(mod_name, rows)
        except Exception:  # noqa: BLE001
            failures.append(mod_name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED modules: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
