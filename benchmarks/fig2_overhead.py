"""Paper Fig. 2: BNN inference overhead vs sample count R.

Digital baseline: 6.2·R× energy per INT8 op on Bayesian layers [20].
This work: X·µ once + R σε-subarray MVMs — overhead (688 + 230·R)/688
per Bayesian tile, plus the 640 aJ/sample GRNG.  Evaluated on the
paper's deployment (YOLO-scale layer stack, last layer Bayesian).
"""

from __future__ import annotations

import time

from repro.core import energy as E


def _deploy_layers():
    # paper deployment proxy: deterministic trunk + Bayesian last layer
    trunk = [E.LayerShape(1152, 1024), E.LayerShape(1024, 1024),
             E.LayerShape(1024, 512)]
    head = [E.LayerShape(512, 1536, bayesian=True)]
    return trunk + head


def bench() -> list[tuple[str, float, str]]:
    t0 = time.time()
    layers = _deploy_layers()
    out = []
    for r in (1, 5, 10, 20, 50):
        ours = E.inference_energy(layers, r_samples=r)["energy_J"]
        base = E.inference_energy(layers, r_samples=1)["energy_J"]
        digital = E.digital_baseline_energy(layers, r_samples=r)
        out.append((f"fig2_overhead_R{r}", 0.0,
                    f"ours={ours/base:.2f}x;digital={digital/base:.1f}x"))
    dt_us = (time.time() - t0) * 1e6
    out = [(n, dt_us / len(out), d) for n, _, d in out]
    # headline at paper's R=20
    ours20 = E.inference_energy(layers, 20)["energy_J"]
    dig20 = E.digital_baseline_energy(layers, 20)
    out.append(("fig2_gain_vs_digital_R20", 0.0, f"{dig20/ours20:.1f}x"))
    return out


if __name__ == "__main__":
    for row in bench():
        print(",".join(str(x) for x in row))
