"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import clt_grng as g
from repro.core import quant as q
from repro.core.lfsr import (indexed_selections, lfsr_states, swapper_select)
from repro.core.uncertainty import (adaptive_calibration_errors, aurc,
                                    risk_coverage_curve)

SETTINGS = dict(max_examples=25, deadline=None)


# ----------------------------------------------------------------------
# selection network invariants
# ----------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(min_value=1, max_value=0xFFFF))
def test_swapper_always_selects_exactly_8(state):
    sel = swapper_select(jnp.uint32(state))
    assert float(sel.sum()) == 8.0
    assert set(np.asarray(sel).tolist()) <= {0.0, 1.0}


@settings(**SETTINGS)
@given(st.integers(min_value=1, max_value=0xFFFF),
       st.integers(min_value=1, max_value=200))
def test_lfsr_never_hits_zero_and_cycles(seed, steps):
    states = np.asarray(lfsr_states(seed, steps))
    assert (states != 0).all()
    assert (states <= 0xFFFF).all()


@settings(**SETTINGS)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_indexed_selections_exactly_8(idx):
    sel = indexed_selections(0xACE1, jnp.uint32(idx))
    assert float(sel.sum()) == 8.0


# ----------------------------------------------------------------------
# CLT-GRNG invariants
# ----------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(min_value=0, max_value=2**20),
       st.integers(min_value=1, max_value=6))
def test_eps_deterministic_and_seed_sensitive(seed, r):
    cfg = g.GRNGConfig(seed=seed)
    a = g.eps(cfg, 8, 8, r)
    b = g.eps(cfg, 8, 8, r)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    other = g.eps(g.GRNGConfig(seed=seed + 1), 8, 8, r)
    assert not np.array_equal(np.asarray(a), np.asarray(other))


@settings(**SETTINGS)
@given(st.integers(min_value=0, max_value=2**20))
def test_device_currents_positive_and_bounded(seed):
    cfg = g.GRNGConfig(seed=seed)
    cur = np.asarray(g.device_currents_grid(cfg, 16, 16))
    assert (cur > 0).all()
    assert (cur < cfg.i_lo + cfg.delta_i + 4 * cfg.gamma).all()


@settings(**SETTINGS)
@given(st.integers(min_value=0, max_value=2**20),
       st.integers(min_value=1, max_value=48),
       st.integers(min_value=1, max_value=48))
def test_stream_extension_exact_on_aged_dies(seed, n1, n2):
    """sample0 stream extension stays EXACT with the aging imprint
    term live: drawing (n1 + n2) samples in one call equals drawing n1
    then extending by n2 — the telemetry probe and the engine's
    escalation rounds rely on this on aged physics too."""
    cfg = g.GRNGConfig(seed=seed, imprint=0.37, imprint_seed=seed ^ 0xA6)
    whole = np.asarray(g.raw_sums(cfg, 4, 2, n1 + n2))
    parts = np.concatenate(
        [np.asarray(g.raw_sums(cfg, 4, 2, n1)),
         np.asarray(g.raw_sums(cfg, 4, 2, n2, sample0=n1))], axis=0)
    np.testing.assert_array_equal(whole, parts)


@settings(**SETTINGS)
@given(st.integers(min_value=0, max_value=2**20))
def test_imprint_zero_is_bit_identical(seed):
    """imprint=0.0 must compile to the PRE-AGING program: the aged-die
    term cannot perturb a single bit of any existing stream."""
    base = g.GRNGConfig(seed=seed)
    with_field = g.GRNGConfig(seed=seed, imprint=0.0,
                              imprint_seed=seed ^ 0x1234)
    np.testing.assert_array_equal(np.asarray(g.raw_sums(base, 4, 4, 16)),
                                  np.asarray(g.raw_sums(with_field,
                                                        4, 4, 16)))
    nonzero = g.GRNGConfig(seed=seed, imprint=0.25)
    assert not np.array_equal(np.asarray(g.raw_sums(base, 4, 4, 16)),
                              np.asarray(g.raw_sums(nonzero, 4, 4, 16)))


def test_raw_sum_subset_bounds():
    """Any 8-of-16 sum lies between the 8 smallest and 8 largest currents."""
    cfg = g.GRNGConfig()
    cur = np.asarray(g.device_currents_grid(cfg, 4, 4))      # [4,4,16]
    raw = np.asarray(g.raw_sums(cfg, 4, 4, 32))              # [32,4,4]
    lo = np.sort(cur, axis=-1)[..., :8].sum(-1)
    hi = np.sort(cur, axis=-1)[..., 8:].sum(-1)
    assert (raw >= lo[None] - 1e-4).all()
    assert (raw <= hi[None] + 1e-4).all()


# ----------------------------------------------------------------------
# quantization invariants
# ----------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(min_value=2, max_value=8), st.integers(0, 2**16))
def test_fake_quant_idempotent_and_bounded(bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    scale = q.symmetric_scale(x, bits)
    xq = q.fake_quant(x, scale, bits)
    xqq = q.fake_quant(xq, scale, bits)
    np.testing.assert_allclose(np.asarray(xq), np.asarray(xqq), atol=1e-6)
    assert float(jnp.abs(xq - x).max()) <= float(scale) * 0.5 + 1e-6


@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_adc_quantize_monotone(seed):
    x = np.sort(np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (64,))))
    cfg = q.QuantConfig()
    y = np.asarray(q.adc_quantize(jnp.asarray(x), jnp.float32(3.0), cfg))
    assert (np.diff(y) >= -1e-6).all()          # monotone
    assert (np.abs(y) <= 3.0 * (1 + 1 / 31) + 1e-6).all()  # clipped


# ----------------------------------------------------------------------
# UQ metric invariants
# ----------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_risk_coverage_perfect_ranking_has_lower_aurc(seed):
    key = jax.random.PRNGKey(seed)
    n = 128
    correct = jax.random.bernoulli(key, 0.7, (n,))
    conf_perfect = correct.astype(jnp.float32) + 0.01 * jax.random.uniform(
        key, (n,))
    conf_random = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    assert float(aurc(conf_perfect, correct)) <= float(
        aurc(conf_random, correct)) + 1e-6


@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_full_coverage_risk_is_error_rate(seed):
    key = jax.random.PRNGKey(seed)
    correct = jax.random.bernoulli(key, 0.6, (200,))
    conf = jax.random.uniform(jax.random.fold_in(key, 1), (200,))
    cov, risk = risk_coverage_curve(conf, correct)
    np.testing.assert_allclose(float(risk[-1]),
                               1.0 - float(correct.mean()), atol=1e-6)
    assert float(cov[-1]) == 1.0


@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_calibration_errors_in_unit_interval(seed):
    key = jax.random.PRNGKey(seed)
    conf = jax.random.uniform(key, (256,), minval=0.5, maxval=1.0)
    correct = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.8, (256,))
    aece, amce = adaptive_calibration_errors(conf, correct)
    assert 0.0 <= float(aece) <= 1.0
    assert float(aece) <= float(amce) + 1e-6


def test_perfectly_calibrated_has_low_aece():
    key = jax.random.PRNGKey(0)
    conf = jax.random.uniform(key, (20000,), minval=0.05, maxval=0.95)
    correct = jax.random.bernoulli(jax.random.fold_in(key, 1), conf)
    aece, _ = adaptive_calibration_errors(conf, correct)
    assert float(aece) < 0.05
