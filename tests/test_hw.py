"""repro/hw digital twin: device statistics, tile compiler, calibration.

Load-bearing claims:

  1. the nonideal GRNG's empirical sum mean/variance track the device
     model's closed form (corner shift + drift folded into the current
     params, read noise added in quadrature), and the rank-16 serving
     fast path reproduces the paper-mode twin's logit statistics on a
     degraded instance (distribution-level, since per-read noise is
     full-rank) while staying bit-exact at zero variation;
  2. the tile compiler round-trips weights exactly, respects the grid
     bound via passes, keeps digital accumulation shard-local, and its
     utilization/area feed the energy model;
  3. per-instance calibration reduces instance-to-instance output error
     vs the uncalibrated factory transform;
  4. instances are deterministic in their seed and survive the
     checkpoint layer.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng as g
from repro.core.energy import LayerShape
from repro.core.sampling import (BayesHeadConfig, logit_samples_paper,
                                 logit_samples_rank16, prepare_serving_head)
from repro.hw import (ChipInstance, TileGrid, VariationSpec,
                      calibration_report, compile_network, load_instances,
                      measured_grng, prepare_instance_head,
                      sample_instances, save_instances,
                      shard_column_partition)

SPEC = VariationSpec()


def _head_inputs(k=48, n=6, b=4):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    mu = jax.random.normal(k1, (k, n)) * 0.05
    sg = jax.nn.softplus(jax.random.normal(k2, (k, n)) - 2.0) * 0.2
    x = jax.random.normal(k3, (b, k))
    return mu, sg, x


# ----------------------------------------------------------------------
# 1. device statistics
# ----------------------------------------------------------------------
def test_nonideal_sum_stats_track_device_model():
    """Empirical mean/SD of the degraded chip's raw sums match the
    closed-form model (drifted currents + read noise in quadrature)."""
    chip = sample_instances(3, 1, SPEC.scaled(2.0))[0]
    icfg = chip.grng(g.GRNGConfig())
    assert icfg.read_sigma > 0 and icfg.seed != g.GRNGConfig().seed
    mean_a, std_a = icfg.analytic_sum_stats()
    raw = g.raw_sums(icfg, 512, 8, 128)
    assert abs(float(raw.mean()) - mean_a) < 0.05 * mean_a
    assert abs(float(raw.std()) - std_a) < 0.1 * std_a


def test_read_noise_extends_stream_and_zero_sigma_is_ideal():
    cfg = dataclasses.replace(g.GRNGConfig(), read_sigma=0.3)
    full = g.eps(cfg, 16, 16, 12)
    tail = g.eps(cfg, 16, 16, 4, sample0=8)
    np.testing.assert_allclose(np.asarray(full[8:]), np.asarray(tail),
                               rtol=1e-6)
    ideal = g.eps(g.GRNGConfig(), 16, 16, 4)
    noisy = g.eps(cfg, 16, 16, 4)
    assert float(jnp.abs(ideal - noisy).max()) > 0.0


def test_rank16_fast_path_matches_paper_twin_statistics():
    """On a degraded instance the mix_samples projection reproduces the
    materialized per-cell noise path in mean and variance."""
    mu, sg, x = _head_inputs()
    grng = dataclasses.replace(g.GRNGConfig(), read_sigma=0.6)
    cfg = BayesHeadConfig(num_samples=400, mode="rank16", grng=grng,
                          compute_dtype=jnp.float32)
    head = prepare_serving_head(mu, sg, cfg)
    sp = logit_samples_paper(head, x, cfg, 400)
    sr = logit_samples_rank16(head, x, cfg, 400)
    np.testing.assert_allclose(np.asarray(sp.mean(0)),
                               np.asarray(sr.mean(0)), atol=0.05)
    np.testing.assert_allclose(np.asarray(sp.std(0)),
                               np.asarray(sr.std(0)), rtol=0.15, atol=0.02)
    # read noise inflates the sample spread vs the ideal chip
    cfg0 = dataclasses.replace(cfg, grng=g.GRNGConfig())
    s0 = logit_samples_rank16(prepare_serving_head(mu, sg, cfg0), x,
                              cfg0, 400)
    assert float(sr.std(0).mean()) > 1.02 * float(s0.std(0).mean())


# ----------------------------------------------------------------------
# 2. tile compiler
# ----------------------------------------------------------------------
def _layers():
    return [LayerShape(144, 16), LayerShape(150, 70),
            LayerShape(64, 2, bayesian=True)]


def test_tilemap_roundtrip_exact():
    prog = compile_network(_layers(), TileGrid(4, 4))
    w = np.random.default_rng(0).standard_normal((150, 70)).astype(np.float32)
    shards = prog.shard_weights("layer1", w)
    np.testing.assert_array_equal(prog.reconstruct("layer1", shards), w)


def test_tilemap_bounded_grid_passes_and_report():
    grid = TileGrid(2, 2)                     # 4 tiles for 12 blocks
    prog = compile_network(_layers(), grid, replicate_bayesian=False)
    n_blocks = 3 + 6 + 1                      # ceil splits of _layers()
    assert len(prog.placements) == n_blocks
    assert prog.n_passes == -(-n_blocks // grid.n_tiles)
    assert all(p.tile_idx < grid.n_tiles for p in prog.placements)
    assert 0.0 < prog.utilization <= 1.0
    rep = prog.report(r_samples=20)
    assert rep["area_mm2"] == pytest.approx(
        prog.physical_tiles_used * 0.0964)
    assert rep["utilization"] == pytest.approx(prog.utilization)
    assert rep["tops_w_mm2_effective"] < 185.0
    assert rep["grng_samples"] == 64 * 64 * 20     # one Bayesian block


def test_tilemap_sharding_partitions_columns():
    prog = compile_network([LayerShape(128, 256)], TileGrid(8, 8),
                           n_shards=2)
    parts = shard_column_partition(prog, "layer0")
    assert set(parts) == {0, 1}
    seen = sorted(c for cols in parts.values() for c in cols)
    assert seen == sorted(set(seen))          # disjoint column groups
    assert len(parts[0]) == len(parts[1])     # balanced for even splits


def test_tilemap_replication_fills_free_tiles():
    prog = compile_network(_layers(), TileGrid(4, 4))
    assert prog.replication_factor("layer2") > 1
    # replicas never displace primary blocks and stay inside the grid
    prim = prog.layer_placements("layer2")
    reps = prog.layer_placements("layer2", replicas=True)
    assert len(reps) == len(prim) * prog.replication_factor("layer2")
    assert len({(p.pass_idx, p.tile_idx) for p in prog.placements}) == \
        len(prog.placements)


# ----------------------------------------------------------------------
# 3. calibration
# ----------------------------------------------------------------------
def test_calibration_reduces_instance_output_error():
    """Across chips, the calibrated head's logit means sit closer to the
    golden head's than the uncalibrated ones — the benchmark's claim at
    unit-test scale."""
    mu, sg, x = _head_inputs()
    cfg = BayesHeadConfig(num_samples=64, mode="rank16",
                          compute_dtype=jnp.float32)
    gold = logit_samples_rank16(prepare_serving_head(mu, sg, cfg), x,
                                cfg, 64).mean(0)
    err = {True: [], False: []}
    for chip in sample_instances(11, 4, SPEC.scaled(2.0)):
        for cal in (False, True):
            head, scfg = prepare_instance_head(mu, sg, cfg, chip,
                                               calibrated=cal)
            got = logit_samples_rank16(head, x, scfg, 64).mean(0)
            err[cal].append(float(jnp.abs(got - gold).mean()))
    assert np.mean(err[True]) < 0.5 * np.mean(err[False])


def test_calibration_report_residuals_and_cost():
    chip = sample_instances(5, 1, SPEC.scaled(2.0))[0]
    rep = calibration_report(chip, g.GRNGConfig(), n_samples=64)
    assert rep.residual_eps_cal < 0.2 * rep.residual_eps_uncal
    assert rep.measured_sum_std != pytest.approx(0.993, abs=1e-6)
    assert rep.energy_J == pytest.approx(54e-12 + 458e-12 * 64)
    assert rep.time_s == pytest.approx(12.8e-6 + 0.64e-6 * 64)


def test_measured_grng_standardizes_degraded_chip():
    chip = sample_instances(9, 1, SPEC.scaled(2.0))[0]
    ccfg = measured_grng(chip.grng(g.GRNGConfig()), n_samples=256)
    e = g.eps(ccfg, 256, 4, 256)
    assert abs(float(e.mean())) < 0.05
    assert abs(float(e.std()) - 1.0) < 0.05


def test_prepare_instance_head_none_is_golden():
    mu, sg, x = _head_inputs()
    cfg = BayesHeadConfig(num_samples=8, mode="rank16",
                          compute_dtype=jnp.float32)
    head, scfg = prepare_instance_head(mu, sg, cfg, None)
    ref = prepare_serving_head(mu, sg, cfg)
    assert scfg == cfg
    np.testing.assert_allclose(np.asarray(head["mu_prime"]),
                               np.asarray(ref["mu_prime"]))


# ----------------------------------------------------------------------
# 4. instances: determinism + serialization
# ----------------------------------------------------------------------
def test_instances_deterministic_and_distinct():
    a = sample_instances(42, 3, SPEC)
    b = sample_instances(42, 3, SPEC)
    for x, y in zip(a, b):
        assert x.device_seed == y.device_seed
        assert x.read_sigma == y.read_sigma
        np.testing.assert_array_equal(x.adc_gain, y.adc_gain)
    assert len({c.device_seed for c in a}) == 3
    w = jnp.ones((8, 8))
    pw = a[0].program_weights(w)
    np.testing.assert_array_equal(np.asarray(pw),
                                  np.asarray(a[0].program_weights(w)))
    assert not np.allclose(np.asarray(pw),
                           np.asarray(a[1].program_weights(w)))


def test_instances_ckpt_roundtrip(tmp_path):
    chips = sample_instances(7, 3, SPEC.scaled(1.5))
    save_instances(tmp_path / "fleet", chips)
    back = load_instances(tmp_path / "fleet")
    assert len(back) == 3
    for x, y in zip(chips, back):
        assert isinstance(y, ChipInstance)
        assert (x.chip_id, x.device_seed, x.noise_seed) == \
            (y.chip_id, y.device_seed, y.noise_seed)
        assert x.read_sigma == pytest.approx(y.read_sigma)
        np.testing.assert_array_equal(x.adc_gain, y.adc_gain)
        np.testing.assert_array_equal(x.adc_offset, y.adc_offset)
    # the round-tripped instance produces the identical physical config
    assert back[0].grng(g.GRNGConfig()) == chips[0].grng(g.GRNGConfig())
