"""int8 + error-feedback gradient compression: mechanics + convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (compress_grads, compressed_gradients,
                                     dequantize_leaf, init_error_state,
                                     quantize_leaf)


def test_quantize_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    codes, scale = quantize_leaf(g)
    assert codes.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_leaf(codes, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the *running sum* of compressed gradients
    tracks the running sum of true gradients (the EF guarantee)."""
    key = jax.random.PRNGKey(1)
    g_sum = comp_sum = 0.0
    err = {"w": jnp.zeros((64,))}
    for i in range(50):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (64,)) * 0.01}
        deq, err = compressed_gradients(g, err)
        g_sum = g_sum + g["w"]
        comp_sum = comp_sum + deq["w"]
    resid = np.abs(np.asarray(comp_sum - g_sum)).max()
    # Residual bounded by one quantization step, NOT growing with steps.
    assert resid < 0.01 * 0.02, resid


def test_compressed_training_converges():
    """Train the same tiny model with and without compression; final
    losses must be close (the paper-scale cross-pod reduction case)."""
    from repro.launch.train import train
    exact = train("qwen3-1.7b", smoke=True, steps=30, batch=4, seq=32,
                  compress=False)
    comp = train("qwen3-1.7b", smoke=True, steps=30, batch=4, seq=32,
                 compress=True)
    assert exact["final_loss"] < exact["history"][0]["loss"]  # it learns
    assert abs(comp["final_loss"] - exact["final_loss"]) < 0.15 * max(
        exact["final_loss"], 1e-3)
