"""Mission-loop tests: determinism, ledger reconciliation, coverage
monotonicity, verification-policy sanity, and device residency — plus
the two satellite APIs the loop leans on (per-image severity-field
corruptions in data/sard.py, the frozen DecisionCost struct in
serving/metrics.py).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.sard import (CORRUPTIONS, SardConfig, batch_at, corrupt)
from repro.mission import (MissionPolicy, UavConfig, WorldConfig,
                           fly_mission)
from repro.mission import rollout as mrollout
from repro.mission import uav as muav
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.serving.metrics import (RequestRecord, decision_cost,
                                   decision_energy, decision_latency,
                                   energy_terms, request_energy)

WCFG = WorldConfig(grid=6, n_victims=3, seed=2)
UCFG = UavConfig(n_drones=2, battery_J=120e-6)
N_STEPS = 18


@pytest.fixture(scope="module")
def sar():
    cfg = SarCnnConfig()
    return init_sar_cnn(jax.random.PRNGKey(3), cfg), cfg


def _fly(sar, pol=None, ucfg=UCFG, wcfg=WCFG, **kw):
    params, cfg = sar
    pol = pol or MissionPolicy()
    kw.setdefault("n_steps", N_STEPS)
    return fly_mission(wcfg, ucfg, pol, params=params, cfg=cfg, **kw)


# ----------------------------------------------------------------------
# satellite: per-image severity-field corruption API
# ----------------------------------------------------------------------
def test_corrupt_scalar_path_bit_identical():
    """A scalar severity must route through the ORIGINAL batch
    functions — bit-identical to the pre-field behaviour."""
    data = batch_at(SardConfig(seed=7), 3, 6)
    key = jax.random.PRNGKey(5)
    for name, fn in CORRUPTIONS.items():
        want = np.asarray(fn(data["images"], key, 1.3))
        got = np.asarray(corrupt(data["images"], key, 1.3, name))
        np.testing.assert_array_equal(got, want, err_msg=name)


@pytest.mark.parametrize("name", ["fog", "motion"])
def test_corrupt_per_image_matches_scalar_for_keyfree(name):
    """For key-free corruptions a CONSTANT severity vector reproduces
    the scalar batch path (frost/snow legitimately differ: the field
    API draws independent weather per image)."""
    data = batch_at(SardConfig(seed=7), 4, 5)
    key = jax.random.PRNGKey(5)
    want = np.asarray(CORRUPTIONS[name](data["images"], key, 0.8))
    got = np.asarray(corrupt(data["images"], key,
                             jnp.full((5,), 0.8), name))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_corrupt_per_image_severity_varies():
    """Severity 0 reproduces the scalar severity-0 image while its
    batchmates corrupt — the property the mission's severity field
    needs.  (Motion at severity 0 still runs its 2-tap floor, exactly
    like the scalar path.)"""
    data = batch_at(SardConfig(seed=7), 1, 4)
    key = jax.random.PRNGKey(9)
    sev = jnp.asarray([0.0, 0.5, 1.0, 2.0])
    for name in CORRUPTIONS:
        out = np.asarray(corrupt(data["images"], key, sev, name))
        want0 = np.asarray(
            CORRUPTIONS[name](data["images"], key, 0.0))[0]
        np.testing.assert_allclose(out[0], want0, rtol=1e-5, atol=1e-6,
                                   err_msg=name)
        assert np.abs(out[1:] - np.asarray(data["images"][1:])).max() \
            > 1e-3, name


# ----------------------------------------------------------------------
# satellite: DecisionCost — one struct, every consumer
# ----------------------------------------------------------------------
def test_decision_cost_matches_metrics_functions():
    from repro.hw import compile_network
    from repro.launch.serve import sar_layer_shapes
    layers = sar_layer_shapes(SarCnnConfig())
    for program in (None, compile_network(layers)):
        c = decision_cost(layers, program)
        for n in (0.0, 4.0, 7.5, 20.0):
            e = decision_energy(n, layers, tile_program=program)
            assert e["energy_J"] == c.decision_energy_J(n)
            assert e["grng_energy_aJ"] == c.grng_energy_aJ(n)
            np.testing.assert_allclose(c.decision_latency_s(n),
                                       decision_latency(n, layers),
                                       rtol=1e-12)
        # frozen + hashable: usable as a compile-cache key
        assert hash(c) == hash(decision_cost(layers, program))


# ----------------------------------------------------------------------
# mission loop properties
# ----------------------------------------------------------------------
def test_mission_determinism(sar):
    """Same seed ⇒ bit-identical trajectory, ledger, and maps."""
    a = _fly(sar)
    b = _fly(sar)
    assert a.summary == b.summary
    for k in a.logs:
        np.testing.assert_array_equal(a.logs[k], b.logs[k], err_msg=k)
    for k in a.maps:
        np.testing.assert_array_equal(a.maps[k], b.maps[k], err_msg=k)


def test_mission_ledger_reconciles_with_serving_metrics(sar):
    """Σ ledger decision energy == serving/metrics request_energy of
    the logged decision/sample counts — the same DecisionCost numbers,
    no copy-pasted constants."""
    _, cfg = sar
    from repro.hw import compile_network
    from repro.launch.serve import sar_layer_shapes
    res = _fly(sar)
    layers = sar_layer_shapes(cfg)
    program = compile_network(layers)
    assert mrollout.sar_mission_cost(cfg) == decision_cost(layers,
                                                           program)
    terms = energy_terms(layers, program)
    active = res.logs["active"]
    orbited = res.logs["orbited"]
    spent = res.logs["spent"]
    want = sum(
        request_energy(
            RequestRecord(rid=0, verdict=0,
                          n_samples=int(spent[t, b]),
                          n_decisions=1 + 2 * int(orbited[t, b]),
                          arrival_s=0.0, admit_s=0.0, done_s=0.0),
            layers, terms=terms)
        for t, b in zip(*np.nonzero(active)))
    got = float(res.logs["e_decision_J"].sum())
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert res.summary["energy_decision_J"] == got
    # and the ledger total splits exactly into its components
    np.testing.assert_allclose(
        res.summary["energy_total_J"],
        res.summary["energy_decision_J"]
        + res.summary["energy_verify_J"]
        + res.summary["energy_orbit_J"]
        + res.summary["energy_flight_J"], rtol=1e-5)


def test_mission_coverage_monotone_in_energy_budget(sar):
    """A larger battery replays the identical trajectory prefix and
    flies further: coverage is non-decreasing in the budget."""
    covs = []
    for budget in (30e-6, 60e-6, 120e-6, 240e-6):
        res = _fly(sar, ucfg=dataclasses.replace(UCFG, battery_J=budget),
                   n_steps=24)
        covs.append(res.summary["coverage"])
    assert covs == sorted(covs), covs
    assert covs[0] < covs[-1]     # the budget actually binds somewhere


def test_mission_verifications_bounded_by_detections(sar):
    """Every verification descends on a detection (µ-positive), every
    orbit loiters over a flagged detection — counts can never exceed
    the detection count; rescues require ground truth."""
    res = _fly(sar, n_episodes=2)
    logs = res.logs
    detections = logs["active"] & (logs["prediction"] == 1)
    assert (logs["verify"] <= detections).all()
    assert (logs["orbited"] <= detections).all()
    assert (logs["found"] <= logs["verify"]).all()
    assert (logs["found"] <= logs["truth"]).all()
    s = res.summary
    assert s["verifications"] <= s["detections"]
    assert s["orbits"] <= s["detections"]
    assert s["false_verifications"] <= s["verifications"]
    assert s["rescued"] <= s["victims"]


def test_mission_deterministic_mode_verifies_every_detection(sar):
    res = _fly(sar, pol=MissionPolicy(mode="deterministic"))
    logs = res.logs
    already_free = logs["verify"] | ~(logs["active"]
                                      & (logs["prediction"] == 1))
    # det verifies every detection except re-visits of cleared cells
    fresh = (logs["active"] & (logs["prediction"] == 1)
             & ~already_free)
    assert fresh.sum() == 0
    assert res.summary["orbits"] == 0
    assert res.summary["mean_samples_per_decision"] == 0.0


def test_mission_sectors_partition_grid():
    for grid, d in ((6, 2), (7, 3), (12, 5)):
        masks = muav.sector_masks(grid, d)
        assert masks.shape == (d, grid * grid)
        np.testing.assert_array_equal(masks.sum(0),
                                      np.ones(grid * grid))


def test_mission_infogain_planner_runs(sar):
    res = _fly(sar, pol=MissionPolicy(planner="infogain"))
    assert res.summary["coverage"] > 0.2
    # infogain stays inside each drone's sector
    masks = muav.sector_masks(WCFG.grid, UCFG.n_drones)
    cells = res.logs["cell"]                     # [T, E·D]
    for d in range(UCFG.n_drones):
        assert masks[d, cells[:, d]].all()


# ----------------------------------------------------------------------
# device residency — asserted like test_decision_kernel checks the
# engine: one host sync per rollout, and the compiled episode never
# materializes a whole-mission image stream (everything per-step in
# the scan) nor an [R, B, N] sample tensor on the fused path.
# ----------------------------------------------------------------------
def test_mission_rollout_single_dispatch(sar):
    res = _fly(sar)
    assert res.host_syncs == 1
    res2 = _fly(sar, n_episodes=2)
    assert res2.host_syncs == 1                  # episodes batch, not loop


def test_mission_per_drone_chips_one_dispatch_per_die(sar):
    """A heterogeneous fleet groups by die: one dispatch per distinct
    chip, sectors merged exactly (every drone's ledger advances)."""
    from repro.hw import VariationSpec, sample_instances
    chip = sample_instances(11, 1, VariationSpec().scaled(1.5))[0]
    res = _fly(sar, chips=[None, chip], n_steps=10)
    assert res.host_syncs == 2
    assert (res.logs["energy_J"][-1] > 0).all()
    assert res.logs["active"][0].all()


def test_mission_episode_hlo_stays_per_step(sar):
    from repro.launch.hlo_analysis import materialized_shapes
    from repro.mission import world as mworld
    params, cfg = sar
    pol = MissionPolicy()
    chip = None
    head, hcfg = mrollout._prepare_group_head(params, cfg, pol.triage,
                                              chip, True)
    cost = mrollout.sar_mission_cost(cfg)
    n_steps, e = 12, 1
    b = e * UCFG.n_drones
    fn = mrollout._episode_fn(WCFG, UCFG, pol, cfg, hcfg, chip, cost,
                              True, n_steps, b, cfg.n_classes)
    worlds = mworld.stack_worlds(WCFG, e)
    fleet0 = muav.init_fleet(UCFG, WCFG.grid, e)
    bind = muav.fleet_bindings(UCFG, WCFG.grid, e)
    maps0 = {"rescued_t": jnp.full((e, WCFG.n_cells), jnp.inf),
             "cleared": jnp.zeros((e, WCFG.n_cells), jnp.int32),
             "visited": jnp.zeros((e, WCFG.n_cells), jnp.int32),
             "entropy": jnp.full((e, WCFG.n_cells), 0.7)}
    bias = jnp.zeros((cfg.n_classes,), jnp.float32)
    txt = fn.lower(params, head, bias, worlds, fleet0, maps0,
                   bind).compile().as_text()
    img_stream = n_steps * b * cfg.image_size**2
    r, n = pol.triage.r_max, cfg.n_classes
    for _, dims in materialized_shapes(txt):
        numel = int(np.prod(dims)) if dims else 1
        # no whole-mission image stream is ever live …
        assert numel < img_stream, dims
        # … and no [R, B, N] logit-sample tensor in any layout
        assert set(dims) != {r, b, n} or len(dims) != 3, dims


def test_mission_fused_matches_jnp(sar):
    """Fused decision kernel and the materializing path fly the same
    mission — verdict-for-verdict (the engine-level guarantee
    test_decision_kernel.py pins at bench scale), with the float
    ledger compared to fp32 tolerance (the two paths reduce the
    logsumexp in different orders)."""
    a = _fly(sar, fused=True)
    b = _fly(sar, fused=False)
    for k in ("verdict", "prediction", "spent", "verify", "found",
              "orbited"):
        np.testing.assert_array_equal(a.logs[k], b.logs[k], err_msg=k)
    for k in ("energy_J", "time_s"):
        np.testing.assert_allclose(a.logs[k], b.logs[k], rtol=1e-6,
                                   err_msg=k)
    for k, v in a.summary.items():
        if isinstance(v, float):
            np.testing.assert_allclose(v, b.summary[k], rtol=1e-6,
                                       err_msg=k)
        else:
            assert v == b.summary[k], k


def test_operating_point_bias_zero_without_chip(sar):
    params, cfg = sar
    bias = mrollout.operating_point_bias(params, cfg, None, None)
    np.testing.assert_array_equal(bias, np.zeros((cfg.n_classes,)))
