"""Fused sample→statistics decision kernel: oracle conformance, engine
verdict-equivalence, and the live-footprint acceptance check.

Three load-bearing claims:

  1. the fused kernel (kernels/decision_kernel.py) computes EXACTLY the
     ``update_stats(mix_samples(...))`` composition — on ideal chips to
     fp32 tolerance, on degraded chip instances draw-for-draw on the
     same hash-keyed read-noise stream, with masked (inactive) slots
     untouched and escalation rounds extending the selection stream
     additively across ``sample0`` offsets;
  2. a serving engine on the fused path produces verdicts identical to
     the materializing path, request for request, over a fixed SARD
     stream at bench scale (192 requests) — ideal and chip-instance;
  3. the compiled fused decision round holds NO array with an R·B·N
     term (asserted on the post-optimization HLO via launch/
     hlo_analysis.materialized_shapes), while the materializing path
     demonstrably does — the memory claim of the kernel.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng as g
from repro.core.sampling import (BayesHeadConfig, activation_basis,
                                 mix_samples, prepare_serving_head)
from repro.kernels import ops, ref
from repro.serving import TriagePolicy, adaptive

CFG = g.GRNGConfig()


def _basis(b, k, n, read_sigma=0.0, tile_n=0, seed=0):
    grng = dataclasses.replace(CFG, read_sigma=read_sigma)
    hcfg = BayesHeadConfig(num_samples=20, mode="rank16", grng=grng,
                           compute_dtype=jnp.float32, hoist_basis=True,
                           hoist_tile_n=tile_n)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    mu = jax.random.normal(k1, (k, n)) * 0.05
    sg = jax.nn.softplus(jax.random.normal(k2, (k, n)) - 3) * 0.2
    head = prepare_serving_head(mu, sg, hcfg)
    x = jax.random.normal(k3, (b, k))
    return activation_basis(head, x, hcfg), hcfg


def _round_inputs(hcfg, b, r, n_drawn=0):
    base = jnp.asarray(np.arange(b, dtype=np.uint32) * 100)
    drawn = jnp.full((b,), n_drawn, jnp.int32)
    sel = adaptive.stream_selections(hcfg.grng, base, drawn, r)
    idx = adaptive.stream_indices(base, drawn, r)
    return sel, idx


# ----------------------------------------------------------------------
# 1. kernel ↔ oracle ↔ update_stats(mix_samples) conformance
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(5, 32, 8), (3, 16, 300), (9, 24, 130),
                                   (1, 8, 1)])
@pytest.mark.parametrize("read_sigma", [0.0, 0.4])
@pytest.mark.parametrize("r", [1, 6])
def test_decision_kernel_matches_composition(shape, read_sigma, r):
    """Fused deltas == update_stats(init, mix_samples(...)) == oracle,
    including tiled-N shapes (N > the 128 kernel block) and the
    degraded-instance read-noise projection (same hash stream)."""
    b, k, n = shape
    ab, hcfg = _basis(b, k, n, read_sigma)
    sel, idx = _round_inputs(hcfg, b, r)
    mask = jnp.asarray(np.arange(b) % 2 == 0)

    samples = mix_samples(ab, sel, hcfg, sample_idx=idx)
    want = adaptive.update_stats(adaptive.init_stats(b, n), samples,
                                 mask=mask)
    got = ops.decision_update(adaptive.init_stats(b, n), ab, sel,
                              hcfg.grng, sample_idx=idx, mask=mask,
                              interpret=True)
    orc = ref.decision_stats_ref(ab["y_mu"], ab["x_sigma"], ab["m"], sel,
                                 hcfg.grng, x_sigsq=ab.get("x_sigsq"),
                                 sample_idx=idx, mask=mask)
    for key in ("sum_p", "sum_psq", "sum_ent", "sum_entsq", "n"):
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=1e-5, atol=1e-5, err_msg=key)
        if key != "n":
            np.testing.assert_allclose(np.asarray(orc[key]),
                                       np.asarray(want[key]),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"oracle:{key}")
    # masked rows advanced nothing
    inactive = ~np.asarray(mask)
    assert (np.asarray(got["n"])[inactive] == 0).all()
    assert (np.asarray(got["sum_p"])[inactive] == 0).all()


def test_decision_kernel_shared_selection_stream():
    """[R, 16] shared-stream selection (no per-slot offsets) broadcasts
    identically to the explicit [R, B, 16] form."""
    ab, hcfg = _basis(4, 16, 12)
    sel2 = g.selections(hcfg.grng, 5)                    # [R, 16]
    sel3 = jnp.broadcast_to(sel2[:, None, :], (5, 4, 16))
    idx = jnp.arange(5, dtype=jnp.uint32)
    a = ops.decision_update(adaptive.init_stats(4, 12), ab, sel2,
                            hcfg.grng, sample_idx=idx, interpret=True)
    b = ops.decision_update(adaptive.init_stats(4, 12), ab, sel3,
                            hcfg.grng,
                            sample_idx=jnp.broadcast_to(idx[:, None],
                                                        (5, 4)),
                            interpret=True)
    for key in a:
        np.testing.assert_array_equal(np.asarray(a[key]),
                                      np.asarray(b[key]), err_msg=key)


@pytest.mark.parametrize("read_sigma", [0.0, 0.4])
def test_escalation_stream_extension_exact(read_sigma):
    """Two fused rounds at consecutive stream offsets accumulate the
    SAME statistics as one large round over the union — sufficient-
    statistic additivity + index-keyed noise make escalation an exact
    stream extension (the serving engine's correctness invariant)."""
    b, n = 5, 9
    ab, hcfg = _basis(b, 24, n, read_sigma)
    sel_a, idx_a = _round_inputs(hcfg, b, 4, n_drawn=0)
    sel_b, idx_b = _round_inputs(hcfg, b, 8, n_drawn=4)
    sel_all, idx_all = _round_inputs(hcfg, b, 12, n_drawn=0)

    stats = ops.decision_update(adaptive.init_stats(b, n), ab, sel_a,
                                hcfg.grng, sample_idx=idx_a,
                                interpret=True)
    stats = ops.decision_update(stats, ab, sel_b, hcfg.grng,
                                sample_idx=idx_b, interpret=True)
    want = ops.decision_update(adaptive.init_stats(b, n), ab, sel_all,
                               hcfg.grng, sample_idx=idx_all,
                               interpret=True)
    for key in stats:
        np.testing.assert_allclose(np.asarray(stats[key]),
                                   np.asarray(want[key]),
                                   rtol=1e-5, atol=1e-6, err_msg=key)


@pytest.mark.parametrize("read_sigma", [0.0, 0.4])
def test_update_stats_streamed_matches_dense(read_sigma):
    """Chunk-hoisted basis (``m_host``): the streaming two-pass stats
    update equals the dense materializing path — the tiled hoist now
    bounds peak device memory without changing any number."""
    b, k, n = 5, 32, 11
    ab_d, hcfg = _basis(b, k, n, read_sigma)
    ab_t, hcfg_t = _basis(b, k, n, read_sigma, tile_n=3)
    assert "m_host" in ab_t and "m" not in ab_t
    assert all(isinstance(blk, np.ndarray) for blk in ab_t["m_host"])
    sel, idx = _round_inputs(hcfg, b, 6)
    mask = jnp.asarray(np.arange(b) % 2 == 0)
    want = adaptive.update_stats(
        adaptive.init_stats(b, n),
        mix_samples(ab_d, sel, hcfg, sample_idx=idx), mask=mask)
    got = adaptive.update_stats_streamed(
        adaptive.init_stats(b, n), ab_t, sel, hcfg_t, sample_idx=idx,
        mask=mask)
    for key in want:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=1e-5, atol=1e-5, err_msg=key)
    # and the sampled path over host chunks still equals dense mixing
    s_t = mix_samples(ab_t, sel, hcfg_t, sample_idx=idx)
    s_d = mix_samples(ab_d, sel, hcfg, sample_idx=idx)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_d),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# 2. engine-level verdict equivalence on a fixed request stream
# ----------------------------------------------------------------------
def _run_sar(params, cfg, fused, n_requests, policy, chip=None,
             head=None, hcfg=None):
    from repro.launch.serve import make_sar_stream
    from repro.serving import SarServingEngine
    eng = SarServingEngine(params, cfg, n_slots=32, policy=policy,
                           adaptive_mode=True, head=head, hcfg=hcfg,
                           chip=chip, fused=fused)
    for r in make_sar_stream(n_requests, corrupt_frac=0.25,
                             corruption="fog"):
        eng.submit(r)
    eng.run()
    return eng


def _records_match(eng_a, eng_b, n_requests):
    recs_a = {r.rid: r for r in eng_a.metrics.records}
    recs_b = {r.rid: r for r in eng_b.metrics.records}
    assert set(recs_a) == set(recs_b) == set(range(n_requests))
    for rid in recs_a:
        a, b = recs_a[rid], recs_b[rid]
        assert a.verdict == b.verdict, rid
        assert a.prediction == b.prediction, rid
        assert a.n_samples == b.n_samples, rid
        np.testing.assert_allclose(a.confidence, b.confidence, atol=1e-5)
        np.testing.assert_allclose(a.mutual_information,
                                   b.mutual_information, atol=1e-5)


def test_sar_engine_fused_matches_baseline_192():
    """Acceptance: fused-path verdicts identical to the materializing
    engine, request for request, on the fixed 192-request SARD stream
    at bench scale (ideal chip)."""
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    policy = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                          r_min=4, r_max=20)
    eng_f = _run_sar(params, cfg, True, 192, policy)
    eng_j = _run_sar(params, cfg, False, 192, policy)
    _records_match(eng_f, eng_j, 192)
    # the device-resident loop syncs at most once per retirement event
    assert eng_f.host_syncs <= 192


def test_sar_engine_fused_matches_baseline_chip_instance():
    """Acceptance: on a degraded chip instance the fused path draws the
    SAME read-noise stream (hash keyed by absolute sample index) —
    verdicts and sample spend match the materializing path draw for
    draw."""
    from repro.core.bayes_layer import sigma_of
    from repro.hw import (VariationSpec, prepare_instance_head,
                          sample_instances)
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    chip = sample_instances(11, 1, VariationSpec().scaled(2.0))[0]
    base_hcfg = BayesHeadConfig(num_samples=20, mode="rank16",
                                grng=cfg.grng, compute_dtype=jnp.float32,
                                hoist_basis=True)
    head, hcfg = prepare_instance_head(
        params["head"]["mu"], sigma_of(params["head"]), base_hcfg, chip)
    assert hcfg.grng.read_sigma > 0
    policy = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                          r_min=4, r_max=20)
    eng_f = _run_sar(params, cfg, True, 48, policy, chip=chip,
                     head=head, hcfg=hcfg)
    eng_j = _run_sar(params, cfg, False, 48, policy, chip=chip,
                     head=head, hcfg=hcfg)
    _records_match(eng_f, eng_j, 48)


def test_sar_engine_serves_chunk_hoisted_head():
    """A ``hoist_tile_n`` head must still serve through the jitted
    engine (activation_basis falls back to the dense concat under
    tracing) on BOTH decision paths, with the same verdicts as the
    dense-hoisted head."""
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    policy = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                          r_min=4, r_max=20)
    hcfg_t = BayesHeadConfig(num_samples=20, mode="rank16",
                             grng=cfg.grng, compute_dtype=jnp.float32,
                             hoist_basis=True, hoist_tile_n=1)
    from repro.core.bayes_layer import to_serving
    head_t = to_serving(params["head"], hcfg_t)
    assert "sigma_basis_host" in head_t
    ref_eng = _run_sar(params, cfg, True, 16, policy)
    for fused in (True, False):
        eng = _run_sar(params, cfg, fused, 16, policy, head=head_t,
                       hcfg=hcfg_t)
        _records_match(eng, ref_eng, 16)


def test_lm_engine_fused_matches_baseline():
    """LM engine: per-token fused decisions reproduce the materializing
    path — same verdicts, token counts and sample spend over a small
    continuous-batching run."""
    import time
    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.serving import LMServingEngine, Request

    cfg = get_config("qwen3-0.6b", smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab), np.int32)
    policy = TriagePolicy(conf_threshold=0.3, mi_threshold=1.0,
                          r_min=4, r_max=8)

    def run(fused):
        eng = LMServingEngine(params, cfg, n_slots=2, prompt_len=8,
                              cache_len=24, policy=policy,
                              adaptive_mode=True, fused=fused)
        for i in range(3):
            eng.submit(Request(rid=i, payload=prompts[i],
                               arrival_s=time.time(), max_new_tokens=2))
        eng.run()
        return eng

    eng_f, eng_j = run(True), run(False)
    recs_f = {r.rid: r for r in eng_f.metrics.records}
    recs_j = {r.rid: r for r in eng_j.metrics.records}
    assert set(recs_f) == set(recs_j) == {0, 1, 2}
    for rid in recs_f:
        assert recs_f[rid].verdict == recs_j[rid].verdict
        assert recs_f[rid].prediction == recs_j[rid].prediction
        assert recs_f[rid].n_samples == recs_j[rid].n_samples
        assert recs_f[rid].n_decisions == recs_j[rid].n_decisions


# ----------------------------------------------------------------------
# 3. live-footprint acceptance: no [R, B, N] term in the fused round
# ----------------------------------------------------------------------
def test_fused_round_hlo_has_no_rbn_term():
    """Compile both decision rounds at an LM-ish scale and scan the
    post-optimization HLO: the materializing path holds [r, B, N]
    logit-sample tensors; the fused path's largest live array is the
    O(B·N·16) basis — nothing scales with R·B·N."""
    from repro.launch.hlo_analysis import (largest_intermediate_bytes,
                                           materialized_shapes)
    from repro.serving.engine import _sar_round_fn

    B, N, R, r_step = 8, 512, 20, 4
    hcfg = BayesHeadConfig(num_samples=R, mode="rank16", grng=CFG,
                           compute_dtype=jnp.float32, hoist_basis=True)
    pol = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                       r_min=r_step, r_max=R)
    pool = {"y_mu": jnp.zeros((B, N)), "x_sigma": jnp.zeros((B, N)),
            "m": jnp.zeros((B, N, 16))}
    stats = adaptive.init_stats(B, N)
    args = (pool, stats, jnp.zeros((B,), jnp.uint32),
            jnp.ones((B,), bool))

    def compiled_shapes(fused):
        fn = _sar_round_fn(hcfg, pol, True, r_step, fused, None)
        txt = fn.lower(*args).compile().as_text()
        return txt, materialized_shapes(txt)

    txt_f, shapes_f = compiled_shapes(True)
    _, shapes_j = compiled_shapes(False)

    sample_shape = {(r_step, B, N), (B, N, r_step), (B, r_step, N)}
    dims_f = {d for _, d in shapes_f}
    dims_j = {d for _, d in shapes_j}
    # the materializing path really does hold the sample tensor …
    assert dims_j & sample_shape, sorted(dims_j)[:10]
    # … the fused path never does, in any layout
    assert not (dims_f & sample_shape), sorted(dims_f & sample_shape)
    # stronger: nothing in the fused round outgrows the rank-16 basis
    basis_bytes = B * N * 16 * 4
    assert largest_intermediate_bytes(txt_f) <= basis_bytes
    # and nothing carries an R·B·N-sized buffer
    for _, dims in shapes_f:
        numel = int(np.prod(dims)) if dims else 1
        assert numel <= basis_bytes // 4, dims
