"""SPMD tests under a forced multi-device host platform (subprocess).

The main test process sees 1 CPU device (per the dry-run contract, the
512-device override lives ONLY in dryrun.py).  These tests spawn fresh
interpreters with XLA_FLAGS to validate multi-device semantics:
sharded-MoE ≡ GSPMD oracle, distributed train-step equivalence, and
elastic re-meshing.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_spmd(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_moe_matches_gspmd_oracle():
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.models.moe import init_moe, moe_apply, make_sharded_moe
from repro.launch.mesh import make_mesh_compat, mesh_context
mesh = make_mesh_compat((4, 2), ("data", "model"))
E, D, F, k = 4, 32, 64, 2
p = init_moe(jax.random.PRNGKey(0), 1, D, F, E)
r, wi, wg, wo = p["router"][0], p["wi"][0], p["wg"][0], p["wo"][0]
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, D))
y_ref, _ = moe_apply(x, r, wi, wg, wo, top_k=k, capacity_factor=8.0)
with mesh_context(mesh):
    moe = make_sharded_moe(mesh, top_k=k, capacity_factor=8.0,
                           n_experts=E, dp_axes=("data",))
    y, _ = jax.jit(moe)(x, r, wi, wg, wo)
assert np.allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
print("OK")
""")


def test_distributed_train_step_matches_single_device():
    """One jitted train step on a (2,2) mesh must equal the unsharded
    step (same data, same init) — the sharding is semantics-preserving."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.steps import jit_train_step, make_train_step, \
    mesh_hinted_config, input_specs
from repro.optim import AdamWConfig, init_opt_state
from repro.models.registry import get_api
from repro.data.tokens import TokenPipelineConfig, batch_at

cfg0 = get_config("qwen3-0.6b", smoke=True)
opt_cfg = AdamWConfig()
from repro.launch.mesh import make_mesh_compat, mesh_context
mesh = make_mesh_compat((2, 2), ("data", "model"))
pipe = TokenPipelineConfig(vocab=cfg0.vocab, seq_len=16, global_batch=4)
batch = batch_at(pipe, 0)

api = get_api(cfg0)
params = api.init(jax.random.PRNGKey(0), cfg0)
opt = init_opt_state(params)
ref_step = make_train_step(cfg0, opt_cfg)
p_ref, o_ref, m_ref = jax.jit(ref_step)(params, opt, batch)

with mesh_context(mesh):
    jitted, _, _, cfg2 = jit_train_step(cfg0, mesh, opt_cfg, 16, 4)
    p_sh, o_sh, m_sh = jitted(params, opt, batch)
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 2e-2, (
    float(m_ref["loss"]), float(m_sh["loss"]))
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=0.05, atol=0.05)
print("OK")
""")


def test_elastic_remesh_under_devices():
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import make_elastic_mesh, shrink_mesh, remesh_train_state
devs = jax.devices()
mesh = make_elastic_mesh(devs)
new_mesh = shrink_mesh(mesh, {devs[-1].id, devs[-2].id})
assert new_mesh.devices.size <= len(devs) - 2
params = {"w": jnp.arange(64.0).reshape(8, 8)}
opt = {"mu": {"w": jnp.zeros((8, 8))}, "nu": {"w": jnp.zeros((8, 8))},
       "count": jnp.int32(3)}
p2, o2 = remesh_train_state(params, opt, new_mesh)
np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
assert int(o2["count"]) == 3
print("OK")
""")


def test_sharded_serving_pool_matches_single_device():
    """ROADMAP open item: run the serving engine under a 2-device mesh
    with the slot axis sharded over 'data' (engine ``slot_axis``).  The
    pool rounds execute data-parallel over the slots; admission scatters
    stay slot-local; every request must retire with the same prediction
    and verdict as the unsharded engine."""
    run_spmd("""
import jax, numpy as np
from repro.launch.mesh import make_mesh_compat, mesh_context
from repro.launch.serve import make_sar_stream
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.serving import SarServingEngine, TriagePolicy

cfg = SarCnnConfig()
params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
policy = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05,
                      r_min=4, r_max=12)

def run(slot_axis, mesh):
    eng = SarServingEngine(params, cfg, n_slots=4, policy=policy,
                           adaptive_mode=True, slot_axis=slot_axis)
    for r in make_sar_stream(10, batch=8):
        eng.submit(r)
    eng.run()
    return {r.rid: (r.prediction, r.verdict, r.n_samples)
            for r in eng.metrics.records}

ref = run(None, None)
mesh = make_mesh_compat((2, 1), ("data", "model"))
with mesh_context(mesh):
    got = run("data", mesh)
assert set(ref) == set(got) == set(range(10))
for rid in ref:
    assert ref[rid] == got[rid], (rid, ref[rid], got[rid])
print("OK")
""", devices=2)


def test_shard_map_fused_kernel_bit_identical():
    """ISSUE gate (shard_map-native decision kernel): on a fixed
    192-request SARD stream the sharded fused engine must produce
    verdicts BIT-FOR-BIT identical to the single-device fused engine
    (confidence/MI floats included — the hash3 read-noise/GRNG streams
    are keyed on global sample index, so shard-local execution draws
    the same noise), and verdict-identical to the materializing jnp
    path.  Ideal die AND a severity-2.5 chip instance (the chip path
    exercises the global-row ``rows`` operand of
    kernels.decision_kernel.decision_stats_sharded).  Host-sync counts
    and the compiled round's largest live intermediate must not grow."""
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.hlo_analysis import largest_intermediate_bytes
from repro.launch.mesh import make_mesh_compat, mesh_context
from repro.launch.serve import make_sar_stream
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.serving import SarServingEngine, TriagePolicy
from repro.serving import adaptive as ad

cfg = SarCnnConfig()
params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
policy = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                      r_min=4, r_max=20)

def chip_head():
    from repro.core.bayes_layer import sigma_of
    from repro.core.sampling import BayesHeadConfig
    from repro.hw import VariationSpec, prepare_instance_head, \\
        sample_instances
    chip = sample_instances(0, 1, VariationSpec().scaled(2.5))[0]
    base = BayesHeadConfig(num_samples=policy.r_max, mode="rank16",
                           grng=cfg.grng, compute_dtype=jnp.float32,
                           hoist_basis=True)
    head, hcfg = prepare_instance_head(
        params["head"]["mu"], sigma_of(params["head"]), base, chip,
        calibrated=True)
    return dict(chip=chip, head=head, hcfg=hcfg)

def run(slot_axis, mesh, fused, extra):
    eng = SarServingEngine(params, cfg, n_slots=32, policy=policy,
                           adaptive_mode=True, slot_axis=slot_axis,
                           mesh=mesh, fused=fused, telemetry=False,
                           **extra)
    for r in make_sar_stream(192, corrupt_frac=0.25):
        eng.submit(r)
    eng.run()
    recs = {r.rid: (int(r.prediction), r.verdict, int(r.n_samples),
                    float(r.confidence), float(r.mutual_information))
            for r in eng.metrics.records}
    return recs, eng

def round_peak(eng):
    b, n = 32, cfg.n_classes
    pool = jax.tree.map(lambda x: jnp.zeros_like(x), eng.pool)
    txt = eng._round.lower(pool, ad.init_stats(b, n),
                           jnp.zeros((b,), jnp.uint32),
                           jnp.ones((b,), bool)).compile().as_text()
    return largest_intermediate_bytes(txt)

mesh = make_mesh_compat((2, 1), ("data", "model"))
for tag, extra in (("ideal", {}), ("chip2.5", chip_head())):
    ref, eng_ref = run(None, None, True, extra)
    jnp_ref, _ = run(None, None, False, extra)
    with mesh_context(mesh):
        got, eng_sh = run("data", mesh, True, extra)
    assert eng_sh._mesh is not None, tag   # shard_map-native path taken
    assert set(ref) == set(got) == set(range(192)), tag
    for rid in ref:
        assert ref[rid] == got[rid], (tag, rid, ref[rid], got[rid])
        assert ref[rid][:3] == jnp_ref[rid][:3], (tag, rid)
    assert eng_ref.host_syncs == eng_sh.host_syncs, (
        tag, eng_ref.host_syncs, eng_sh.host_syncs)
    peak_ref = round_peak(eng_ref)
    with mesh_context(mesh):
        peak_sh = round_peak(eng_sh)
    assert peak_sh <= peak_ref * 1.01, (tag, peak_sh, peak_ref)
    print(tag, "OK", eng_ref.host_syncs, peak_ref, peak_sh)
print("OK")
""", devices=2)


def test_fleet_gang_matches_standalone_pools():
    """ISSUE gate (mesh-of-pools fleet): the ONE-gang-dispatch-per-tick
    fleet over a 4-device ("pool",) mesh must produce bit-for-bit the
    verdicts of the sequential fallback — which dispatches each pool
    through its OWN engine round, i.e. standalone pools fed the same
    admission sequences (the router is deterministic)."""
    run_spmd("""
import jax
from repro.launch.serve import serve_sar_fleet
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn

cfg = SarCnnConfig()
params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
kw = dict(n_requests=256, n_pools=4, slots_per_pool=16,
          corrupt_frac=0.25, params=params, cfg=cfg)
a = serve_sar_fleet(gang=True, **kw)
b = serve_sar_fleet(gang=False, **kw)
assert a["gang"] is True and b["gang"] is False
assert a["decisions"] == b["decisions"] == 256
assert a["routed_per_pool"] == b["routed_per_pool"]
assert a["verdicts"] == b["verdicts"]   # bitwise: floats + pool ids
# the gang folds P pools into one sync per tick: strictly fewer host
# syncs than one-dispatch-per-pool, at the same decision count
assert a["host_syncs"] < b["host_syncs"]
print("OK")
""", devices=8)


def test_microbatched_step_matches_full_batch():
    run_spmd("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.steps import jit_train_step
from repro.optim import AdamWConfig, init_opt_state
from repro.models.registry import get_api
from repro.data.tokens import TokenPipelineConfig, batch_at

cfg0 = get_config("qwen3-1.7b", smoke=True)
from repro.launch.mesh import make_mesh_compat, mesh_context
mesh = make_mesh_compat((2, 2), ("data", "model"))
pipe = TokenPipelineConfig(vocab=cfg0.vocab, seq_len=16, global_batch=8)
batch = batch_at(pipe, 0)
api = get_api(cfg0)

def fresh():
    # the jitted step DONATES params/opt — fresh, uncommitted copies
    # per call (created OUTSIDE the mesh context so jit may reshard)
    p = api.init(jax.random.PRNGKey(0), cfg0)
    return p, init_opt_state(p)

params, opt = fresh()
with mesh_context(mesh):
    j1, _, _, _ = jit_train_step(cfg0, mesh, AdamWConfig(), 16, 8)
    p1, o1, m1 = j1(params, opt, batch)
params, opt = fresh()
with mesh_context(mesh):
    j4, _, _, _ = jit_train_step(cfg0, mesh, AdamWConfig(), 16, 8,
                                 microbatches=4)
    p4, o4, m4 = j4(params, opt, batch)
# NOTE: microbatch CE is averaged over chunks — losses should be close;
# grads differ only by accumulation order (and the per-step CLT draw is
# shared since step index is equal).
assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
print("OK")
""")
