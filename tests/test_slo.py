"""SLO observability acceptance: the lifecycle tracker must be FREE at
the decision level, the load harness deterministic, and the alert bus
correct on both edges (fire under burn, quiet when nominal).

The load-bearing claims, mirroring tests/test_obs.py's telemetry
gates:

  1. zero overhead — SLO tracking on vs off: bit-identical verdicts,
     the SAME host-sync count, and the SAME compiled round executable
     (``lru_cache`` identity — the builders never see the tracker), on
     the engine AND the fleet path;
  2. the numbers are CORRECT — per-request queue-wait + service
     decomposition reconciles against total latency and the wall span;
     histogram quantiles agree with numpy on the raw samples to within
     a bucket;
  3. the seeded arrival generators are deterministic and hit their
     mean rates;
  4. one fleet trace is a SINGLE stitched timeline: per-pool process
     tracks, router tick spans, and matched flow start/end pairs per
     request;
  5. the alert bus pages on SLO burn and backpressure and stays quiet
     otherwise, and its advisories export through the Prometheus
     registry.
"""

import json
import math

import jax
import numpy as np
import pytest

from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.obs.alerts import AlertBus
from repro.obs.registry import MetricsRegistry, add_alerts, add_slo, \
    quantile
from repro.obs.slo import NULL_SLO, SLO, SloTracker, _EDGES
from repro.obs.trace import Tracer
from repro.serving import TriagePolicy
from repro.serving.load import ArrivalSpec, run_open_loop

POLICY = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                      r_min=4, r_max=20)


@pytest.fixture(scope="module")
def sar():
    cfg = SarCnnConfig()
    return init_sar_cnn(jax.random.PRNGKey(3), cfg), cfg


def _stream(n):
    from repro.launch.serve import make_sar_stream
    return make_sar_stream(n, corrupt_frac=0.25, corruption="fog")


def _engine(sar, *, slo=True, n_slots=8, tracer=None):
    from repro.serving import SarServingEngine
    params, cfg = sar
    return SarServingEngine(params, cfg, n_slots=n_slots, policy=POLICY,
                            adaptive_mode=True, fused=True,
                            telemetry=False, slo=slo, tracer=tracer)


def _fleet(sar, *, slo=True, tracer=None, n_pools=2, slots=4):
    from repro.serving import SarServingFleet
    params, cfg = sar
    return SarServingFleet(params, cfg, n_pools=n_pools,
                           slots_per_pool=slots, policy=POLICY,
                           adaptive_mode=True, fused=True,
                           telemetry=False, gang=False, slo=slo,
                           tracer=tracer)


def _records_match(eng_a, eng_b, n_requests):
    recs_a = {r.rid: r for r in eng_a.metrics.records}
    recs_b = {r.rid: r for r in eng_b.metrics.records}
    assert set(recs_a) == set(recs_b) == set(range(n_requests))
    for rid in recs_a:
        a, b = recs_a[rid], recs_b[rid]
        assert a.verdict == b.verdict, rid
        assert a.prediction == b.prediction, rid
        assert a.n_samples == b.n_samples, rid


# ----------------------------------------------------------------------
# registry.quantile: log-bucket interpolation vs numpy
# ----------------------------------------------------------------------
def test_quantile_matches_numpy_within_a_bucket():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-5.0, sigma=1.5, size=4000)
    h = SloTracker()
    for s in samples:
        h._ttv.observe(float(s))
    hist = h._ttv.to_dict()
    edges = np.asarray(hist["edges"])
    for q in (0.5, 0.9, 0.95, 0.99):
        est = quantile(hist, q)
        exact = float(np.quantile(samples, q))
        # the estimate must land within one log bucket of the truth
        ratio = edges[1] / edges[0]
        assert exact / ratio <= est <= exact * ratio, (q, est, exact)


def test_quantile_edge_cases():
    empty = {"counts": [0, 0], "edges": [0.1, 1.0, 10.0], "overflow": 0}
    assert math.isnan(quantile(empty, 0.5))
    over = {"counts": [0, 0], "edges": [0.1, 1.0, 10.0], "overflow": 5}
    assert quantile(over, 0.5) == 10.0          # overflow -> last edge
    one = {"counts": [4, 0], "edges": [0.1, 1.0, 10.0], "overflow": 0}
    v = quantile(one, 0.5)
    assert 0.1 <= v <= 1.0


# ----------------------------------------------------------------------
# SLO spec parsing + burn-rate math
# ----------------------------------------------------------------------
def test_slo_parse_and_burn_math():
    s = SLO.parse("0.25:p99")
    assert s.target_s == 0.25 and s.percentile == 0.99
    assert s.name == "p99<=0.25s"
    assert abs(s.error_budget - 0.01) < 1e-9
    # 5 violations in 100 at a 1% budget -> burn 5x -> breach at 2x
    ev = s.evaluate(5, 100)
    assert abs(ev["burn_rate"] - 5.0) < 1e-6
    assert ev["breach"] is True
    # exactly on budget: burn 1x, no breach
    ev = s.evaluate(1, 100)
    assert abs(ev["burn_rate"] - 1.0) < 1e-6
    assert ev["breach"] is False
    # custom burn threshold rides the spec string
    s = SLO.parse("1.5:p95:4")
    assert s.burn_alert == 4.0
    assert s.evaluate(10, 100)["breach"] is False      # burn 2x < 4x
    # no requests -> no breach
    assert SLO.parse("0.1:p99").evaluate(0, 0)["breach"] is False


def test_slo_bad_specs_raise():
    with pytest.raises(ValueError):
        SLO.parse("0.25:q99")
    with pytest.raises(ValueError):
        SLO.parse("fast:p99")
    # bare target defaults to p99
    assert SLO.parse("0.25").percentile == 0.99


# ----------------------------------------------------------------------
# arrival generators: determinism + mean rates
# ----------------------------------------------------------------------
def test_arrival_specs_deterministic_and_rated():
    # ramp: time per request is 1/rate_i, so the realized overall rate
    # is the log-mean (80-20)/ln(80/20) = 43.28 req/s
    for spec_str, mean in (("poisson:50", 50.0), ("burst:50", 50.0),
                           ("burst:50:4", 50.0),
                           ("ramp:20:80", 60.0 / math.log(4.0))):
        spec = ArrivalSpec.parse(spec_str)
        assert spec.mean_rate == pytest.approx(mean)
        a = spec.offsets(4000, seed=3)
        b = spec.offsets(4000, seed=3)
        np.testing.assert_array_equal(a, b)          # same seed, same
        c = spec.offsets(4000, seed=4)
        assert not np.array_equal(a, c)              # new seed, new
        assert np.all(np.diff(a) >= 0)               # ascending
        measured = len(a) / a[-1]
        assert measured == pytest.approx(mean, rel=0.1), spec_str


def test_burst_spec_actually_bursts():
    spec = ArrivalSpec.parse("burst:100:10")
    gaps = np.diff(np.concatenate([[0.0], spec.offsets(640, seed=0)]))
    group = (np.arange(640) // 16) % 2
    burst_mean = gaps[group == 0].mean()
    lull_mean = gaps[group == 1].mean()
    assert lull_mean > 5 * burst_mean


def test_arrival_parse_rejects_unknown():
    with pytest.raises(ValueError):
        ArrivalSpec.parse("uniform:5")


# ----------------------------------------------------------------------
# 1. zero-overhead gates: engine, fleet
# ----------------------------------------------------------------------
def test_engine_slo_zero_overhead(sar):
    n = 24
    eng_on = _engine(sar, slo=True)
    eng_off = _engine(sar, slo=False)
    for e in (eng_on, eng_off):
        for r in _stream(n):
            e.submit(r)
        e.run()
    _records_match(eng_on, eng_off, n)
    assert eng_on.host_syncs == eng_off.host_syncs
    # the compiled round executable is the SAME cached object — the
    # builders never see the tracker, so the graph cannot differ
    assert eng_on._round is eng_off._round
    assert eng_off.slo is NULL_SLO
    assert eng_off.slo.snapshot() == {}
    snap = eng_on.slo.snapshot()
    assert snap["requests"] == n
    assert snap["time_to_verdict"]["count"] == n
    by_verdict_n = sum(v["count"] for v in snap["by_verdict"].values())
    assert by_verdict_n == n


def test_fleet_slo_zero_overhead(sar):
    n = 24
    fl_on = _fleet(sar, slo=True)
    fl_off = _fleet(sar, slo=False)
    outs = []
    for fl in (fl_on, fl_off):
        for r in _stream(n):
            fl.submit(r)
        outs.append(fl.run())
    recs_on = {r.rid: r for e in fl_on.engines
               for r in e.metrics.records}
    recs_off = {r.rid: r for e in fl_off.engines
                for r in e.metrics.records}
    assert set(recs_on) == set(recs_off) == set(range(n))
    for rid in recs_on:
        assert recs_on[rid].verdict == recs_off[rid].verdict
        assert recs_on[rid].n_samples == recs_off[rid].n_samples
    assert fl_on.host_syncs == fl_off.host_syncs
    snap = outs[0]["slo"]
    assert snap["requests"] == n
    assert snap["fleet"]["ticks"] >= 1
    assert len(snap["fleet"]["queue_depth_peak"]) == fl_on.n_pools
    assert "slo" not in outs[1]


def test_mission_summary_unchanged_by_alert_bus():
    """The mission bus is post-hoc: feeding it must not mutate the
    summary it reads."""
    summary = {"decisions": 10, "rescued": 1}
    telem = {"g0": {"drift": {"drifted": True, "advisory": "drift!",
                              "z_mean": 9.0, "z_std": 1.0, "n": 64}}}
    before = json.dumps(telem, sort_keys=True) + json.dumps(summary,
                                                            sort_keys=True)
    bus = AlertBus()
    for g, t in telem.items():
        bus.observe_drift(t["drift"], source=f"mission/{g}")
    assert len(bus) == 1 and bus.advisories[0].kind == "drift"
    after = json.dumps(telem, sort_keys=True) + json.dumps(summary,
                                                           sort_keys=True)
    assert before == after


# ----------------------------------------------------------------------
# 2. queue/service decomposition reconciles
# ----------------------------------------------------------------------
def test_queue_plus_service_reconciles_with_latency(sar):
    n = 16
    eng = _engine(sar, slo=True)
    for r in _stream(n):
        eng.submit(r)
    out = eng.run()
    span = out["slo"]["span_s"]
    for rec in eng.metrics.records:
        q, s, tot = rec.queue_latency_s, rec.service_latency_s, \
            rec.latency_s
        assert q >= 0 and s >= 0
        assert q + s == pytest.approx(tot, rel=1e-6, abs=1e-9)
        assert tot <= span + 1e-3
        # verdict stamp: taken at the sync INSIDE the last dispatch, so
        # it can only precede the retire-side stamp
        assert rec.verdict_latency_s <= tot + 1e-9
    summ = eng.metrics.summary()
    assert summ["queue_wait_total_s"] + summ["service_total_s"] == \
        pytest.approx(sum(r.latency_s for r in eng.metrics.records),
                      rel=1e-6)
    assert 0.0 <= summ["queue_wait_share"] <= 1.0


# ----------------------------------------------------------------------
# 3. open-loop harness
# ----------------------------------------------------------------------
def test_open_loop_engine_and_snapshot(sar):
    n = 16
    eng = _engine(sar, slo=True, n_slots=4)
    reqs = _stream(n)
    spec = ArrivalSpec.parse("poisson:400")
    out = run_open_loop(eng, reqs, spec.offsets(n, seed=0))
    assert out["requests"] == n
    assert out["offered"]["submitted"] == n
    snap = out["slo"]
    assert snap["requests"] == n
    assert snap["p50_s"] <= snap["p95_s"] <= snap["p99_s"]
    assert math.isfinite(snap["mean_s"])


def test_slo_tracker_targets_and_breach(sar):
    n = 12
    tracker = SloTracker(slos=("1e9:p50", "1e-9:p99"))
    eng = _engine(sar, slo=tracker, n_slots=4)
    for r in _stream(n):
        eng.submit(r)
    eng.run()
    snap = tracker.snapshot()
    results = {s["name"]: s for s in snap["slos"]}
    huge = results["p50<=1e+09s"]
    tiny = results["p99<=1e-09s"]
    assert huge["violations"] == 0 and huge["breach"] is False
    assert tiny["violations"] == n and tiny["breach"] is True
    assert tiny["attainment"] == 0.0


# ----------------------------------------------------------------------
# 4. fleet trace stitching
# ----------------------------------------------------------------------
def test_fleet_trace_single_stitched_timeline(sar):
    n = 16
    tr = Tracer("fleet-test")
    fl = _fleet(sar, tracer=tr)
    for r in _stream(n):
        fl.submit(r)
    fl.run()
    doc = tr.to_chrome()
    ev = doc["traceEvents"]
    # per-pool process tracks, named
    pnames = {e["pid"]: e["args"]["name"] for e in ev
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert pnames[0] == "router"
    for p in range(fl.n_pools):
        assert pnames[p + 1] == f"pool {p}"
    # router tick spans + per-pool gang-dispatch spans
    assert any(e["ph"] == "X" and e["name"] == "fleet_tick"
               and e["pid"] == 0 for e in ev)
    disp_pids = {e["pid"] for e in ev
                 if e["ph"] == "X" and e["name"] == "gang_dispatch"}
    assert disp_pids and disp_pids <= {p + 1
                                       for p in range(fl.n_pools)}
    # request flows: every rid has a start on the router track and an
    # end on some pool's slot track, with matching flow ids
    starts = {e["id"]: e for e in ev if e["ph"] == "s"}
    ends = {e["id"]: e for e in ev if e["ph"] == "f"}
    assert set(starts) == set(ends) == set(range(n))
    for rid in range(n):
        assert starts[rid]["pid"] == 0
        assert ends[rid]["pid"] in range(1, fl.n_pools + 1)
        assert ends[rid]["bp"] == "e"
        assert starts[rid]["ts"] <= ends[rid]["ts"]
    # request spans live on the pool that the router recorded
    req_spans = {e["name"]: e for e in ev
                 if e["ph"] == "X" and e["name"].startswith("req ")}
    for rid, pool in fl.routes.items():
        assert req_spans[f"req {rid}"]["pid"] == pool + 1
    json.dumps(doc)                                   # valid JSON


# ----------------------------------------------------------------------
# 5. alert bus
# ----------------------------------------------------------------------
def test_alert_bus_slo_burn_fires_and_quiet():
    bus = AlertBus()
    breached = {"slos": [
        {"name": "p99<=0.25s", "breach": True, "burn_rate": 8.0,
         "burn_alert": 2.0, "violations": 9, "requests": 100},
        {"name": "p50<=1s", "breach": False, "burn_rate": 0.1,
         "burn_alert": 2.0, "violations": 0, "requests": 100}]}
    bus.observe_slo(breached, source="test")
    assert bus.counts() == {"slo_burn": 1}
    assert bus.worst_severity() == "critical"
    quiet = AlertBus()
    quiet.observe_slo({"slos": [breached["slos"][1]]}, source="test")
    quiet.observe_drift({"drifted": False}, source="test")
    quiet.observe_backpressure({"fleet": {"backpressure_ticks": 0,
                                          "ticks": 9}})
    assert len(quiet) == 0


def test_alert_bus_backpressure_severity_scales():
    bus = AlertBus()
    bus.observe_backpressure({"fleet": {"backpressure_ticks": 1,
                                        "ticks": 10,
                                        "backlog_peak": 3}})
    bus.observe_backpressure({"fleet": {"backpressure_ticks": 9,
                                        "ticks": 10,
                                        "backlog_peak": 40}})
    sev = [a.severity for a in bus.advisories]
    assert sev == ["warning", "critical"]


def test_alert_bus_heal_and_drift_dialects():
    bus = AlertBus()
    bus.observe_drift({"drifted": True, "advisory": "recalibrate",
                       "z_mean": 7.5, "z_std": 2.0, "n": 128},
                      source="serve_sar")
    bus.observe_heal({"age_s": 3.0e7, "calib_epoch": 2, "z_mean": 7.5,
                      "z_std": 2.0, "advisory": ""}, source="lifetime")
    assert bus.counts() == {"drift": 1, "heal": 1}
    js = bus.to_json()
    assert js[0]["message"] == "recalibrate"
    assert js[1]["fields"]["calib_epoch"] == 2
    json.dumps(js)


def test_registry_exports_slo_and_alerts(sar, tmp_path):
    n = 12
    eng = _engine(sar, slo=True, n_slots=4)
    for r in _stream(n):
        eng.submit(r)
    out = eng.run()
    reg = MetricsRegistry()
    add_slo(reg, out["slo"], job="test")
    bus = AlertBus()
    bus.emit("slo_burn", "critical", "test", "burning")
    add_alerts(reg, bus.to_json(), job="test")
    text = reg.to_prometheus()
    assert "slo_requests_total" in text
    assert "slo_time_to_verdict_seconds_bucket" in text
    assert 'le="+Inf"' in text
    assert "alerts_total" in text
    assert 'kind="slo_burn"' in text
    prom, js = reg.write(str(tmp_path / "m"))
    doc = json.loads((tmp_path / "m.json").read_text())
    assert any(m["name"].endswith("slo_requests_total")
               for m in doc["metrics"])
    assert any(m["name"].endswith("alerts_total")
               for m in doc["metrics"])


def test_null_slo_is_inert():
    NULL_SLO.observe(object())
    NULL_SLO.observe_router(0.1)
    NULL_SLO.sample_queues([1], [1], 2)
    NULL_SLO.backpressure(5)
    assert NULL_SLO.snapshot() == {}
    assert not NULL_SLO.enabled


def test_slo_hist_edges_cover_wide_range():
    t = SloTracker()
    t._ttv.observe(1e-7)      # below first edge
    t._ttv.observe(float("nan"))
    t._ttv.observe(-1.0)
    t._ttv.observe(1e3)       # overflow
    d = t._ttv.to_dict()
    assert d["count"] == 3    # NaN dropped
    assert d["overflow"] == 1
    assert sum(d["counts"]) + d["overflow"] == 3
    assert len(d["edges"]) == len(_EDGES)
