"""Lifetime conformance suite: aging determinism, drift advisories,
and the self-healing recalibrate-and-redeploy loop (hw/aging.py +
hw/redeploy.py + obs/drift.py + SarServingEngine.swap_head).

Locked down here, at increasing strictness:

  * **aging is a pure function of (die, t)** — same seeds + same age
    → bit-identical instance; ``at_age(0)`` IS the birth instance;
    ages are absolute (re-aging an aged die raises);
  * **drift grows monotonically and trips the gate** — the probe-block
    z statistic against the calibration-time belief rises with field
    age and crosses the |z| > 5 advisory gate;
  * **no false positives** — a golden die streaming forever never
    draws an advisory, while an uncalibrated severity-2.5 die is
    flagged from the same probe (the obs/drift CLI separation check,
    promoted to pytest with explicit thresholds);
  * **hot-swap is invisible** — a healed head swapped into a running
    engine serves bit-identical verdicts to a cold-built engine on the
    same recalibrated aged instance, and rebuilds NO slot-plumbing
    executables (scatter / stats_reset compile counters are flat);
  * **the closed loop actually closes** (slow tier) — a served aged
    die raises an advisory before its accuracy deviation exceeds the
    PR 2 uncalibrated bound, and healing returns it to the calibrated
    band while the no-heal arm stays degraded.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng as g
from repro.core.bayes_layer import sigma_of
from repro.core.sampling import BayesHeadConfig
from repro.hw import (VariationSpec, prepare_instance_head,
                      sample_instances)
from repro.hw.aging import AgingSpec, age_factors, at_age
from repro.hw.redeploy import (LifetimeConfig, SelfHealingController,
                               aged_belief_view, recalibrate)
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.obs.drift import DriftGate, DriftMonitor, reference_for
from repro.serving import ServingMetrics, TriagePolicy

DAY = 86400.0
SEV = VariationSpec().scaled(2.5)
UNCAL_BOUND = 0.183      # PR 2 uncalibrated acc-dev at severity 2.5
HEALED_BOUND = 0.014     # 2x the PR 2 calibrated acc-dev bound


def _chip(seed: int = 11):
    return sample_instances(seed, 1, SEV)[0]


@pytest.fixture(scope="module")
def sar():
    cfg = SarCnnConfig()
    return init_sar_cnn(jax.random.PRNGKey(3), cfg), cfg


def _base_hcfg(cfg, hoist: bool = False) -> BayesHeadConfig:
    return BayesHeadConfig(num_samples=20, mode="rank16", grng=cfg.grng,
                           compute_dtype=jnp.float32, hoist_basis=hoist)


# ----------------------------------------------------------------------
# aging determinism
# ----------------------------------------------------------------------
def test_aging_deterministic_bit_identity():
    """Same die + same age → bit-identical instance, across separately
    sampled copies (rates are keyed by serialized seeds, never stored)."""
    a = _chip().at_age(30 * DAY)
    b = _chip().at_age(30 * DAY)
    ta, tb = a.to_tree(), b.to_tree()
    assert (jax.tree_util.tree_structure(ta)
            == jax.tree_util.tree_structure(tb))
    for la, lb in zip(jax.tree_util.tree_leaves(ta),
                      jax.tree_util.tree_leaves(tb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # a different die ages differently (per-die rate draw)
    c = _chip(12).at_age(30 * DAY)
    assert (c.imprint, c.read_sigma) != (a.imprint, a.read_sigma)


def test_at_age_zero_is_the_birth_instance():
    chip = _chip()
    assert chip.at_age(0.0) is chip
    assert age_factors(chip, 0.0) == (1.0, 1.0, 0.0, 0.0)


def test_ages_are_absolute_never_compounded():
    chip = _chip()
    aged = chip.at_age(7 * DAY)
    assert aged.age_s == 7 * DAY
    with pytest.raises(ValueError):
        aged.at_age(30 * DAY)
    with pytest.raises(ValueError):
        chip.at_age(-1.0)


def test_aging_monotone_physics():
    """Imprint, read noise, and spread growth never run backwards."""
    chip = _chip()
    ages = [0.0, 3600.0, DAY, 7 * DAY, 30 * DAY, 90 * DAY]
    dies = [chip.at_age(t) for t in ages]
    imprints = [d.imprint for d in dies]
    sigmas = [d.read_sigma for d in dies]
    gammas = [d.f_gamma for d in dies]
    assert imprints == sorted(imprints) and imprints[-1] > imprints[0]
    assert sigmas == sorted(sigmas) and sigmas[-1] > sigmas[0]
    assert gammas == sorted(gammas)


# ----------------------------------------------------------------------
# drift monotonicity + advisory gate
# ----------------------------------------------------------------------
def _probe_zmax(ref, phys, gate=None) -> tuple[float, bool]:
    mon = DriftMonitor(ref, gate or DriftGate())
    raw = np.asarray(g.raw_sums(phys, 32, 1, 256), dtype=np.float64)
    mon.observe(float(raw.size), float(raw.sum()),
                float((raw ** 2).sum()))
    st = mon.status()
    return max(abs(st.z_mean), abs(st.z_std)), st.drifted


def test_drift_z_grows_with_age_and_crosses_gate(sar):
    _, cfg = sar
    chip = _chip()
    base = _base_hcfg(cfg)
    _, hcfg0 = prepare_instance_head(
        jnp.zeros((16, 2)), jnp.full((16, 2), 0.1), base, chip,
        calibrated=True)
    ref = reference_for(base, hcfg0, calibrated=True)
    zs = []
    for t in (0.0, DAY, 7 * DAY, 30 * DAY, 90 * DAY):
        phys = chip.at_age(t).grng(base.grng)
        zs.append(_probe_zmax(ref, phys)[0])
    assert zs == sorted(zs), f"drift z not monotone in age: {zs}"
    assert zs[0] < DriftGate().z_gate           # fresh die: healthy
    assert zs[-1] > DriftGate().z_gate          # aged die: advisory


def test_golden_die_long_stream_never_advises(sar):
    """False-positive control: a die whose physics matches its belief
    can stream forever without drawing an advisory."""
    _, cfg = sar
    base = _base_hcfg(cfg)
    ref = reference_for(base, None, calibrated=False)
    mon = DriftMonitor(ref, DriftGate())
    for k in range(16):                         # 4096-sample stream
        raw = np.asarray(g.raw_sums(base.grng, 32, 1, 256,
                                    sample0=k * 256), dtype=np.float64)
        mon.observe(float(raw.size), float(raw.sum()),
                    float((raw ** 2).sum()))
        st = mon.status()
        assert not st.drifted, (
            f"false advisory on golden die at block {k}: "
            f"z_mean={st.z_mean:.2f} z_std={st.z_std:.2f}")
    assert max(abs(st.z_mean), abs(st.z_std)) < DriftGate().z_gate


def test_drift_monitor_separates_golden_from_degraded(sar):
    """The obs/drift CLI separation check, as a pytest with explicit
    thresholds: golden die |z| < 5 healthy, uncalibrated severity-2.5
    die |z| > 5 advisory — same probe, same belief."""
    _, cfg = sar
    base = _base_hcfg(cfg)
    ref = reference_for(base, None, calibrated=False)
    z_gold, drifted_gold = _probe_zmax(ref, base.grng)
    z_bad, drifted_bad = _probe_zmax(ref, _chip().grng(base.grng))
    assert z_gold < 5.0 and not drifted_gold
    assert z_bad > 5.0 and drifted_bad


# ----------------------------------------------------------------------
# self-healing controller
# ----------------------------------------------------------------------
def _cumulative_probe(ctl, base, state) -> dict:
    """Fake one segment of CUMULATIVE telemetry: fold a fresh probe
    read of the controller's current aged physics into the running
    counters (device counters never reset)."""
    chip = ctl.chip.at_age(ctl.age_s, ctl.spec) if ctl.age_s else ctl.chip
    raw = np.asarray(g.raw_sums(chip.grng(base.grng), 32, 1, 256),
                     dtype=np.float64)
    state["n"] += raw.size
    state["sum"] += raw.sum()
    state["sumsq"] += (raw ** 2).sum()
    return {"grng": dict(state)}


def test_controller_advises_then_heals_then_quiet(sar):
    _, cfg = sar
    base = _base_hcfg(cfg)
    mu = jnp.zeros((16, 2))
    sg = jnp.full((16, 2), 0.1)
    ctl = SelfHealingController(_chip(), mu, sg, base)
    cum = {"n": 0.0, "sum": 0.0, "sumsq": 0.0}

    st = ctl.observe_snapshot(_cumulative_probe(ctl, base, cum))
    assert not st.drifted and ctl.maybe_heal(st) is None

    ctl.advance(30 * DAY)
    st = ctl.observe_snapshot(_cumulative_probe(ctl, base, cum))
    assert st.drifted and st.advisory
    ev = ctl.maybe_heal(st)
    assert ev is not None and ev.calib_epoch == 1
    assert ctl.hcfg.calib_epoch == 1

    # healed belief matches the aged physics: monitor is quiet again
    st = ctl.observe_snapshot(_cumulative_probe(ctl, base, cum))
    assert not st.drifted
    rep = ctl.report()
    assert rep["heals"] == 1 and rep["age_s"] == 30 * DAY


def test_healed_head_is_cold_deployment_bit_identical(sar):
    """recalibrate() == prepare_instance_head on the aged die: the
    heal path adds nothing beyond the calibration epoch key."""
    _, cfg = sar
    base = _base_hcfg(cfg, hoist=True)
    mu = jnp.zeros((16, 2))
    sg = jnp.full((16, 2), 0.1)
    aged = _chip().at_age(30 * DAY)
    healed, hcfg_h = recalibrate(mu, sg, base, aged, epoch=3)
    import dataclasses
    cold, hcfg_c = prepare_instance_head(
        mu, sg, dataclasses.replace(base, calib_epoch=3), aged,
        calibrated=True)
    assert hcfg_h == hcfg_c and hcfg_h.calib_epoch == 3
    assert set(healed) == set(cold)
    for k in healed:
        np.testing.assert_array_equal(np.asarray(healed[k]),
                                      np.asarray(cold[k]), err_msg=k)


# ----------------------------------------------------------------------
# engine hot-swap
# ----------------------------------------------------------------------
def _drain(engine, reqs) -> list[tuple]:
    start = len(engine.metrics.records)
    for r in reqs:
        engine.submit(r)
    engine.run()
    return [(r.rid, r.verdict, r.n_samples, r.confidence,
             r.mutual_information)
            for r in engine.metrics.records[start:]]


def test_hot_swap_bit_identity_and_no_foreign_rebuilds(sar):
    """A healed head swapped into a RUNNING engine must serve exactly
    what a cold-built engine on the same recalibrated aged instance
    serves — and the swap must rebuild only the head-dependent
    executables (featurize/round), never the slot plumbing."""
    from repro.launch.serve import make_sar_stream
    from repro.obs.prof import builder_builds
    from repro.serving.engine import SarServingEngine

    params, cfg = sar
    chip = _chip()
    mu, sg = params["head"]["mu"], sigma_of(params["head"])
    base = _base_hcfg(cfg, hoist=True)
    pol = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05, r_max=20)

    ctl = SelfHealingController(chip, mu, sg, base)
    hot = SarServingEngine(params, cfg, n_slots=8, policy=pol,
                           metrics=ServingMetrics(),
                           head=ctl.head, hcfg=ctl.hcfg, chip=chip)
    stream = make_sar_stream(32, image_size=cfg.image_size)
    seg1 = _drain(hot, stream[:16])
    assert len(seg1) == 16

    # drift arrives, the loop heals, the healed head hot-swaps in
    ctl.advance(30 * DAY)
    ctl.heal()
    before = builder_builds()
    hot.swap_head(*ctl.view())
    seg2 = _drain(hot, stream[16:])
    after = builder_builds()
    for name in ("scatter", "stats_reset"):
        assert after.get(name, 0) == before.get(name, 0), (
            f"hot-swap rebuilt the {name} executable")

    # cold engine on the recalibrated aged instance, same requests.
    # Each decision owns a fixed region of the global selection stream
    # (keyed by the engine's decision counter), and swap_head preserves
    # that counter — so a faithful cold redeploy resumes at the same
    # stream position.
    aged = chip.at_age(30 * DAY)
    cold = SarServingEngine(params, cfg, n_slots=8, policy=pol,
                            metrics=ServingMetrics(),
                            head=ctl.head, hcfg=ctl.hcfg, chip=aged)
    cold._decision_counter = 16
    want = _drain(cold, make_sar_stream(32, image_size=cfg.image_size)[16:])
    assert seg2 == want, "hot-swapped engine diverged from cold build"


def test_swap_head_refuses_while_slots_active(sar):
    from repro.serving.engine import SarServingEngine
    params, cfg = sar
    eng = SarServingEngine(params, cfg, n_slots=4,
                           policy=TriagePolicy(),
                           metrics=ServingMetrics())
    eng.free.pop()                  # one slot in flight
    with pytest.raises(RuntimeError):
        eng.swap_head({}, _base_hcfg(cfg))


# ----------------------------------------------------------------------
# serving: un-aged lifetime path is the plain path
# ----------------------------------------------------------------------
def test_inactive_lifetime_serve_bit_identical(sar):
    from repro.launch.serve import serve_sar, serve_sar_lifetime
    params, cfg = sar
    chip = _chip()
    a = serve_sar(n_requests=16, n_slots=8, chip_instance=chip,
                  params=params, cfg=cfg)
    b = serve_sar_lifetime(lifetime=LifetimeConfig(), chip_instance=chip,
                           n_requests=16, n_slots=8, params=params,
                           cfg=cfg)
    assert not b["lifetime"]["active"]
    assert a["verdicts"] == b["verdicts"]
    assert a["host_syncs"] == b["host_syncs"]


def test_inactive_lifetime_mission_bit_identical(sar):
    from repro.mission import (MissionPolicy, UavConfig, WorldConfig,
                               fly_mission)
    params, cfg = sar
    kw = dict(params=params, cfg=cfg, n_steps=10)
    wcfg = WorldConfig(grid=6, n_victims=3, seed=2)
    ucfg = UavConfig(n_drones=2, battery_J=120e-6)
    a = fly_mission(wcfg, ucfg, MissionPolicy(), **kw)
    b = fly_mission(wcfg, ucfg, MissionPolicy(),
                    lifetime=LifetimeConfig(), **kw)
    assert a.host_syncs == b.host_syncs
    for k in a.logs:
        np.testing.assert_array_equal(a.logs[k], b.logs[k], err_msg=k)


# ----------------------------------------------------------------------
# the closed loop, end to end (slow tier: trains the SAR detector)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def trained():
    from benchmarks.serving_bench import trained_params
    cfg = SarCnnConfig()
    return trained_params(cfg), cfg


@pytest.mark.slow
def test_advisory_fires_before_accuracy_exceeds_uncal_bound(trained):
    """The monitor must flag the die while its verdicts are still far
    inside the PR 2 uncalibrated deviation bound — drift is caught
    early, not after the fleet has degraded to uncalibrated levels."""
    from benchmarks.hw_variation import (_chip_features, _eval_head,
                                         _eval_images)
    from repro.hw import golden_instance
    from repro.core.sampling import prepare_serving_head

    params, cfg = trained
    chip = _chip()
    base = _base_hcfg(cfg)
    mu, sg = params["head"]["mu"], sigma_of(params["head"])
    head0, hcfg0 = prepare_instance_head(mu, sg, base, chip,
                                         calibrated=True)
    ref = reference_for(base, hcfg0, calibrated=True)

    # earliest advisory age on a geometric scan
    t_fire = None
    for t in (3600.0 * 2 ** k for k in range(14)):   # 1 h .. ~1 yr
        if _probe_zmax(ref, chip.at_age(t).grng(base.grng))[1]:
            t_fire = t
            break
    assert t_fire is not None, "advisory never fired within a year"

    # at that age the stale head is still far inside the uncal bound
    images = _eval_images(cfg)
    eval_sets = _chip_features(params, cfg, images, chip)
    gold_sets = _chip_features(params, cfg, images,
                               golden_instance(cfg.grng))
    gold = prepare_serving_head(mu, sg, base)
    aged = chip.at_age(t_fire)
    sh, shc = aged_belief_view(head0, hcfg0, aged, cfg.grng)
    for (name, feats, labels), (_, gfeats, glabels) in zip(eval_sets,
                                                           gold_sets):
        dev = abs(_eval_head(sh, shc, feats, labels)["accuracy"]
                  - _eval_head(gold, base, gfeats, glabels)["accuracy"])
        assert dev < UNCAL_BOUND, (
            f"advisory too late: {name} acc-dev {dev:.3f} already at "
            f"uncalibrated levels when the gate fired (t={t_fire:.0f}s)")


@pytest.mark.slow
def test_heal_returns_to_calibrated_band_stale_stays_out(trained):
    """At 30 field-days the severity-2.5 die's stale head is outside
    the calibrated band; recalibrate-and-redeploy brings it back in."""
    from benchmarks.hw_variation import (_chip_features, _eval_head,
                                         _eval_images)
    from repro.hw import golden_instance
    from repro.core.sampling import prepare_serving_head

    params, cfg = trained
    chip = _chip()
    base = _base_hcfg(cfg)
    mu, sg = params["head"]["mu"], sigma_of(params["head"])
    images = _eval_images(cfg)
    eval_sets = _chip_features(params, cfg, images, chip)
    gold_sets = _chip_features(params, cfg, images,
                               golden_instance(cfg.grng))
    gold = prepare_serving_head(mu, sg, base)
    golden_acc = {n: _eval_head(gold, base, f, l)["accuracy"]
                  for n, f, l in gold_sets}

    head0, hcfg0 = prepare_instance_head(mu, sg, base, chip,
                                         calibrated=True)
    aged = chip.at_age(30 * DAY)
    stale = aged_belief_view(head0, hcfg0, aged, cfg.grng)
    healed = recalibrate(mu, sg, base, aged, epoch=1)
    name, feats, labels = eval_sets[0]          # clean split
    dev_stale = abs(_eval_head(*stale, feats, labels)["accuracy"]
                    - golden_acc[name])
    dev_healed = abs(_eval_head(*healed, feats, labels)["accuracy"]
                     - golden_acc[name])
    assert dev_stale > HEALED_BOUND, (
        f"aged die not degraded (stale clean acc-dev {dev_stale:.4f})")
    assert dev_healed <= HEALED_BOUND, (
        f"heal failed: clean acc-dev {dev_healed:.4f} > {HEALED_BOUND}")


@pytest.mark.slow
def test_serve_lifetime_closed_loop(sar):
    """Aged serving raises an advisory; auto_recalibrate heals it while
    the no-heal arm ends the stream still drifted."""
    from repro.launch.serve import serve_sar_lifetime
    params, cfg = sar
    chip = _chip()
    kw = dict(chip_instance=chip, n_requests=64, n_slots=8,
              params=params, cfg=cfg)
    rate = 30 * DAY / 64
    healed = serve_sar_lifetime(
        lifetime=LifetimeConfig(age_rate=rate, epochs=4,
                                auto_recalibrate=True), **kw)
    lt = healed["lifetime"]
    assert lt["advisories"] >= 1 and lt["heals"] >= 1
    assert lt["calib_epoch"] >= 1
    assert not lt["status"]["drifted"]

    stale = serve_sar_lifetime(
        lifetime=LifetimeConfig(age_rate=rate, epochs=4,
                                auto_recalibrate=False), **kw)
    lt = stale["lifetime"]
    assert lt["advisories"] >= 1 and lt["heals"] == 0
    assert lt["status"]["drifted"], "no-heal arm should stay degraded"
