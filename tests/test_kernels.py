"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng as g
from repro.core.quant import QuantConfig
from repro.kernels import ops, ref

CFG = g.GRNGConfig()


# ----------------------------------------------------------------------
# clt_grng kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (200, 130),
                                   (64, 512), (1, 1)])
@pytest.mark.parametrize("r", [1, 8])
def test_grng_eps_matches_oracle(shape, r):
    k, n = shape
    got = ops.grng_eps(CFG, k, n, r, interpret=True)
    want = ref.grng_eps_ref(CFG, k, n, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_grng_eps_offsets_match_global_grid():
    """Block offsets must reproduce the corresponding global sub-block."""
    full = ref.grng_eps_ref(CFG, 64, 64, 4)
    blk = ops.grng_eps(CFG, 32, 32, 4, row0=16, col0=16, interpret=True)
    np.testing.assert_allclose(np.asarray(blk),
                               np.asarray(full[:, 16:48, 16:48]),
                               rtol=1e-5, atol=1e-5)


def test_grng_eps_sample_offset():
    a = ops.grng_eps(CFG, 32, 32, 6, sample0=0, interpret=True)
    b = ops.grng_eps(CFG, 32, 32, 2, sample0=4, interpret=True)
    np.testing.assert_allclose(np.asarray(a[4:]), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# bayes_mvm kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 128, 128), (8, 256, 192),
                                   (3, 130, 70)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["rank16", "paper"])
def test_bayes_mvm_matches_oracle(shape, dtype, mode):
    b, k, n = shape
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, k), dtype)
    mu = (jax.random.normal(k2, (k, n)) * 0.05).astype(dtype)
    sigma = (jax.nn.softplus(jax.random.normal(k3, (k, n)) - 2.0) * 0.1).astype(dtype)
    r = 5
    got = ops.bayes_head_mvm(x, mu, sigma, CFG, r, mode=mode, interpret=True)
    want = ref.bayes_mvm_ref(x, mu, sigma, CFG, r)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_modes_agree_exactly():
    """rank16 and paper modes must produce the SAME samples (not just the
    same distribution) — the rank-16 factorization is exact."""
    b, k, n, r = 4, 128, 128, 7
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, k), jnp.float32)
    mu = jax.random.normal(k2, (k, n)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(k3, (k, n)) - 2.0) * 0.1
    a = ops.bayes_head_mvm(x, mu, sigma, CFG, r, mode="rank16", interpret=True)
    p = ops.bayes_head_mvm(x, mu, sigma, CFG, r, mode="paper", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(p),
                               rtol=1e-4, atol=1e-4)


def test_bayes_mvm_adc_matches_oracle():
    qcfg = QuantConfig(enabled=True)
    b, k, n, r = 4, 128, 128, 3
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, k), jnp.float32)
    mu = jax.random.normal(k2, (k, n)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(k3, (k, n)) - 2.0) * 0.1
    got = ops.bayes_head_mvm(x, mu, sigma, CFG, r, mode="paper", qcfg=qcfg,
                             interpret=True)
    want = ref.bayes_mvm_adc_ref(x, mu, sigma, CFG, qcfg, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bayes_mvm_matches_core_sampling():
    """Kernel path ≡ core/sampling.py jnp path (serving integration)."""
    from repro.core.sampling import BayesHeadConfig, logit_samples
    b, k, n, r = 2, 128, 192, 4
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, k), jnp.float32)
    mu = jax.random.normal(k2, (k, n)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(k3, (k, n)) - 2.0) * 0.1
    hcfg = BayesHeadConfig(num_samples=r, mode="rank16", grng=CFG,
                           compute_dtype=jnp.float32)
    head = {"mu_prime": mu, "sigma": sigma}
    want = logit_samples(head, x, hcfg)
    got = ops.bayes_head_mvm(x, mu, sigma, CFG, r, mode="rank16",
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
# cim_mvm kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 128, 128), (4, 256, 96), (130, 192, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cim_mvm_matches_oracle(shape, dtype):
    b, k, n = shape
    qcfg = QuantConfig(enabled=True)
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (b, k), dtype)
    w = (jax.random.normal(k2, (k, n)) * 0.05).astype(dtype)
    got = ops.cim_matmul(x, w, qcfg, interpret=True)
    x32, w32 = x.astype(jnp.float32), w.astype(jnp.float32)
    fs = ops._measured_full_scale(x, w, qcfg)
    want = ref.cim_mvm_ref(x32, w32, qcfg, fs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_cim_mvm_nonideal_matches_oracle():
    """Per-column ADC gain/offset path vs its oracle; zero-variation
    parameters must reproduce the ideal kernel bit-for-bit (acceptance
    criterion for the repro/hw nonideal path)."""
    qcfg = QuantConfig(enabled=True)
    key = jax.random.PRNGKey(6)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    b, k, n = 8, 192, 130
    x = jax.random.normal(k1, (b, k))
    w = jax.random.normal(k2, (k, n)) * 0.05
    gain = 1.0 + 0.05 * jax.random.normal(k3, (n,))
    off = 0.5 * jax.random.normal(k4, (n,))
    got = ops.cim_matmul_nonideal(x, w, qcfg, gain, off, interpret=True)
    fs = ops._measured_full_scale(x, w, qcfg)
    want = ref.cim_mvm_nonideal_ref(x, w, qcfg, fs, gain, off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # zero-variation == ideal path, and == the zero-variation oracle
    ones, zeros = jnp.ones((n,)), jnp.zeros((n,))
    ideal = ops.cim_matmul(x, w, qcfg, interpret=True)
    got0 = ops.cim_matmul_nonideal(x, w, qcfg, ones, zeros, interpret=True)
    np.testing.assert_array_equal(np.asarray(got0), np.asarray(ideal))
    np.testing.assert_allclose(
        np.asarray(got0),
        np.asarray(ref.cim_mvm_nonideal_ref(x, w, qcfg, fs, ones, zeros)),
        rtol=1e-4, atol=1e-4)


def test_grng_eps_kernel_matches_oracle_with_read_noise():
    """Degraded-instance ε kernel: bit-compatible read noise, stream
    extension across sample0 preserved."""
    import dataclasses
    cfg = dataclasses.replace(CFG, read_sigma=0.4)
    got = ops.grng_eps(cfg, 128, 128, 6, interpret=True)
    want = ref.grng_eps_ref(cfg, 128, 128, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    tail = ops.grng_eps(cfg, 128, 128, 2, sample0=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got[4:]), np.asarray(tail),
                               rtol=1e-5, atol=1e-5)


def test_bayes_mvm_paper_mode_matches_oracle_with_read_noise():
    import dataclasses
    cfg = dataclasses.replace(CFG, read_sigma=0.4)
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (3, 128))
    mu = jax.random.normal(k2, (128, 128)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(k3, (128, 128)) - 2.0) * 0.1
    got = ops.bayes_head_mvm(x, mu, sigma, cfg, 4, sample0=2, mode="paper",
                             interpret=True)
    want = ref.bayes_mvm_ref(x, mu, sigma, cfg, 4, sample0=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_bayes_mvm_rank16_mode_matches_oracle_with_read_noise():
    """Degraded-instance rank16 kernel: logit-level noise projection,
    keyed by the absolute sample index (stream-extension-exact)."""
    import dataclasses
    cfg = dataclasses.replace(CFG, read_sigma=0.4)
    key = jax.random.PRNGKey(8)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (3, 200))
    mu = jax.random.normal(k2, (200, 150)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(k3, (200, 150)) - 2.0) * 0.1
    got = ops.bayes_head_mvm(x, mu, sigma, cfg, 6, mode="rank16",
                             interpret=True)
    want = ref.bayes_mvm_rank16_ref(x, mu, sigma, cfg, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # stream extension: samples [2:6] reproduce a draw starting at 2
    tail = ops.bayes_head_mvm(x, mu, sigma, cfg, 4, sample0=2,
                              mode="rank16", interpret=True)
    np.testing.assert_allclose(np.asarray(got[2:]), np.asarray(tail),
                               rtol=1e-4, atol=1e-4)
    # the noise term is exactly additive: kernel(σ_r) − kernel(0) must
    # reproduce the oracle's projection term (so read_sigma = 0 adds
    # nothing beyond the ideal kernel, which the per-mode oracle sweeps
    # above already pin down)
    got0 = ops.bayes_head_mvm(
        x, mu, sigma, dataclasses.replace(cfg, read_sigma=0.0), 6,
        mode="rank16", interpret=True)
    want0 = ref.bayes_mvm_rank16_ref(
        x, mu, sigma, dataclasses.replace(cfg, read_sigma=0.0), 6)
    np.testing.assert_allclose(np.asarray(got0), np.asarray(want0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got - got0),
                               np.asarray(want - want0),
                               rtol=1e-4, atol=1e-4)


def test_cim_mvm_snr_reasonable():
    """6-bit chunked ADC keeps the MVM SNR high enough for inference."""
    qcfg = QuantConfig(enabled=True)
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (32, 512))
    w = jax.random.normal(k2, (512, 256)) * 0.05
    y = ops.cim_matmul(x, w, qcfg, interpret=True)
    exact = x @ w
    snr = 10 * np.log10(float(jnp.mean(exact**2) / jnp.mean((y - exact) ** 2)))
    assert snr > 15.0, f"ADC path SNR too low: {snr:.1f} dB"
