"""Fault-tolerance substrate: checkpoint/restart, elastic, straggler."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.data.tokens import TokenPipelineConfig, batch_at
from repro.runtime import (StragglerConfig, StragglerMonitor,
                           make_elastic_mesh, remesh_train_state, shrink_mesh)


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------
def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (17, 13)),
            "b": {"c": jax.random.normal(k2, (5,)),
                  "count": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save(tmp_path, 42, tree)
    out, step = restore(tmp_path)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_publish_and_gc(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save(tmp_path, s, _tree(jax.random.PRNGKey(s)), keep_last=2)
    assert latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_0000000004", "step_0000000005"]


def test_checksum_detects_corruption(tmp_path):
    save(tmp_path, 1, _tree(jax.random.PRNGKey(0)))
    shard = tmp_path / "step_0000000001" / "shard_0.bin.zst"
    blob = bytearray(shard.read_bytes())
    # corrupt the compressed payload -> either zstd or crc must fail
    blob[len(blob) // 2] ^= 0xFF
    shard.write_bytes(bytes(blob))
    with pytest.raises(Exception):
        restore(tmp_path, 1)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep_last=2)
    for s in (10, 20):
        ck.submit(s, _tree(jax.random.PRNGKey(s)))
    ck.wait()
    assert latest_step(tmp_path) == 20


# ----------------------------------------------------------------------
# exact resume: train N steps == train k, crash, resume, train N-k
# ----------------------------------------------------------------------
def test_resume_is_bit_exact(tmp_path):
    from repro.launch.train import train
    full = train("qwen3-0.6b", smoke=True, steps=6, batch=2, seq=16,
                 ckpt_dir=None)
    # crash-and-resume run
    ck = tmp_path / "ck"
    with pytest.raises(SystemExit):
        train("qwen3-0.6b", smoke=True, steps=6, batch=2, seq=16,
              ckpt_dir=str(ck), ckpt_every=3, fail_at=4)
    resumed = train("qwen3-0.6b", smoke=True, steps=6, batch=2, seq=16,
                    ckpt_dir=str(ck), ckpt_every=3)
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"],
                               rtol=1e-4)


# ----------------------------------------------------------------------
# data pipeline determinism
# ----------------------------------------------------------------------
def test_pipeline_pure_function_of_step():
    cfg = TokenPipelineConfig(vocab=97, seq_len=12, global_batch=4)
    a = batch_at(cfg, 7)
    b = batch_at(cfg, 7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = batch_at(cfg, 8)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_pipeline_learnable_structure():
    cfg = TokenPipelineConfig(vocab=97, seq_len=64, global_batch=8,
                              noise=0.1)
    b = batch_at(cfg, 0)
    toks, labs = np.asarray(b["tokens"]), np.asarray(b["labels"])
    pred = (toks * cfg.a + cfg.c) % cfg.vocab
    agreement = (pred == labs).mean()
    assert agreement > 0.8          # mostly-deterministic bigram


# ----------------------------------------------------------------------
# straggler monitor
# ----------------------------------------------------------------------
def test_straggler_detection():
    mon = StragglerMonitor(StragglerConfig(threshold=2.0, patience=2,
                                           policy="rebatch"))
    for s in range(10):
        mon.end_step(s, duration=1.0)
    r1 = mon.end_step(10, duration=5.0)
    assert r1["flagged"] and r1["action"] is None
    r2 = mon.end_step(11, duration=5.0)
    assert r2["action"] == "rebatch"
    assert mon.microbatch_share(8) == 4
    # EMA not poisoned by the stall
    assert mon.ema < 1.5


# ----------------------------------------------------------------------
# elastic re-mesh
# ----------------------------------------------------------------------
def test_elastic_shrink_and_continue():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (run under forced host device count)")
    mesh = make_elastic_mesh(devs)
    lost = {devs[-1].id}
    new_mesh = shrink_mesh(mesh, lost)
    assert new_mesh.devices.size < mesh.devices.size
    params = {"head": {"mu": jnp.ones((8, 16)), "rho": jnp.zeros((8, 16))}}
    opt = {"mu": jax.tree.map(jnp.zeros_like, params),
           "nu": jax.tree.map(jnp.zeros_like, params),
           "count": jnp.int32(0)}
    p2, o2 = remesh_train_state(params, opt, new_mesh)
    np.testing.assert_array_equal(np.asarray(p2["head"]["mu"]),
                                  np.asarray(params["head"]["mu"]))
