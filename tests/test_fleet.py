"""Mesh-of-pools fleet tests that run on ONE device (the sequential
dispatch fallback): router determinism, admission backpressure, and
fleet-level energy/telemetry reconciliation.

The multi-device gates — gang-dispatch bit-identity vs standalone
pools and the shard_map-native kernel equivalence — live in
tests/test_spmd.py (fresh subprocess with forced host devices)."""

import jax
import numpy as np
import pytest

from repro.launch.serve import make_sar_stream, sar_layer_shapes
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.serving import SarServingEngine, TriagePolicy
from repro.serving.fleet import SarServingFleet
from repro.serving.metrics import request_energy

CFG = SarCnnConfig()
POLICY = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05,
                      r_min=4, r_max=12)


@pytest.fixture(scope="module")
def params():
    return init_sar_cnn(jax.random.PRNGKey(3), CFG)


def _verdicts(metrics_records):
    return {r.rid: (int(r.prediction), r.verdict, int(r.n_samples),
                    float(r.confidence), float(r.mutual_information))
            for r in metrics_records}


def test_single_pool_fleet_matches_standalone_engine(params):
    """A 1-pool fleet is the engine plus a trivial router — verdicts,
    sample counts and confidences must be bit-for-bit identical."""
    eng = SarServingEngine(params, CFG, n_slots=8, policy=POLICY,
                           adaptive_mode=True)
    for r in make_sar_stream(20, corrupt_frac=0.25, batch=8):
        eng.submit(r)
    eng.run()
    ref = _verdicts(eng.metrics.records)

    fleet = SarServingFleet(params, CFG, n_pools=1, slots_per_pool=8,
                            policy=POLICY, adaptive_mode=True)
    for r in make_sar_stream(20, corrupt_frac=0.25, batch=8):
        fleet.submit(r)
    out = fleet.run()
    got = _verdicts(fleet.engines[0].metrics.records)

    assert set(ref) == set(got) == set(range(20))
    for rid in ref:
        assert ref[rid] == got[rid], (rid, ref[rid], got[rid])
    assert out["gang"] is False
    assert out["routed_per_pool"] == [20]


def test_router_is_consistent_least_loaded(params):
    """Same submission sequence → same routes, and the router balances:
    with equal pools the split is even."""
    outs = []
    for _ in range(2):
        fleet = SarServingFleet(params, CFG, n_pools=2, slots_per_pool=4,
                                policy=POLICY)
        for r in make_sar_stream(16, batch=8):
            fleet.submit(r)
        fleet.run()
        outs.append(dict(fleet.routes))
    assert outs[0] == outs[1]
    counts = [sum(1 for p in outs[0].values() if p == q) for q in (0, 1)]
    assert counts == [8, 8]


def test_router_backpressure_skips_saturated_pool(params):
    """ISSUE satellite: a pool with a full admission queue must receive
    NOTHING (backpressure), traffic goes to pools with headroom, and
    when every pool is saturated the remainder holds in the fleet
    backlog — then drains to completion once capacity frees."""
    fleet = SarServingFleet(params, CFG, n_pools=2, slots_per_pool=4,
                            policy=POLICY, queue_cap=2)
    stream = make_sar_stream(10, batch=8)
    # saturate pool 0's admission queue out-of-band (as if earlier
    # traffic filled it): queue length == queue_cap
    for r in stream[:2]:
        fleet.engines[0].queue.append(r)
    for r in stream[2:]:
        fleet.submit(r)
    fleet._route()
    # pool 0 saturated: none of the new requests may land there
    assert len(fleet.engines[0].queue) == 2
    assert all(p == 1 for p in fleet.routes.values())
    # pool 1 absorbed up to its cap; the rest held in the fleet backlog
    assert len(fleet.engines[1].queue) == 2
    assert len(fleet.backlog) == 6
    assert fleet.backlog_peak >= 6

    out = fleet.run()
    # backpressure is flow control, not loss: every request retires
    assert out["requests"] == 10
    assert out["decisions"] == 10
    assert len(fleet.backlog) == 0
    assert all(not e.queue for e in fleet.engines)
    # once pool 0 drained its queue, later backlog items reached it
    assert sum(1 for p in fleet.routes.values() if p == 0) > 0


def test_fleet_energy_reconciles_to_per_request_sum(params):
    """Σ_pools Σ_requests request_energy ≡ fleet ``energy_total_J`` —
    the fleet summary is an exact sum of pool sums, which are exact
    sums of per-record energies (no double counting, nothing dropped)."""
    layers = sar_layer_shapes(CFG)
    fleet = SarServingFleet(params, CFG, n_pools=2, slots_per_pool=8,
                            policy=POLICY, layers=layers)
    for r in make_sar_stream(24, corrupt_frac=0.25, batch=8):
        fleet.submit(r)
    out = fleet.run()
    per_record = sum(request_energy(r, layers)
                     for eng in fleet.engines
                     for r in eng.metrics.records)
    per_pool = sum(e.metrics.summary()["energy_total_J"]
                   for e in fleet.engines)
    assert out["energy_total_J"] == pytest.approx(per_record, rel=1e-9)
    assert out["energy_total_J"] == pytest.approx(per_pool, rel=1e-12)
    # per-pool breakdown rides in the summary and reconciles too
    assert sum(p["energy_total_J"] for p in out["pools"]) == \
        pytest.approx(out["energy_total_J"], rel=1e-12)


def test_fleet_telemetry_merges_without_double_counting(params):
    """Each request's device-telemetry counters live in exactly one
    pool's snapshot; the merged fleet snapshot must equal the sums."""
    fleet = SarServingFleet(params, CFG, n_pools=2, slots_per_pool=8,
                            policy=POLICY, telemetry=True)
    for r in make_sar_stream(24, corrupt_frac=0.25, batch=8):
        fleet.submit(r)
    out = fleet.run()
    snaps = [e.metrics.telemetry for e in fleet.engines]
    assert all(s is not None for s in snaps)
    merged = out["telemetry"]
    for key in ("rounds", "dispatches", "samples", "decisions"):
        assert merged[key] == sum(s[key] for s in snaps), key
    # decisions counted on-device must equal the host-side retirements
    assert merged["decisions"] == out["decisions"] == 24
    # sample spend also reconciles with the host-side mean
    host_samples = sum(r.n_samples for e in fleet.engines
                       for r in e.metrics.records)
    assert merged["samples"] == host_samples
