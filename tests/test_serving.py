"""Serving subsystem: adaptive statistics, engine invariants, triage.

Three claims are load-bearing and tested here:

  1. the incremental (running-sum) predictive statistics equal
     core.uncertainty.predictive_stats on the same samples, and
     escalation via ``sample0`` stream offsets EXTENDS the GRNG stream —
     the union of rounds is bit-identical to one large draw, so a fully
     escalated request computes exactly the fixed-R distribution;
  2. the continuous-batching engine's slot bookkeeping: every request
     retires exactly once, sample spend is bounded by the policy, slots
     return to the free pool, and mid-batch admission is numerically
     faithful for RoPE transformers;
  3. the triage policy is monotone in its thresholds and collapses to
     the fixed-R rule at the sample budget — on clean AND corrupted
     SARD batches.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sampling import (BayesHeadConfig, activation_basis,
                                 logit_samples_rank16, mix_samples,
                                 prepare_serving_head)
from repro.core.uncertainty import predictive_stats
from repro.serving import (ACCEPT, ESCALATE, FLAG, Request,
                           SarServingEngine, TriagePolicy, decide,
                           escalation_schedule, finalize, fixed_r_decide,
                           init_stats, stream_selections, update_stats)


def _head_and_x(k=32, n=8, b=5, hoist=True):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    mu = jax.random.normal(k1, (k, n)) * 0.05
    sg = jax.nn.softplus(jax.random.normal(k2, (k, n)) - 3) * 0.2
    cfg = BayesHeadConfig(num_samples=20, mode="rank16",
                          compute_dtype=jnp.float32, hoist_basis=hoist)
    head = prepare_serving_head(mu, sg, cfg)
    x = jax.random.normal(k3, (b, k))
    return head, x, cfg


# ----------------------------------------------------------------------
# 1. adaptive statistics
# ----------------------------------------------------------------------
def test_running_stats_match_predictive_stats():
    head, x, cfg = _head_and_x()
    samples = logit_samples_rank16(head, x, cfg, num_samples=20)
    ref = predictive_stats(samples)
    stats = init_stats(x.shape[0], samples.shape[-1])
    # fold in uneven chunks — escalation-round shaped
    for lo, hi in ((0, 4), (4, 12), (12, 20)):
        stats = update_stats(stats, samples[lo:hi])
    fin = finalize(stats)
    for key in ("probs", "confidence", "predictive_entropy",
                "expected_entropy", "mutual_information"):
        np.testing.assert_allclose(np.asarray(fin[key]),
                                   np.asarray(ref[key]), atol=1e-5,
                                   err_msg=key)
    np.testing.assert_array_equal(np.asarray(fin["prediction"]),
                                  np.asarray(ref["prediction"]))
    assert int(fin["n"][0]) == 20


def test_stream_extension_matches_single_draw():
    """Rounds at consecutive sample0 offsets == one large draw."""
    head, x, cfg = _head_and_x()
    ab = activation_basis(head, x, cfg)
    b = x.shape[0]
    base = jnp.asarray(np.arange(b, dtype=np.uint32) * 100)
    full = mix_samples(ab, stream_selections(cfg.grng, base,
                                             jnp.zeros(b, jnp.int32), 12),
                       cfg)
    parts = []
    drawn = jnp.zeros(b, jnp.int32)
    for r in (4, 8):
        parts.append(mix_samples(
            ab, stream_selections(cfg.grng, base, drawn, r), cfg))
        drawn = drawn + r
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, 0)),
                               np.asarray(full), rtol=1e-6)


def test_hoisted_basis_matches_rehash():
    head_h, x, cfg_h = _head_and_x(hoist=True)
    head_r, _, cfg_r = _head_and_x(hoist=False)
    assert "sigma_basis" in head_h and "sigma_basis" not in head_r
    s_h = logit_samples_rank16(head_h, x, cfg_h)
    s_r = logit_samples_rank16(head_r, x, cfg_r)
    np.testing.assert_allclose(np.asarray(s_h), np.asarray(s_r), atol=1e-5)


def test_tiled_hoist_matches_dense_hoist():
    """hoist_tile_n stores host-resident basis chunks; streamed
    activation_basis must equal the dense hoisted path bit-for-bit."""
    import dataclasses
    head_d, x, cfg = _head_and_x(hoist=True)
    cfg_t = dataclasses.replace(cfg, hoist_tile_n=3)
    from repro.core.sampling import prepare_serving_head as prep
    k1, k2, _ = jax.random.split(jax.random.PRNGKey(0), 3)   # _head_and_x
    mu = jax.random.normal(k1, (32, 8)) * 0.05
    sg = jax.nn.softplus(jax.random.normal(k2, (32, 8)) - 3) * 0.2
    head_t = prep(mu, sg, cfg_t)
    assert "sigma_basis_host" in head_t and "sigma_basis" not in head_t
    assert all(isinstance(blk, np.ndarray)
               for blk in head_t["sigma_basis_host"])
    assert head_t["sigma_basis_host"][0].shape == (32, 3, 16)
    s_t = logit_samples_rank16(head_t, x, cfg_t)
    s_d = logit_samples_rank16(head_d, x, cfg)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_d), atol=1e-5)


def test_engine_runs_on_degraded_chip_instance():
    """The engine's rank-16 fast path serves a sampled chip instance
    unchanged (hw/ digital twin): requests retire, and the degraded
    pool carries the read-noise projection leaf."""
    from repro.core.bayes_layer import sigma_of
    from repro.core.sampling import BayesHeadConfig
    from repro.hw import VariationSpec, prepare_instance_head, \
        sample_instances
    params, cfg = _sar_setup()
    chip = sample_instances(21, 1, VariationSpec().scaled(2.0))[0]
    base = BayesHeadConfig(num_samples=20, mode="rank16", grng=cfg.grng,
                           compute_dtype=jnp.float32, hoist_basis=True)
    head, hcfg = prepare_instance_head(
        params["head"]["mu"], sigma_of(params["head"]), base, chip)
    assert hcfg.grng.read_sigma > 0
    policy = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05,
                          r_min=4, r_max=20)
    eng = SarServingEngine(params, cfg, n_slots=4, policy=policy,
                           adaptive_mode=True, head=head, hcfg=hcfg)
    for r in _sar_requests(8):
        eng.submit(r)
    summary = eng.run()
    assert summary["requests"] == 8
    assert "x_sigsq" in eng.pool
    assert len(eng.free) == eng.n_slots and not eng.queue


def test_escalation_schedule_sums_to_budget():
    pol = TriagePolicy(r_min=4, r_max=20, r_growth=2)
    sched = escalation_schedule(pol)
    assert sum(sched) == 20 and sched[0] == 4
    sched1 = escalation_schedule(TriagePolicy(r_min=20, r_max=20))
    assert sched1 == (20,)


# ----------------------------------------------------------------------
# 2. engine invariants (SAR stream)
# ----------------------------------------------------------------------
def _sar_setup():
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    return params, cfg


def _sar_requests(n, corrupt_frac=0.0):
    from repro.launch.serve import make_sar_stream
    return make_sar_stream(n, corrupt_frac=corrupt_frac, batch=16)


def _run_engine(params, cfg, reqs, policy, adaptive):
    eng = SarServingEngine(params, cfg, n_slots=8, policy=policy,
                           adaptive_mode=adaptive)
    for r in reqs:
        eng.submit(r)
    summary = eng.run()
    return eng, summary


def test_engine_slot_retirement_invariants():
    params, cfg = _sar_setup()
    reqs = _sar_requests(20)
    policy = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05,
                          r_min=4, r_max=20)
    eng, summary = _run_engine(params, cfg, reqs, policy, adaptive=True)
    # every request retired exactly once, queue drained, slots all free
    assert summary["requests"] == 20 and summary["decisions"] == 20
    assert sorted(r.rid for r in eng.metrics.records) == list(range(20))
    assert len(eng.free) == eng.n_slots and not eng.queue
    for rec in eng.metrics.records:
        assert policy.r_min <= rec.n_samples <= policy.r_max
        assert rec.n_samples % policy.r_min == 0
        assert rec.verdict in (ACCEPT, FLAG)
        assert rec.done_s >= rec.admit_s >= 0


def test_engine_full_escalation_equals_fixed_r():
    """With an unbounded ambiguity band the adaptive engine escalates
    every request to r_max; its per-request stats must then be
    IDENTICAL to the fixed-R engine's (same stream regions, same
    samples — exactness of stream extension, end to end)."""
    params, cfg = _sar_setup()
    policy = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05,
                          r_min=4, r_max=20, z=1e9)
    eng_a, _ = _run_engine(params, cfg, _sar_requests(12), policy, True)
    fixed_pol = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05,
                             r_min=4, r_max=20)
    eng_f, _ = _run_engine(params, cfg, _sar_requests(12), fixed_pol, False)
    recs_a = {r.rid: r for r in eng_a.metrics.records}
    recs_f = {r.rid: r for r in eng_f.metrics.records}
    assert set(recs_a) == set(recs_f)
    for rid in recs_a:
        assert recs_a[rid].n_samples == 20 == recs_f[rid].n_samples
        assert recs_a[rid].prediction == recs_f[rid].prediction
        np.testing.assert_allclose(recs_a[rid].confidence,
                                   recs_f[rid].confidence, atol=1e-5)
        np.testing.assert_allclose(recs_a[rid].mutual_information,
                                   recs_f[rid].mutual_information,
                                   atol=1e-5)
        assert recs_a[rid].verdict == recs_f[rid].verdict


def test_engine_oversubscribed_queue_drains():
    params, cfg = _sar_setup()
    reqs = _sar_requests(30, corrupt_frac=0.3)   # 30 reqs, 8 slots
    policy = TriagePolicy(conf_threshold=0.6, mi_threshold=0.05)
    eng, summary = _run_engine(params, cfg, reqs, policy, adaptive=True)
    assert summary["requests"] == 30
    assert summary["mean_samples_per_decision"] <= policy.r_max


# ----------------------------------------------------------------------
# 2b. LM engine: mid-batch admission + retirement
# ----------------------------------------------------------------------
def test_lm_admission_alignment_is_faithful():
    """Left-pad + roll + RoPE re-rotation + start-mask admission equals
    an isolated decode of the same prompt (bf16 tolerance)."""
    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.serving.engine import _rotate_k

    cfg = get_config("qwen3-0.6b", smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    P0, CL, delta = 12, 32, 7
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab)

    cache_ref, _ = api.prefill(params, prompt, cfg, cache_len=CL)
    padded = jnp.concatenate(
        [jnp.zeros((1, P0 - 8), jnp.int32), prompt], 1)
    cache_adm, _ = api.prefill(params, padded, cfg, cache_len=CL,
                               prompt_lengths=jnp.array([8]))
    k = _rotate_k(jnp.roll(cache_adm["k"], delta, axis=2), delta,
                  cfg.rope_theta)
    cache_adm = dict(cache_adm, k=k,
                     v=jnp.roll(cache_adm["v"], delta, axis=2),
                     pos=jnp.int32(P0 + delta),
                     start=cache_adm["start"] + delta)
    tok = prompt[:, -1:]
    for _ in range(2):
        x_ref, cache_ref = api.decode_hidden(params, cache_ref, tok, cfg)
        x_adm, cache_adm = api.decode_hidden(params, cache_adm, tok, cfg)
        ref = np.asarray(x_ref, np.float32)
        adm = np.asarray(x_adm, np.float32)
        denom = max(np.abs(ref).max(), 1e-3)
        assert np.abs(ref - adm).max() / denom < 0.05   # bf16 rounding
        tok = jnp.argmax(x_ref @ params["head"]["mu"].astype(x_ref.dtype),
                         -1)[:, None] % cfg.vocab


def test_lm_engine_continuous_batching():
    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.serving import LMServingEngine

    cfg = get_config("qwen3-0.6b", smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab), np.int32)
    # accept-always policy: every token decides at the first round
    policy = TriagePolicy(conf_threshold=0.0, mi_threshold=1e9,
                          r_min=4, r_max=8)
    eng = LMServingEngine(params, cfg, n_slots=2, prompt_len=8,
                          cache_len=24, policy=policy, adaptive_mode=True)
    for i in range(3):
        eng.submit(Request(rid=i, payload=prompts[i],
                           arrival_s=time.time(), max_new_tokens=2))
    summary = eng.run()
    assert summary["requests"] == 3           # 3rd admitted mid-stream
    assert summary["decisions"] == 6          # 2 tokens each
    assert summary["accept_fraction"] == 1.0
    assert summary["mean_samples_per_decision"] == 4.0
    assert len(eng.free) == eng.n_slots and not eng.queue


def test_ssm_leftpad_admission_pollution_quantified():
    """Quantify the documented SSM admission approximation (ROADMAP open
    item, prefill_ssm docstring): left-padded prefill runs the pad
    prefix through the recurrence, so the admitted state differs from an
    exact re-run of the bare prompt at slot-local positions.  The exact
    reference is built by stepping ``decode_hidden`` from a zeroed
    recurrent state — validated here against whole-prompt prefill (they
    agree to bf16 accumulation noise).  Measured at smoke scale: a
    4-token prompt behind 28 zero-pad tokens lands ~30% off at
    admission and the selective state space forgets the pad within a
    few decode steps (<5% by step 3) — the approximation is sound for
    decode but this test pins its magnitude so a regression (e.g. a
    non-decaying pad contribution) fails loudly."""
    from repro.configs import get_config
    from repro.models.registry import get_api

    cfg = get_config("mamba2-130m", smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    L = cfg.ssm_chunk                  # SSD prefill needs chunk alignment
    CL = 4 * L

    def decode_exact(tokens):
        """Step a zeroed state through ``tokens`` — the exact re-run."""
        c0, _ = api.prefill(params, jnp.zeros((1, L), jnp.int32), cfg,
                            cache_len=CL)
        cache = {k: (jnp.zeros_like(v) if k in ("ssm", "conv") else v)
                 for k, v in c0.items()}
        h = None
        for i in range(tokens.shape[1]):
            h, cache = api.decode_hidden(params, cache, tokens[:, i:i + 1],
                                         cfg)
        return h, cache

    def rel(a, b):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        return float(np.abs(a - b).max() / np.abs(a).max())

    # harness exactness: decode-by-step == aligned whole-prompt prefill
    full = jax.random.randint(jax.random.PRNGKey(5), (1, L), 1, cfg.vocab)
    h_step, _ = decode_exact(full)
    _, h_pre = api.prefill(params, full, cfg, cache_len=CL)
    assert rel(h_pre, h_step) < 0.05

    # admission path: short prompt, long zero pad
    prompt = full[:, :4]
    padded = jnp.concatenate([jnp.zeros((1, 2 * L - 4), jnp.int32), prompt],
                             1)
    cache_adm, h_adm = api.prefill(params, padded, cfg, cache_len=CL,
                                   prompt_lengths=jnp.array([4]))
    h_ref, cache_ref = decode_exact(prompt)
    err0 = rel(h_ref, h_adm)
    assert err0 > 0.01                 # it IS an approximation
    errs = []
    tok = prompt[:, -1:]
    for _ in range(3):
        h_ref, cache_ref = api.decode_hidden(params, cache_ref, tok, cfg)
        h_adm, cache_adm = api.decode_hidden(params, cache_adm, tok, cfg)
        errs.append(rel(h_ref, h_adm))
        tok = (tok + 1) % cfg.vocab
    # the recurrence forgets the pad: monotone-ish decay, <5% by step 3
    assert errs[-1] < 0.05, (err0, errs)
    assert errs[-1] < 0.5 * err0, (err0, errs)


# ----------------------------------------------------------------------
# 3. triage thresholds on clean vs corrupted SARD
# ----------------------------------------------------------------------
def _batch_stats(corruption=None):
    from repro.data.sard import SardConfig, batch_at, corrupted_batch
    from repro.models.sar_cnn import (SarCnnConfig, init_sar_cnn,
                                      logit_samples_serve)
    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    dcfg = SardConfig(image_size=32, seed=7)
    batch = (batch_at(dcfg, 500, 64) if corruption is None
             else corrupted_batch(dcfg, 500, 64, corruption, 1.0))
    samples = logit_samples_serve(params, batch["images"], cfg, 20)
    stats = init_stats(64, samples.shape[-1])
    return finalize(update_stats(stats, samples))


@pytest.mark.parametrize("corruption", [None, "fog"])
def test_triage_threshold_monotone_and_final_collapse(corruption):
    fin = _batch_stats(corruption)
    prev_flagged = -1.0
    for tau in (0.3, 0.6, 0.9):
        pol = TriagePolicy(conf_threshold=tau, mi_threshold=1e9)
        v_fixed = np.asarray(fixed_r_decide(fin, pol))
        flagged = (v_fixed == FLAG).mean()
        assert flagged >= prev_flagged          # monotone in τ_conf
        prev_flagged = flagged
        # at the sample budget the sequential rule collapses to fixed-R
        v_final = np.asarray(decide(fin, pol, final=True))
        assert (v_final != ESCALATE).all()
        np.testing.assert_array_equal(v_final, v_fixed)


def test_triage_ambiguity_band_escalates():
    fin = _batch_stats()
    med = float(np.median(np.asarray(fin["confidence"])))
    pol = TriagePolicy(conf_threshold=med, mi_threshold=1e9, z=1e9)
    v = np.asarray(decide(fin, pol, final=False))
    assert (v == ESCALATE).all()                 # unbounded band
    v2 = np.asarray(decide(fin, pol, final=True))
    assert (v2 != ESCALATE).all()                # budget forces decision


def test_triage_mi_threshold_flags_epistemic():
    fin = _batch_stats()
    mi = np.asarray(fin["mutual_information"])
    tau_mi = float(np.percentile(mi, 50))
    pol = TriagePolicy(conf_threshold=0.0, mi_threshold=tau_mi)
    v = np.asarray(fixed_r_decide(fin, pol))
    assert (v == FLAG).sum() == (mi > tau_mi).sum()
