"""Property-based tile-compiler invariants (hw/tilemap.py).

For hypothesis-generated layer shapes and grid geometries the compiler
must: round-trip weights exactly, partition each weight matrix into
non-overlapping primary blocks that cover it, never double-book a
physical (pass, tile) slot, keep utilization in (0, 1], and report
placed-block energy no smaller than the logical-tile math it replaced
(every placed block burns a full tile MVM; physical tiles never exceed
the paper's 64×64, so placed counts can only grow).
"""

import numpy as np
import pytest

from repro.core.energy import LayerShape
from repro.hw import TileGrid, compile_network
from repro.serving.metrics import (decision_energy, decision_latency,
                                   placed_decision_latency)

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.smoke


@settings(max_examples=25, deadline=None)
@given(d_in=st.integers(1, 300), d_out=st.integers(1, 300),
       rows=st.integers(1, 4), cols=st.integers(1, 4),
       tile=st.sampled_from([16, 32, 64]), bayes=st.booleans())
def test_roundtrip_partition_and_slots(d_in, d_out, rows, cols, tile,
                                       bayes):
    layers = [LayerShape(d_in, d_out, bayesian=bayes),
              LayerShape(37, 5, bayesian=True)]
    prog = compile_network(layers, TileGrid(rows, cols, tile=tile))

    # exact weight round-trip
    w = np.random.default_rng(0).standard_normal(
        (d_in, d_out)).astype(np.float32)
    np.testing.assert_array_equal(
        prog.reconstruct("layer0", prog.shard_weights("layer0", w)), w)

    # primary blocks partition the weight matrix: full cover, no overlap
    ps = prog.layer_placements("layer0")
    cover = np.zeros((d_in, d_out), np.int32)
    for p in ps:
        assert 0 < p.rows <= tile and 0 < p.cols <= tile
        cover[p.r0:p.r0 + p.rows, p.c0:p.c0 + p.cols] += 1
    assert (cover == 1).all(), "blocks overlap or miss weight cells"

    # no two blocks (any layer, replicas included) share a physical slot
    slots = [(p.pass_idx, p.tile_idx) for p in prog.placements]
    assert len(slots) == len(set(slots))
    assert all(p.tile_idx < prog.grid.n_tiles for p in prog.placements)


@settings(max_examples=25, deadline=None)
@given(d_in=st.integers(1, 300), d_out=st.integers(1, 300),
       rows=st.integers(1, 4), cols=st.integers(1, 4),
       tile=st.sampled_from([16, 32, 64]), bayes=st.booleans())
def test_utilization_and_placed_energy(d_in, d_out, rows, cols, tile,
                                       bayes):
    layers = [LayerShape(d_in, d_out, bayesian=bayes),
              LayerShape(37, 5, bayesian=True)]
    prog = compile_network(layers, TileGrid(rows, cols, tile=tile))

    assert 0.0 < prog.utilization <= 1.0
    assert all(0.0 < prog.layer_utilization(n) <= 1.0
               for n, _ in prog.layers)
    counts = prog.layer_block_counts()
    assert counts[prog.layers[0][0]] == len(prog.layer_placements("layer0"))

    placed = decision_energy(20.0, layers, prog)["energy_J"]
    logical = decision_energy(20.0, layers)["energy_J"]
    assert placed >= logical * (1.0 - 1e-12)


@settings(max_examples=25, deadline=None)
@given(d_in=st.integers(1, 300), d_out=st.integers(1, 300),
       rows=st.integers(1, 4), cols=st.integers(1, 4),
       tile=st.sampled_from([16, 32, 64]), bayes=st.booleans(),
       n_samples=st.integers(1, 40))
def test_placed_latency_dominates_logical(d_in, d_out, rows, cols, tile,
                                          bayes, n_samples):
    """ROADMAP reconciliation: the tilemap-aware latency model (per-layer
    pass spans serialize; inter-layer data dependence respected) can
    only be SLOWER than the paper's one-configuration-per-layer §V-A
    math — every layer spans ≥ 1 pass.  The replication-credited bound
    is optimistic (reported, not asserted) but never slower than the
    un-credited placed model."""
    layers = [LayerShape(d_in, d_out, bayesian=bayes),
              LayerShape(37, 5, bayesian=True)]
    prog = compile_network(layers, TileGrid(rows, cols, tile=tile))

    logical = decision_latency(float(n_samples), layers)
    placed = placed_decision_latency(float(n_samples), layers, prog)
    replicated = placed_decision_latency(float(n_samples), layers, prog,
                                         replicated=True)
    assert placed >= logical * (1.0 - 1e-12)
    assert replicated <= placed * (1.0 + 1e-12)
    # a single-pass placement has no multiplexing penalty: models agree
    if prog.n_passes == 1:
        np.testing.assert_allclose(placed, logical, rtol=1e-12)


# ----------------------------------------------------------------------
# die-lifetime invariants (hw/aging.py) — hypothesis over the die space
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
       sev=st.floats(0.25, 3.0, allow_nan=False, allow_infinity=False))
def test_at_age_zero_is_identity_for_any_die(seed, sev):
    """at_age(0) returns the birth instance itself — no new identity,
    so identity-keyed jit caches see the same die."""
    from repro.hw import VariationSpec, sample_instances
    chip = sample_instances(seed, 1, VariationSpec().scaled(sev))[0]
    assert chip.at_age(0.0) is chip


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
       sev=st.floats(0.25, 3.0, allow_nan=False, allow_infinity=False),
       days=st.floats(1e-3, 365.0, allow_nan=False,
                      allow_infinity=False))
def test_aging_commutes_with_save_load(seed, sev, days):
    """age(load(save(die))) == age(die), bit for bit: the aging-rate
    PRNG is keyed only by fields that serialize exactly, so a restored
    fleet stays on its own aging trajectory."""
    import jax

    from repro.hw import VariationSpec, sample_instances
    from repro.hw.instance import ChipInstance
    chip = sample_instances(seed, 1, VariationSpec().scaled(sev))[0]
    t = days * 86400.0
    direct = chip.at_age(t).to_tree()
    roundtrip = ChipInstance.from_tree(chip.to_tree()).at_age(t).to_tree()
    assert (jax.tree_util.tree_structure(direct)
            == jax.tree_util.tree_structure(roundtrip))
    for a, b in zip(jax.tree_util.tree_leaves(direct),
                    jax.tree_util.tree_leaves(roundtrip)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
