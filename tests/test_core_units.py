"""Unit tests: offset compensation, sampling modes, energy model, CIM."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng as g
from repro.core import energy as E
from repro.core import quant as q
from repro.core.bayes_layer import (BayesDenseConfig, apply_train, init,
                                    kl_divergence, sigma_of, to_serving)
from repro.core.cim import adc_snr_db, cim_matmul
from repro.core.offset import compensate_mu, compensation_report
from repro.core.sampling import (BayesHeadConfig, logit_moments,
                                 logit_samples_paper, logit_samples_rank16,
                                 prepare_serving_head)

CFG = g.GRNGConfig()


# ----------------------------------------------------------------------
# offset compensation (§III-B1)
# ----------------------------------------------------------------------
def test_compensation_removes_mean_offset():
    k, n = 32, 48
    key = jax.random.PRNGKey(0)
    mu = jax.random.normal(key, (k, n)) * 0.05
    sigma = jnp.full((k, n), 0.1)
    mu_p = compensate_mu(mu, sigma, CFG, exact=True)
    # effective weights over many samples must average to mu
    eps = g.eps(CFG, k, n, 4096)
    w_mean = mu_p[None] + sigma[None] * eps
    resid = np.abs(np.asarray(w_mean.mean(0) - mu))
    uncomp = np.abs(np.asarray((mu[None] + sigma[None] * eps).mean(0) - mu))
    assert resid.mean() < 0.35 * uncomp.mean()


def test_estimated_offset_converges_to_exact():
    d_exact = g.cell_mean_offset(CFG, 16, 16)
    d_est = g.estimate_mean_offset(CFG, 16, 16, 4096)
    corr = np.corrcoef(np.asarray(d_exact).ravel(),
                       np.asarray(d_est).ravel())[0, 1]
    assert corr > 0.95


def test_compensation_report_matches_paper_scale():
    key = jax.random.PRNGKey(1)
    mu = jax.random.normal(key, (64, 64)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(key, (64, 64)) - 2) * 0.1
    rep = compensation_report(mu, sigma, CFG, mu_bits=8)
    # paper: ~1.5 bits of dynamic range consumed (8 -> 6.54)
    assert 5.0 < rep.effective_mu_bits <= 8.0


# ----------------------------------------------------------------------
# sampling modes (§IV / core/sampling.py)
# ----------------------------------------------------------------------
def _head(key, k=64, n=96):
    k1, k2 = jax.random.split(key)
    mu = jax.random.normal(k1, (k, n)) * 0.05
    sigma = jax.nn.softplus(jax.random.normal(k2, (k, n)) - 2.0) * 0.1
    return {"mu_prime": mu, "sigma": sigma}


def test_rank16_equals_paper_mode_exactly():
    head = _head(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    hcfg = BayesHeadConfig(num_samples=9, grng=CFG, compute_dtype=jnp.float32)
    a = logit_samples_paper(head, x, hcfg)
    b = logit_samples_rank16(head, x, hcfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_moment_mode_matches_empirical_moments():
    """Also validates §III-B1: WITHOUT offset compensation the empirical
    mean is biased by x·(σ·Δε); with exact compensation it matches."""
    raw = _head(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    hcfg = BayesHeadConfig(num_samples=2048, grng=CFG,
                           compute_dtype=jnp.float32)
    head = prepare_serving_head(raw["mu_prime"], raw["sigma"], hcfg)
    # the compensated head's mean target is the ORIGINAL mu product
    samples = logit_samples_paper(head, x, hcfg, num_samples=2048)
    mean_a = x @ raw["mu_prime"]
    _, var_a = logit_moments(head, x, hcfg)
    emp_mean = samples.mean(0)
    emp_var = samples.var(0)
    np.testing.assert_allclose(np.asarray(emp_mean), np.asarray(mean_a),
                               rtol=0.05, atol=0.05)
    # variance: analytic drops shared-selection covariance; check scale
    ratio = float(jnp.median(emp_var / jnp.maximum(var_a, 1e-9)))
    assert 0.5 < ratio < 2.0, ratio


def test_prepare_serving_head_quantizes():
    head_raw = _head(jax.random.PRNGKey(4))
    hcfg = BayesHeadConfig(grng=CFG, quant=q.QuantConfig(enabled=True),
                           compute_dtype=jnp.float32)
    served = prepare_serving_head(head_raw["mu_prime"], head_raw["sigma"],
                                  hcfg)
    sig = np.asarray(served["sigma"])
    for col in range(sig.shape[1]):     # per-channel 4-bit codes
        assert len(np.unique(sig[:, col])) <= 16


# ----------------------------------------------------------------------
# variational layer
# ----------------------------------------------------------------------
def test_bayes_layer_train_and_kl():
    cfg = BayesDenseConfig(d_in=32, d_out=8, grng=CFG)
    params = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))

    def loss(p, step):
        y, kl = apply_train(p, x, cfg, step)
        return (y ** 2).mean() + 1e-4 * kl

    g1 = jax.grad(loss)(params, 0)
    assert all(np.isfinite(np.asarray(v)).all()
               for v in jax.tree.leaves(g1))
    # different steps -> different CLT draws -> different loss
    assert float(loss(params, 0)) != float(loss(params, 1))
    # KL decreases when sigma approaches prior
    p2 = dict(params, rho=jnp.full_like(params["rho"], 10.0))
    assert float(kl_divergence(params, cfg)) < float(kl_divergence(p2, cfg))


def test_to_serving_roundtrip():
    cfg = BayesDenseConfig(d_in=16, d_out=8, grng=CFG)
    params = init(jax.random.PRNGKey(0), cfg)
    hcfg = BayesHeadConfig(grng=CFG, compute_dtype=jnp.float32)
    head = to_serving(params, hcfg)
    assert head["mu_prime"].shape == (16, 8)
    assert (np.asarray(head["sigma"]) >= 0).all()


# ----------------------------------------------------------------------
# CIM path
# ----------------------------------------------------------------------
def test_cim_matmul_disabled_is_exact():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32)) * 0.1
    y = cim_matmul(x, w, q.QuantConfig(enabled=False))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)


def test_cim_snr_improves_with_adc_bits():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64)) * 0.1
    snr6 = float(adc_snr_db(x, w, q.QuantConfig(adc_bits=6)))
    snr8 = float(adc_snr_db(x, w, q.QuantConfig(adc_bits=8)))
    assert snr8 > snr6 > 10.0


# ----------------------------------------------------------------------
# energy model cross-checks (Table I / §V-A)
# ----------------------------------------------------------------------
def test_energy_headline_numbers():
    assert abs(E.tile_efficiency_tops_w() - 17.8) / 17.8 < 0.01
    assert abs(E.efficiency_density() - 185.0) / 185.0 < 0.01
    assert abs(E.grng_throughput_gsas() - 40.96) < 0.01
    assert 500 < E.grng_energy_improvement() < 600
    assert 25 < E.endurance_hours(10e6) < 30


def test_inference_energy_scales_with_r():
    layers = [E.LayerShape(256, 256), E.LayerShape(256, 128, bayesian=True)]
    e1 = E.inference_energy(layers, r_samples=1)["energy_J"]
    e20 = E.inference_energy(layers, r_samples=20)["energy_J"]
    assert e20 > e1 * 2.5
    dig = E.digital_baseline_energy(layers, r_samples=20)
    assert dig > e20          # the paper's headline advantage
