"""Per-architecture smoke tests: reduced config, one train + serve step.

For each of the 10 assigned architectures: instantiate the SMOKE config,
run one forward/train step and a prefill→decode step on CPU, assert
output shapes and no NaNs.  (The FULL configs are exercised only via the
dry-run — ShapeDtypeStruct, no allocation.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import get_api

SEQ, BATCH = 32, 2


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (BATCH, SEQ), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (BATCH, SEQ), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (BATCH, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (BATCH, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = api.train_loss(p, batch, cfg, step=0)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # Loss should be ~log(vocab) at init.
    assert float(metrics["ce"]) < np.log(cfg.vocab) * 2
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    extras = {k: v for k, v in batch.items()
              if k in ("frames", "image_embeds")}

    cache, last_h = api.prefill(params, batch["tokens"], cfg,
                                cache_len=SEQ + 4, **extras)
    assert last_h.shape == (BATCH, cfg.d_model)
    assert np.isfinite(np.asarray(last_h, np.float32)).all()

    token = batch["tokens"][:, -1:]
    samples, cache = api.decode_step(params, cache, token, cfg)
    assert samples.shape == (cfg.uq_samples, BATCH, cfg.vocab_padded)
    assert np.isfinite(np.asarray(samples, np.float32)).all(), f"{arch}: NaN"
    assert int(cache["pos"]) == SEQ + 1

    # Second step must differ (fresh CLT-GRNG samples per position).
    samples2, cache = api.decode_step(params, cache, token, cfg)
    assert not np.allclose(np.asarray(samples, np.float32),
                           np.asarray(samples2, np.float32))


@pytest.mark.parametrize("arch", ["mixtral-8x7b"])
def test_swa_rolling_cache(arch):
    """Decode with cache smaller than sequence (rolling SWA window)."""
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab)
    # cache_len > window triggers rolling mode (window=16 in smoke cfg)
    cache, _ = api.prefill(params, tokens, cfg, cache_len=SEQ + 8)
    assert cache["k"].shape[2] == cfg.swa_window
    for _ in range(3):
        samples, cache = api.decode_step(params, cache,
                                         tokens[:, -1:], cfg)
        assert np.isfinite(np.asarray(samples, np.float32)).all()


def test_decode_matches_full_forward_dense():
    """Prefill+decode logits must match the full-sequence forward."""
    from repro.models import transformer as T
    cfg = get_config("qwen3-0.6b", smoke=True)
    cfg = type(cfg)(**{**cfg.__dict__, "bayesian_head": False})
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)

    h, _, _, _ = T.trunk_forward(params, tokens, cfg)
    full_logits = h @ params["head"]["w"].astype(h.dtype)

    cache, _ = T.prefill(params, tokens[:, :4], cfg, cache_len=8)
    logits = None
    for t in range(4, 8):
        logits, cache = T.decode_step(params, cache, tokens[:, t:t + 1], cfg)
    np.testing.assert_allclose(
        np.asarray(logits[0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.1, atol=0.15)


def test_decode_matches_full_forward_ssm():
    from repro.models import ssm_lm as S
    cfg = get_config("mamba2-130m", smoke=True)
    cfg = type(cfg)(**{**cfg.__dict__, "bayesian_head": False,
                       "ssm_chunk": 4})
    params = S.init_ssm_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)

    h, _, _ = S.trunk_forward_ssm(params, tokens, cfg)
    full_logits = h @ params["head"]["w"].astype(h.dtype)

    cache, _ = S.prefill_ssm(params, tokens[:, :4], cfg, cache_len=8)
    logits = None
    for t in range(4, 8):
        logits, cache = S.decode_step_ssm(params, cache,
                                          tokens[:, t:t + 1], cfg)
    np.testing.assert_allclose(
        np.asarray(logits[0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.1, atol=0.15)
