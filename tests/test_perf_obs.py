"""Performance-observability layer: stage profiler primitives, bench
history records, the regression gate, the decision-path roofline, and
the compile-cache invariant.

The load-bearing assertions mirror the PR's acceptance criteria:
  * regress.compare passes on identical metrics and FAILS on an
    injected 2x slowdown in a wall-clock metric;
  * every history record is schema-versioned and carries git SHA +
    backend fingerprint (with the honest interpret_mode bit);
  * constructing a second engine with identical frozen configs
    triggers ZERO new builder compilations (process-wide lru_cache);
  * Prometheus text-format edge cases round-trip: label escaping,
    NaN/inf histogram counts, empty histograms, overflow bucket.
"""

from __future__ import annotations

import json
import math

import jax
import numpy as np
import pytest

from repro.obs import prof
from repro.obs.prof import StageProfiler, NULL_PROFILER
from repro.obs.registry import MetricsRegistry, serving_registry

from benchmarks import history, regress


# ----------------------------------------------------------------------
# stage profiler primitives
# ----------------------------------------------------------------------
def test_stage_profiler_observe_and_snapshot():
    p = StageProfiler()
    p.observe("dispatch", 1e-4)
    p.observe("dispatch", 2e-4)
    p.observe("dispatch", float("nan"))      # dropped
    p.observe("dispatch", -1.0)              # clamped to 0
    p.observe("dispatch", 1e9)               # beyond last edge: overflow
    with p.span("triage_loop"):
        pass
    snap = p.snapshot()
    d = snap["dispatch"]
    assert d["count"] == 4                   # nan dropped
    assert d["overflow"] == 1
    assert sum(d["counts"]) == 3
    # overflow observations are finite: they still count toward total_s
    assert d["total_s"] == pytest.approx(1e9 + 3e-4)
    assert math.isfinite(d["mean_s"])
    assert snap["triage_loop"]["count"] == 1
    # serving stages come first, in order, in the snapshot
    keys = list(snap)
    assert keys[: keys.index("triage_loop") + 1] == \
        [s for s in prof.SERVING_STAGES
         if s in keys][: keys.index("triage_loop") + 1]


def test_null_profiler_is_inert():
    assert NULL_PROFILER.enabled is False
    NULL_PROFILER.observe("x", 1.0)
    with NULL_PROFILER.span("x"):
        pass
    assert NULL_PROFILER.snapshot() == {}


def test_compile_counters_shape():
    cc = prof.compile_counters()
    assert set(cc) == {"builder_builds", "xla_compile_events",
                       "xla_compile_seconds"}
    assert isinstance(cc["builder_builds"], dict)


def test_compiled_cost_of_simple_fn():
    f = jax.jit(lambda a, b: a @ b)
    x = jax.ShapeDtypeStruct((8, 16), jax.numpy.float32)
    y = jax.ShapeDtypeStruct((16, 4), jax.numpy.float32)
    rec = prof.compiled_cost("mm", f, x, y)
    assert rec["name"] == "mm"
    assert rec["flops"] >= 2 * 8 * 16 * 4 * 0.5   # loop-aware estimate
    assert rec["hbm_bytes"] > 0
    assert rec["compile_s"] > 0


def test_trace_capture_none_is_noop():
    with prof.trace_capture(None):
        pass


# ----------------------------------------------------------------------
# compile-cache invariant (satellite: compilation caching regression)
# ----------------------------------------------------------------------
def test_engine_compile_cache_shared_across_instances():
    """Two engines with identical frozen configs: the first builds each
    jitted builder at most once; the second builds NOTHING (the
    process-wide lru_cache is the compile cache, and the new
    compile-event counter is how we now catch cache-key drift)."""
    from repro.launch.serve import make_sar_stream
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    from repro.serving import SarServingEngine, TriagePolicy

    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(11), cfg)
    # unique thresholds -> guaranteed-cold lru_cache keys for this test
    policy = TriagePolicy(conf_threshold=0.7123, mi_threshold=0.0511,
                          r_min=4, r_max=20)

    def run_one():
        before = dict(prof.builder_builds())
        eng = SarServingEngine(params, cfg, n_slots=8, policy=policy,
                               adaptive_mode=True, fused=True,
                               telemetry=False)
        for r in make_sar_stream(8, corrupt_frac=0.0):
            eng.submit(r)
        eng.run()
        after = prof.builder_builds()
        return {k: after.get(k, 0) - before.get(k, 0)
                for k in set(after) | set(before)}

    delta1 = run_one()
    # the round builder keys on the (unique) policy -> guaranteed cold;
    # featurize/scatter/reset key only on shapes+cfg and may already be
    # cached by earlier tests in the same process — hence <= 1.
    assert delta1.get("sar_round", 0) == 1
    assert all(v <= 1 for v in delta1.values()), delta1

    delta2 = run_one()
    assert all(v == 0 for v in delta2.values()), \
        f"second identical engine recompiled builders: {delta2}"


# ----------------------------------------------------------------------
# bench history
# ----------------------------------------------------------------------
def test_history_record_roundtrip(tmp_path):
    p = tmp_path / "hist.jsonl"
    rec = history.record("unit_bench", {"m": 1.5}, path=p)
    rec2 = history.record_rows(
        "unit_bench", [("row_a", 12.0, "d=1")], path=p)
    assert rec["schema"] == history.SCHEMA_VERSION == 1
    fp = rec["fingerprint"]
    assert set(fp) >= {"backend", "device_kind", "jax", "python",
                       "interpret_mode"}
    assert isinstance(fp["interpret_mode"], bool)
    assert "ts" in rec and "git_sha" in rec
    assert rec2["metrics"]["row_a"]["us_per_call"] == 12.0

    loaded = history.load(p)
    assert len(loaded) == 2
    assert loaded[0]["metrics"] == {"m": 1.5}
    assert history.latest("unit_bench", p)["metrics"]["row_a"]
    assert history.latest("absent", p) is None
    assert history.load(tmp_path / "missing.jsonl") == []


def test_history_git_sha_present_in_repo():
    sha = history.git_sha()
    assert sha is None or (len(sha) == 40
                           and all(c in "0123456789abcdef" for c in sha))


# ----------------------------------------------------------------------
# regression gate
# ----------------------------------------------------------------------
BASE = {
    "serving.adaptive.decisions_per_s_warm": 100.0,
    "serving.adaptive.host_syncs_per_decision": 0.5,
    "serving.adaptive.flag_fraction": 0.25,
    "kernels.kernel_decision_fused.us_per_call_warm": 200.0,
    "kernels.fused.peak_vs_r_growth": 1.0,
}


def test_regress_identical_passes():
    assert regress.compare(dict(BASE), dict(BASE)) == []


def test_regress_catches_2x_wall_slowdown():
    cur = dict(BASE)
    cur["kernels.kernel_decision_fused.us_per_call_warm"] = 400.0
    fails = regress.compare(cur, BASE, wall_ratio=1.5)
    assert [f["metric"] for f in fails] == \
        ["kernels.kernel_decision_fused.us_per_call_warm"]
    # a generous CI ratio lets the same 2x through (honest wide band)
    assert regress.compare(cur, BASE, wall_ratio=5.0) == []


def test_regress_catches_throughput_drop_and_abs_band():
    cur = dict(BASE)
    cur["serving.adaptive.decisions_per_s_warm"] = 40.0    # < 100/1.5
    cur["serving.adaptive.flag_fraction"] = 0.45           # |d|>0.05
    fails = {f["metric"] for f in regress.compare(cur, BASE)}
    assert "serving.adaptive.decisions_per_s_warm" in fails
    assert "serving.adaptive.flag_fraction" in fails


def test_regress_missing_metric_is_failure():
    cur = dict(BASE)
    del cur["serving.adaptive.host_syncs_per_decision"]
    fails = regress.compare(cur, BASE)
    assert fails and fails[0]["kind"] == "missing"
    # extra current-only metrics are ignored until baseline refresh
    cur2 = dict(BASE, **{"serving.new.metric": 1.0})
    assert regress.compare(cur2, BASE) == []


def test_regress_deterministic_band_is_tight():
    cur = dict(BASE)
    cur["serving.adaptive.host_syncs_per_decision"] = 0.7  # > 0.5*1.25
    fails = regress.compare(cur, BASE, wall_ratio=100.0)
    assert [f["metric"] for f in fails] == \
        ["serving.adaptive.host_syncs_per_decision"]


def test_regress_floor_band_is_absolute():
    """FLOOR_BANDS gate on the committed constant, not the baseline
    value: a weak committed baseline must not weaken the gate, and a
    strong baseline must not tighten it into a wall-clock-style ratio."""
    base = {"fleet.scaling_efficiency_4pools": 2.0,
            "fleet.speedup_4pools": 8.0}
    # above the floors but far below baseline: still a PASS
    cur = {"fleet.scaling_efficiency_4pools": 0.75,
           "fleet.speedup_4pools": 3.5}
    assert regress.compare(cur, base, wall_ratio=1.0) == []
    # below a floor: FAIL even if the baseline were weaker than the floor
    cur["fleet.speedup_4pools"] = 2.9
    fails = regress.compare(cur, {**base, "fleet.speedup_4pools": 2.5},
                            wall_ratio=100.0)
    assert [f["metric"] for f in fails] == ["fleet.speedup_4pools"]
    assert fails[0]["kind"] == "floor" and fails[0]["limit"] == 3.0


def test_regress_current_metrics_extraction(tmp_path):
    serving = tmp_path / "s.json"
    kernels = tmp_path / "k.json"
    serving.write_text(json.dumps({"configs": {"adaptive": {
        "decisions_per_s_warm": 50.0, "flag_fraction": 0.2,
        "host_syncs_per_decision": 1.0, "model_decisions_per_s": 9.0,
        "mean_samples_per_decision": 6.0,
        "peak_live_bytes_per_decision": 4096.0,
        "energy_total_J": 1.0}}}))
    kernels.write_text(json.dumps({"rows": [
        {"name": "kernel_decision_fused", "us_per_call": 9.0,
         "us_per_call_warm": 8.0, "derived": ""},
        {"name": "kernel_decision_peak_vs_R_fused", "us_per_call": 0.0,
         "derived": "R8=1B;R64=1B;growth=1.00x"}]}))
    lifetime = tmp_path / "lt.json"
    lifetime.write_text(json.dumps({
        "serve": {"healed": {"lifetime": {"advisories": 1, "heals": 1}},
                  "fresh": {"lifetime": {"advisories": 0}}},
        "static": {"arms": {"healed": {"clean": {"acc_dev": 0.01}}}},
        "gates": {"healed_loop_closed": True, "stale_degraded": True}}))
    cur = regress.current_metrics(serving, kernels, lifetime)
    assert cur["serving.adaptive.decisions_per_s_warm"] == 50.0
    assert cur["kernels.kernel_decision_fused.us_per_call_warm"] == 8.0
    assert cur["kernels.fused.peak_vs_r_growth"] == 1.0
    assert cur["lifetime.serve_healed.heals"] == 1.0
    assert cur["lifetime.serve_fresh.false_advisories"] == 0.0
    assert cur["lifetime.static.healed_clean_acc_dev"] == 0.01
    assert cur["lifetime.gates_all_pass"] == 1.0
    assert "serving.adaptive.energy_total_J" not in cur   # not gated
    # fleet snapshot (BENCH_fleet.json) flattens per-pool structural
    # metrics plus the floor-gated scaling quantities
    fleet = tmp_path / "f.json"
    fleet.write_text(json.dumps({
        "pools": {"1": {"decisions_per_s_warm": 10.0,
                        "decisions_per_s_mesh": 11.0,
                        "host_syncs_per_decision": 0.03,
                        "per_pool_syncs_per_decision": 0.03},
                  "4": {"decisions_per_s_warm": 30.0,
                        "decisions_per_s_mesh": 44.0,
                        "host_syncs_per_decision": 0.01,
                        "per_pool_syncs_per_decision": 0.04}},
        "speedup_4pools": 4.0, "scaling_efficiency_4pools": 1.0}))
    cur = regress.current_metrics(serving, kernels, lifetime, fleet)
    assert cur["fleet.pools4.decisions_per_s_mesh"] == 44.0
    assert cur["fleet.pools1.per_pool_syncs_per_decision"] == 0.03
    assert cur["fleet.speedup_4pools"] == 4.0
    assert cur["fleet.scaling_efficiency_4pools"] == 1.0
    # SLO snapshot (BENCH_slo.json) contributes the gate boolean plus
    # the nominal-Poisson structural metrics
    slo = tmp_path / "s.json"
    slo.write_text(json.dumps({
        "gates": {"slo_report_well_formed": True,
                  "burn_alert_fires_under_spike": True,
                  "quiet_under_nominal": True, "gates_all_pass": True},
        "configs": {"poisson_engine": {
            "queue_wait_share": 0.3,
            "host_syncs_per_decision": 0.25}}}))
    cur = regress.current_metrics(serving, kernels, lifetime, fleet, slo)
    assert cur["slo.gates_all_pass"] == 1.0
    assert cur["slo.poisson_engine.queue_wait_share"] == 0.3
    assert cur["slo.poisson_engine.slo_syncs_per_decision"] == 0.25
    # no snapshots at all -> empty (regress exits 2 in main)
    assert regress.current_metrics(tmp_path / "a.json",
                                   tmp_path / "b.json",
                                   tmp_path / "c.json",
                                   tmp_path / "d.json",
                                   tmp_path / "e.json") == {}


def test_committed_baseline_gates_clean(tmp_path):
    """The committed baseline must pass against the committed BENCH
    snapshots — i.e. the repo ships in a green-gate state."""
    from pathlib import Path
    repo = Path(__file__).resolve().parent.parent
    serving, kernels = repo / "BENCH_serving.json", \
        repo / "BENCH_kernels.json"
    lifetime = repo / "BENCH_lifetime.json"
    if not (regress.BASELINE_PATH.exists() and serving.exists()
            and kernels.exists()):
        pytest.skip("no committed bench snapshots")
    cur = regress.current_metrics(serving, kernels, lifetime)
    fails = regress.compare(cur, regress.load_baseline(),
                            wall_ratio=1.0 + 1e-9)
    assert fails == [], fails


# ----------------------------------------------------------------------
# decision-path roofline
# ----------------------------------------------------------------------
def test_roofline_serving_cells():
    from benchmarks import roofline
    cells = roofline.serving_cells(
        points=((4, 8, 4),), measure_reps=2)
    names = [c["name"] for c in cells]
    assert any(n.startswith("decision_update_") for n in names)
    assert any(n.startswith("sar_round_") for n in names)
    for c in cells:
        assert c["bound"] in ("compute", "memory")
        assert c["bound_us"] > 0
        assert c["measured_us"] > 0
        assert c["flops"] > 0 and c["hbm_bytes"] > 0
        assert isinstance(c["interpret_mode"], bool)


# ----------------------------------------------------------------------
# Prometheus text-format edge cases (satellite: registry hardening)
# ----------------------------------------------------------------------
def _parse_prom(text):
    """Minimal exposition-format parser: {name{labels}: value}."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        key, val = line.rsplit(" ", 1)
        out[key] = float(val)
    return out


def test_prometheus_label_escaping_roundtrip():
    reg = MetricsRegistry()
    nasty = 'a\\b"c\nd'
    reg.counter("decisions_total", 3, path=nasty)
    text = reg.to_prometheus()
    assert 'path="a\\\\b\\"c\\nd"' in text
    # a raw newline inside a label value would split the sample line
    body = [ln for ln in text.splitlines()
            if ln and not ln.startswith("#")]
    assert len(body) == 1
    assert _parse_prom(text)[
        'repro_decisions_total{path="a\\\\b\\"c\\nd"}'] == 3


def test_prometheus_nonfinite_histogram_counts_sanitized():
    reg = MetricsRegistry()
    reg.histogram("lat", [float("nan"), 2, float("inf")],
                  [0.0, 1.0, 2.0, 3.0])
    text = reg.to_prometheus()
    parsed = _parse_prom(text)
    assert parsed['repro_lat_bucket{le="1.0"}'] == 0     # nan -> 0
    assert parsed['repro_lat_bucket{le="2.0"}'] == 2
    assert parsed['repro_lat_bucket{le="3.0"}'] == 2     # inf -> 0
    assert parsed['repro_lat_bucket{le="+Inf"}'] == 2
    assert parsed["repro_lat_count"] == 2
    assert all(math.isfinite(v) for v in parsed.values())


def test_prometheus_empty_histogram_and_overflow():
    reg = MetricsRegistry()
    reg.histogram("empty", [], [0.0, 1.0])
    reg.histogram("over", [1, 1], [0.0, 0.5, 1.0], overflow=3,
                  sum=42.0)
    text = reg.to_prometheus()
    parsed = _parse_prom(text)
    assert parsed['repro_empty_bucket{le="+Inf"}'] == 0
    assert parsed["repro_empty_count"] == 0
    # overflow lands in +Inf (and only there) and counts in _count
    assert parsed['repro_over_bucket{le="1.0"}'] == 2
    assert parsed['repro_over_bucket{le="+Inf"}'] == 5
    assert parsed["repro_over_count"] == 5
    assert parsed["repro_over_sum"] == 42.0              # explicit sum


def test_prometheus_text_parse_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a_total", 7, job="x")
    reg.gauge("g", 0.5)
    reg.histogram("h", [1, 2], [0.0, 1.0, 2.0])
    prom, js = reg.write(str(tmp_path / "m"))
    parsed = _parse_prom(open(prom).read())
    assert parsed['repro_a_total{job="x"}'] == 7
    assert parsed["repro_g"] == 0.5
    assert parsed['repro_h_bucket{le="+Inf"}'] == 3
    assert json.loads(open(js).read())["metrics"]


def test_serving_registry_accepts_perf_sections():
    snap = {"admission": {"count": 2, "total_s": 1e-3, "mean_s": 5e-4,
                          "counts": [2] + [0] * 27, "overflow": 0,
                          "edges": list(np.logspace(-6, 1, 29))}}
    cc = {"builder_builds": {"sar_round": 1},
          "xla_compile_events": 10, "xla_compile_seconds": 0.5}
    costs = [{"name": "sar_round", "flops": 1e6, "hbm_bytes": 2e6,
              "peak_live_bytes": 65536, "compile_s": 0.1,
              "backend": "cpu"}]
    reg = serving_registry({"decisions": 0}, profile=snap,
                           compile_counters=cc, compiled_costs=costs)
    text = reg.to_prometheus()
    parsed = _parse_prom(text)
    assert 'repro_stage_latency_seconds_bucket' in text
    assert parsed['repro_engine_builder_builds_total'
                  '{builder="sar_round",job="serving"}'] == 1
    assert parsed['repro_xla_compile_events_total'
                  '{job="serving"}'] == 10
    assert parsed['repro_compiled_flops'
                  '{job="serving",fn="sar_round"}'] == 1e6
