"""Hardware conformance suite: does every nonideal path tell one story?

PR 3 closed the three hw loops (nonideal rank-16 kernel, nonideal conv
trunk, tilemap-true energy).  The closures are only trustworthy if the
redundant implementations of each nonideality agree, so this suite
checks, at increasing strictness:

  * **bit-identity at zero variation** — a zero-variation instance
    (and the golden instance itself) must add NOTHING: kernel ≡ ideal
    kernel, trunk ≡ ideal CIM pipeline, instance head ≡ factory head.
  * **draw-for-draw equality where streams are shared** — the fused
    rank16 kernel and the engine's ``mix_samples`` fast path key their
    read-noise off the same hash stream, so they must agree sample-for-
    sample (to float tolerance), not just in distribution.
  * **distributional equality where they can't be shared** — the
    faithful ``paper`` path materializes per-cell noise the rank-16
    projection can only match in law: two-sample KS + moment tests
    across severities (marked ``slow`` — the full statistical tier CI
    runs in the hw_variation job).
  * **energy reconciliation** — per-request tilemap-true energies must
    sum to the engine-level ``grid_inference_energy`` total (the
    logical-vs-placed drift this PR removed cannot reappear silently).
  * **tile-compiler invariants** under hypothesis-generated shapes.

Statistical tests are deterministic (hash-derived samples, fixed
seeds): they either always pass or always fail — no flake budget.
Every check appends its measurements to
``artifacts/conformance/summary.json`` (uploaded as a CI artifact).
"""

import dataclasses
import json
import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng as g
from repro.core import energy
from repro.core.energy import LayerShape
from repro.core.quant import QuantConfig
from repro.core.sampling import (BayesHeadConfig, logit_samples_paper,
                                 logit_samples_rank16, prepare_serving_head)
from repro.hw import (VariationSpec, compile_network, golden_instance,
                      prepare_instance_head, sample_instances)
from repro.kernels import ops, ref

ART = Path("artifacts/conformance")
_SUMMARY: dict = {}


@pytest.fixture(scope="session", autouse=True)
def _write_summary():
    """Collect per-test conformance measurements into the CI artifact."""
    yield
    if _SUMMARY:
        ART.mkdir(parents=True, exist_ok=True)
        (ART / "summary.json").write_text(json.dumps(_SUMMARY, indent=1,
                                                     sort_keys=True))


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------
def _head_inputs(k=48, n=6, b=4):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    mu = jax.random.normal(k1, (k, n)) * 0.05
    sg = jax.nn.softplus(jax.random.normal(k2, (k, n)) - 2.0) * 0.2
    x = jax.random.normal(k3, (b, k))
    return mu, sg, x


def ks_statistic(a, b) -> float:
    """Two-sample Kolmogorov–Smirnov D (no scipy dependency)."""
    a = np.sort(np.asarray(a, np.float64).ravel())
    b = np.sort(np.asarray(b, np.float64).ravel())
    grid = np.concatenate([a, b])
    cdf_a = np.searchsorted(a, grid, side="right") / a.size
    cdf_b = np.searchsorted(b, grid, side="right") / b.size
    return float(np.abs(cdf_a - cdf_b).max())


def ks_threshold(n: int, m: int, alpha: float = 1e-3) -> float:
    """Asymptotic two-sample KS critical value at level ``alpha``."""
    c = math.sqrt(-0.5 * math.log(alpha / 2.0))
    return c * math.sqrt((n + m) / (n * m))


def _standardized(samples) -> np.ndarray:
    """Pool per-logit standardized residuals: [R,B,N] -> flat [R·B·N].

    Each logit has its own spread (σ, x and the noise projection vary
    per (b, n)); standardizing per logit makes the pooled residual
    distribution comparable across paths."""
    s = np.asarray(samples, np.float64)
    mu = s.mean(axis=0, keepdims=True)
    sd = np.maximum(s.std(axis=0, keepdims=True), 1e-12)
    return ((s - mu) / sd).ravel()


# ----------------------------------------------------------------------
# bit-identity at zero variation
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_golden_instance_head_bitexact():
    """The golden instance pushed through the full instance plumbing
    reproduces the factory serving head bit-for-bit (the anchor
    benchmarks/hw_variation.py re-asserts before every fleet sweep)."""
    mu, sg, x = _head_inputs()
    cfg = BayesHeadConfig(num_samples=8, mode="rank16",
                          compute_dtype=jnp.float32)
    gold = prepare_serving_head(mu, sg, cfg)
    head, scfg = prepare_instance_head(mu, sg, cfg,
                                       golden_instance(cfg.grng),
                                       calibrated=False)
    assert scfg.grng == cfg.grng
    for key in gold:
        np.testing.assert_array_equal(np.asarray(gold[key]),
                                      np.asarray(head[key]))
    np.testing.assert_array_equal(
        np.asarray(logit_samples_rank16(gold, x, cfg)),
        np.asarray(logit_samples_rank16(head, x, scfg)))
    _SUMMARY["golden_instance_head_bitexact"] = True


@pytest.mark.smoke
def test_severity0_instance_grng_folds_to_exact_golden_params():
    """A severity-0 sampled instance's physical GRNG config must equal
    the golden config with only the chip seeds swapped — EXACT float
    equality, not approximate: the corner/drift folds are pure
    multiplications by 1.0 and read noise is identically zero.  Config
    equality is what makes the severity-0 kernel path bit-identical to
    the ideal one (same static config → same trace), so this is the
    load-bearing half of that criterion; the noise term's additivity is
    pinned separately in test_kernels.py."""
    base = g.GRNGConfig()
    chip = sample_instances(5, 1, VariationSpec().scaled(0.0))[0]
    icfg = chip.grng(base)
    assert icfg == dataclasses.replace(
        base, seed=chip.device_seed, noise_seed=chip.noise_seed,
        read_sigma=0.0)
    # and the golden instance folds to the golden config itself
    assert golden_instance(base).grng(base) == base
    _SUMMARY["severity0_instance_grng_exact_fold"] = True


@pytest.mark.smoke
def test_trunk_severity0_bit_identical():
    """A severity-0 instance's conv trunk (nonideal CIM route) equals
    the ideal quantize→chunked-ADC kernel pipeline bit-for-bit, and the
    golden instance's trunk equals the severity-0 one; the pure-jnp
    ``cim_execution`` trunk agrees only to calibration level (different
    ADC full-scale measurement — documented in models/sar_cnn.py), so
    that gap is bounded, not asserted away."""
    from repro.core import quant as q
    from repro.models.sar_cnn import SarCnnConfig, _im2col, features, \
        init_sar_cnn
    cfg = SarCnnConfig()
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 1))
    sev0 = sample_instances(5, 1, VariationSpec().scaled(0.0))[0]
    assert np.all(sev0.adc_gain == 1.0) and np.all(sev0.adc_offset == 0.0)
    got = features(params, imgs, cfg, chip=sev0)

    # gain/offset/programming add nothing: the IDEAL kernel (no
    # nonideal arguments at all) reproduces the chip route bit-for-bit
    h = imgs
    for layer in params["convs"]:
        w = layer["w"]
        cols = _im2col(h, w.shape[0], 2)
        bsz, ho, wo, d = cols.shape
        xq, _ = q.quantize_input(cols.reshape(-1, d), cfg.quant)
        wq, _ = q.quantize_mu(w.reshape(-1, w.shape[-1]), cfg.quant)
        pad = (-d) % cfg.quant.chunk
        xq = jnp.pad(xq, ((0, 0), (0, pad)))
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
        y = ops.cim_matmul(xq, wq, cfg.quant).reshape(bsz, ho, wo, -1)
        h = jax.nn.relu(y + layer["b"])
    want = h.mean(axis=(1, 2))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # the golden instance is a severity-0 die with golden seeds: same
    # trunk output exactly (different parameter objects, equal values)
    gold = features(params, imgs, cfg, chip=golden_instance())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(gold))

    # calibration-level (not bit-level) agreement with the pure-jnp
    # cim_execution trunk: full-batch vs 16-row ADC full-scale
    jnp_trunk = features(params, imgs,
                         dataclasses.replace(cfg, cim_execution=True))
    gap = float(jnp.abs(got - jnp_trunk).max())
    assert gap < 0.1, f"kernel vs jnp CIM trunk diverged: {gap}"
    _SUMMARY["trunk_severity0_bitexact"] = True
    _SUMMARY["trunk_kernel_vs_jnp_cim_gap"] = gap


# ----------------------------------------------------------------------
# draw-for-draw: kernel path vs engine fast path (shared hash stream)
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_rank16_kernel_matches_mix_samples_draw_for_draw():
    """On a degraded instance the fused kernel and mix_samples key the
    read-noise off the SAME hash of the absolute sample index — they
    agree per sample, including across a stream-extension boundary."""
    mu, sg, x = _head_inputs(k=40, n=10)
    grng = dataclasses.replace(g.GRNGConfig(), read_sigma=0.5)
    cfg = BayesHeadConfig(num_samples=6, mode="rank16", grng=grng,
                          compute_dtype=jnp.float32)
    head = {"mu_prime": mu, "sigma": sg}
    for sample0 in (0, 7):
        got = ops.bayes_head_mvm(x, mu, sg, grng, 6, sample0=sample0,
                                 mode="rank16", interpret=True)
        want = logit_samples_rank16(head, x, cfg, 6, sample0=sample0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        oracle = ref.bayes_mvm_rank16_ref(x, mu, sg, grng, 6,
                                          sample0=sample0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   rtol=1e-4, atol=1e-4)
    _SUMMARY["rank16_kernel_matches_mix_samples"] = True


# ----------------------------------------------------------------------
# distributional conformance across severities (the statistical tier)
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("severity", [1.0, 2.5])
def test_kernel_mix_paper_agree_in_distribution(severity):
    """Across chip severities: the faithful per-cell noise path, the
    mix_samples projection, and the fused rank16 kernel produce the
    same logit-sample distribution (KS on pooled standardized
    residuals + per-logit moment agreement)."""
    mu, sg, x = _head_inputs()
    chip = sample_instances(13, 1, VariationSpec().scaled(severity))[0]
    cfg = BayesHeadConfig(num_samples=400, mode="rank16",
                          compute_dtype=jnp.float32)
    head, scfg = prepare_instance_head(mu, sg, cfg, chip, calibrated=True)
    assert scfg.grng.read_sigma > 0
    r = 400
    paper = np.asarray(logit_samples_paper(head, x, scfg, r))
    mix = np.asarray(logit_samples_rank16(head, x, scfg, r))
    kern = np.asarray(ops.bayes_head_mvm(
        x, head["mu_prime"], head["sigma"], scfg.grng, r, mode="rank16",
        interpret=True))

    # kernel ≡ mix draw-for-draw (shared stream) at serving scale
    np.testing.assert_allclose(kern, mix, rtol=1e-4, atol=1e-4)

    # moments: per-logit mean/std of paper vs projection paths
    np.testing.assert_allclose(paper.mean(0), mix.mean(0), atol=0.05)
    np.testing.assert_allclose(paper.std(0), mix.std(0), rtol=0.15,
                               atol=0.02)

    entry = {"severity": severity, "read_sigma": float(scfg.grng.read_sigma),
             "mean_abs_dev": float(np.abs(paper.mean(0) - mix.mean(0)).max()),
             "std_rel_dev": float(np.abs(paper.std(0) / np.maximum(
                 mix.std(0), 1e-12) - 1.0).max())}
    for name, other in (("mix", mix), ("kernel", kern)):
        d = ks_statistic(_standardized(paper), _standardized(other))
        crit = ks_threshold(paper.size, other.size)
        entry[f"ks_paper_vs_{name}"] = d
        entry[f"ks_threshold"] = crit
        assert d < crit, (f"KS({name} vs paper) = {d:.4f} ≥ {crit:.4f} "
                          f"at severity {severity}")
    _SUMMARY[f"distribution_sev{severity}"] = entry


@pytest.mark.slow
def test_severity0_instance_collapses_to_no_noise():
    """A severity-0 sampled instance (own die, golden statistics) has
    zero read noise: rank16 ≡ paper mode bit-for-bit again, despite the
    chip-specific device seed."""
    mu, sg, x = _head_inputs()
    chip = sample_instances(13, 1, VariationSpec().scaled(0.0))[0]
    cfg = BayesHeadConfig(num_samples=32, mode="rank16",
                          compute_dtype=jnp.float32)
    head, scfg = prepare_instance_head(mu, sg, cfg, chip, calibrated=False)
    assert scfg.grng.read_sigma == 0.0
    a = np.asarray(logit_samples_rank16(head, x, scfg, 32))
    b = np.asarray(logit_samples_paper(head, x, scfg, 32))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    _SUMMARY["severity0_instance_rank16_eq_paper"] = True


@pytest.mark.slow
@pytest.mark.parametrize("severity", [0.5, 2.5])
def test_cim_nonideal_kernel_conforms_to_oracle(severity):
    """The nonideal CIM kernel tracks ``cim_mvm_nonideal_ref`` across
    ADC-severity levels (deterministic path → exact agreement), and the
    severity scales the output distortion monotonically from zero."""
    qcfg = QuantConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    x = jax.random.normal(k1, (8, 192))
    w = jax.random.normal(k2, (192, 70)) * 0.05
    chip = sample_instances(21, 1, VariationSpec().scaled(severity))[0]
    gain, off = chip.adc_columns(70)
    got = ops.cim_matmul_nonideal(x, w, qcfg, jnp.asarray(gain),
                                  jnp.asarray(off), interpret=True)
    fs = ops._measured_full_scale(x, w, qcfg)
    want = ref.cim_mvm_nonideal_ref(x, w, qcfg, fs, jnp.asarray(gain),
                                    jnp.asarray(off))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    ideal = np.asarray(ops.cim_matmul(x, w, qcfg, interpret=True))
    dev = float(np.abs(np.asarray(got) - ideal).mean())
    assert dev > 0.0
    _SUMMARY[f"cim_nonideal_sev{severity}"] = {
        "mean_abs_distortion": dev,
        "adc_gain_sigma": float(np.std(gain)),
    }


# ----------------------------------------------------------------------
# energy reconciliation (tilemap-true accounting)
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_energy_reconciliation_served_batch():
    """Sum of per-request energies in a served batch equals the engine-
    level grid_inference_energy total computed from the same placed
    blocks — the logical-vs-placed drift this PR removed would break
    this equality."""
    from repro.launch.serve import sar_layer_shapes, serve_sar
    from repro.models.sar_cnn import SarCnnConfig
    out = serve_sar(n_requests=10, n_slots=4)
    cfg = SarCnnConfig()
    layers = sar_layer_shapes(cfg)
    program = compile_network(layers)
    det, bayes = program.det_bayes_blocks()
    n_dec = out["decisions"]
    r_bar = out["mean_samples_per_decision"]
    grid = energy.grid_inference_energy(
        n_det_tiles=det, n_bayes_tiles=bayes, r_samples=r_bar, batch=n_dec)
    assert out["energy_total_J"] == pytest.approx(grid["energy_J"],
                                                  rel=1e-9)
    # per-decision summary consistency with the same accounting
    per_dec = energy.grid_inference_energy(
        n_det_tiles=det, n_bayes_tiles=bayes, r_samples=r_bar, batch=1)
    assert out["energy_per_decision_pJ"] == pytest.approx(
        per_dec["energy_J"] * 1e12, rel=1e-9)
    assert out["tile_utilization"] == pytest.approx(program.utilization)
    _SUMMARY["energy_reconciliation"] = {
        "energy_total_J": out["energy_total_J"],
        "grid_energy_J": grid["energy_J"] ,
        "decisions": n_dec,
        "mean_samples": r_bar,
    }


@pytest.mark.smoke
def test_request_energy_uses_placed_blocks():
    """metrics.request_energy charges placed blocks: on a grid whose
    physical tile is smaller than the logical TILE_DIM the placed count
    strictly exceeds the logical one, and the energy follows."""
    from repro.hw import TileGrid
    from repro.serving.metrics import decision_energy, request_energy, \
        RequestRecord
    layers = [LayerShape(100, 40), LayerShape(100, 2, bayesian=True)]
    program = compile_network(layers, TileGrid(8, 8, tile=32))
    placed = decision_energy(20.0, layers, program)
    logical = decision_energy(20.0, layers)
    assert placed["energy_J"] > logical["energy_J"]
    rec = RequestRecord(rid=0, verdict=0, n_samples=20, n_decisions=1,
                        arrival_s=0.0, admit_s=0.0, done_s=0.0)
    assert request_energy(rec, layers, program) == pytest.approx(
        placed["energy_J"])
    # mismatched program fails loudly rather than mis-charging
    with pytest.raises(ValueError):
        decision_energy(20.0, [LayerShape(64, 64)], program)


# Tile-compiler invariants under hypothesis-generated shapes live in
# tests/test_tilemap_properties.py (module-level importorskip: the whole
# property module skips when hypothesis is absent, this suite never does).
