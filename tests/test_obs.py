"""obs/ acceptance: telemetry must be free, tracing must not perturb
verdicts, and the drift monitor must separate healthy from degraded.

The load-bearing claims:

  1. zero overhead — with telemetry enabled the SAR engine produces
     bit-identical verdicts, the SAME number of host syncs, and the
     compiled decision round's largest live intermediate is unchanged
     (the probe is a gather + [probe_cells, 16] matmul, far below the
     rank-16 basis);
  2. the counters are CORRECT — snapshot decisions/samples/verdict mix
     reconcile exactly against the engine's retired records;
  3. request tracing exports valid Chrome/Perfetto JSON without
     changing a single verdict;
  4. the drift monitor stays quiet on a golden die and raises a
     recalibration advisory on a σ-shifted one (unit level here; the
     engine-level separation runs as the CI drift smoke via
     ``python -m repro.obs.drift``);
  5. the mission loop carries telemetry through its ``lax.scan`` with
     log-identical trajectories and still one host sync per die group.
"""

import dataclasses
import json
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clt_grng
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
from repro.obs.drift import (DriftGate, DriftMonitor, DriftReference,
                             drift_status)
from repro.obs.log import Logger
from repro.obs.registry import (MetricsRegistry, add_telemetry,
                                serving_registry)
from repro.obs.telemetry import TelemetryConfig
from repro.obs.trace import NULL_TRACER, Tracer, mission_trace
from repro.serving import TriagePolicy

POLICY = TriagePolicy(conf_threshold=0.7, mi_threshold=0.05,
                      r_min=4, r_max=20)


@pytest.fixture(scope="module")
def sar():
    cfg = SarCnnConfig()
    return init_sar_cnn(jax.random.PRNGKey(3), cfg), cfg


def _run_sar(sar, n_requests, *, telemetry, tracer=None, n_slots=8,
             profiler=True):
    from repro.launch.serve import make_sar_stream
    from repro.serving import SarServingEngine
    params, cfg = sar
    eng = SarServingEngine(params, cfg, n_slots=n_slots, policy=POLICY,
                          adaptive_mode=True, fused=True,
                          telemetry=telemetry, tracer=tracer,
                          profiler=profiler)
    for r in make_sar_stream(n_requests, corrupt_frac=0.25,
                             corruption="fog"):
        eng.submit(r)
    eng.run()
    return eng


def _records_match(eng_a, eng_b, n_requests):
    recs_a = {r.rid: r for r in eng_a.metrics.records}
    recs_b = {r.rid: r for r in eng_b.metrics.records}
    assert set(recs_a) == set(recs_b) == set(range(n_requests))
    for rid in recs_a:
        a, b = recs_a[rid], recs_b[rid]
        assert a.verdict == b.verdict, rid
        assert a.prediction == b.prediction, rid
        assert a.n_samples == b.n_samples, rid
        np.testing.assert_allclose(a.confidence, b.confidence, atol=1e-6)


# ----------------------------------------------------------------------
# 1-2. telemetry: zero overhead + exact counter reconciliation
# ----------------------------------------------------------------------
def test_sar_telemetry_zero_overhead_and_counts(sar):
    """Telemetry on vs off: bit-identical verdicts, equal host syncs,
    and a snapshot that reconciles exactly against the retired
    records."""
    n = 24
    eng_on = _run_sar(sar, n, telemetry=True)
    eng_off = _run_sar(sar, n, telemetry=False)
    _records_match(eng_on, eng_off, n)
    # same dispatch pattern ⇒ same number of blocking host syncs
    assert eng_on.host_syncs == eng_off.host_syncs
    assert eng_off.telemetry_snapshot() is None

    snap = eng_on.telemetry_snapshot()
    recs = eng_on.metrics.records
    assert snap["decisions"] == len(recs) == n
    assert snap["samples"] == sum(r.n_samples for r in recs)
    mix = Counter(r.verdict for r in recs)
    assert snap["verdicts"]["accept"] == mix.get(0, 0)
    assert snap["verdicts"]["escalate"] == mix.get(1, 0)
    assert snap["verdicts"]["flag"] == mix.get(2, 0)
    # R-at-verdict histogram totals one entry per decision, r ≤ r_max
    assert sum(snap["r_hist"]) == n
    assert len(snap["r_hist"]) == POLICY.r_max + 1
    assert sum(snap["conf_hist"]) == n
    # GRNG probe moments land near the golden die's array-sum stats
    g = snap["grng"]
    assert g["n"] > 0
    assert abs(g["sum_mean_uA"] - 10.1) < 1.0
    assert 0.5 < g["sum_std_uA"] < 2.0
    # perf_counter interval clocks: latencies are non-negative
    for r in recs:
        assert r.latency_s >= 0.0
        assert r.queue_latency_s >= 0.0


def test_sar_round_hlo_footprint_unchanged_by_telemetry():
    """The compiled fused decision round's largest live intermediate is
    IDENTICAL with telemetry riding the while_loop carry — the probe
    must never introduce a new largest array."""
    from repro.core.sampling import BayesHeadConfig
    from repro.launch.hlo_analysis import largest_intermediate_bytes
    from repro.obs.telemetry import init_telemetry
    from repro.serving import adaptive
    from repro.serving.engine import _sar_round_fn

    B, N = 8, 512
    cfg = clt_grng.GRNGConfig()
    hcfg = BayesHeadConfig(num_samples=POLICY.r_max, mode="rank16",
                           grng=cfg, compute_dtype=jnp.float32,
                           hoist_basis=True)
    pool = {"y_mu": jnp.zeros((B, N)), "x_sigma": jnp.zeros((B, N)),
            "m": jnp.zeros((B, N, 16))}
    stats = adaptive.init_stats(B, N)
    base = jnp.zeros((B,), jnp.uint32)
    active = jnp.ones((B,), bool)

    fn0 = _sar_round_fn(hcfg, POLICY, True, POLICY.r_min, True, None)
    txt0 = fn0.lower(pool, stats, base, active).compile().as_text()

    tcfg = TelemetryConfig()
    telem = init_telemetry(tcfg, POLICY.r_max)
    fn1 = _sar_round_fn(hcfg, POLICY, True, POLICY.r_min, True, None,
                        tcfg)
    txt1 = fn1.lower(pool, stats, base, active,
                     telem).compile().as_text()
    assert (largest_intermediate_bytes(txt1)
            == largest_intermediate_bytes(txt0))


# ----------------------------------------------------------------------
# 3. request tracing
# ----------------------------------------------------------------------
def test_tracer_chrome_export_and_verdict_identity(sar, tmp_path):
    n = 16
    tracer = Tracer("test-serving")
    eng_t = _run_sar(sar, n, telemetry=True, tracer=tracer)
    eng_0 = _run_sar(sar, n, telemetry=True, tracer=None)
    _records_match(eng_t, eng_0, n)
    assert eng_0.tracer is NULL_TRACER and not NULL_TRACER.enabled

    doc = tracer.to_chrome()
    evs = doc["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X"]
    # one complete span per retired request + per-dispatch spans
    req_spans = [e for e in spans if e["name"].startswith("req ")]
    assert len(req_spans) == n
    assert any(e["name"] == "sar_rounds" for e in spans)
    assert any(e["name"] == "featurize" for e in spans)
    for e in spans:
        assert e["dur"] >= 0.0 and e["ts"] >= 0.0
    for e in req_spans:
        assert e["args"]["verdict"] in (0, 1, 2)
        assert e["args"]["n_samples"] >= POLICY.r_min

    path = tmp_path / "trace.json"
    tracer.export(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"]


# ----------------------------------------------------------------------
# 4. drift monitor
# ----------------------------------------------------------------------
def _stream_moments(grng_cfg, probe_cells=32, n_samples=40):
    raw = np.asarray(clt_grng.raw_sums(grng_cfg, probe_cells, 1,
                                       n_samples), np.float64)
    return float(raw.size), float(raw.sum()), float((raw * raw).sum())


def test_drift_monitor_golden_quiet_shifted_fires():
    cfg = clt_grng.GRNGConfig()
    ref = DriftReference.measure(cfg, probe_cells=32, n_samples=256)
    assert abs(ref.sum_mean_uA - cfg.sum_mean) < 1.0

    mon = DriftMonitor(ref)
    mon.observe(*_stream_moments(cfg))
    st = mon.status()
    assert st.ok and not st.drifted and st.advisory is None
    assert abs(st.z_mean) < 5.0 and abs(st.z_std) < 5.0

    # σ-shifted die, golden belief: the monitor must fire with an
    # advisory that points at the hw/calib recalibration path
    shifted = dataclasses.replace(cfg, i_lo=cfg.i_lo * 1.15,
                                  delta_i=cfg.delta_i * 1.2)
    mon2 = DriftMonitor(ref)
    mon2.observe(*_stream_moments(shifted))
    st2 = mon2.status()
    assert st2.drifted and not st2.ok
    assert "recalibration" in st2.advisory
    assert max(abs(st2.z_mean), abs(st2.z_std)) > DriftGate().z_gate

    # round-trip: to_dict is JSON-ready and re-judgeable
    d = st2.to_dict()
    json.dumps(d)
    ref2 = DriftReference(**d["reference"])
    assert ref2 == ref


def test_drift_min_samples_gate():
    cfg = clt_grng.GRNGConfig()
    ref = DriftReference.measure(cfg, probe_cells=4, n_samples=64)
    # far-off moments, but only n=8 samples: the gate must hold fire
    st = drift_status({"n": 8.0, "sum": 8 * 25.0, "sumsq": 8 * 626.0},
                      ref, DriftGate(min_samples=256))
    assert st.ok and not st.drifted and np.isnan(st.z_mean)
    # same moments past min_samples: fires
    st2 = drift_status({"n": 512.0, "sum": 512 * 25.0,
                        "sumsq": 512 * 626.0}, ref,
                       DriftGate(min_samples=256))
    assert st2.drifted


# ----------------------------------------------------------------------
# 5. mission: telemetry rides the scan, trajectories untouched
# ----------------------------------------------------------------------
def test_mission_telemetry_identity_and_residency(sar):
    from repro.mission import MissionPolicy, UavConfig, WorldConfig, \
        fly_mission
    params, cfg = sar
    wcfg = WorldConfig(grid=6, n_victims=3, seed=2)
    ucfg = UavConfig(n_drones=2, battery_J=120e-6)
    pol = MissionPolicy()
    on = fly_mission(wcfg, ucfg, pol, params=params, cfg=cfg,
                     n_steps=18, telemetry=True)
    off = fly_mission(wcfg, ucfg, pol, params=params, cfg=cfg,
                      n_steps=18, telemetry=False)
    assert on.summary == off.summary
    for k in on.logs:
        np.testing.assert_array_equal(on.logs[k], off.logs[k], err_msg=k)
    # still exactly one host sync per die group, telemetry riding along
    assert on.host_syncs == off.host_syncs == 1
    assert off.telemetry is None

    t = on.telemetry["ideal"]
    snap, drift = t["telemetry"], t["drift"]
    assert snap["decisions"] > 0
    # inside the scan, "dispatches" counts decision-kernel invocations
    # (look + orbit rounds), not host round trips — host_syncs above is
    # the residency claim
    assert snap["dispatches"] >= 1
    # golden die serving its factory belief: no advisory
    assert not drift["drifted"] and drift["advisory"] is None

    # post-hoc Perfetto trace on the simulated clock: one span per
    # active drone-step
    doc = mission_trace(on.logs)
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert len(spans) == int(np.asarray(on.logs["active"]).sum())
    json.dumps(doc)


# ----------------------------------------------------------------------
# satellites: structured logging + metric exporters + clock fallback
# ----------------------------------------------------------------------
def test_logger_levels_and_json_mode(capsys, monkeypatch):
    monkeypatch.delenv("REPRO_LOG_LEVEL", raising=False)
    monkeypatch.delenv("REPRO_LOG_JSON", raising=False)
    log = Logger("t")
    log.debug("hidden")
    log.info("served", decisions=192)
    out = capsys.readouterr().out
    assert "hidden" not in out
    assert "[t] served decisions=192" in out

    monkeypatch.setenv("REPRO_LOG_LEVEL", "error")
    log.warning("also hidden")
    assert capsys.readouterr().out == ""

    monkeypatch.setenv("REPRO_LOG_LEVEL", "debug")
    monkeypatch.setenv("REPRO_LOG_JSON", "1")
    log.debug("drained", n=3, obj={"a": 1})
    rec = json.loads(capsys.readouterr().out)
    assert rec["level"] == "debug" and rec["logger"] == "t"
    assert rec["msg"] == "drained" and rec["n"] == 3
    assert isinstance(rec["obj"], str)   # non-scalars stringified


def test_registry_prometheus_exposition(tmp_path):
    reg = MetricsRegistry()
    reg.counter("decisions_total", 192, job="serving")
    reg.gauge("flag_fraction", 0.25)
    reg.histogram("confidence", [5, 10, 9], [0.0, 0.5, 0.8, 1.0])
    text = reg.to_prometheus()
    assert "# TYPE repro_decisions_total counter" in text
    assert 'repro_decisions_total{job="serving"} 192' in text
    # cumulative buckets, +Inf bucket equals _count
    assert 'repro_confidence_bucket{le="0.5"} 5' in text
    assert 'repro_confidence_bucket{le="0.8"} 15' in text
    assert 'repro_confidence_bucket{le="+Inf"} 24' in text
    assert "repro_confidence_count 24" in text

    prom, js = reg.write(str(tmp_path / "m"))
    assert json.loads(open(js).read())["metrics"]
    assert open(prom).read() == text


def test_serving_registry_from_engine_snapshot(sar):
    eng = _run_sar(sar, 16, telemetry=True)
    snap = eng.telemetry_snapshot()
    cfg = clt_grng.GRNGConfig()
    ref = DriftReference.measure(cfg, probe_cells=32, n_samples=64)
    st = drift_status(snap, ref)
    reg = serving_registry(eng.metrics.summary(), telemetry=snap,
                           drift=st.to_dict(), arch="sar_cnn")
    text = reg.to_prometheus()
    assert "repro_telemetry_decisions_total" in text
    assert "repro_grng_drift_z_mean" in text
    assert 'verdict="accept"' in text
    json.dumps(reg.to_json())

    # add_telemetry tolerates an empty snapshot (disabled engines)
    add_telemetry(MetricsRegistry(), {})


def test_request_record_clock_fallback():
    from repro.serving.metrics import RequestRecord
    # old-style record (wall clocks only): latency math still works
    r = RequestRecord(rid=0, verdict=0, n_samples=4, n_decisions=1,
                      arrival_s=10.0, admit_s=11.0, done_s=12.0)
    assert r.queue_latency_s == 1.0 and r.latency_s == 2.0
    # perf_counter arrival wins when present
    r2 = RequestRecord(rid=0, verdict=0, n_samples=4, n_decisions=1,
                       arrival_s=99.0, admit_s=11.0, done_s=12.0,
                       arrival_pc=10.5)
    assert r2.queue_latency_s == 0.5 and r2.latency_s == 1.5


# ----------------------------------------------------------------------
# stage profiler: zero-overhead identity + exposition
# ----------------------------------------------------------------------
def test_stage_profiler_verdict_identity_and_exposition(sar):
    """Profiler on vs off: bit-identical verdicts, equal host syncs
    (the profiler is host-side bookkeeping around the existing blocking
    pulls — it must never add device round-trips), stage histograms in
    the summary, and stage/compile metrics in the .prom exposition."""
    n = 16
    eng_on = _run_sar(sar, n, telemetry=False, profiler=True)
    eng_off = _run_sar(sar, n, telemetry=False, profiler=False)
    _records_match(eng_on, eng_off, n)
    assert eng_on.host_syncs == eng_off.host_syncs

    s_on = eng_on.metrics.summary()
    assert "stage_profile" not in eng_off.metrics.summary()
    snap = s_on["stage_profile"]
    for stage in ("admission", "featurize", "dispatch", "triage_loop",
                  "retirement"):
        assert snap[stage]["count"] > 0, stage
        assert snap[stage]["total_s"] >= 0.0
        assert sum(snap[stage]["counts"]) + snap[stage]["overflow"] \
            == snap[stage]["count"]
    cc = s_on["compile_counters"]
    assert cc["builder_builds"].get("sar_round", 0) >= 1

    text = serving_registry(s_on).to_prometheus()
    assert "repro_stage_latency_seconds_bucket" in text
    assert 'stage="triage_loop"' in text
    assert "repro_engine_builder_builds_total" in text
    assert "repro_xla_compile_events_total" in text


def test_compiled_cost_records_from_engine(sar):
    """AOT cost capture off the live engine: the fused round + the
    featurize fn, each with nonzero FLOPs/bytes and a peak-live figure
    (the profiling path never perturbs the serving jit cache)."""
    eng = _run_sar(sar, 8, telemetry=False)
    recs = eng.compiled_cost_records()
    names = {r["name"] for r in recs}
    assert names == {"sar_round", "sar_featurize"}
    for r in recs:
        assert r["flops"] > 0 and r["hbm_bytes"] > 0
        assert r["peak_live_bytes"] > 0
        assert r["compile_s"] >= 0.0
