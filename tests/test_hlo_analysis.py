"""Validate the loop-aware HLO analyzer against analytic ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch.hlo_analysis import analyze


def _compile(fn, *abstract):
    return jax.jit(fn).lower(*abstract).compile()


def test_scan_flops_scale_with_trip_count():
    def make(n_layers):
        def f(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = lax.scan(body, x, ws)
            return h.sum()
        return f

    d = 128
    results = {}
    for layers in (2, 8):
        ws = jax.ShapeDtypeStruct((layers, d, d), jnp.float32)
        x = jax.ShapeDtypeStruct((32, d), jnp.float32)
        compiled = _compile(make(layers), ws, x)
        results[layers] = analyze(compiled.as_text(), 1)["flops_per_device"]
        # analytic: 2 * 32 * d * d per layer
        expect = 2 * 32 * d * d * layers
        assert abs(results[layers] / expect - 1) < 0.05, (
            layers, results[layers], expect)
    assert results[8] / results[2] > 3.5


def test_grad_scan_flops():
    """Backward-of-scan (reverse loop) must also be trip-counted."""
    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = lax.scan(body, x, ws)
        return (h * h).sum()

    layers, d, b = 6, 128, 32
    ws = jax.ShapeDtypeStruct((layers, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)
    compiled = _compile(jax.grad(f), ws, x)
    flops = analyze(compiled.as_text(), 1)["flops_per_device"]
    # fwd (2bdd) + bwd (2 matmuls: 2·2bdd) per layer = 6·b·d·d
    expect = 6 * b * d * d * layers
    assert flops > 0.7 * expect, (flops, expect)
    assert flops < 2.0 * expect, (flops, expect)


def test_bytes_nonzero_and_loop_scaled():
    def make(n):
        def f(x):
            def body(h, _):
                return jnp.sin(h), None
            h, _ = lax.scan(body, x, None, length=n)
            return h
        return f

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    b2 = analyze(_compile(make(2), x).as_text(), 1)["hbm_bytes_per_device"]
    b16 = analyze(_compile(make(16), x).as_text(), 1)["hbm_bytes_per_device"]
    assert b16 > 4 * b2


def test_collectives_counted(tmp_path):
    hlo = """
HloModule test

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[128]) %p), index=0
  %n = s32[] constant(10)
  ROOT %cmp = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[128]) %p), index=0
  %x = f32[128] get-tuple-element((s32[], f32[128]) %p), index=1
  %ar = f32[128] all-reduce(f32[128] %x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(s32[] %i, s32[] %one)
  ROOT %t = (s32[], f32[128]) tuple(s32[] %i2, f32[128] %ar)
}

ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(s32[] %zero, f32[128] %x)
  %w = (s32[], f32[128]) while((s32[], f32[128]) %init), condition=%cond, body=%body
  ROOT %out = f32[128] get-tuple-element((s32[], f32[128]) %w), index=1
}
"""
    res = analyze(hlo, 4)
    ar = res["collectives"]["all-reduce"]
    assert ar["count"] == 10.0
    # 10 trips × 2·(3/4)·512B
    np.testing.assert_allclose(ar["wire_bytes"], 10 * 2 * 0.75 * 512)
