"""UAV fleet model: kinematics, sectors, and the per-sortie ledger.

A drone is four numbers of mutable state — flat cell position, a
path-step counter, cumulative energy spent, cumulative mission time —
plus static per-drone bindings: its search sector (a contiguous row
band of the map; sectors partition the grid so drones never collide)
and its chip instance (rollout.py groups drones by die, since each
die's nonideal constants compile into their own executable).

The battery is a LEDGER, not a decrement: energy accumulates from
zero and the drone goes inactive once it crosses ``battery_J``.  That
keeps float32 accumulation well-conditioned and makes the coverage-
monotone-in-budget property exact (a larger budget replays the
identical trajectory prefix).  Decision energy/latency are charged
from the SAME frozen ``serving.metrics.DecisionCost`` struct the
serving summaries use — the reconciliation test in
tests/test_mission.py holds by construction.  Flight and maneuver
costs are the mission-level terms the paper's abstract prices against
triage quality: a verification maneuver (descend-orbit-confirm) costs
orders of magnitude more than the decision that gates it, which is
exactly why filtering low-confidence detections buys coverage.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class UavConfig:
    """Fleet-wide platform constants (hashable, keys compile caches).

    Energy scale: the SAR CNN's fixed decision sweep is ~7 nJ on the
    analytic model, so the defaults put one cell transit at ~200
    decisions and a full verification maneuver at ~6000 — maneuvers
    dominate, inference is the cheap gate, matching the paper's
    deployment story (88.7 mW platform vs aJ-scale GRNG).
    """
    n_drones: int = 3
    battery_J: float = 250e-6         # per-sortie energy budget
    flight_energy_J: float = 1.5e-6   # one cell-to-cell transit
    verify_energy_J: float = 40e-6    # descend-orbit-confirm maneuver
    orbit_energy_J: float = 8e-6      # flag-and-orbit second look
    flight_time_s: float = 2.0
    verify_time_s: float = 25.0
    orbit_time_s: float = 8.0


def sector_rows(grid: int, n_drones: int) -> np.ndarray:
    """[n_drones, 2] (row0, n_rows): contiguous row bands partitioning
    the map as evenly as possible — every cell is owned by exactly one
    drone, so scatters inside an episode never collide.  Requires
    n_drones ≤ grid: a zero-row sector would alias its drone onto a
    neighbour's cells (index clamping), silently corrupting the maps
    the partition invariant protects."""
    if n_drones > grid:
        raise ValueError(
            f"n_drones={n_drones} exceeds grid rows={grid}: row-band "
            f"sectors cannot give every drone at least one row")
    base, extra = divmod(grid, n_drones)
    out, r0 = [], 0
    for d in range(n_drones):
        rows = base + (1 if d < extra else 0)
        out.append((r0, rows))
        r0 += rows
    return np.asarray(out, np.int32)


def sector_masks(grid: int, n_drones: int) -> np.ndarray:
    """[n_drones, grid²] bool — each drone's owned cells (the
    information-gain planner's argmax domain)."""
    rows = sector_rows(grid, n_drones)
    cell_row = np.arange(grid * grid) // grid
    return np.stack([(cell_row >= r0) & (cell_row < r0 + nr)
                     for r0, nr in rows])


def init_fleet(ucfg: UavConfig, grid: int, n_episodes: int = 1) -> dict:
    """Fresh fleet state for ``n_episodes`` stacked worlds, flattened to
    one batch of B = n_episodes · n_drones drones (the decision-kernel
    batch dimension).  Each drone starts at its sector's origin."""
    rows = sector_rows(grid, ucfg.n_drones)
    start = jnp.asarray(rows[:, 0] * grid, jnp.int32)        # [D]
    pos = jnp.tile(start, n_episodes)                        # [E·D]
    b = pos.shape[0]
    return {
        "pos": pos,                                # flat cell index
        "path_k": jnp.zeros((b,), jnp.int32),      # planner step counter
        "energy_J": jnp.zeros((b,), jnp.float32),  # ledger: spent so far
        "time_s": jnp.zeros((b,), jnp.float32),    # mission clock
    }


def fleet_bindings(ucfg: UavConfig, grid: int,
                   n_episodes: int = 1) -> dict:
    """Static per-drone arrays for the flattened fleet batch: world id
    ``wid`` [B], sector (row0, n_rows) [B, 2], sector mask [B, grid²]."""
    d = ucfg.n_drones
    rows = sector_rows(grid, d)
    masks = sector_masks(grid, d)
    return {
        "wid": jnp.repeat(jnp.arange(n_episodes, dtype=jnp.int32), d),
        "sector": jnp.asarray(np.tile(rows, (n_episodes, 1)), jnp.int32),
        "sector_mask": jnp.asarray(np.tile(masks, (n_episodes, 1))),
    }
