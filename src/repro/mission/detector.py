"""Mission detector: weather-augmented SAR CNN training + caching.

The serving benchmarks train the detector on CLEAN synthetic SARD; a
mission flies through weather, and a clean-trained detector is
CONFIDENTLY wrong under heavy corruption (the overconfidence the paper
opens with) — no triage policy can filter what the model is sure
about.  Deployment practice is to train with the expected corruption
in the augmentation pipe; this module does exactly that, drawing a
per-image severity from U(0, severity_hi) through the severity-field
API (data/sard.corrupt), which is also what makes the weather an
IN-distribution ambiguity the Bayesian head can price: transient-snow
false positives land at low confidence (flagged → orbited) while
victims stay near-certain (accepted → verified).

Parameters are cached through the repo checkpoint layer under
``artifacts/mission/detector-<corruption>``, shared by the CLI, the
mission bench, and the tests.
"""

from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp

from repro.data.sard import SardConfig, batch_at, corrupt
from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn, train_loss
from repro.obs.log import get_logger

log = get_logger("mission.detector")

ART = Path("artifacts/mission")
TRAIN_STEPS = 1600
TRAIN_BATCH = 64
DATA_SEED = 7          # the repo's shared SARD training stream


def trained_detector(cfg: SarCnnConfig | None = None,
                     corruption: str = "snow",
                     severity_hi: float = 0.5,
                     steps: int = TRAIN_STEPS,
                     ckpt_dir: Path | None = None):
    """(params, cfg): the weather-augmented Bayesian-head detector.

    Trains once (Bayes-by-backprop, AdamW, per-image severities
    ~ U(0, severity_hi)) and restores from the checkpoint cache on
    every later call.
    """
    from repro.ckpt import latest_step, restore, save
    from repro.optim import AdamWConfig, adamw_update, init_opt_state
    cfg = cfg or SarCnnConfig()
    # cache key carries every training knob: a CI smoke run (few steps)
    # and the default-scale bench must never restore each other's model
    ckpt_dir = ckpt_dir or (
        ART / f"detector-{corruption}-h{severity_hi:g}-s{steps}")
    if latest_step(ckpt_dir) is not None:
        tree, _ = restore(ckpt_dir)
        return jax.tree.map(jnp.asarray, tree), cfg

    dcfg = SardConfig(image_size=cfg.image_size, seed=DATA_SEED)
    params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)

    @jax.jit
    def step_fn(params, opt, batch, step):
        (_, m), g = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg, step),
            has_aux=True)(params)
        params, opt, _ = adamw_update(params, g, opt, opt_cfg)
        return params, opt, m

    for s in range(steps):
        batch = batch_at(dcfg, s, TRAIN_BATCH)
        k1, k2 = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(0xA06), s))
        sev = jax.random.uniform(k1, (TRAIN_BATCH,), maxval=severity_hi)
        batch = {"images": corrupt(batch["images"], k2, sev, corruption),
                 "labels": batch["labels"]}
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        if s % 400 == 0:
            log.info(f"step {s} ce={float(m['ce']):.4f} "
                     f"acc={float(m['acc']):.3f}")
    save(ckpt_dir, steps, params)
    return params, cfg
