"""Grid-world SAR environment: victims + spatially-correlated weather.

The mission map is a ``grid × grid`` lattice of aerial patches.  Each
cell renders through the SAME generators the detector was trained and
benchmarked on (data/sard.py ``make_image``), so mission observations
stay distribution-matched to the serving stream — the only differences
from a serving batch are (a) the victim prior is the map's, not the
balanced 50 %, and (b) corruption severity varies OVER THE MAP: a
multi-octave smooth field assigns every cell its own fog/frost/motion/
snow severity, rendered through the per-image severity API
(data/sard.corrupt / CORRUPTIONS_IMAGE).

Observations split into a persistent SCENE and a transient EXPOSURE:
the terrain, the distractor rock, and the victim (placement and pose)
are a pure function of ``(map seed, cell)`` and never change, while
sensor noise and transient weather (falling snow, frost crystals) are
additionally keyed by the ``look`` index.  Re-observing a cell — an
orbit maneuver, an information-gain revisit — therefore sees the same
ground truth under fresh noise and fresh weather, which is exactly
what lets the rollout's flag-and-orbit policy filter transient false
positives without losing persistent victims (rollout.py's 2-of-3
evidence rule depends on this split; do not re-merge the keys).
``observe_cells`` is jittable and vmap-batched, so the rollout driver
renders the whole fleet's observations inside its device-resident
episode scan.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.data.sard import (CORRUPTIONS_IMAGE, SardConfig, _smooth_noise,
                             make_image)

# Domain-separation tags for the world's three random substreams.
_SEED_SCENE = 0x0B5E
_SEED_WEATHER = 0x7EA7
_SEED_LAYOUT = 0x5A12


@dataclasses.dataclass(frozen=True)
class WorldConfig:
    """Static mission-map parameters (hashable: keys compile caches)."""
    grid: int = 12                   # grid × grid cells
    n_victims: int = 5
    seed: int = 0
    corruption: str = "snow"         # the map's weather modality
    severity_lo: float = 0.0         # clear-sky corner of the field
    severity_hi: float = 0.5         # worst-weather corner of the field
    field_octaves: int = 3
    image_size: int = 32

    @property
    def n_cells(self) -> int:
        return self.grid * self.grid

    def sard(self) -> SardConfig:
        return SardConfig(image_size=self.image_size, seed=self.seed)


def make_world(cfg: WorldConfig, seed: int | None = None) -> dict:
    """Sample one mission map.  Returns a device pytree:

      victims   [n_cells] bool — ground-truth victim presence
      severity  [n_cells] f32  — the cell's corruption severity, from a
                smooth multi-octave field min-max normalized into
                [severity_lo, severity_hi] (spatially correlated: fog
                banks, not salt-and-pepper)
      seed      []        i32  — the map's seed (observe_cells keys the
                per-cell scene/weather streams off it, so stacked
                multi-episode worlds stay independent)

    ``seed`` overrides ``cfg.seed`` — the episode-stacking path draws
    world i from ``seed + i`` while everything static stays shared.
    """
    s = cfg.seed if seed is None else seed
    key = jax.random.fold_in(jax.random.PRNGKey(_SEED_LAYOUT), s)
    kv, kf = jax.random.split(key)
    placed = jax.random.choice(kv, cfg.n_cells, (cfg.n_victims,),
                               replace=False)
    victims = jnp.zeros((cfg.n_cells,), bool).at[placed].set(True)
    field = _smooth_noise(kf, cfg.grid, octaves=cfg.field_octaves)
    lo, hi = field.min(), field.max()
    field = (field - lo) / jnp.maximum(hi - lo, 1e-9)
    severity = cfg.severity_lo + (cfg.severity_hi - cfg.severity_lo) * field
    return {
        "victims": victims,
        "severity": severity.reshape(-1).astype(jnp.float32),
        "seed": jnp.asarray(s, jnp.int32),
    }


def observe_cell(cfg: WorldConfig, wseed, cell, has_victim, severity,
                 look=0):
    """Render ONE cell's aerial patch.  The SCENE (terrain, distractor
    rock, victim placement/pose) is a pure function of (map seed, cell)
    and persists across observations; the EXPOSURE — sensor noise and
    transient weather (falling snow specks, frost crystals) — is keyed
    by ``look`` as well, so an orbit maneuver or a revisit sees the
    same ground truth under an independent exposure.  That is exactly
    why a second look filters weather-induced false positives but not
    persistent victims (rollout.py's flag-and-orbit routing)."""
    scene = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(_SEED_SCENE), wseed), cell)
    noise = jax.random.fold_in(scene, 1 + jnp.asarray(look))
    img = make_image(cfg.sard(), scene, has_victim, noise_key=noise)
    weather = jax.random.fold_in(jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(_SEED_WEATHER), wseed),
        cell), look)
    return CORRUPTIONS_IMAGE[cfg.corruption](img, weather, severity)


def observe_cells(cfg: WorldConfig, worlds: dict, wid: jnp.ndarray,
                  cells: jnp.ndarray, look=0) -> jnp.ndarray:
    """Batched fleet observation: drone b (on world ``wid[b]``) looks at
    ``cells[b]`` (exposure index ``look``, scalar or [B]).  worlds:
    ``make_world`` pytrees stacked on a leading episode axis.  Returns
    [B, H, W, 1] detector inputs.  Jittable — the rollout calls this
    inside its device-resident episode scan."""
    has = worlds["victims"][wid, cells].astype(jnp.float32)
    sev = worlds["severity"][wid, cells]
    seeds = worlds["seed"][wid]
    look = jnp.broadcast_to(jnp.asarray(look, jnp.int32), cells.shape)
    return jax.vmap(
        lambda s, c, h, v, lk: observe_cell(cfg, s, c, h, v, lk)
    )(seeds, cells, has, sev, look)


def stack_worlds(cfg: WorldConfig, n_episodes: int) -> dict:
    """``n_episodes`` independent maps (seeds cfg.seed … cfg.seed+E-1)
    stacked leaf-wise — the fleet-scale rollout's world batch."""
    worlds = [make_world(cfg, cfg.seed + e) for e in range(n_episodes)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *worlds)
