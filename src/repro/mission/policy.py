"""Mission policies: coverage planners + the verification router.

Two coverage planners (both pure index arithmetic / argmax over [B]
batches — they run inside the rollout's device-resident scan):

  * ``lawnmower`` — the classic serpentine sweep of the drone's sector;
    deterministic, revisit-free until the sector wraps.  The coverage
    baseline every SAR study reports against.
  * ``infogain`` — greedy uncertainty-directed search: fly to the
    sector cell with the highest remaining predictive entropy,
    distance-discounted.  Unvisited cells carry the maximal prior
    entropy ln(n_classes); an observed cell keeps the entropy its
    triage decision LEFT there, so a flagged-and-skipped cell stays
    attractive and gets revisited while confidently-accepted cells
    drop out — the map-level analogue of the paper's escalation.

The verification router turns the serving-layer triage verdict
(serving/triage: accept / flag) plus the class prediction into the
flight decision the abstract prices:

  accept + victim    → VERIFY: descend-orbit-confirm maneuver (costly;
                       a false one is the metric the paper attacks)
  accept + no victim → move on
  flag               → ``flag_action``: 'orbit' re-decides once at full
                       R from a loiter orbit (cheap vs a verification
                       descent) and routes the collapsed accept/flag
                       verdict; 'skip' defers the cell (the infogain
                       planner may come back to it).

``mode`` selects the decision engine the router sits on: Bayesian
adaptive-R (the paper's Fig. 1 triage with sequential escalation),
Bayesian fixed-R (R = r_max every cell), or the deterministic baseline
(µ-only logits, zero GRNG samples, every positive verified — the
overconfident detector the paper motivates against).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.serving.triage import TriagePolicy

PLANNERS = ("lawnmower", "infogain")
MODES = ("bayes_adaptive", "bayes_fixed", "deterministic")
FLAG_ACTIONS = ("orbit", "skip")


@dataclasses.dataclass(frozen=True)
class MissionPolicy:
    """Frozen (hashable) mission decision policy — keys the rollout's
    compiled-episode cache together with the world/fleet configs."""
    mode: str = "bayes_adaptive"
    planner: str = "lawnmower"
    flag_action: str = "orbit"
    # Fig. 1 thresholds: conf 0.8 / MI 0.5 (TriagePolicy defaults).
    triage: TriagePolicy = dataclasses.field(default_factory=TriagePolicy)
    infogain_lambda: float = 0.05     # distance discount, nats per cell

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}: {self.mode}")
        if self.planner not in PLANNERS:
            raise ValueError(
                f"planner must be one of {PLANNERS}: {self.planner}")
        if self.flag_action not in FLAG_ACTIONS:
            raise ValueError(
                f"flag_action must be one of {FLAG_ACTIONS}: "
                f"{self.flag_action}")

    @property
    def bayesian(self) -> bool:
        return self.mode != "deterministic"


# ----------------------------------------------------------------------
# coverage planners ([B]-batched, jit/scan friendly)
# ----------------------------------------------------------------------
def lawnmower_cell(sector: jnp.ndarray, grid: int,
                   k: jnp.ndarray) -> jnp.ndarray:
    """Serpentine cell for path step ``k`` [B] in ``sector`` [B, 2]
    (row0, n_rows).  Wraps at the sector size (a drone that outlives
    its sweep starts over)."""
    row0, n_rows = sector[:, 0], sector[:, 1]
    s = k % (n_rows * grid)
    r, c = s // grid, s % grid
    col = jnp.where(r % 2 == 0, c, grid - 1 - c)
    return (row0 + r) * grid + col


def infogain_cell(pos: jnp.ndarray, entropy: jnp.ndarray,
                  sector_mask: jnp.ndarray, grid: int,
                  lam: float) -> jnp.ndarray:
    """Greedy next cell [B]: argmax over the drone's sector of the
    remaining predictive entropy minus ``lam`` · Manhattan distance.

    pos [B] flat cells; entropy [B, n_cells] (each drone's view of ITS
    world's entropy map); sector_mask [B, n_cells] bool.
    """
    cells = jnp.arange(entropy.shape[-1], dtype=jnp.int32)
    pr, pc = pos // grid, pos % grid
    cr, cc = cells // grid, cells % grid
    dist = (jnp.abs(cr[None] - pr[:, None])
            + jnp.abs(cc[None] - pc[:, None])).astype(jnp.float32)
    score = entropy - lam * dist
    return jnp.argmax(jnp.where(sector_mask, score, -jnp.inf),
                      axis=-1).astype(jnp.int32)


def next_cell(policy: MissionPolicy, grid: int, *, sector, path_k, pos,
              entropy, sector_mask) -> jnp.ndarray:
    """Planner dispatch (static on ``policy.planner``)."""
    if policy.planner == "lawnmower":
        return lawnmower_cell(sector, grid, path_k)
    return infogain_cell(pos, entropy, sector_mask, grid,
                         policy.infogain_lambda)
