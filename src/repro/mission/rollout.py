"""Device-resident mission rollouts: the closed loop the paper claims.

One episode = a ``lax.scan`` over mission steps, jitted ONCE per
(configs, chip) and executed in a SINGLE dispatch — the host is
re-entered exactly once per rollout, to pull the finished logs.  Each
step, for the whole (episodes × drones) flattened fleet batch:

  observe   render every drone's current cell through the SARD
            generators + the map's severity field (world.observe_cells)
  featurize the serving engine's cached conv-trunk + activation-basis
            builder (engine._sar_featurize_fn — nonideal CIM when a
            chip is bound), so mission decisions flow through the SAME
            compiled path as served requests
  decide    the engine's cached device-resident token-decision builder
            (engine._lm_token_fn): the full escalation schedule with
            cond-skipped rounds through the FUSED decision kernel —
            the [R, B, N] sample tensor never exists here either
  route     verification policy (policy.py): the µ-MVM detection plus
            an accepting posterior → verification descent; a FLAGGED
            detection → loiter orbit (two further independent
            exposures, each re-featurized and re-decided at full R —
            descend only if the evidence repeats) or skip
  ledger    battery/time charged from the frozen
            serving.metrics.DecisionCost struct plus flight + maneuver
            costs (uav.py); a drone past its budget freezes in place
  plan      lawnmower or information-gain next cell (policy.py)

Drones bound to DIFFERENT chip instances compile to different
executables (each die's constants are static, exactly like the serving
engines), so ``fly_mission`` groups the fleet by die and dispatches one
episode per group — sectors partition the map, so groups are
independent and their logs/maps merge exactly.  ``host_syncs`` counts
the blocking pulls: one per die group, never per step.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.mission import policy as mpolicy
from repro.mission import uav as muav
from repro.mission import world as mworld
from repro.mission.policy import MissionPolicy
from repro.mission.uav import UavConfig
from repro.mission.world import WorldConfig
from repro.obs.telemetry import (TelemetryConfig, init_telemetry,
                                 record_decisions)
from repro.obs.telemetry import snapshot as telemetry_snapshot
from repro.serving import adaptive
from repro.serving.metrics import DecisionCost, decision_cost
from repro.serving.triage import ACCEPT, FLAG


def sar_mission_cost(cfg) -> DecisionCost:
    """The mission ledger's per-decision cost struct: tilemap-TRUE
    (compiled placement, not logical tiles) for the SAR detector — the
    same `DecisionCost` numbers `serve_sar`'s summaries charge."""
    from repro.hw import compile_network
    from repro.launch.serve import sar_layer_shapes
    layers = sar_layer_shapes(cfg)
    return decision_cost(layers, compile_network(layers))


# ----------------------------------------------------------------------
# compiled episode builder (process-wide cache, one entry per die group)
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=32)
def _episode_fn(wcfg: WorldConfig, ucfg: UavConfig, pol: MissionPolicy,
                snn_cfg, hcfg, chip, cost: DecisionCost, fused: bool,
                n_steps: int, n_batch: int, n_classes: int,
                tcfg: TelemetryConfig | None = None, step0: int = 0,
                slot_axis: str | None = None, mesh=None):
    """jit (params, head, logit_bias, worlds, fleet0, maps0, bind)
           -> (fleet, maps, logs [n_steps, n_batch] pytree).

    ``n_batch`` is the flattened episodes×group-drones batch — the
    decision kernel's B.  Cached on the frozen configs + the chip's
    identity, like every other pool builder in serving/engine.py.

    ``step0``: absolute mission step of the scan's first iteration.
    The lifetime loop (``fly_mission(..., lifetime=...)``) cuts one
    mission into age-epoch segments; scanning over ABSOLUTE step
    indices keeps every decision's s2 stream base globally unique, so
    a segmented mission draws the same GRNG sample streams a
    single-dispatch mission would.  ``step0=0`` with ``n_steps`` equal
    to the mission length is exactly the pre-lifetime episode.

    ``slot_axis``/``mesh``: shard the fleet×episodes batch axis over a
    device mesh — the decision rounds run through the shard_map-native
    fused kernel (kernels/decision_stats_sharded), read-noise streams
    keyed on GLOBAL lane ids so sharded missions replay the
    single-device sample streams bit for bit.  ``n_batch`` must divide
    evenly; ``_lm_token_fn`` falls back to the unsharded kernel
    otherwise.

    With ``tcfg`` set (obs/telemetry), the episode takes a telemetry
    pytree as an eighth argument and returns it as a fourth output: it
    rides the scan carry (and the orbit ``lax.cond`` state) across all
    ``n_steps``, so this die group's counters and GRNG probe moments
    come home in the SAME single device pull as the logs.
    """
    from repro.serving.engine import _lm_token_fn, _sar_featurize_fn

    tri = pol.triage
    grid = wcfg.grid
    featurize = _sar_featurize_fn(snn_cfg, hcfg, chip, None)
    decide_fn = orbit_fn = None
    if pol.bayesian:
        schedule = (adaptive.escalation_schedule(tri)
                    if pol.mode == "bayes_adaptive" else (tri.r_max,))
        decide_fn = _lm_token_fn(hcfg, tri, pol.mode == "bayes_adaptive",
                                 schedule, fused, n_batch, n_classes,
                                 tcfg, slot_axis=slot_axis, mesh=mesh)
        if pol.flag_action == "orbit":
            orbit_fn = _lm_token_fn(hcfg, tri, False, (tri.r_max,),
                                    fused, n_batch, n_classes, tcfg,
                                    slot_axis=slot_axis, mesh=mesh)
    r_max = jnp.uint32(tri.r_max)
    lane = jnp.arange(n_batch, dtype=jnp.uint32)

    def step(worlds, bind, params, head, logit_bias, carry, step_idx):
        fleet, maps, telem = carry
        wid, cells = bind["wid"], fleet["pos"]
        active = fleet["energy_J"] < ucfg.battery_J

        def look_at(look):
            """Observe + featurize one exposure, at the die's calibrated
            operating point (per-class logit bias subtracted)."""
            imgs = mworld.observe_cells(wcfg, worlds, wid, cells, look)
            rows = dict(featurize(params, head, imgs))
            rows["y_mu"] = rows["y_mu"] - logit_bias
            return rows

        # Exposure 3·step: the scene under this cell is persistent, but
        # sensor noise and transient weather (snow specks, frost) are
        # re-drawn every observation — a revisit gets fresh evidence.
        rows = look_at(3 * step_idx)

        orbited = jnp.zeros((n_batch,), bool)
        if not pol.bayesian:
            logp = jax.nn.log_softmax(rows["y_mu"].astype(jnp.float32))
            pred = jnp.argmax(logp, -1).astype(jnp.int32)
            conf = jnp.exp(logp.max(-1))
            pred_ent = -(jnp.exp(logp) * logp).sum(-1)
            verdict = jnp.full((n_batch,), ACCEPT, jnp.int32)
            spent = jnp.zeros((n_batch,), jnp.int32)
            want_verify = pred == 1          # verify EVERY detection
            if telem is not None:
                # deterministic decisions have no sampled statistics:
                # record the softmax-derived quality fields so verdict
                # mix / entropy histograms stay comparable across modes
                fin_lite = {"probs": jnp.exp(logp), "confidence": conf,
                            "predictive_entropy": pred_ent,
                            "mutual_information":
                                jnp.zeros((n_batch,), jnp.float32),
                            "n": jnp.zeros((n_batch,), jnp.int32)}
                telem = record_decisions(telem, tcfg, fin_lite, verdict,
                                         active)
        else:
            # The DETECTION is the hardware's deterministic output (the
            # X·µ' MVM it computes regardless); the posterior is the
            # Fig. 1 UNCERTAINTY GATE on top of it.  Class from y_mu,
            # accept/flag from the sampled predictive statistics.
            pred = jnp.argmax(rows["y_mu"].astype(jnp.float32),
                              -1).astype(jnp.int32)
            # 3 decision slots per (step, drone): primary + 2 re-looks.
            s2 = jnp.uint32(3) * step_idx.astype(jnp.uint32) \
                * jnp.uint32(n_batch)
            if telem is None:
                verdict, fin, spent = decide_fn(
                    rows, (s2 + lane) * r_max, active)
            else:
                verdict, fin, spent, telem = decide_fn(
                    rows, (s2 + lane) * r_max, active, telem)
            conf = fin["confidence"]
            pred_ent = fin["predictive_entropy"]
            want_verify = (verdict == ACCEPT) & (pred == 1)
            if orbit_fn is not None:
                # Flag-and-orbit: a LOW-CONFIDENCE detection buys one
                # loiter orbit — TWO further independent exposures
                # (looks 3t+1, 3t+2), each with a fresh featurization
                # and a full-R decision, before any verification
                # descent.  The descent launches if EITHER re-look
                # detects again (2-of-3 evidence): a transient-weather
                # false positive must re-roll twice to survive, while a
                # persistent victim only has to show up once more.
                flagged = active & (verdict == FLAG) & (pred == 1)

                def orbit(state):
                    relook, conf, pred_ent, spent, telem = state
                    for j in (1, 2):
                        rows_j = look_at(3 * step_idx + j)
                        b_j = (s2 + jnp.uint32(j * n_batch) + lane) \
                            * r_max
                        if telem is None:
                            _, fin_j, spent_j = orbit_fn(rows_j, b_j,
                                                         flagged)
                        else:
                            _, fin_j, spent_j, telem = orbit_fn(
                                rows_j, b_j, flagged, telem)
                        pred_j = jnp.argmax(
                            rows_j["y_mu"].astype(jnp.float32),
                            -1).astype(jnp.int32)
                        relook = relook | (pred_j == 1)
                        conf = jnp.where(flagged, fin_j["confidence"],
                                         conf)
                        pred_ent = jnp.where(
                            flagged, fin_j["predictive_entropy"],
                            pred_ent)
                        spent = spent + spent_j
                    return relook, conf, pred_ent, spent, telem

                # re-looks cost 2 more trunk sweeps + decisions — skip
                # the whole branch on the (common) nothing-flagged step
                relook, conf, pred_ent, spent, telem = lax.cond(
                    jnp.any(flagged), orbit, lambda s: s,
                    (jnp.zeros((n_batch,), bool), conf, pred_ent,
                     spent, telem))
                orbited = flagged
                want_verify = want_verify | (flagged & relook)

        truth = worlds["victims"][wid, cells]
        already = (jnp.isfinite(maps["rescued_t"][wid, cells])
                   | (maps["cleared"][wid, cells] > 0))
        verify = active & want_verify & ~already
        found = verify & truth
        false_verify = verify & ~truth

        # ledger: decision terms from the SAME DecisionCost struct the
        # serving summaries use, plus the mission-level maneuver costs.
        # An orbit re-featurizes twice (two more fixed MVM sweeps) and
        # re-samples, so it charges 2·e_fixed + its sample spend.
        spent_f = spent.astype(jnp.float32)
        n_dec = 1.0 + 2.0 * orbited.astype(jnp.float32)
        e_dec = n_dec * cost.e_fixed_J + spent_f * cost.e_per_sample_J
        t_dec = n_dec * cost.t_fixed_s + spent_f * cost.t_per_sample_s
        e_step = (ucfg.flight_energy_J + e_dec
                  + jnp.where(orbited, ucfg.orbit_energy_J, 0.0)
                  + jnp.where(verify, ucfg.verify_energy_J, 0.0))
        t_step = (ucfg.flight_time_s + t_dec
                  + jnp.where(orbited, ucfg.orbit_time_s, 0.0)
                  + jnp.where(verify, ucfg.verify_time_s, 0.0))
        energy = fleet["energy_J"] + jnp.where(active, e_step, 0.0)
        time_s = fleet["time_s"] + jnp.where(active, t_step, 0.0)

        maps = dict(maps)
        maps["rescued_t"] = maps["rescued_t"].at[wid, cells].min(
            jnp.where(found, time_s, jnp.inf))
        maps["cleared"] = maps["cleared"].at[wid, cells].max(
            verify.astype(jnp.int32))
        maps["visited"] = maps["visited"].at[wid, cells].max(
            active.astype(jnp.int32))
        ent_seen = jnp.where(found, 0.0, pred_ent)
        ent_old = maps["entropy"][wid, cells]
        maps["entropy"] = maps["entropy"].at[wid, cells].set(
            jnp.where(active, ent_seen, ent_old))

        path_k = fleet["path_k"] + active.astype(jnp.int32)
        ent_view = maps["entropy"][wid]
        if pol.planner == "infogain":
            # never loiter: the just-observed cell is excluded this turn
            ent_view = ent_view.at[lane.astype(jnp.int32), cells].set(
                -jnp.inf)
        nxt = mpolicy.next_cell(pol, grid, sector=bind["sector"],
                                path_k=path_k, pos=cells,
                                entropy=ent_view,
                                sector_mask=bind["sector_mask"])
        fleet = {"pos": jnp.where(active, nxt, cells), "path_k": path_k,
                 "energy_J": energy, "time_s": time_s}

        log = {"cell": cells, "active": active, "verdict": verdict,
               "prediction": pred, "confidence": conf, "spent": spent,
               "orbited": orbited, "verify": verify, "found": found,
               "false_verify": false_verify, "truth": truth,
               "e_decision_J": jnp.where(active, e_dec, 0.0),
               "energy_J": energy, "time_s": time_s}
        return (fleet, maps, telem), log

    # ``telem0=None`` keeps the pre-telemetry signature and return
    # arity for callers that lower/execute the 7-argument form (None is
    # an empty pytree, so the carry slot costs nothing).
    def episode(params, head, logit_bias, worlds, fleet0, maps0, bind,
                telem0=None):
        (fleet, maps, telem), logs = lax.scan(
            functools.partial(step, worlds, bind, params, head,
                              logit_bias),
            (fleet0, maps0, telem0),
            jnp.arange(step0, step0 + n_steps, dtype=jnp.int32))
        if telem0 is None:
            return fleet, maps, logs
        return fleet, maps, logs, telem

    return jax.jit(episode)


# ----------------------------------------------------------------------
# mission driver
# ----------------------------------------------------------------------
@dataclasses.dataclass
class MissionResult:
    summary: dict
    logs: dict           # numpy [n_steps, E·D] arrays, fleet order
    maps: dict           # merged {rescued_t, cleared, visited, entropy}
    worlds: dict         # numpy world pytree [E, ...]
    host_syncs: int      # blocking device→host pulls (one per die group,
    #                      or one per age-epoch segment of an aged group)
    # per die group: {"telemetry": obs snapshot, "drift": obs.drift
    # status dict} — None when telemetry was disabled
    telemetry: dict | None = None
    # per AGED die group: hw/redeploy.SelfHealingController.report()
    # plus advisory/epoch counts — None when no lifetime loop ran
    lifetime: dict | None = None


def _group_base_hcfg(cfg, tri):
    from repro.core.sampling import BayesHeadConfig
    return BayesHeadConfig(num_samples=tri.r_max, mode="rank16",
                           grng=cfg.grng, compute_dtype=jnp.float32,
                           hoist_basis=True)


def _prepare_group_head(params, cfg, tri, chip, calibrated: bool):
    """(head, serving hcfg) for one die group — golden transform when
    ``chip`` is None, else hw/calib's per-instance deployment."""
    from repro.core.bayes_layer import sigma_of
    from repro.hw import prepare_instance_head
    return prepare_instance_head(params["head"]["mu"],
                                 sigma_of(params["head"]),
                                 _group_base_hcfg(cfg, tri),
                                 chip, calibrated=calibrated)


def operating_point_bias(params, cfg, head, chip,
                         n_patches: int = 256) -> np.ndarray:
    """Per-die detection operating-point transfer (logit bias [N]).

    hw/calib recalibrates the HEAD (sum statistics + offsets), but a
    degraded conv trunk — per-column ADC gain/offset, programming
    error — additionally shifts and compresses the detection margin
    y₁−y₀, which silently moves the die's alarm rate (one sampled
    severity-2.5 die fires on 3× the cells of the golden chip, another
    goes nearly blind).  The mission deployment closes that loop the
    way §III-B1 closes the GRNG's: fly ``n_patches`` held-out SARD
    calibration patches through BOTH the golden model and the die's
    digital twin, and choose the margin offset τ that matches the
    die's calibration alarm rate to the golden chip's (Neyman–Pearson
    operating-point transfer; quantile matching, no labels needed).
    Returns a per-class logit bias to SUBTRACT from y_mu — zeros when
    ``chip`` is None.  Applied identically to every policy in the
    bench, deterministic baseline included.
    """
    if chip is None:
        return np.zeros((cfg.n_classes,), np.float32)
    if cfg.n_classes != 2:
        raise NotImplementedError(
            "operating-point transfer is margin-based (binary heads)")
    from repro.core.bayes_layer import to_serving
    from repro.core.sampling import BayesHeadConfig
    from repro.data.sard import SardConfig, batch_at
    from repro.models.sar_cnn import features
    dcfg = SardConfig(image_size=cfg.image_size, seed=0xCA1)
    imgs = jnp.concatenate(
        [batch_at(dcfg, i, 64)["images"]
         for i in range((n_patches + 63) // 64)])[:n_patches]
    gold = to_serving(params["head"], BayesHeadConfig(
        mode="rank16", grng=cfg.grng, compute_dtype=jnp.float32))
    y_g = np.asarray(features(params, imgs, cfg).astype(jnp.float32)
                     @ gold["mu_prime"].astype(jnp.float32))
    q = float((y_g[:, 1] - y_g[:, 0] > 0).mean())
    y_d = np.asarray(
        features(params, imgs, cfg, chip=chip).astype(jnp.float32)
        @ jnp.asarray(head["mu_prime"], jnp.float32))
    tau = float(np.quantile(y_d[:, 1] - y_d[:, 0], 1.0 - q))
    return np.asarray([0.0, tau], np.float32)


def _fly_group_lifetime(wcfg, ucfg, pol, cfg, chip, cost, fused,
                        n_steps, n_episodes, tcfg, params, calibrated,
                        worlds, fleet0_g, maps0, bind_g, rows, lifetime,
                        slot_axis=None, mesh=None):
    """One AGED die group's mission: segmented rollout with in-flight
    drift watch and (optionally) recalibrate-and-redeploy.

    The mission is cut into ``lifetime.epochs`` step segments.  Each
    segment scans ABSOLUTE step indices (``step0``) so the decision
    stream bases match the unsegmented mission; between segments the
    die advances to the age its step count implies, the cumulative
    telemetry snapshot's delta folds into the group's streaming drift
    monitor, and — with ``auto_recalibrate`` — an advisory triggers a
    heal: fresh §III-B1 calibration at the current age, calib_epoch
    bump, and a re-derived operating-point bias for the healed head.
    One host sync per segment; carry (fleet, maps, telemetry) threads
    through unchanged, so logs concatenate into the exact mission
    shape.

    Returns (fleet, maps, logs, telemetry, controller, host_syncs,
    advisories).
    """
    from repro.core.bayes_layer import sigma_of
    from repro.hw.redeploy import SelfHealingController
    ctl = SelfHealingController(
        chip, params["head"]["mu"], sigma_of(params["head"]),
        _group_base_hcfg(cfg, pol.triage), calibrated=calibrated,
        spec=lifetime.spec, gate=lifetime.gate,
        probe_cells=tcfg.probe_cells)
    head, hcfg = ctl.head, ctl.hcfg
    bias = operating_point_bias(params, cfg, head, chip) \
        if calibrated else np.zeros((cfg.n_classes,), np.float32)
    epochs = max(1, int(lifetime.epochs))
    seg = -(-n_steps // epochs)
    fleet_c, maps_c = fleet0_g, maps0
    telem_c = init_telemetry(tcfg, pol.triage.r_max)
    logs_parts: list[dict] = []
    step0, n_syncs, advisories = 0, 0, 0
    while step0 < n_steps:
        ns = min(seg, n_steps - step0)
        if step0:
            # drift ARRIVES mid-mission: physics moves to the age the
            # elapsed steps imply; the bias is a µ'-only quantity, so
            # the stale view keeps it and only a heal re-derives it.
            head, hcfg = ctl.advance(lifetime.age_rate * step0)
        fn = _episode_fn(wcfg, ucfg, pol, cfg, hcfg, chip, cost, fused,
                         ns, len(rows), cfg.n_classes, tcfg, step0,
                         slot_axis, mesh)
        fleet_c, maps_c, logs_c, telem_c = fn(
            params, head, jnp.asarray(bias), worlds, fleet_c, maps_c,
            bind_g, telem_c)
        # the single blocking pull of this segment
        fleet_c, maps_c, logs_c, telem_c = jax.device_get(
            (fleet_c, maps_c, logs_c, telem_c))
        n_syncs += 1
        logs_parts.append(logs_c)
        status = ctl.observe_snapshot(telemetry_snapshot(telem_c, tcfg))
        if status.drifted:
            advisories += 1
        if lifetime.auto_recalibrate and status.drifted:
            ctl.heal(status)
            head, hcfg = ctl.view()
            bias = operating_point_bias(params, cfg, ctl.head, chip) \
                if calibrated else bias
        step0 += ns
    logs_g = {k: np.concatenate([p[k] for p in logs_parts], axis=0)
              for k in logs_parts[0]}
    return fleet_c, maps_c, logs_g, telem_c, ctl, n_syncs, advisories


def fly_mission(wcfg: WorldConfig, ucfg: UavConfig, pol: MissionPolicy,
                *, params=None, cfg=None, chips=None,
                calibrated: bool = True, n_steps: int = 96,
                n_episodes: int = 1, fused: bool = True,
                telemetry: bool | TelemetryConfig = True,
                lifetime=None, slot_axis: str | None = None,
                mesh=None) -> MissionResult:
    """Run ``n_episodes`` independent missions for the whole fleet.

    ``lifetime`` (hw/redeploy.LifetimeConfig): age each CHIP-BOUND die
    group ``lifetime.age_rate`` field-seconds per mission step, cutting
    its rollout into ``lifetime.epochs`` segments — drift arrives
    MID-MISSION through the telemetry probe, and with
    ``auto_recalibrate`` a drift advisory between segments triggers an
    in-flight recalibrate-and-redeploy (one host sync per segment for
    aged groups; ideal groups and inactive lifetimes keep the exact
    single-dispatch path).  Segments scan ABSOLUTE step indices, so
    decision sample streams match the unsegmented mission.

    ``chips``: None (ideal fleet), one hw.ChipInstance (whole fleet on
    that die), or a sequence of per-drone instances/None — drones are
    grouped by die and each group flies its sectors in ONE device
    dispatch per rollout.  Episodes are independent worlds (seeds
    wcfg.seed+e) batched into the decision kernel's slot dimension —
    fleet-scale batching, zero per-step host traffic.

    ``telemetry``: per-die-group device-resident telemetry riding the
    episode scan (obs/telemetry) — the snapshot and its GRNG drift
    status (obs/drift, z-tested against the group's calibration-time
    belief) land in ``MissionResult.telemetry`` without any extra host
    pull; False compiles the exact pre-telemetry episode.

    ``slot_axis``/``mesh``: shard each die group's episodes×drones
    batch over a device mesh axis (the same axis the serving engine
    shards its slot dimension over) — shard_map-native decision rounds
    with GLOBAL-lane read-noise keys keep sharded mission verdicts
    bit-identical to the single-device rollout.
    """
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    cfg = cfg or SarCnnConfig()
    if params is None:
        params = init_sar_cnn(jax.random.PRNGKey(3), cfg)
    d, e = ucfg.n_drones, n_episodes
    if chips is None or not isinstance(chips, (tuple, list)):
        chips = [chips] * d
    if len(chips) != d:
        raise ValueError(f"chips: expected {d} per-drone entries, "
                         f"got {len(chips)}")
    cost = sar_mission_cost(cfg)
    worlds = mworld.stack_worlds(wcfg, e)
    fleet0 = muav.init_fleet(ucfg, wcfg.grid, e)
    bind = muav.fleet_bindings(ucfg, wcfg.grid, e)
    n_cells = wcfg.n_cells
    maps0 = {
        "rescued_t": jnp.full((e, n_cells), jnp.inf, jnp.float32),
        "cleared": jnp.zeros((e, n_cells), jnp.int32),
        "visited": jnp.zeros((e, n_cells), jnp.int32),
        "entropy": jnp.full((e, n_cells), float(np.log(cfg.n_classes)),
                            jnp.float32),
    }

    groups: dict[int, list[int]] = {}
    for di, chip in enumerate(chips):
        groups.setdefault(id(chip), []).append(di)

    if telemetry is True:
        telemetry = TelemetryConfig()
    tcfg = telemetry or None

    lt_active = lifetime is not None and lifetime.active
    if lt_active and tcfg is None:
        raise ValueError("lifetime missions watch drift through the "
                         "device-resident telemetry probe — telemetry "
                         "must stay enabled")

    logs_full: dict[str, np.ndarray] = {}
    maps_merged = {k: np.asarray(v) for k, v in maps0.items()}
    fleet_final = {k: np.zeros_like(np.asarray(v))
                   for k, v in fleet0.items()}
    host_syncs = 0
    telemetry_out: dict[str, dict] | None = {} if tcfg else None
    lifetime_out: dict[str, dict] | None = {} if lt_active else None
    for drone_ids in groups.values():
        chip = chips[drone_ids[0]]
        rows = np.asarray([ep * d + di for ep in range(e)
                           for di in drone_ids])
        sub = lambda t: jax.tree.map(lambda x: x[rows], t)  # noqa: E731
        if lt_active and chip is not None:
            (fleet_g, maps_g, logs_g, telem_g, ctl, n_syncs,
             advisories) = _fly_group_lifetime(
                wcfg, ucfg, pol, cfg, chip, cost, fused, n_steps,
                n_episodes, tcfg, params, calibrated, worlds,
                sub(fleet0), maps0, sub(bind), rows, lifetime,
                slot_axis, mesh)
            host_syncs += n_syncs
            snap = telemetry_snapshot(telem_g, tcfg)
            gname = f"chip{chip.chip_id}_seed{chip.device_seed}"
            telemetry_out[gname] = {
                "drones": [int(di) for di in drone_ids],
                "telemetry": snap,
                # drift judged by the controller's streaming monitor —
                # delta-folded per belief epoch, so a healed group
                # reports its POST-heal status, not the stale history
                "drift": ctl.monitor.status().to_dict(),
            }
            lifetime_out[gname] = dict(
                ctl.report(), advisories=advisories,
                epochs=int(lifetime.epochs),
                age_rate=float(lifetime.age_rate),
                auto_recalibrate=bool(lifetime.auto_recalibrate))
            for k, v in logs_g.items():
                logs_full.setdefault(k,
                                     np.zeros((n_steps, e * d), v.dtype))
                logs_full[k][:, rows] = v
            for k in fleet_final:
                fleet_final[k][rows] = fleet_g[k]
            maps_merged["rescued_t"] = np.minimum(
                maps_merged["rescued_t"], maps_g["rescued_t"])
            maps_merged["cleared"] = np.maximum(maps_merged["cleared"],
                                                maps_g["cleared"])
            maps_merged["visited"] = np.maximum(maps_merged["visited"],
                                                maps_g["visited"])
            maps_merged["entropy"] = np.minimum(maps_merged["entropy"],
                                                maps_g["entropy"])
            continue
        head, hcfg = _prepare_group_head(params, cfg, pol.triage, chip,
                                         calibrated)
        bias = operating_point_bias(params, cfg, head, chip) \
            if calibrated else np.zeros((cfg.n_classes,), np.float32)
        fn = _episode_fn(wcfg, ucfg, pol, cfg, hcfg, chip, cost, fused,
                         n_steps, len(rows), cfg.n_classes, tcfg,
                         slot_axis=slot_axis, mesh=mesh)
        if tcfg is None:
            fleet_g, maps_g, logs_g = fn(params, head, jnp.asarray(bias),
                                         worlds, sub(fleet0), maps0,
                                         sub(bind))
            # the single blocking pull of this group's whole episode
            fleet_g, maps_g, logs_g = jax.device_get(
                (fleet_g, maps_g, logs_g))
        else:
            telem0 = init_telemetry(tcfg, pol.triage.r_max)
            fleet_g, maps_g, logs_g, telem_g = fn(
                params, head, jnp.asarray(bias), worlds, sub(fleet0),
                maps0, sub(bind), telem0)
            # telemetry comes home in the SAME single pull as the logs
            fleet_g, maps_g, logs_g, telem_g = jax.device_get(
                (fleet_g, maps_g, logs_g, telem_g))
            from repro.obs.drift import drift_status, reference_for
            snap = telemetry_snapshot(telem_g, tcfg)
            ref = reference_for(cfg, hcfg, calibrated=calibrated,
                                probe_cells=tcfg.probe_cells)
            gname = ("ideal" if chip is None else
                     f"chip{chip.chip_id}_seed{chip.device_seed}")
            telemetry_out[gname] = {
                "drones": [int(di) for di in drone_ids],
                "telemetry": snap,
                "drift": drift_status(snap, ref).to_dict(),
            }
        host_syncs += 1
        for k, v in logs_g.items():
            logs_full.setdefault(k, np.zeros((n_steps, e * d), v.dtype))
            logs_full[k][:, rows] = v
        for k in fleet_final:
            fleet_final[k][rows] = fleet_g[k]
        maps_merged["rescued_t"] = np.minimum(maps_merged["rescued_t"],
                                              maps_g["rescued_t"])
        maps_merged["cleared"] = np.maximum(maps_merged["cleared"],
                                            maps_g["cleared"])
        maps_merged["visited"] = np.maximum(maps_merged["visited"],
                                            maps_g["visited"])
        # sectors partition the map: each group only moved its own
        # cells' entropy, so elementwise min keeps every update
        maps_merged["entropy"] = np.minimum(maps_merged["entropy"],
                                            maps_g["entropy"])

    summary = summarize(wcfg, ucfg, pol, cost, n_steps,
                        {k: np.asarray(v) for k, v in worlds.items()},
                        maps_merged, logs_full, fleet_final)
    return MissionResult(summary=summary, logs=logs_full,
                         maps=maps_merged,
                         worlds={k: np.asarray(v)
                                 for k, v in worlds.items()},
                         host_syncs=host_syncs,
                         telemetry=telemetry_out,
                         lifetime=lifetime_out)


def mission_horizon_s(ucfg: UavConfig, cost: DecisionCost,
                      tri, n_steps: int) -> float:
    """Static worst-case mission clock — the rescue-delay penalty for a
    victim never rescued.  Identical across policies sharing a spec, so
    delay comparisons between modes are apples-to-apples.  The worst
    step flies, orbits (3 full decisions: primary + 2 re-looks, up to
    3·r_max samples) AND verifies, so the per-step bound charges all of
    it — the ledger's ``time_s`` can never cross the horizon."""
    per_step = (ucfg.flight_time_s + ucfg.orbit_time_s
                + ucfg.verify_time_s
                + 3 * cost.decision_latency_s(tri.r_max))
    return float(n_steps * per_step)


def summarize(wcfg: WorldConfig, ucfg: UavConfig, pol: MissionPolicy,
              cost: DecisionCost, n_steps: int, worlds: dict,
              maps: dict, logs: dict, fleet_final: dict) -> dict:
    """Mission metrics over all episodes (host-side, after the pull)."""
    victims = np.asarray(worlds["victims"], bool)           # [E, C]
    rescued_t = np.asarray(maps["rescued_t"])               # [E, C]
    e = victims.shape[0]
    horizon = mission_horizon_s(ucfg, cost, pol.triage, n_steps)

    rescued = np.isfinite(rescued_t) & victims
    n_victims = victims.sum(1)                              # [E]
    n_rescued = rescued.sum(1)
    t_rescue = np.where(n_rescued > 0,
                        np.where(rescued, rescued_t, np.inf).min(1),
                        horizon)
    delay = np.where(victims, np.minimum(rescued_t, horizon), 0.0)
    rescue_delay = delay.sum(1) / np.maximum(n_victims, 1)

    active = logs["active"]
    # first DETECTION (µ-positive on a true victim cell) per episode —
    # distinct from the first completed rescue above
    det_hit = active & (logs["prediction"] == 1) & logs["truth"]
    drone_ep = np.arange(det_hit.shape[1]) // ucfg.n_drones  # [E·D]
    t_first_det = np.full((e,), horizon)
    for ep in range(e):
        t = logs["time_s"][:, drone_ep == ep][det_hit[:, drone_ep == ep]]
        if t.size:
            t_first_det[ep] = t.min()
    decisions = active.sum()
    samples = logs["spent"].sum()
    verifies = logs["verify"].sum()
    false_verifies = logs["false_verify"].sum()
    detections = (active & (logs["prediction"] == 1)).sum()
    energy_total = fleet_final["energy_J"].sum()
    e_decision = logs["e_decision_J"].sum()
    e_verify = ucfg.verify_energy_J * verifies
    e_orbit = ucfg.orbit_energy_J * logs["orbited"].sum()
    e_flight = ucfg.flight_energy_J * decisions

    return {
        "episodes": int(e),
        "n_drones": int(ucfg.n_drones),
        "grid": int(wcfg.grid),
        "n_steps": int(n_steps),
        "battery_J": float(ucfg.battery_J),
        "horizon_s": horizon,
        "decisions": int(decisions),
        "mean_samples_per_decision": float(samples / max(decisions, 1)),
        "coverage": float(np.asarray(maps["visited"]).mean()),
        "time_to_first_detection_s": float(t_first_det.mean()),
        "time_to_first_rescue_s": float(t_rescue.mean()),
        "rescue_delay_s": float(rescue_delay.mean()),
        "victims": int(n_victims.sum()),
        "rescued": int(n_rescued.sum()),
        "missed_victim_rate": float(
            1.0 - n_rescued.sum() / max(n_victims.sum(), 1)),
        "detections": int(detections),
        "verifications": int(verifies),
        "false_verifications": int(false_verifies),
        "false_verification_rate": float(
            false_verifies / max(verifies, 1)),
        "orbits": int(logs["orbited"].sum()),
        "energy_total_J": float(energy_total),
        "energy_decision_J": float(e_decision),
        "energy_verify_J": float(e_verify),
        "energy_orbit_J": float(e_orbit),
        "energy_flight_J": float(e_flight),
        "mean_time_s": float(fleet_final["time_s"].mean()),
        "mode": pol.mode,
        "planner": pol.planner,
        "flag_action": pol.flag_action,
    }
