"""repro.mission — closed-loop aerial SAR mission simulator.

Turns the repo's serving stack into the system the paper's abstract
actually claims: triage verdicts drive flight decisions, flight
decisions burn battery, and battery bounds coverage and rescue delay.

  world.py    grid-world map: victims + a spatially-correlated
              corruption-severity field, rendered through data/sard.py
  uav.py      fleet model: sectors, kinematics counters, and the
              per-sortie energy/time ledger (DecisionCost-charged)
  policy.py   lawnmower / information-gain planners + the verification
              router (accept → verify maneuver, flag → orbit or skip)
  rollout.py  device-resident episodes: one dispatch per die group,
              fleet-scale batched through the fused decision kernel

Entry points: ``fly_mission`` (rollout.py), ``launch/mission.py`` CLI,
``benchmarks/mission_bench.py`` (BENCH_mission.json).
"""

from repro.mission.detector import trained_detector
from repro.mission.policy import MissionPolicy
from repro.mission.rollout import (MissionResult, fly_mission,
                                   mission_horizon_s, sar_mission_cost)
from repro.mission.uav import UavConfig, init_fleet, sector_rows
from repro.mission.world import (WorldConfig, make_world, observe_cells,
                                 stack_worlds)

__all__ = [
    "MissionPolicy", "MissionResult", "UavConfig", "WorldConfig",
    "fly_mission", "init_fleet", "make_world", "mission_horizon_s",
    "observe_cells", "sar_mission_cost", "sector_rows", "stack_worlds",
    "trained_detector",
]
