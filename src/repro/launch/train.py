"""Training driver: real data-parallel training with fault tolerance.

This is the launcher the examples use.  It runs any registered arch
(reduced or full config) on whatever devices exist, with:

  * stateless data pipeline (exact resume from any step),
  * async checksummed checkpointing + atomic publish (repro.ckpt),
  * straggler monitor feeding the metrics stream,
  * optional int8+error-feedback gradient compression,
  * optional simulated failure (--fail-at) to exercise restart: rerun
    the same command and it resumes from the last checkpoint.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ck [--fail-at 60]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs import ARCHS, get_config
from repro.data.tokens import TokenPipelineConfig, batch_at, stub_frames, \
    stub_image_embeds
from repro.launch.mesh import make_debug_mesh, mesh_context
from repro.launch.steps import make_train_step, mesh_hinted_config
from repro.models.registry import get_api
from repro.optim import AdamWConfig, init_opt_state
from repro.optim.compression import compressed_gradients, init_error_state
from repro.runtime import StragglerConfig, StragglerMonitor
from repro.sharding import specs as S


def build_batch(cfg, pipe_cfg, step):
    batch = batch_at(pipe_cfg, step)
    if cfg.family == "audio":
        batch["frames"] = stub_frames(pipe_cfg, cfg.n_frames, cfg.d_model,
                                      step, pipe_cfg.global_batch)
    if cfg.family == "vlm":
        batch["image_embeds"] = stub_image_embeds(
            pipe_cfg, cfg.n_image_tokens, cfg.d_model, step,
            pipe_cfg.global_batch)
    return batch


def train(arch: str, *, smoke: bool = True, steps: int = 100, batch: int = 8,
          seq: int = 64, ckpt_dir: str | None = None, ckpt_every: int = 50,
          fail_at: int | None = None, compress: bool = False,
          lr: float = 3e-4, log_every: int = 10,
          metrics_path: str | None = None) -> dict:
    cfg = get_config(arch, smoke=smoke)
    mesh = make_debug_mesh()
    cfg = mesh_hinted_config(cfg, mesh, batch)
    api = get_api(cfg)
    opt_cfg = AdamWConfig(lr=lr)
    pipe_cfg = TokenPipelineConfig(vocab=cfg.vocab, seq_len=seq,
                                   global_batch=batch)

    base_step = make_train_step(cfg, opt_cfg, total_steps=steps,
                                warmup_steps=max(1, steps // 20))

    if compress:
        def step_fn(params, opt_state, err, batch_):
            def loss_fn(p):
                return api.train_loss(p, batch_, cfg,
                                      step=opt_state["count"])
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            grads, err = compressed_gradients(grads, err)
            from repro.optim import adamw_update, warmup_cosine
            lr_scale = warmup_cosine(opt_state["count"],
                                     warmup_steps=max(1, steps // 20),
                                     total_steps=steps)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg, lr_scale)
            return params, opt_state, err, dict(metrics, loss=loss, **om)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    else:
        jitted = jax.jit(base_step, donate_argnums=(0, 1))

    # --- init or resume -------------------------------------------------
    start = 0
    err_state = None
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        tree, start = restore(ckpt_dir)
        params, opt_state = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        print(f"[train] resumed from step {start}")
    else:
        params = api.init(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt_state(params)
    if compress:
        err_state = init_error_state(params)

    ckptr = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    monitor = StragglerMonitor(StragglerConfig())
    metrics_file = open(metrics_path, "a") if metrics_path else None
    history = []

    with mesh_context(mesh):
        for step in range(start, steps):
            monitor.start_step()
            data = build_batch(cfg, pipe_cfg, step)
            if compress:
                params, opt_state, err_state, metrics = jitted(
                    params, opt_state, err_state, data)
            else:
                params, opt_state, metrics = jitted(params, opt_state, data)
            metrics = {k: float(v) for k, v in metrics.items()}
            report = monitor.end_step(step)
            metrics["step_time"] = report["duration"]
            history.append({"step": step, **metrics})
            if metrics_file:
                metrics_file.write(json.dumps(history[-1]) + "\n")
                metrics_file.flush()
            if step % log_every == 0:
                print(f"[train] step {step} loss={metrics['loss']:.4f} "
                      f"ce={metrics['ce']:.4f} t={report['duration']:.2f}s")
            if ckptr and (step + 1) % ckpt_every == 0:
                ckptr.submit(step + 1, {"params": params, "opt": opt_state})
            if fail_at is not None and step + 1 == fail_at:
                if ckptr:
                    ckptr.wait()
                raise SystemExit(f"[train] simulated failure at step {step+1}")

    if ckptr:
        ckptr.submit(steps, {"params": params, "opt": opt_state})
        ckptr.wait()
    if metrics_file:
        metrics_file.close()
    return {"params": params, "opt": opt_state, "history": history,
            "final_loss": history[-1]["loss"] if history else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--metrics", default=None)
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, fail_at=args.fail_at,
                compress=args.compress, lr=args.lr,
                metrics_path=args.metrics)
    print(f"[train] done, final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
