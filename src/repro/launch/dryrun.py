import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating any model-sized buffer:
  * a compiled SPMD executable for the production mesh (16×16 single pod
    / 2×16×16 multi-pod) — sharding mismatches, compile-time OOM and
    unsupported collectives all fail loudly here;
  * compiled.memory_analysis()  — proves the per-device footprint fits;
  * compiled.cost_analysis()    — per-device HLO FLOPs/bytes;
  * a parse of the post-SPMD HLO summing wire bytes of every collective
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — the roofline's collective term.

Results land in artifacts/dryrun/<arch>__<shape>__<mesh>.json, consumed
by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells_for, get_config
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import (jit_decode_step, jit_prefill_step,
                                jit_train_step)
from repro.obs.log import get_logger
from repro.optim import AdamWConfig

log = get_logger("dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"= .*?\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_bytes(line: str, op: str) -> tuple[int, int]:
    """(result_bytes, operand_bytes) for one HLO instruction line."""
    idx = line.find(op)
    head, tail = line[:idx], line[idx:]
    res = sum(_shape_bytes(m.group(1), m.group(2))
              for m in _SHAPE_RE.finditer(head)
              if m.group(1) in _DTYPE_BYTES)
    ops = sum(_shape_bytes(m.group(1), m.group(2))
              for m in _SHAPE_RE.finditer(tail)
              if m.group(1) in _DTYPE_BYTES)
    return res, ops


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int) -> dict:
    """Per-device wire-byte model per collective type.

    ring estimates: AR 2(g-1)/g·s, AG/RS (g-1)/g·full, A2A (g-1)/g·s,
    permute s.  (s = max(result, operand) bytes on the line.)
    """
    out: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        op = m.group(1)
        res, opd = _line_bytes(line, m.group(0).split("= ")[-1] if "= " in m.group(0) else op)
        size = max(res, opd)
        g = _group_size(line, total_devices)
        if g <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * (g - 1) / g * size
        elif op == "collective-permute":
            wire = float(size)
        else:  # all-gather / reduce-scatter / all-to-all
            wire = (g - 1) / g * size
        rec = out.setdefault(op, {"count": 0, "wire_bytes": 0.0,
                                  "payload_bytes": 0.0})
        rec["count"] += 1
        rec["wire_bytes"] += wire
        rec["payload_bytes"] += size
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, serve_r: int | None = None,
             head_mode: str | None = None, tag: str = "",
             master_weights: bool = False, microbatches: int = 1,
             explicit_tp: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    overrides = {}
    if serve_r is not None:
        overrides["uq_samples"] = serve_r
    if head_mode is not None:
        overrides["head_mode"] = head_mode
    if explicit_tp:
        overrides["explicit_tp"] = True
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.perf_counter()

    with mesh_context(mesh):
        if shape.kind == "train":
            jitted, abstracts, _, cfg2 = jit_train_step(
                cfg, mesh, AdamWConfig(master_weights=master_weights),
                shape.seq_len, shape.global_batch,
                microbatches=microbatches)
        elif shape.kind == "prefill":
            jitted, abstracts, _, cfg2 = jit_prefill_step(
                cfg, mesh, shape.seq_len, shape.global_batch)
        else:
            jitted, abstracts, _, cfg2 = jit_decode_step(
                cfg, mesh, shape.seq_len, shape.global_batch)
        lowered = jitted.lower(*abstracts)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    loop_aware = hlo_analyze(hlo, n_dev)   # trip-count-corrected
    colls = loop_aware["collectives"]
    log.debug(str(compiled.memory_analysis()))
    log.debug(str({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed", "optimal_seconds")}))

    # Useful-FLOP accounting (global, whole step).
    n_tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        model_flops = 6 * cfg2.active_param_count() * n_tokens
    elif shape.kind == "prefill":
        model_flops = 2 * cfg2.active_param_count() * n_tokens
    else:  # decode: one token per sequence; R head samples
        head_flops = 2 * cfg2.d_model * cfg2.vocab_padded
        r_eff = cfg2.uq_samples if cfg2.head_mode == "paper" else min(
            cfg2.uq_samples, 17)
        model_flops = (2 * cfg2.active_param_count()
                       + (r_eff + 1) * head_flops) * shape.global_batch

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "devices": n_dev,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "uq_samples": cfg2.uq_samples, "head_mode": cfg2.head_mode,
        "param_count": cfg2.param_count(),
        "active_param_count": cfg2.active_param_count(),
        "model_flops_global": float(model_flops),
        "flops_per_device": loop_aware["flops_per_device"],
        "hbm_bytes_per_device": loop_aware["hbm_bytes_per_device"],
        "xla_flops_uncorrected": float(cost.get("flops", -1)),
        "xla_bytes_uncorrected": float(cost.get("bytes accessed", -1)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "collectives": colls,
        "wire_bytes_per_device": sum(c["wire_bytes"] for c in colls.values()),
        "wire_bytes_per_device_tpu": loop_aware["wire_bytes_per_device_tpu"],
        "wire_bytes_f32_per_device": loop_aware["wire_bytes_f32_per_device"],
        "hlo_bytes": len(hlo),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    path.write_text(json.dumps(result, indent=2))
    log.info(f"OK {arch} × {shape_name} × {mesh_name}"
             f" (lower {t_lower:.1f}s, compile {t_compile:.1f}s)"
             f" -> {path}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--serve-r", type=int, default=None,
                    help="override uq_samples (hillclimb sweeps)")
    ap.add_argument("--head-mode", default=None,
                    choices=("paper", "rank16", "moment"))
    ap.add_argument("--tag", default="", help="suffix for output file")
    ap.add_argument("--master-weights", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--explicit-tp", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        failures = []
        for arch in ARCHS:
            for shape_name in cells_for(arch):
                mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
                path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    log.info(f"skip existing {path.name}")
                    continue
                try:
                    run_cell(arch, shape_name, args.multi_pod, out_dir)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, repr(e)))
                    traceback.print_exc()
        if failures:
            log.error(f"{len(failures)} FAILURES:")
            for f in failures:
                log.error(f"  {f}")
            raise SystemExit(1)
        log.info("all cells OK")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                 serve_r=args.serve_r, head_mode=args.head_mode,
                 tag=args.tag, master_weights=args.master_weights,
                 microbatches=args.microbatch, explicit_tp=args.explicit_tp)


if __name__ == "__main__":
    main()
