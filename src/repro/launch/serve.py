"""Serving driver: a thin CLI over the continuous-batching engine.

The old driver here was a single-batch sequential loop spending a fixed
R = 20 GRNG samples on every input.  It is replaced by the
``repro.serving`` subsystem: fixed decode slots, an admission queue,
mid-batch retirement, and an adaptive-fidelity controller that starts
every decision at a small R and escalates only while the accept/flag
triage is statistically ambiguous (paper Fig. 1).

Two workload modes:

  * LM archs (``--arch qwen3-0.6b`` etc.): continuous-batching token
    decode; each token decision is triaged, flagged requests retire to
    the verification queue.
  * ``--arch sar_cnn``: the paper's aerial search-and-rescue stream —
    synthetic SARD image patches (data/sard.py), optionally with a
    corrupted fraction (fog/frost/motion/snow), classified through the
    Bayesian-head CNN with per-slot escalation depths.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --slots 4 --prompt-len 16 --gen 8 --requests 16 [--fixed]
  PYTHONPATH=src python -m repro.launch.serve --arch sar_cnn \
      --requests 128 --corrupt-frac 0.25 --corruption fog

Multi-device note: wrap engine construction + run in
``mesh_context(make_debug_mesh())`` and pass a mesh-hinted config to
shard the pool batch across 'data' — the engine's jitted pool updates
are ordinary jit calls and follow the ambient mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.energy import LayerShape
from repro.data.tokens import TokenPipelineConfig, batch_at, stub_frames, \
    stub_image_embeds
from repro.obs.log import get_logger
from repro.obs.telemetry import TelemetryConfig
from repro.serving import (LMServingEngine, Request, SarServingEngine,
                           ServingMetrics, TriagePolicy)

log = get_logger("serve")


def _open_loop_offsets(arrival, n: int, seed: int):
    """Resolve an ``--arrival`` spec (string or ArrivalSpec) into the
    parsed spec + its [n] seeded offsets."""
    from repro.serving.load import ArrivalSpec
    spec = (ArrivalSpec.parse(arrival) if isinstance(arrival, str)
            else arrival)
    return spec, spec.offsets(n, seed=seed)


def collect_alerts(out: dict, source: str):
    """Run the unified alert bus over a finished serve summary: drift
    advisories, lifetime heal events, SLO burn breaches, and fleet
    backpressure saturation become one typed advisory stream (logged as
    they are emitted; attached as ``out["alerts"]`` when non-empty)."""
    from repro.obs.alerts import AlertBus
    bus = AlertBus()
    bus.observe_drift(out.get("drift"), source=source)
    for ev in (out.get("lifetime") or {}).get("events", []):
        bus.observe_heal(ev, source=source)
    bus.observe_slo(out.get("slo"), source=source)
    bus.observe_backpressure(out.get("slo"), source=source)
    if bus.advisories:
        out["alerts"] = bus.to_json()
    return bus


def lm_layer_shapes(cfg) -> list:
    """Analytic energy layers: d_model-square trunk approximation + the
    Bayesian vocab head (the R-sampled part)."""
    shapes = [LayerShape(cfg.d_model, cfg.d_model)] * (4 * cfg.n_layers)
    shapes.append(LayerShape(cfg.d_model, cfg.vocab_padded, bayesian=True))
    return shapes


def sar_layer_shapes(cfg) -> list:
    shapes, c_in = [], 1
    for c_out in cfg.channels:
        shapes.append(LayerShape(cfg.kernel**2 * c_in, c_out))
        c_in = c_out
    shapes.append(LayerShape(cfg.channels[-1], cfg.n_classes, bayesian=True))
    return shapes


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 16, gen_len: int = 8, n_requests: int | None = None,
          adaptive: bool = True, policy: TriagePolicy | None = None,
          seed: int = 0, cache_margin: int = 4, fused: bool = True,
          telemetry: bool | TelemetryConfig = True,
          tracer=None, profiler=True,
          cost_records: bool = False) -> dict:
    """LM serving through the engine. ``batch`` is the slot count.

    ``fused``: run escalation rounds through the fused Pallas decision
    kernel (kernels/decision_kernel.py — no [R, B, V] materialization);
    False selects the materializing ``mix_samples → update_stats``
    path (verdict-identical).

    ``telemetry``/``tracer``: obs/ device-resident telemetry (snapshot
    under out["telemetry"]) and per-request span tracing."""
    cfg = get_config(arch, smoke=smoke)
    n_requests = n_requests or 2 * batch
    policy = policy or TriagePolicy()
    pipe = TokenPipelineConfig(vocab=cfg.vocab, seq_len=prompt_len,
                               global_batch=batch, seed=seed)
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = stub_frames(pipe, cfg.n_frames, cfg.d_model, 0,
                                       batch)
    if cfg.family == "vlm":
        extras["image_embeds"] = stub_image_embeds(
            pipe, cfg.n_image_tokens, cfg.d_model, 0, batch)

    cache_len = prompt_len + gen_len * (1 + cache_margin)
    if cfg.swa_window is not None:
        # Rolling caches don't support mid-stream admission (engine
        # guard); stay within the window.  Oversized prompt+gen then
        # fails loudly in the engine's capacity check.
        cache_len = min(cache_len, cfg.swa_window)
    from repro.hw import compile_network
    layers = lm_layer_shapes(cfg)
    metrics = ServingMetrics(layers=layers,
                             tile_program=compile_network(layers))
    engine = LMServingEngine(
        jax_params_init(cfg, seed), cfg, n_slots=batch,
        prompt_len=prompt_len, cache_len=cache_len, policy=policy,
        adaptive_mode=adaptive, metrics=metrics, extras=extras,
        fused=fused, telemetry=telemetry, tracer=tracer,
        profiler=profiler)

    rid = 0
    t0 = time.perf_counter()
    for step in range((n_requests + batch - 1) // batch):
        prompts = np.asarray(batch_at(pipe, step)["tokens"])
        for i in range(min(batch, n_requests - rid)):
            engine.submit(Request(rid=rid, payload=prompts[i],
                                  arrival_s=time.time(),
                                  max_new_tokens=gen_len))
            rid += 1
    out = engine.run()
    out["wall_s"] = time.perf_counter() - t0
    out["tokens_per_s"] = out["decisions"] / out["wall_s"]
    out["host_syncs"] = engine.host_syncs
    if cost_records:
        out["compiled_costs"] = engine.compiled_cost_records()
    out["flagged_fraction"] = out.get("flag_fraction", float("nan"))
    out["verdicts"] = [
        {"rid": r.rid, "verdict": r.verdict, "confidence": r.confidence,
         "mutual_information": r.mutual_information,
         "n_samples": r.n_samples, "n_tokens": r.n_decisions}
        for r in metrics.records]
    return out


def jax_params_init(cfg, seed: int):
    from repro.models.registry import get_api
    return get_api(cfg).init(jax.random.PRNGKey(seed), cfg)


def make_sar_stream(n_requests: int, *, corrupt_frac: float = 0.0,
                    corruption: str = "fog", severity: float = 1.0,
                    image_size: int = 32, seed: int = 7, batch: int = 32,
                    step0: int = 1000) -> list:
    """Request stream over synthetic SARD, with a corrupted tail mixed in.

    step0 offsets past the training stream so serving never sees
    training images.  Returns a list of Requests with
    ``meta={'corrupted': bool, 'label': int}``.
    """
    from repro.data.sard import SardConfig, batch_at as sard_batch, \
        corrupted_batch
    dcfg = SardConfig(image_size=image_size, seed=seed)
    reqs, rid = [], 0
    n_batches = (n_requests + batch - 1) // batch
    for b in range(n_batches):
        clean = sard_batch(dcfg, step0 + b, batch)
        dirty = corrupted_batch(dcfg, step0 + b, batch, corruption, severity)
        n_dirty = int(round(batch * corrupt_frac))
        for i in range(min(batch, n_requests - rid)):
            corrupted = i < n_dirty
            img = (dirty if corrupted else clean)["images"][i]
            reqs.append(Request(
                rid=rid, payload=np.asarray(img), arrival_s=time.time(),
                meta={"corrupted": corrupted,
                      "label": int(clean["labels"][i])}))
            rid += 1
    return reqs


def serve_sar(*, n_requests: int = 128, n_slots: int = 32,
              adaptive: bool = True, policy: TriagePolicy | None = None,
              corrupt_frac: float = 0.0, corruption: str = "fog",
              params=None, cfg=None, seed: int = 0,
              chip_instance=None, calibrated: bool = True,
              slot_axis: str | None = None, fused: bool = True,
              telemetry: bool | TelemetryConfig = True,
              tracer=None, profiler=True, slo=(),
              arrival=None, cost_records: bool = False) -> dict:
    """SAR image-stream serving. Untrained params unless provided.

    ``slo``: SLO spec strings (``"0.25:p99"``) the time-to-verdict
    tracker evaluates — attainment/burn-rate land in ``out["slo"]``.
    ``arrival``: an ``--arrival`` spec (``"poisson:8"`` etc.) — the
    stream is then driven OPEN-LOOP by serving/load.py on a seeded
    arrival schedule instead of being enqueued all at once, so queue
    wait and time-to-verdict measure a real traffic regime.

    ``chip_instance``: a hw.ChipInstance (or an int seed — one chip is
    sampled from the default VariationSpec) — the engine then serves
    *fully* on that die's digital twin: the conv trunk through the
    nonideal CIM kernel (per-column ADC gain/offset + programming
    noise), the Bayesian head on the degraded GRNG with per-chip
    constants; ``calibrated`` selects the per-instance recalibrated
    head (hw/calib.py) vs the golden factory transform.  The summary
    gains chip metadata; energy/area accounting is tilemap-true (placed
    blocks + utilization from the tile compiler) with or without a
    chip.
    """
    from repro.hw import compile_network
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    cfg = cfg or SarCnnConfig()
    if params is None:
        params = init_sar_cnn(jax.random.PRNGKey(3 + seed), cfg)
    policy = policy or TriagePolicy(conf_threshold=0.7, mi_threshold=0.05)
    layers = sar_layer_shapes(cfg)
    program = compile_network(layers)
    head = hcfg = None
    extra = {}
    if chip_instance is not None:
        from repro.core.bayes_layer import sigma_of
        from repro.core.sampling import BayesHeadConfig
        from repro.hw import prepare_instance_head, sample_instances
        if not hasattr(chip_instance, "grng"):
            chip_instance = sample_instances(int(chip_instance), 1)[0]
        base_hcfg = BayesHeadConfig(
            num_samples=policy.r_max, mode="rank16", grng=cfg.grng,
            compute_dtype=jnp.float32, hoist_basis=True)
        head, hcfg = prepare_instance_head(
            params["head"]["mu"], sigma_of(params["head"]), base_hcfg,
            chip_instance, calibrated=calibrated)
        extra = {
            "chip_id": chip_instance.chip_id,
            "chip_device_seed": chip_instance.device_seed,
            "chip_read_sigma": chip_instance.read_sigma,
            "chip_temp_c": chip_instance.temp_c,
            "calibrated": bool(calibrated),
        }
    metrics = ServingMetrics(layers=layers, extra=extra,
                             tile_program=program)
    from repro.obs.slo import SloTracker
    slo_tracker = SloTracker(slos=tuple(slo)) if slo else True
    engine = SarServingEngine(params, cfg, n_slots=n_slots, policy=policy,
                              adaptive_mode=adaptive, metrics=metrics,
                              head=head, hcfg=hcfg, chip=chip_instance,
                              slot_axis=slot_axis, fused=fused,
                              telemetry=telemetry, tracer=tracer,
                              profiler=profiler, slo=slo_tracker)
    reqs = make_sar_stream(n_requests, corrupt_frac=corrupt_frac,
                           corruption=corruption,
                           image_size=cfg.image_size)
    t0 = time.perf_counter()
    if arrival is not None:
        from repro.serving.load import run_open_loop
        spec, offsets = _open_loop_offsets(arrival, len(reqs), seed)
        out = run_open_loop(engine, reqs, offsets)
        out["arrival"] = spec.to_dict()
    else:
        for r in reqs:
            engine.submit(r)
        out = engine.run()
    out["wall_s"] = time.perf_counter() - t0
    if slo:
        # engine shares the caller-built tracker (so the SLO specs ride
        # along) — attach its snapshot here
        out["slo"] = slo_tracker.snapshot()
    out["host_syncs"] = engine.host_syncs
    out["host_syncs_per_decision"] = (engine.host_syncs
                                      / max(out["decisions"], 1))
    if cost_records:
        # AOT compiled-cost capture of the live hot functions —
        # profiling path only (compiles fresh executables).
        out["compiled_costs"] = engine.compiled_cost_records()
    out["flagged_fraction"] = out.get("flag_fraction", float("nan"))
    out["verdicts"] = [
        {"rid": r.rid, "verdict": r.verdict, "confidence": r.confidence,
         "mutual_information": r.mutual_information,
         "n_samples": r.n_samples} for r in metrics.records]
    if engine.tcfg is not None and out.get("telemetry"):
        # Online drift check against the deployment's calibration-time
        # belief: the measured instance config when calibrated, the
        # golden factory config otherwise (obs/drift docstring).
        from repro.obs.drift import drift_status, reference_for
        ref = reference_for(cfg, engine.hcfg,
                            calibrated=(chip_instance is not None
                                        and calibrated),
                            probe_cells=engine.tcfg.probe_cells)
        out["drift"] = drift_status(out["telemetry"], ref).to_dict()
        if out["drift"]["advisory"]:
            log.warning(out["drift"]["advisory"])
    collect_alerts(out, "serve_sar")
    return out


def serve_sar_fleet(*, n_requests: int = 256, n_pools: int = 4,
                    slots_per_pool: int = 32, adaptive: bool = True,
                    policy: TriagePolicy | None = None,
                    corrupt_frac: float = 0.0, corruption: str = "fog",
                    params=None, cfg=None, seed: int = 0,
                    chip_instance=None, calibrated: bool = True,
                    fused: bool = True, gang: bool | None = None,
                    queue_cap: int | None = None,
                    telemetry: bool | TelemetryConfig = True,
                    tracer=None, profiler=True, slo=(),
                    arrival=None) -> dict:
    """Mesh-of-pools SAR serving (serving/fleet.py).

    ``tracer``: a shared obs.trace.Tracer — the fleet stitches router
    tick spans (pid 0) and per-pool dispatch/slot tracks (pid p+1) into
    ONE Chrome/Perfetto timeline, with flow arrows router → slot per
    request.  ``slo``/``arrival``: as in :func:`serve_sar` (the SLO
    tracker is fleet-wide: one snapshot covering router latency, queue
    depths, and backpressure).

    ``n_pools`` complete serving pools tiled over a 1-D ``("pool",)``
    device mesh behind a least-loaded admission router; each fleet tick
    runs ONE shard_map'd gang round for every pool (``gang=None``
    auto-enables it when the process has >= n_pools devices — use
    XLA_FLAGS=--xla_force_host_platform_device_count=N or ``--mesh N``
    to simulate a mesh on CPU).  Verdicts are bit-identical to
    ``serve_sar`` pools fed the same admission sequences; the summary
    is the exact sum of the per-pool reports (energy, telemetry,
    decisions) plus router stats (``routed_per_pool``,
    ``backlog_peak``).

    ``chip_instance``/``calibrated``: as in ``serve_sar`` — every pool
    serves the same die's digital twin.
    """
    from repro.hw import compile_network
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    from repro.serving import SarServingFleet
    cfg = cfg or SarCnnConfig()
    if params is None:
        params = init_sar_cnn(jax.random.PRNGKey(3 + seed), cfg)
    policy = policy or TriagePolicy(conf_threshold=0.7, mi_threshold=0.05)
    layers = sar_layer_shapes(cfg)
    program = compile_network(layers)
    head = hcfg = None
    if chip_instance is not None:
        from repro.core.bayes_layer import sigma_of
        from repro.core.sampling import BayesHeadConfig
        from repro.hw import prepare_instance_head, sample_instances
        if not hasattr(chip_instance, "grng"):
            chip_instance = sample_instances(int(chip_instance), 1)[0]
        base_hcfg = BayesHeadConfig(
            num_samples=policy.r_max, mode="rank16", grng=cfg.grng,
            compute_dtype=jnp.float32, hoist_basis=True)
        head, hcfg = prepare_instance_head(
            params["head"]["mu"], sigma_of(params["head"]), base_hcfg,
            chip_instance, calibrated=calibrated)
    from repro.obs.slo import SloTracker
    slo_tracker = SloTracker(slos=tuple(slo)) if slo else True
    fleet = SarServingFleet(
        params, cfg, n_pools=n_pools, slots_per_pool=slots_per_pool,
        policy=policy, adaptive_mode=adaptive, head=head, hcfg=hcfg,
        chip=chip_instance, fused=fused, telemetry=telemetry,
        layers=layers, tile_program=program, queue_cap=queue_cap,
        gang=gang, tracer=tracer, profiler=profiler, slo=slo_tracker)
    reqs = make_sar_stream(n_requests, corrupt_frac=corrupt_frac,
                           corruption=corruption,
                           image_size=cfg.image_size)
    if arrival is not None:
        from repro.serving.load import run_open_loop
        spec, offsets = _open_loop_offsets(arrival, len(reqs), seed)
        out = run_open_loop(fleet, reqs, offsets)
        out["arrival"] = spec.to_dict()
    else:
        for r in reqs:
            fleet.submit(r)
        out = fleet.run()
    if chip_instance is not None:
        out["chip_id"] = chip_instance.chip_id
        out["chip_device_seed"] = chip_instance.device_seed
        out["calibrated"] = bool(calibrated)
    out["flagged_fraction"] = out.get("flag_fraction", float("nan"))
    out["verdicts"] = [
        {"rid": r.rid, "pool": fleet.routes.get(r.rid),
         "verdict": r.verdict, "confidence": r.confidence,
         "mutual_information": r.mutual_information,
         "n_samples": r.n_samples}
        for eng in fleet.engines for r in eng.metrics.records]
    out["verdicts"].sort(key=lambda v: v["rid"])
    collect_alerts(out, "serve_sar_fleet")
    return out


def serve_sar_lifetime(*, lifetime, chip_instance,
                       n_requests: int = 128, n_slots: int = 32,
                       adaptive: bool = True,
                       policy: TriagePolicy | None = None,
                       corrupt_frac: float = 0.0, corruption: str = "fog",
                       params=None, cfg=None, seed: int = 0,
                       calibrated: bool = True, fused: bool = True,
                       telemetry: bool | TelemetryConfig = True,
                       tracer=None, profiler=True) -> dict:
    """SAR serving across a die's LIFETIME: the stream is cut into
    ``lifetime.epochs`` segments, the die ages ``lifetime.age_rate``
    simulated field-seconds per decision, and (with
    ``auto_recalibrate``) drift advisories from the streamed telemetry
    trigger an in-place recalibrate-and-hot-swap between segments
    (hw/redeploy.SelfHealingController + SarServingEngine.swap_head).

    With ``lifetime.active`` False this IS ``serve_sar`` — one segment,
    no controller, bit-identical verdicts and host-sync counts — so
    callers can pass a lifetime config unconditionally.

    Returns the usual serve summary plus ``out["lifetime"]``: age, heal
    events, advisory count, and the final drift status.
    """
    from repro.core.bayes_layer import sigma_of
    from repro.core.sampling import BayesHeadConfig
    from repro.hw import compile_network, sample_instances
    from repro.hw.redeploy import SelfHealingController
    from repro.models.sar_cnn import SarCnnConfig, init_sar_cnn
    if not lifetime.active:
        out = serve_sar(n_requests=n_requests, n_slots=n_slots,
                        adaptive=adaptive, policy=policy,
                        corrupt_frac=corrupt_frac, corruption=corruption,
                        params=params, cfg=cfg, seed=seed,
                        chip_instance=chip_instance, calibrated=calibrated,
                        fused=fused, telemetry=telemetry, tracer=tracer,
                        profiler=profiler)
        out["lifetime"] = {"active": False, "age_s": 0.0, "heals": 0,
                           "advisories": 0, "epochs": 1}
        return out
    if chip_instance is None:
        raise ValueError("lifetime serving ages a specific die — pass "
                         "chip_instance (a ChipInstance or an int seed)")
    if telemetry is False:
        raise ValueError("lifetime serving watches drift through the "
                         "device-resident telemetry probe — telemetry "
                         "must stay enabled")
    if not hasattr(chip_instance, "grng"):
        chip_instance = sample_instances(int(chip_instance), 1)[0]
    cfg = cfg or SarCnnConfig()
    if params is None:
        params = init_sar_cnn(jax.random.PRNGKey(3 + seed), cfg)
    policy = policy or TriagePolicy(conf_threshold=0.7, mi_threshold=0.05)
    base_hcfg = BayesHeadConfig(
        num_samples=policy.r_max, mode="rank16", grng=cfg.grng,
        compute_dtype=jnp.float32, hoist_basis=True)
    tcfg = telemetry if isinstance(telemetry, TelemetryConfig) \
        else TelemetryConfig()
    ctl = SelfHealingController(
        chip_instance, params["head"]["mu"], sigma_of(params["head"]),
        base_hcfg, calibrated=calibrated, spec=lifetime.spec,
        gate=lifetime.gate, probe_cells=tcfg.probe_cells)
    layers = sar_layer_shapes(cfg)
    metrics = ServingMetrics(
        layers=layers, tile_program=compile_network(layers),
        extra={"chip_id": chip_instance.chip_id,
               "chip_device_seed": chip_instance.device_seed,
               "calibrated": bool(calibrated)})
    engine = SarServingEngine(params, cfg, n_slots=n_slots, policy=policy,
                              adaptive_mode=adaptive, metrics=metrics,
                              head=ctl.head, hcfg=ctl.hcfg,
                              chip=chip_instance, fused=fused,
                              telemetry=tcfg, tracer=tracer,
                              profiler=profiler)
    reqs = make_sar_stream(n_requests, corrupt_frac=corrupt_frac,
                           corruption=corruption,
                           image_size=cfg.image_size)
    epochs = max(1, int(lifetime.epochs))
    seg = -(-len(reqs) // epochs)
    served, advisories = 0, 0
    t0 = time.perf_counter()
    for k in range(epochs):
        chunk = reqs[k * seg:(k + 1) * seg]
        if not chunk:
            break
        if k:
            # Drift ARRIVES mid-stream: the die moves to the age its
            # decision count implies and the engine serves the stale
            # belief on the aged physics (telemetry probe included).
            head, hcfg = ctl.advance(lifetime.age_rate * served)
            engine.swap_head(head, hcfg)
        for r in chunk:
            engine.submit(r)
        out = engine.run()
        served += len(chunk)
        status = ctl.observe_snapshot(engine.telemetry_snapshot())
        if status.drifted:
            advisories += 1
            log.warning(status.advisory)
        if lifetime.auto_recalibrate and status.drifted:
            ev = ctl.heal(status)
            engine.swap_head(*ctl.view())
            log.info("healed", age_s=ev.age_s, calib_epoch=ev.calib_epoch,
                     z_mean=round(ev.z_mean, 2), z_std=round(ev.z_std, 2))
    out["wall_s"] = time.perf_counter() - t0
    out["host_syncs"] = engine.host_syncs
    out["host_syncs_per_decision"] = (engine.host_syncs
                                      / max(out["decisions"], 1))
    out["flagged_fraction"] = out.get("flag_fraction", float("nan"))
    out["verdicts"] = [
        {"rid": r.rid, "verdict": r.verdict, "confidence": r.confidence,
         "mutual_information": r.mutual_information,
         "n_samples": r.n_samples} for r in metrics.records]
    out["lifetime"] = dict(ctl.report(), active=True, epochs=epochs,
                           advisories=advisories,
                           age_rate=lifetime.age_rate,
                           auto_recalibrate=lifetime.auto_recalibrate)
    collect_alerts(out, "serve_sar_lifetime")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=tuple(ARCHS) + ("sar_cnn",),
                    required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=None,
                    help="decode slots (default: 4 for LM, 32 for sar_cnn)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--fixed", action="store_true",
                    help="fixed R=r_max per decision (paper baseline)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    default=True,
                    help="disable the fused Pallas decision kernel and "
                         "use the materializing mix_samples → "
                         "update_stats path (verdict-identical)")
    ap.add_argument("--conf-threshold", type=float, default=0.8)
    ap.add_argument("--mi-threshold", type=float, default=0.5)
    ap.add_argument("--r-min", type=int, default=4)
    ap.add_argument("--r-max", type=int, default=20)
    ap.add_argument("--pools", type=int, default=None,
                    help="sar_cnn only: serve through the mesh-of-pools "
                         "fleet with this many engine pools "
                         "(serving/fleet.py; one shard_map'd gang "
                         "dispatch per tick when devices allow)")
    ap.add_argument("--slots-per-pool", type=int, default=32,
                    help="decode slots per fleet pool (with --pools)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="simulate an N-device host mesh: re-execs the "
                         "process with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N so "
                         "--pools can gang-dispatch over a real device "
                         "mesh on CPU")
    ap.add_argument("--corrupt-frac", type=float, default=0.0)
    ap.add_argument("--corruption", default="fog",
                    choices=("fog", "frost", "motion", "snow"))
    ap.add_argument("--chip-instance", type=int, default=None,
                    help="serve on a sampled FeFET chip instance "
                         "(hw/ digital twin) drawn with this seed")
    ap.add_argument("--chip-severity", type=float, default=1.0,
                    help="variation severity multiplier for the "
                         "sampled chip")
    ap.add_argument("--uncalibrated", action="store_true",
                    help="skip per-instance recalibration (golden "
                         "factory transform on the degraded chip)")
    ap.add_argument("--age-rate", type=float, default=0.0,
                    help="simulated field-seconds of FeFET aging per "
                         "decision (hw/aging.py); 0 disables the "
                         "lifetime loop (exact pre-lifetime path)")
    ap.add_argument("--age-epochs", type=int, default=4,
                    help="age/heal checkpoints the stream is cut into")
    ap.add_argument("--auto-recalibrate", action="store_true",
                    help="act on drift advisories: recalibrate the aged "
                         "die and hot-swap the healed head mid-stream "
                         "(hw/redeploy.py)")
    ap.add_argument("--no-telemetry", dest="telemetry",
                    action="store_false", default=True,
                    help="disable the device-resident obs/ telemetry "
                         "(compiles the exact pre-telemetry graph)")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace JSON of the "
                         "run's request spans to PATH (with --pools: "
                         "ONE stitched fleet timeline — router ticks, "
                         "per-pool gang-dispatch tracks, and request "
                         "flow arrows router -> pool -> slot)")
    ap.add_argument("--arrival", type=str, default=None, metavar="SPEC",
                    help="sar_cnn: drive serving OPEN-LOOP on a seeded "
                         "arrival schedule instead of enqueueing "
                         "everything up front — poisson:RATE, "
                         "burst:RATE[:FACTOR], or ramp:LO:HI (req/s)")
    ap.add_argument("--slo", action="append", default=None,
                    metavar="TARGET:PCT[:BURN]",
                    help="time-to-verdict SLO, e.g. 0.25:p99 — "
                         "repeatable; attainment and error-budget burn "
                         "rate land in the summary, breaches on the "
                         "alert bus")
    ap.add_argument("--metrics-out", type=str, default=None,
                    metavar="PREFIX",
                    help="write PREFIX.prom (Prometheus text) and "
                         "PREFIX.json with the run's metrics + "
                         "telemetry snapshot")
    ap.add_argument("--profile", type=str, default=None, metavar="DIR",
                    help="capture a jax.profiler (XLA) trace of the "
                         "whole run into DIR (TensorBoard-loadable) "
                         "and record compiled-cost analyses of the "
                         "engine's hot functions")
    args = ap.parse_args()
    if args.mesh:
        import os
        import sys
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            # The import chain above already initialized the backend,
            # which reads XLA_FLAGS exactly once — re-exec with the
            # device-count flag in place (same argv; this branch is a
            # no-op on the second pass).
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count"
                f"={args.mesh}").strip()
            os.execvpe(sys.executable,
                       [sys.executable, "-m", "repro.launch.serve",
                        *sys.argv[1:]], env)
    policy = TriagePolicy(conf_threshold=args.conf_threshold,
                          mi_threshold=args.mi_threshold,
                          r_min=args.r_min, r_max=args.r_max)

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        tracer = Tracer("repro-serving")

    from repro.obs.prof import trace_capture
    if args.arch == "sar_cnn":
        chip = None
        if args.chip_instance is not None:
            from repro.hw import VariationSpec, sample_instances
            chip = sample_instances(
                args.chip_instance, 1,
                VariationSpec().scaled(args.chip_severity))[0]
        with trace_capture(args.profile):
            if args.pools:
                out = serve_sar_fleet(
                    n_requests=args.requests or 128,
                    n_pools=args.pools,
                    slots_per_pool=args.slots_per_pool,
                    adaptive=not args.fixed, policy=policy,
                    corrupt_frac=args.corrupt_frac,
                    corruption=args.corruption, chip_instance=chip,
                    calibrated=not args.uncalibrated, fused=args.fused,
                    telemetry=args.telemetry, tracer=tracer,
                    slo=tuple(args.slo or ()), arrival=args.arrival)
                log.info("fleet", pools=out["n_pools"],
                         gang=out["gang"],
                         routed=out["routed_per_pool"],
                         backlog_peak=out["backlog_peak"],
                         host_syncs_per_decision=round(
                             out["host_syncs_per_decision"], 4))
            elif args.age_rate > 0.0 or args.auto_recalibrate:
                from repro.hw.redeploy import LifetimeConfig
                out = serve_sar_lifetime(
                    lifetime=LifetimeConfig(
                        age_rate=args.age_rate, epochs=args.age_epochs,
                        auto_recalibrate=args.auto_recalibrate),
                    chip_instance=chip, n_requests=args.requests or 128,
                    n_slots=args.slots or 32, adaptive=not args.fixed,
                    policy=policy, corrupt_frac=args.corrupt_frac,
                    corruption=args.corruption,
                    calibrated=not args.uncalibrated, fused=args.fused,
                    telemetry=args.telemetry, tracer=tracer)
                lt = out["lifetime"]
                log.info("lifetime", age_s=lt.get("age_s", 0.0),
                         advisories=lt.get("advisories", 0),
                         heals=lt.get("heals", 0),
                         calib_epoch=lt.get("calib_epoch", 0))
            else:
                out = serve_sar(n_requests=args.requests or 128,
                                n_slots=args.slots or 32,
                                adaptive=not args.fixed, policy=policy,
                                corrupt_frac=args.corrupt_frac,
                                corruption=args.corruption,
                                chip_instance=chip,
                                calibrated=not args.uncalibrated,
                                fused=args.fused,
                                telemetry=args.telemetry,
                                tracer=tracer,
                                slo=tuple(args.slo or ()),
                                arrival=args.arrival,
                                cost_records=bool(args.profile))
        chip_note = ""
        if chip is not None and "tile_area_mm2" in out:
            chip_note = (f" [chip seed={args.chip_instance} "
                         f"T={chip.temp_c:.0f}C "
                         f"{'cal' if not args.uncalibrated else 'UNCAL'} "
                         f"area={out['tile_area_mm2']:.2f}mm2 "
                         f"util={out['tile_utilization']:.2f}]")
        grng_note = ""
        if "grng_energy_per_decision_aJ" in out:
            grng_note = (f"; GRNG "
                         f"{out['grng_energy_per_decision_aJ']:.0f} "
                         f"aJ/decision")
        log.info(
            f"[sar] {out['decisions']} decisions in "
            f"{out['wall_s']:.2f}s ({out['decisions_per_s']:.1f}/s); "
            f"mean samples/decision "
            f"{out.get('mean_samples_per_decision', float('nan')):.1f}; "
            f"{100*out['flagged_fraction']:.1f}% flagged"
            + grng_note + chip_note)
        if out.get("drift"):
            log.info("drift", drifted=out["drift"]["drifted"],
                     z_mean=round(out["drift"]["z_mean"], 2),
                     z_std=round(out["drift"]["z_std"], 2))
        if out.get("slo"):
            snap = out["slo"]
            log.info("slo", p50_s=round(snap["p50_s"], 4),
                     p95_s=round(snap["p95_s"], 4),
                     p99_s=round(snap["p99_s"], 4),
                     queue_wait_share=round(
                         snap.get("queue_wait_share", float("nan")), 3))
            for s in snap.get("slos", []):
                log.info("slo target", name=s["name"],
                         attainment=round(s["attainment"], 4),
                         burn_rate=round(s["burn_rate"], 2),
                         breach=s["breach"])
    else:
        with trace_capture(args.profile):
            out = serve(args.arch, smoke=args.smoke,
                        batch=args.slots or 4,
                        prompt_len=args.prompt_len, gen_len=args.gen,
                        n_requests=args.requests,
                        adaptive=not args.fixed,
                        policy=policy, fused=args.fused,
                        telemetry=args.telemetry, tracer=tracer,
                        cost_records=bool(args.profile))
        log.info(
            f"{out['requests']} requests / {out['decisions']} "
            f"tokens in {out['wall_s']:.2f}s "
            f"({out['tokens_per_s']:.1f} tok/s); mean samples/token "
            f"{out['mean_samples_per_decision']:.1f}; "
            f"{100*out['flagged_fraction']:.1f}% flagged for verification")

    if tracer is not None:
        import os
        d = os.path.dirname(args.trace)
        if d:
            os.makedirs(d, exist_ok=True)
        tracer.export(args.trace)
        log.info("trace written", path=args.trace,
                 events=len(tracer.events))
    if args.metrics_out:
        from repro.obs.registry import serving_registry
        reg = serving_registry(
            {k: v for k, v in out.items()
             if isinstance(v, (int, float)) and not isinstance(v, bool)},
            telemetry=out.get("telemetry"), drift=out.get("drift"),
            profile=out.get("stage_profile"),
            compile_counters=out.get("compile_counters"),
            compiled_costs=out.get("compiled_costs"),
            slo=out.get("slo"), alerts=out.get("alerts"),
            arch=args.arch)
        prom, js = reg.write(args.metrics_out)
        log.info("metrics written", prom=prom, json=js)


if __name__ == "__main__":
    main()
