"""Serving driver: batched uncertainty-aware generation.

Implements the paper's deployment story at the framework level: prefill
a batch of prompts, decode with the Bayesian head sampling R CLT-GRNG
draws per token, and *filter by predictive confidence* — the SAR
"verify vs keep searching" decision (paper Fig. 1) becomes a per-token
verdict stream: tokens whose mutual information exceeds the threshold
are flagged as needing verification.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 16 --gen 8 [--mode rank16]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.core.uncertainty import predictive_stats
from repro.data.tokens import TokenPipelineConfig, batch_at, stub_frames, \
    stub_image_embeds
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import mesh_hinted_config
from repro.models.registry import get_api


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 16, gen_len: int = 8, mode: str | None = None,
          mi_threshold: float = 0.5, seed: int = 0) -> dict:
    import dataclasses
    cfg = get_config(arch, smoke=smoke)
    if mode is not None:
        cfg = dataclasses.replace(cfg, head_mode=mode)
    mesh = make_debug_mesh()
    cfg = mesh_hinted_config(cfg, mesh, batch)
    api = get_api(cfg)

    params = api.init(jax.random.PRNGKey(seed), cfg)
    pipe = TokenPipelineConfig(vocab=cfg.vocab, seq_len=prompt_len,
                               global_batch=batch, seed=seed)
    prompts = batch_at(pipe, 0)["tokens"]
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = stub_frames(pipe, cfg.n_frames, cfg.d_model, 0,
                                       batch)
    if cfg.family == "vlm":
        extras["image_embeds"] = stub_image_embeds(
            pipe, cfg.n_image_tokens, cfg.d_model, 0, batch)

    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg),
                     donate_argnums=(1,))

    with jax.set_mesh(mesh):
        t0 = time.time()
        cache, last_h = api.prefill(params, prompts, cfg,
                                    cache_len=prompt_len + gen_len, **extras)
        token = prompts[:, -1:]
        generated, verdicts = [], []
        for _ in range(gen_len):
            samples, cache = decode(params, cache, token)
            stats = predictive_stats(samples)
            token = stats["prediction"][:, None].astype(jnp.int32)
            generated.append(token)
            verdicts.append({
                "confidence": stats["confidence"],
                "mutual_information": stats["mutual_information"],
                "needs_verification":
                    stats["mutual_information"] > mi_threshold,
            })
        dt = time.time() - t0

    tokens = jnp.concatenate(generated, axis=1)
    flags = jnp.stack([v["needs_verification"] for v in verdicts], axis=1)
    return {
        "tokens": tokens,
        "verdicts": verdicts,
        "flagged_fraction": float(flags.mean()),
        "wall_s": dt,
        "tokens_per_s": batch * gen_len / dt,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--mode", default=None,
                    choices=(None, "paper", "rank16", "moment"))
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen,
                mode=args.mode)
    print(f"[serve] generated {out['tokens'].shape} tokens in "
          f"{out['wall_s']:.2f}s ({out['tokens_per_s']:.1f} tok/s); "
          f"{100*out['flagged_fraction']:.1f}% flagged for verification")


if __name__ == "__main__":
    main()
