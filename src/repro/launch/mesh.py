"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (TPU v5e pod);
multi-pod: 2 × 256 = 512 chips with the leading 'pod' axis crossing the
inter-pod (DCN-class) boundary — gradient reduction and nothing else
should travel on it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
