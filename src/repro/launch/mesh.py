"""Production mesh construction + jax version compatibility shims.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (TPU v5e pod);
multi-pod: 2 × 256 = 512 chips with the leading 'pod' axis crossing the
inter-pod (DCN-class) boundary — gradient reduction and nothing else
should travel on it.

Version compatibility: the repo targets the current jax API
(``jax.sharding.AxisType``, ``jax.set_mesh``, ``jax.shard_map``) but
must run on older installs (0.4.x) where those names do not exist.
Every mesh construction and mesh-context entry in the codebase goes
through the ``make_mesh_compat`` / ``mesh_context`` / ``shard_map_compat``
shims below so the fallback lives in exactly one place.
"""

from __future__ import annotations

import contextlib

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_SHARD_MAP = hasattr(jax, "shard_map")
HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` on new jax, nothing on old jax (whose
    meshes are implicitly fully-auto)."""
    if HAS_AXIS_TYPE:
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh_compat(shape: tuple, axes: tuple):
    """jax.make_mesh with Auto axis types where the install supports it."""
    return jax.make_mesh(shape, axes, **axis_types_kwargs(len(axes)))


def mesh_context(mesh):
    """Context manager activating ``mesh`` for jit/wsc spec resolution.

    New jax: ``jax.set_mesh`` (abstract-mesh aware).  Old jax: the Mesh
    object itself is a context manager installing the legacy global
    mesh, which is what ``with_sharding_constraint`` with bare
    PartitionSpecs resolves against.
    """
    if HAS_SET_MESH:
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """jax.shard_map (check_vma off) or the jax.experimental fallback
    (check_rep off — same semantics, pre-rename)."""
    if HAS_SHARD_MAP:
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def abstract_mesh_or(mesh=None):
    """The ambient abstract mesh on new jax; ``mesh`` (or the legacy
    global physical mesh) on old jax."""
    if HAS_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    if mesh is not None:
        return mesh
    from jax.interpreters.pxla import thread_resources
    env_mesh = thread_resources.env.physical_mesh
    return None if env_mesh.empty else env_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return make_mesh_compat((n // model, model), ("data", "model"))
