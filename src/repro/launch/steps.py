"""Distributed step builders: train / prefill / decode with shardings.

These produce the jitted callables used by both the real launcher
(train.py / serve.py) and the multi-pod dry-run (dryrun.py).  All
abstract-shape plumbing lives here so the dry-run lowers *exactly* the
functions the launcher executes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.registry import get_api
from repro.models.transformer import ModelConfig
from repro.optim import AdamWConfig, adamw_update, init_opt_state, warmup_cosine
from repro.sharding import specs as S


# ----------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins — never allocated)
# ----------------------------------------------------------------------
def shape_adjusted_config(cfg: ModelConfig, seq_len: int) -> ModelConfig:
    """Per-cell config tweaks: size learned-pos tables to the cell."""
    if cfg.learned_pos and cfg.learned_pos < seq_len + 1:
        cfg = dataclasses.replace(cfg, learned_pos=seq_len + 1)
    return cfg


def mesh_hinted_config(cfg: ModelConfig, mesh: Mesh,
                       global_batch: int) -> ModelConfig:
    """Inject activation-sharding hints: DP axes that divide the batch
    and the model-axis size (for divisibility-guarded constraints)."""
    dp = S.dp_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if global_batch % size != 0:
        dp = ("data",) if global_batch % mesh.shape["data"] == 0 else ()
    return dataclasses.replace(cfg, batch_axes=tuple(dp),
                               model_axis_size=mesh.shape["model"])


def input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                kind: str) -> dict:
    """Abstract model inputs for one (arch × shape) cell."""
    b, s = global_batch, seq_len
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
    elif kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
    elif kind == "decode":
        batch = {"tokens": sds((b, 1), jnp.int32)}
    else:
        raise ValueError(kind)
    if cfg.family == "audio":
        batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def abstract_params(cfg: ModelConfig, dtype=None):
    api = get_api(cfg)
    tree = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
    if dtype is not None:
        tree = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
    return tree


def abstract_cache(cfg: ModelConfig, seq_len: int, global_batch: int,
                   serve_dtype=jnp.bfloat16):
    """Abstract KV/SSM cache as produced by prefill at this shape."""
    api = get_api(cfg)
    params = abstract_params(cfg, serve_dtype)
    batch = input_specs(cfg, seq_len, global_batch, "prefill")
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    # Lower-cost abstract prefill: sequence length 2·chunk is enough to
    # infer cache shapes when cache_len is passed explicitly.
    cache, _ = jax.eval_shape(
        partial(api.prefill, cfg=cfg, cache_len=seq_len),
        params, batch["tokens"], **extras)
    return cache


# ----------------------------------------------------------------------
# Train step
# ----------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    total_steps: int = 10000, warmup_steps: int = 200,
                    microbatches: int = 1):
    """§Perf I3: ``microbatches`` > 1 runs gradient accumulation — the
    activation peak shrinks ~k× (each microbatch's remat residuals are
    freed before the next) at the cost of re-gathering weights per
    microbatch."""
    api = get_api(cfg)

    def grads_of(params, batch, step):
        def loss_fn(p):
            return api.train_loss(p, batch, cfg, step=step)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        step = opt_state["count"]
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch, step)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches,
                                    x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_body(acc, mbatch):
                acc_g, acc_loss, _ = acc
                (loss, metrics), g = grads_of(params, mbatch, step)
                metrics = jax.tree.map(
                    lambda m: m.astype(jnp.float32), metrics)
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_loss + loss.astype(jnp.float32),
                        metrics), None

            (grads, loss_sum, metrics), _ = jax.lax.scan(
                mb_body, (acc0, jnp.zeros((), jnp.float32),
                          {"ce": jnp.zeros(()), "kl": jnp.zeros(()),
                           "aux": jnp.zeros(())}), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
        lr_scale = warmup_cosine(step, warmup_steps=warmup_steps,
                                 total_steps=total_steps)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg, lr_scale)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def jit_train_step(cfg: ModelConfig, mesh: Mesh, opt_cfg: AdamWConfig,
                   seq_len: int, global_batch: int, **kw):
    """AOT-ready jitted train step + abstract (params, opt, batch)."""
    cfg = shape_adjusted_config(cfg, seq_len)
    cfg = mesh_hinted_config(cfg, mesh, global_batch)
    step_fn = make_train_step(cfg, opt_cfg, **kw)
    aparams = abstract_params(
        cfg, jnp.bfloat16 if opt_cfg.master_weights else None)
    aopt = jax.eval_shape(
        lambda p: init_opt_state(p, opt_cfg.master_weights), aparams)
    abatch = input_specs(cfg, seq_len, global_batch, "train")

    pspecs = S.param_specs(aparams, mesh)
    ospecs = S.opt_state_specs(aopt, mesh)
    bspecs = S.batch_specs(abatch, mesh)
    metric_specs = None  # replicated scalars

    jitted = jax.jit(
        step_fn,
        in_shardings=(S.to_named(pspecs, mesh), S.to_named(ospecs, mesh),
                      S.to_named(bspecs, mesh)),
        out_shardings=(S.to_named(pspecs, mesh), S.to_named(ospecs, mesh),
                       None),
        donate_argnums=(0, 1),
    )
    return jitted, (aparams, aopt, abatch), (pspecs, ospecs, bspecs), cfg


# ----------------------------------------------------------------------
# Serve steps
# ----------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, cache_len: int):
    api = get_api(cfg)

    def prefill_step(params, batch):
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        cache, last_h = api.prefill(params, batch["tokens"], cfg,
                                    cache_len=cache_len, **extras)
        from repro.models.transformer import apply_bayes_head
        samples = apply_bayes_head(params, last_h, cfg, cache["pos"])
        return cache, samples

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    api = get_api(cfg)

    def decode_step(params, cache, token):
        return api.decode_step(params, cache, token, cfg)

    return decode_step


def jit_prefill_step(cfg: ModelConfig, mesh: Mesh, seq_len: int,
                     global_batch: int, serve_dtype=jnp.bfloat16):
    cfg = shape_adjusted_config(cfg, seq_len)
    cfg = mesh_hinted_config(cfg, mesh, global_batch)
    fn = make_prefill_step(cfg, cache_len=seq_len)
    aparams = abstract_params(cfg, serve_dtype)
    abatch = input_specs(cfg, seq_len, global_batch, "prefill")
    acache = jax.eval_shape(fn, aparams, abatch)[0]

    pspecs = S.param_specs(aparams, mesh)
    bspecs = S.batch_specs(abatch, mesh)
    cspecs = S.cache_specs(acache, mesh)
    lspec = S.logits_spec(mesh, global_batch)

    jitted = jax.jit(
        fn,
        in_shardings=(S.to_named(pspecs, mesh), S.to_named(bspecs, mesh)),
        out_shardings=(S.to_named(cspecs, mesh),
                       NamedSharding(mesh, lspec)),
    )
    return jitted, (aparams, abatch), (pspecs, bspecs, cspecs), cfg


def jit_decode_step(cfg: ModelConfig, mesh: Mesh, seq_len: int,
                    global_batch: int, serve_dtype=jnp.bfloat16):
    cfg = shape_adjusted_config(cfg, seq_len)
    cfg = mesh_hinted_config(cfg, mesh, global_batch)
    fn = make_decode_step(cfg)
    aparams = abstract_params(cfg, serve_dtype)
    acache = abstract_cache(cfg, seq_len, global_batch, serve_dtype)
    atoken = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)

    pspecs = S.param_specs(aparams, mesh)
    cspecs = S.cache_specs(acache, mesh)
    tspec = S.batch_specs({"tokens": atoken}, mesh)["tokens"]
    lspec = S.logits_spec(mesh, global_batch)

    jitted = jax.jit(
        fn,
        in_shardings=(S.to_named(pspecs, mesh), S.to_named(cspecs, mesh),
                      NamedSharding(mesh, tspec)),
        out_shardings=(NamedSharding(mesh, lspec),
                       S.to_named(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, (aparams, acache, atoken), (pspecs, cspecs), cfg
