"""Loop-aware cost analysis of post-SPMD HLO.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, no
matter the trip count — useless for scan-over-layers programs where >95%
of FLOPs live inside loops (verified: scan L=2 and L=8 report identical
flops).  This module re-derives the three roofline inputs by walking the
HLO text with loop multiplicity:

  * flops            — 2·M·N·K for every dot (incl. inside fusions),
                       × the product of enclosing while trip counts;
  * hbm bytes        — 2 × result bytes of every materializing
                       instruction (each post-fusion instruction ≈ one
                       kernel; its result is written once and read once
                       by consumers; dynamic-slice results count at
                       their sliced size, so scanned weight reads are
                       not overcounted), × trips;
  * collective bytes — ring-model wire bytes per collective op, × trips.

Trip counts come from each while's condition computation (largest
``s32[] constant(N)`` ⇒ N).  Conditionals take the max over branches.
Static model, assumes loop-invariant shapes (true for lax.scan);
validated against analytic FLOPs in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"=\s*(?:\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ZERO_COST = {"parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "after-all", "partition-id", "replica-id", "iota",
              "tuple-select"}


def _strip_meta(line: str) -> str:
    for marker in (", metadata=", ", backend_config=", ", frontend_attributes="):
        idx = line.find(marker)
        if idx >= 0:
            line = line[:idx]
    return line


def _shapes_in(text: str):
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((dt, dims))
    return out


def _bytes_of(shapes) -> float:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return float(total)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "Costs":
        coll = {op: {kk: vv * k for kk, vv in rec.items()}
                for op, rec in self.coll.items()}
        return Costs(self.flops * k, self.bytes * k, coll)

    def add(self, other: "Costs") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for op, rec in other.coll.items():
            mine = self.coll.setdefault(
                op, {"count": 0.0, "wire_bytes": 0.0, "payload_bytes": 0.0,
                     "wire_bytes_tpu": 0.0, "wire_bytes_f32": 0.0})
            for k, v in rec.items():
                mine[k] += v


class HloAnalyzer:
    def __init__(self, hlo_text: str, total_devices: int):
        self.devices = total_devices
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        self._split(hlo_text)
        self._memo: dict[str, Costs] = {}
        # per-computation symbol tables: name -> shapes list
        self._symtabs: dict[str, dict[str, list]] = {}

    # -- parsing --------------------------------------------------------
    def _split(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if cur is None:
                is_hdr = (stripped.startswith("ENTRY") or
                          (stripped.startswith("%") and "->" in stripped
                           and stripped.endswith("{")))
                if is_hdr:
                    name_m = re.match(r"(?:ENTRY\s+)?%([\w\.\-]+)", stripped)
                    if name_m:
                        cur = name_m.group(1)
                        self.comps[cur] = []
                        if stripped.startswith("ENTRY"):
                            self.entry = cur
                continue
            if stripped == "}":
                cur = None
                continue
            if stripped:
                self.comps[cur].append(_strip_meta(stripped))

    def _symtab(self, comp: str) -> dict:
        if comp in self._symtabs:
            return self._symtabs[comp]
        tab: dict[str, list] = {}
        for line in self.comps.get(comp, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            # result type is everything before the op name's '('
            head = rhs.split("(", 1)[0]
            # for "(tuple) op" results the shapes live in the tuple text
            tab[m.group(1)] = _shapes_in(rhs[:rhs.find(head.split()[-1])]
                                         if head else rhs) or _shapes_in(rhs)
        self._symtabs[comp] = tab
        return tab

    @staticmethod
    def _result_shapes(line: str) -> list:
        m = _DEF_RE.match(line)
        if not m:
            return []
        rhs = m.group(2)
        # result type = prefix of rhs up to the op name token
        # e.g. "f32[32,128]{1,0} dot(%a, %b), ..." or "(f32[..], f32[..]) tuple(...)"
        idx = rhs.find("(")
        if rhs.startswith("("):
            # tuple type: find matching close paren
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        return _shapes_in(rhs[:i + 1])
            return _shapes_in(rhs)
        head = rhs[:idx] if idx >= 0 else rhs
        # strip trailing op name token
        parts = head.rsplit(None, 1)
        return _shapes_in(parts[0] if len(parts) == 2 else head)

    @staticmethod
    def _op_name(line: str) -> str:
        m = _DEF_RE.match(line)
        if not m:
            return ""
        rhs = m.group(2)
        idx = rhs.find("(")
        if idx < 0:
            return ""
        head = rhs[:idx]
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        rest = rhs[i + 1:].strip()
                        return rest.split("(", 1)[0].strip()
            return ""
        return head.rsplit(None, 1)[-1] if head.strip() else ""

    @staticmethod
    def _operand_names(line: str) -> list[str]:
        m = _DEF_RE.match(line)
        if not m:
            return []
        rhs = m.group(2)
        op = HloAnalyzer._op_name(line)
        idx = rhs.find(op + "(")
        if idx < 0:
            return []
        args = rhs[idx + len(op) + 1:]
        depth = 1
        end = 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _OPERANDS_RE.findall(args[:end])

    def _trip_count(self, cond_name: str) -> float:
        consts = [int(m.group(1)) for l in self.comps.get(cond_name, [])
                  for m in _CONST_RE.finditer(l)]
        return float(max(consts)) if consts else 1.0

    def _dot_flops(self, line: str, comp: str) -> float:
        result = self._result_shapes(line)
        if not result:
            return 0.0
        tab = self._symtab(comp)
        opnames = self._operand_names(line)
        lhs = tab.get(opnames[0], result) if opnames else result
        lhs_dims = lhs[0][1] if lhs else []
        m = _CONTRACT_RE.search(line)
        k = 1
        if m and m.group(1):
            for idx in m.group(1).split(","):
                d = int(idx)
                if d < len(lhs_dims):
                    k *= lhs_dims[d]
        n_out = 1
        for d in result[0][1]:
            n_out *= d
        return 2.0 * n_out * k

    def _io_bytes(self, line: str, comp: str) -> float:
        # write-once/read-once model: result bytes, doubled in analyze()
        return _bytes_of(self._result_shapes(line))

    def _dus_update_bytes(self, callee: str) -> float | None:
        """If the fused computation performs dynamic-update-slice(s),
        only the update slice moves through HBM (XLA updates in place;
        counting the full buffer would overcount by the trip count).
        Returns the summed update bytes, or None if no DUS present."""
        tab = self._symtab(callee)
        total = 0.0
        found = False
        for line in self.comps.get(callee, []):
            if self._op_name(line) != "dynamic-update-slice":
                continue
            found = True
            ops = self._operand_names(line)
            if len(ops) >= 2:
                total += _bytes_of(tab.get(ops[1], []))
        return total if found else None

    def _is_promoted_bf16(self, operand: str, comp: str) -> bool:
        """XLA CPU's reduction promotion rewrites bf16 collectives as
        convert(bf16→f32) → collective(f32) → convert(→bf16) — verified
        by probing an explicit bf16 psum.  On the TPU target the wire
        payload is bf16; detect the signature so the roofline can report
        the TPU-adjusted collective term."""
        for l in self.comps.get(comp, []):
            m = _DEF_RE.match(l)
            if not m or m.group(1) != operand:
                continue
            if "convert" not in l:
                return False
            mc = _CALLS_RE.search(l)
            if mc:
                callee = self.comps.get(mc.group(1), [])
                return any("bf16[" in cl and "parameter(" in cl
                           for cl in callee)
            ops = self._operand_names(l)
            tab = self._symtab(comp)
            return any(sh[0] == "bf16" for n in ops for sh in tab.get(n, []))
        return False

    def _collective(self, line: str, op: str, comp: str) -> dict:
        tab = self._symtab(comp)
        res = _bytes_of(self._result_shapes(line))
        opd = sum(_bytes_of(tab.get(n, []))
                  for n in self._operand_names(line))
        size = max(res, opd)
        opnames = self._operand_names(line)
        promoted = bool(opnames) and all(
            self._is_promoted_bf16(n, comp) for n in opnames)
        g = self.devices
        m = _GROUPS_LIST_RE.search(line)
        if m:
            g = len(m.group(1).split(","))
        else:
            m = _GROUPS_IOTA_RE.search(line)
            if m:
                g = int(m.group(2))
        if g <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * (g - 1) / g * size
        elif op == "collective-permute":
            wire = float(size)
        else:
            wire = (g - 1) / g * size
        shapes = self._result_shapes(line) or [("f32", [])]
        is_f32 = shapes[0][0] == "f32"
        return {"count": 1.0, "wire_bytes": wire, "payload_bytes": float(size),
                "wire_bytes_tpu": wire / 2.0 if promoted else wire,
                "wire_bytes_f32": wire if is_f32 else 0.0}

    # -- cost walk ------------------------------------------------------
    def comp_costs(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        total = Costs()
        self._memo[name] = total  # cycle guard
        for line in self.comps.get(name, []):
            op = self._op_name(line)
            if not op:
                continue
            if op == "while":
                body = _BODY_RE.search(line)
                cond = _COND_RE.search(line)
                trips = self._trip_count(cond.group(1)) if cond else 1.0
                if body:
                    total.add(self.comp_costs(body.group(1)).scaled(trips))
                continue
            mb = _BRANCHES_RE.search(line)
            if mb:
                branch_costs = [self.comp_costs(b.strip().lstrip("%"))
                                for b in mb.group(1).split(",")]
                if branch_costs:
                    total.add(max(branch_costs,
                                  key=lambda c: c.flops + c.bytes))
                continue
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES:
                rec = self._collective(line, base_op, name)
                mine = total.coll.setdefault(
                    base_op, {"count": 0.0, "wire_bytes": 0.0,
                              "payload_bytes": 0.0, "wire_bytes_tpu": 0.0,
                              "wire_bytes_f32": 0.0})
                for k, v in rec.items():
                    mine[k] += v
                total.bytes += _bytes_of(self._result_shapes(line))
                continue
            if op.endswith("-done"):
                continue
            mc = _CALLS_RE.search(line)
            if mc or op in ("fusion", "call"):
                callee = mc.group(1) if mc else None
                dus = None
                if callee:
                    inner = self.comp_costs(callee)
                    total.flops += inner.flops
                    for cop, rec in inner.coll.items():
                        mine = total.coll.setdefault(
                            cop, {"count": 0.0, "wire_bytes": 0.0,
                                  "payload_bytes": 0.0,
                                  "wire_bytes_tpu": 0.0,
                                  "wire_bytes_f32": 0.0})
                        for k, v in rec.items():
                            mine[k] += v
                    dus = self._dus_update_bytes(callee)
                total.bytes += dus if dus is not None else self._io_bytes(
                    line, name)
                continue
            if op == "dynamic-update-slice":
                ops_ = self._operand_names(line)
                tab = self._symtab(name)
                total.bytes += (_bytes_of(tab.get(ops_[1], []))
                                if len(ops_) >= 2 else
                                self._io_bytes(line, name))
                continue
            if op == "dot":
                total.flops += self._dot_flops(line, name)
                total.bytes += self._io_bytes(line, name)
                continue
            if op in _ZERO_COST:
                continue
            total.bytes += self._io_bytes(line, name)
        self._memo[name] = total
        return total

    def entry_costs(self) -> Costs:
        assert self.entry, "no ENTRY computation found"
        return self.comp_costs(self.entry)


def analyze(hlo_text: str, total_devices: int) -> dict:
    """Loop-aware per-device costs of a post-SPMD HLO module."""
    an = HloAnalyzer(hlo_text, total_devices)
    c = an.entry_costs()
    return {
        "flops_per_device": c.flops,
        "hbm_bytes_per_device": 2.0 * c.bytes,
        "collectives": c.coll,
        "wire_bytes_per_device": sum(r["wire_bytes"] for r in c.coll.values()),
        "wire_bytes_per_device_tpu": sum(
            r.get("wire_bytes_tpu", r["wire_bytes"]) for r in c.coll.values()),
        "wire_bytes_f32_per_device": sum(
            r.get("wire_bytes_f32", 0.0) for r in c.coll.values()),
    }


# ----------------------------------------------------------------------
# live-footprint queries (serving fast-path acceptance checks)
# ----------------------------------------------------------------------
_FOOTPRINT_FREE = _ZERO_COST | {"parameter", "constant"}


def materialized_shapes(hlo_text: str) -> list:
    """Result shapes of every value-producing instruction, everywhere.

    Walks ALL computations (fusion bodies and loop bodies included —
    a buffer a fusion writes is still a live array while the fusion
    runs) and returns ``[(dtype, (dims...)), ...]`` for each non-free
    instruction.  Inputs (parameters/constants) and shape-only plumbing
    (tuples, GTEs, bitcasts, iota) are excluded: the question this
    answers is what the COMPILED program ever holds live beyond its
    operands.

    The serving acceptance check: the fused decision round's HLO must
    contain no shape with an R·B·N term — the logit-sample tensor
    (and any padded block of comparable size) never exists.
    """
    an = HloAnalyzer(hlo_text, 1)
    out = []
    for comp, lines in an.comps.items():
        for line in lines:
            op = an._op_name(line)
            if not op or op in _FOOTPRINT_FREE:
                continue
            for dt, dims in an._result_shapes(line):
                out.append((dt, tuple(dims)))
    return out


def largest_intermediate_bytes(hlo_text: str) -> float:
    """Largest single materialized result in bytes — the dominant term
    of a program's live-array footprint beyond its inputs/outputs.
    serving_bench reports this for the compiled decision round as
    ``peak_live_bytes_per_decision``."""
    best = 0.0
    for dt, dims in materialized_shapes(hlo_text):
        n = 1
        for d in dims:
            n *= d
        best = max(best, n * _DTYPE_BYTES[dt])
    return best
