"""Mission driver: fly the closed-loop SAR simulator from the CLI.

Wraps repro/mission: builds the world + fleet from flags, trains (or
restores) the weather-augmented detector, optionally binds every drone
to a sampled FeFET chip instance, and flies the whole mission in one
device dispatch per die group.

Usage:
  PYTHONPATH=src python -m repro.launch.mission \
      --grid 14 --victims 10 --drones 4 --steps 70 --episodes 2
  PYTHONPATH=src python -m repro.launch.mission --policy deterministic
  PYTHONPATH=src python -m repro.launch.mission \
      --chip-instance 11 --chip-severity 2.5 [--uncalibrated]
  PYTHONPATH=src python -m repro.launch.mission --planner infogain \
      --flag-action skip --battery-uJ 250

``--policy``: bayes_adaptive (default) | bayes_fixed | deterministic —
the three systems benchmarks/mission_bench.py compares.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=14)
    ap.add_argument("--victims", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0,
                    help="world seed (episode e uses seed+e)")
    ap.add_argument("--corruption", default="snow",
                    choices=("fog", "frost", "motion", "snow"))
    ap.add_argument("--severity-hi", type=float, default=0.5,
                    help="worst-weather corner of the severity field")
    ap.add_argument("--drones", type=int, default=4)
    ap.add_argument("--battery-uJ", type=float, default=320.0,
                    help="per-sortie energy budget in microjoules")
    ap.add_argument("--steps", type=int, default=70)
    ap.add_argument("--episodes", type=int, default=1)
    ap.add_argument("--policy", default="bayes_adaptive",
                    choices=("bayes_adaptive", "bayes_fixed",
                             "deterministic"))
    ap.add_argument("--planner", default="lawnmower",
                    choices=("lawnmower", "infogain"))
    ap.add_argument("--flag-action", default="orbit",
                    choices=("orbit", "skip"))
    ap.add_argument("--conf-threshold", type=float, default=0.8)
    ap.add_argument("--mi-threshold", type=float, default=0.5)
    ap.add_argument("--r-min", type=int, default=4)
    ap.add_argument("--r-max", type=int, default=20)
    ap.add_argument("--chip-instance", type=int, default=None,
                    help="bind the fleet to a FeFET die sampled with "
                         "this seed (hw/ digital twin)")
    ap.add_argument("--chip-severity", type=float, default=1.0)
    ap.add_argument("--uncalibrated", action="store_true",
                    help="skip per-die head recalibration AND the "
                         "mission operating-point transfer")
    ap.add_argument("--age-rate", type=float, default=0.0,
                    help="simulated field-seconds of FeFET aging per "
                         "mission step (hw/aging.py); 0 disables the "
                         "lifetime loop")
    ap.add_argument("--age-epochs", type=int, default=4,
                    help="age/heal segments the mission is cut into")
    ap.add_argument("--auto-recalibrate", action="store_true",
                    help="heal drift advisories in flight: recalibrate "
                         "the aged die between segments and redeploy "
                         "(hw/redeploy.py)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    default=True)
    ap.add_argument("--train-steps", type=int, default=None,
                    help="detector training steps (default: the shared "
                         "1600-step detector; CI smoke passes the "
                         "mission bench's 400 to reuse its cache)")
    ap.add_argument("--no-telemetry", dest="telemetry",
                    action="store_false", default=True,
                    help="disable per-die-group device-resident "
                         "telemetry + GRNG drift monitoring")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace JSON of the "
                         "mission (per-drone tracks on the simulated "
                         "clock) to PATH")
    ap.add_argument("--metrics-out", type=str, default=None,
                    metavar="PREFIX",
                    help="write PREFIX.prom / PREFIX.json with the "
                         "mission summary + per-die telemetry")
    ap.add_argument("--profile", type=str, default=None, metavar="DIR",
                    help="capture a jax.profiler (XLA) trace of the "
                         "mission into DIR (TensorBoard-loadable)")
    args = ap.parse_args()

    from repro.mission import (MissionPolicy, UavConfig, WorldConfig,
                               fly_mission, trained_detector)
    from repro.obs.log import get_logger
    from repro.serving import TriagePolicy
    log = get_logger("mission")

    wcfg = WorldConfig(grid=args.grid, n_victims=args.victims,
                       seed=args.seed, corruption=args.corruption,
                       severity_hi=args.severity_hi)
    ucfg = UavConfig(n_drones=args.drones,
                     battery_J=args.battery_uJ * 1e-6)
    pol = MissionPolicy(
        mode=args.policy, planner=args.planner,
        flag_action=args.flag_action,
        triage=TriagePolicy(conf_threshold=args.conf_threshold,
                            mi_threshold=args.mi_threshold,
                            r_min=args.r_min, r_max=args.r_max))
    chips = None
    chip_note = ""
    if args.chip_instance is not None:
        from repro.hw import VariationSpec, sample_instances
        chips = sample_instances(
            args.chip_instance, 1,
            VariationSpec().scaled(args.chip_severity))[0]
        chip_note = (f" [chip seed={args.chip_instance} "
                     f"sev={args.chip_severity} "
                     f"{'UNCAL' if args.uncalibrated else 'cal'}]")

    det_kw = {} if args.train_steps is None else \
        {"steps": args.train_steps}
    from repro.obs.prof import trace_capture
    with trace_capture(args.profile):
        params, cfg = trained_detector(corruption=args.corruption,
                                       severity_hi=args.severity_hi,
                                       **det_kw)
        lifetime = None
        if args.age_rate > 0.0 or args.auto_recalibrate:
            from repro.hw.redeploy import LifetimeConfig
            lifetime = LifetimeConfig(
                age_rate=args.age_rate, epochs=args.age_epochs,
                auto_recalibrate=args.auto_recalibrate)
        res = fly_mission(wcfg, ucfg, pol, params=params, cfg=cfg,
                          chips=chips,
                          calibrated=not args.uncalibrated,
                          n_steps=args.steps, n_episodes=args.episodes,
                          fused=args.fused, telemetry=args.telemetry,
                          lifetime=lifetime)
    s = res.summary
    log.info(
        f"[{args.policy}/{args.planner}] "
        f"{s['episodes']}x{s['n_drones']} drones on "
        f"{s['grid']}x{s['grid']}{chip_note}: "
        f"rescued {s['rescued']}/{s['victims']}, "
        f"rescue delay {s['rescue_delay_s']:.0f}s, "
        f"coverage {100*s['coverage']:.0f}%, "
        f"false-verification rate "
        f"{100*s['false_verification_rate']:.1f}% "
        f"({s['false_verifications']}/{s['verifications']})")
    log.info(
        f"{s['decisions']} decisions, "
        f"{s['mean_samples_per_decision']:.1f} samples/decision, "
        f"{s['orbits']} orbits; energy "
        f"{1e6*s['energy_total_J']:.0f} uJ "
        f"(decisions {1e6*s['energy_decision_J']:.2f}, verify "
        f"{1e6*s['energy_verify_J']:.0f}, flight "
        f"{1e6*s['energy_flight_J']:.0f}); "
        f"host syncs {res.host_syncs}")
    for group, lt in (res.lifetime or {}).items():
        log.info("die lifetime", die_group=group,
                 age_s=lt["age_s"], advisories=lt["advisories"],
                 heals=lt["heals"], calib_epoch=lt["calib_epoch"])
    for group, t in (res.telemetry or {}).items():
        drift = t["drift"]
        if drift.get("advisory"):
            log.warning(drift["advisory"], die_group=group)
        else:
            log.info("die group healthy", die_group=group,
                     z_mean=round(drift["z_mean"], 2),
                     z_std=round(drift["z_std"], 2),
                     decisions=t["telemetry"]["decisions"])

    # Unified alert bus (obs/alerts): fold the per-die-group drift
    # statuses and lifetime heal events into one typed advisory stream
    # — post-hoc over the finished summary, so the mission hot path is
    # untouched.
    from repro.obs.alerts import AlertBus
    bus = AlertBus()
    for group, t in (res.telemetry or {}).items():
        bus.observe_drift(t.get("drift"), source=f"mission/{group}")
    for group, lt in (res.lifetime or {}).items():
        for ev in lt.get("events", []):
            bus.observe_heal(ev, source=f"mission/{group}")
    alerts = bus.to_json() if bus.advisories else None

    if args.trace:
        import json
        import os
        from repro.obs.trace import mission_trace
        d = os.path.dirname(args.trace)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.trace, "w") as f:
            json.dump(mission_trace(res.logs), f)
        log.info("trace written", path=args.trace)
    if args.metrics_out:
        from repro.obs.registry import mission_registry
        reg = mission_registry(s, telemetry=res.telemetry, alerts=alerts,
                               policy=args.policy, planner=args.planner)
        prom, js = reg.write(args.metrics_out)
        log.info("metrics written", prom=prom, json=js)


if __name__ == "__main__":
    main()
