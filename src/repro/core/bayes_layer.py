"""Variational Bayesian dense layer (training side of the paper's BNN).

The paper converts only the final projection of its detector to Bayesian
weights (§V-B1: "converting only the last layer balances computational
cost with UQ capability") and trains with variational inference
(Eq. 1).  This module provides:

  * parameter init (µ, ρ) with σ = softplus(ρ),
  * the reparameterized forward pass  w = µ + σ·ε  where ε comes from
    the *same CLT-GRNG* used at inference — train/serve distribution
    match, which the paper relies on for its "CLT ≈ ideal" accuracy
    claims (Table II),
  * the closed-form KL(q ‖ N(0, σ_p²)) regularizer,
  * conversion to the quantized, offset-compensated serving head.

Quantization-aware training uses the STE quantizers in core/quant.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import clt_grng as g
from repro.core import quant as q
from repro.core.sampling import BayesHeadConfig, prepare_serving_head


@dataclasses.dataclass(frozen=True)
class BayesDenseConfig:
    d_in: int
    d_out: int
    sigma_init: float = 0.05
    prior_sigma: float = 0.1
    grng: g.GRNGConfig = dataclasses.field(default_factory=g.GRNGConfig)
    quant: q.QuantConfig = dataclasses.field(
        default_factory=lambda: q.QuantConfig(enabled=False))
    param_dtype: Any = jnp.float32


def _inv_softplus(x: float) -> float:
    import math
    return math.log(math.expm1(x))


def init(key: jax.Array, cfg: BayesDenseConfig) -> dict:
    kmu, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_in, jnp.float32))
    mu = jax.random.normal(kmu, (cfg.d_in, cfg.d_out), cfg.param_dtype) * scale
    rho = jnp.full((cfg.d_in, cfg.d_out), _inv_softplus(cfg.sigma_init),
                   cfg.param_dtype)
    return {"mu": mu, "rho": rho}


def sigma_of(params: dict) -> jnp.ndarray:
    return jax.nn.softplus(params["rho"])


def sample_weights(params: dict, cfg: BayesDenseConfig, step) -> jnp.ndarray:
    """Reparameterized weight draw using the CLT-GRNG stream at ``step``.

    ε is a constant w.r.t. (µ, ρ) — gradients flow through the affine
    reparameterization exactly as in standard Bayes-by-backprop.
    """
    sigma = sigma_of(params)
    eps = g.eps(cfg.grng, cfg.d_in, cfg.d_out, 1, sample0=0)[0]
    # Advance the stream per training step without re-tracing: hash the
    # step into the selection seed (write-free: new subset, same devices).
    del step  # stream offset folded into lfsr seed by caller when needed
    w = params["mu"] + sigma * jax.lax.stop_gradient(eps)
    if cfg.quant.enabled:
        scale = q.symmetric_scale(jax.lax.stop_gradient(w), cfg.quant.mu_bits)
        w = q.fake_quant_ste(w, scale, cfg.quant.mu_bits)
    return w


def sample_weights_at(params: dict, cfg: BayesDenseConfig,
                      sample0: jnp.ndarray) -> jnp.ndarray:
    """Like ``sample_weights`` but with a dynamic (traced) stream offset.

    Uses the hardware's layer-shared selection (one 16-bit selection per
    training step, random-accessed via lfsr.indexed_selections) and
    accumulates the subset sum with a scan over the 16 virtual devices —
    peak temp is one [d_in, d_out] f32 buffer, never [d_in, d_out, 16].
    """
    from repro.core.hashing import gaussianish, hash3, uniform_bit
    from repro.core.lfsr import indexed_selections
    sigma = sigma_of(params)
    sel = indexed_selections(cfg.grng.lfsr_seed,
                             jnp.asarray(sample0, jnp.uint32))     # [16]
    rows = jnp.arange(cfg.d_in, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(cfg.d_out, dtype=jnp.uint32)[None, :]
    grng = cfg.grng

    def body(raw, j):
        h = hash3(rows, cols, j, grng.seed)
        i_j = (grng.i_lo + grng.delta_i * uniform_bit(h)
               + grng.gamma * gaussianish(h))
        return raw + sel[j] * i_j, None

    raw0 = jnp.zeros((cfg.d_in, cfg.d_out), jnp.float32)
    raw, _ = jax.lax.scan(body, raw0, jnp.arange(16, dtype=jnp.uint32))
    eps = (raw - grng.sum_mean) / grng.sum_std
    return params["mu"] + sigma * jax.lax.stop_gradient(eps)


def kl_divergence(params: dict, cfg: BayesDenseConfig) -> jnp.ndarray:
    """KL( N(µ,σ²) ‖ N(0,σ_p²) ), summed over all weights."""
    sigma = sigma_of(params)
    sp = cfg.prior_sigma
    kl = (jnp.log(sp / sigma) + (sigma**2 + params["mu"] ** 2) / (2 * sp**2)
          - 0.5)
    return kl.sum()


def apply_train(params: dict, x: jnp.ndarray, cfg: BayesDenseConfig,
                step) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: one reparameterized sample. Returns (y, kl)."""
    w = sample_weights_at(params, cfg, step)
    y = x @ w.astype(x.dtype)
    return y, kl_divergence(params, cfg)


def to_serving(params: dict, head_cfg: BayesHeadConfig) -> dict:
    """Freeze the variational posterior into the quantized serving head."""
    return prepare_serving_head(params["mu"], sigma_of(params), head_cfg)
