"""Uncertainty quantification metrics (paper §V-B2).

Implements exactly the evaluation protocol of the paper:

  * risk–coverage curves and AURC (Ding et al. [46]) — "risk" is the
    selective error among retained predictions; coverage is the fraction
    retained after filtering by confidence,
  * adaptive-binning calibration errors AECE / AMCE (equal-mass bins,
    robust to non-uniform confidence distributions),
  * predictive statistics from Monte-Carlo logit samples: mean
    probabilities, predictive entropy, mutual information (epistemic
    share), and max-prob confidence.

All metrics are pure jnp and differentiable where meaningful, so they
can double as validation-time monitors inside jitted eval steps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def predictive_stats(logit_samples: jnp.ndarray) -> dict:
    """From [R, B, C] logit samples compute predictive quantities."""
    logp = jax.nn.log_softmax(logit_samples.astype(jnp.float32), axis=-1)
    # Mean predictive distribution p̄ = E_r softmax(logits_r).
    logp_mean = jax.nn.logsumexp(logp, axis=0) - jnp.log(logit_samples.shape[0])
    p_mean = jnp.exp(logp_mean)
    pred_entropy = -(p_mean * logp_mean).sum(-1)
    # Expected entropy of individual samples (aleatoric part).
    ent_each = -(jnp.exp(logp) * logp).sum(-1)
    exp_entropy = ent_each.mean(0)
    return {
        "probs": p_mean,                          # [B, C]
        "confidence": p_mean.max(-1),             # [B]
        "prediction": p_mean.argmax(-1),          # [B]
        "predictive_entropy": pred_entropy,       # [B] total uncertainty
        "expected_entropy": exp_entropy,          # [B] aleatoric
        "mutual_information": pred_entropy - exp_entropy,  # [B] epistemic
        "logit_std": logit_samples.astype(jnp.float32).std(0).mean(-1),
    }


def risk_coverage_curve(confidence: jnp.ndarray, correct: jnp.ndarray):
    """Selective risk at every coverage level.

    Returns (coverage [B], risk [B]) where entry i is the risk when
    keeping the i+1 most confident predictions.
    """
    order = jnp.argsort(-confidence)
    correct_sorted = correct[order].astype(jnp.float32)
    n = confidence.shape[0]
    cum_correct = jnp.cumsum(correct_sorted)
    kept = jnp.arange(1, n + 1, dtype=jnp.float32)
    coverage = kept / n
    risk = 1.0 - cum_correct / kept
    return coverage, risk


def aurc(confidence: jnp.ndarray, correct: jnp.ndarray) -> jnp.ndarray:
    """Area under the risk–coverage curve (lower is better)."""
    coverage, risk = risk_coverage_curve(confidence, correct)
    return jnp.trapezoid(risk, coverage)


def _adaptive_bins(confidence: jnp.ndarray, n_bins: int):
    """Equal-mass bin assignment by confidence rank."""
    n = confidence.shape[0]
    order = jnp.argsort(confidence)
    ranks = jnp.argsort(order)
    return jnp.minimum((ranks * n_bins) // n, n_bins - 1)


def adaptive_calibration_errors(confidence: jnp.ndarray, correct: jnp.ndarray,
                                n_bins: int = 15):
    """(AECE, AMCE) with equal-mass (adaptive) binning — paper's metric."""
    bins = _adaptive_bins(confidence, n_bins)
    correct = correct.astype(jnp.float32)
    one_hot = jax.nn.one_hot(bins, n_bins, dtype=jnp.float32)  # [B, n_bins]
    counts = one_hot.sum(0)
    acc = (one_hot * correct[:, None]).sum(0) / jnp.maximum(counts, 1.0)
    conf = (one_hot * confidence[:, None]).sum(0) / jnp.maximum(counts, 1.0)
    gap = jnp.abs(acc - conf)
    weights = counts / confidence.shape[0]
    aece = (weights * gap).sum()
    amce = jnp.max(jnp.where(counts > 0, gap, 0.0))
    return aece, amce


def uq_report(logit_samples: jnp.ndarray, labels: jnp.ndarray,
              n_bins: int = 15) -> dict:
    """Full paper-style UQ report from MC logit samples + labels."""
    stats = predictive_stats(logit_samples)
    correct = (stats["prediction"] == labels)
    aece, amce = adaptive_calibration_errors(stats["confidence"], correct, n_bins)
    return {
        "accuracy": correct.mean(),
        "aurc": aurc(stats["confidence"], correct),
        "aece": aece,
        "amce": amce,
        "mean_predictive_entropy": stats["predictive_entropy"].mean(),
        "mean_mutual_information": stats["mutual_information"].mean(),
    }


def deterministic_report(logits: jnp.ndarray, labels: jnp.ndarray,
                         n_bins: int = 15) -> dict:
    """Same report for a deterministic model (CNN baseline)."""
    return uq_report(logits[None], labels, n_bins)
