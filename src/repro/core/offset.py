"""Static offset compensation (paper §III-B1).

Every CLT-GRNG instance has a static mean offset Δε_(k,n) caused by its
particular draw of device states.  Left uncompensated it distorts the
effective weight:  w = µ + σ·(ε + Δε).  The fix is one-time folding into
the stored mean:

    µ' = µ − σ·Δε        ⇒        w = µ' + σ·ε   (ε now zero-mean)

The compensation consumes µ-subarray dynamic range: the paper reports
the correction term reaching 162.72 µ-LSBs for a 4-bit σ, costing ~1.5
bits of µ precision (8 → 6.54 effective bits).  ``compensation_report``
reproduces that bookkeeping; the energy/time cost model
(54 + 458N pJ, 12.8 + 0.64N µs) lives in core/energy.py.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import clt_grng as g


def compensate_mu(mu: jnp.ndarray, sigma: jnp.ndarray, cfg: g.GRNGConfig,
                  exact: bool = True, n_est: int = 64) -> jnp.ndarray:
    """Return µ' = µ − σ·Δε (exact closed form or N-sample estimate)."""
    k, n = mu.shape
    if exact:
        d_eps = g.cell_mean_offset(cfg, k, n)
    else:
        d_eps = g.estimate_mean_offset(cfg, k, n, n_est)
    return mu - sigma * d_eps


@dataclasses.dataclass(frozen=True)
class CompensationReport:
    max_correction_lsb: float     # |σ·Δε| / µ_LSB, worst cell
    effective_mu_bits: float      # paper: ~6.54
    residual_mean_offset: float   # post-compensation E[ε̂] magnitude


def compensation_report(mu: jnp.ndarray, sigma: jnp.ndarray,
                        cfg: g.GRNGConfig, mu_bits: int = 8) -> CompensationReport:
    k, n = mu.shape
    d_eps = g.cell_mean_offset(cfg, k, n)
    corr = jnp.abs(sigma * d_eps)
    mu_lsb = jnp.max(jnp.abs(mu)) / (2 ** (mu_bits - 1) - 1)
    max_corr_lsb = float(jnp.max(corr) / jnp.maximum(mu_lsb, 1e-12))
    # Range consumed shrinks the representable µ span; effective bits:
    span_ratio = 1.0 + float(jnp.max(corr)) / float(jnp.maximum(jnp.max(jnp.abs(mu)), 1e-12))
    eff_bits = mu_bits - float(np.log2(span_ratio))
    # Residual offset after exact compensation (should be ~0 over samples).
    eps_hat = g.eps(cfg, min(k, 64), min(n, 64), 256)
    d_small = g.cell_mean_offset(cfg, min(k, 64), min(n, 64))
    resid = float(jnp.abs((eps_hat - d_small[None]).mean()))
    return CompensationReport(max_corr_lsb, eff_bits, resid)
