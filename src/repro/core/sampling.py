"""Bayesian-head execution modes — where the paper's dataflow meets TPU.

The hardware computes X·µ once and re-reads the σε subarray R times
(§IV, Fig. 11).  Because the selection lines are *shared* across all
cells (§III-B), sample r of the head output is an affine function of
the 16 shared selection bits s_r:

    Y_r = X·µ' + (1/ĝ)·( Σ_j s_r[j] · X·(σ⊙I_j)  −  m̂ · X·σ )

with I_j the fixed virtual current of device j per cell and (m̂, ĝ) the
standardization constants.  Three execution modes exploit this:

  * ``paper``  — faithful baseline: R explicit σε MVMs, ε materialized
    per sample.  Cost ≈ (1+R)·MVM.  Matches hardware dataflow.
  * ``rank16`` — beyond-paper, *mathematically identical* samples: the
    16 basis MVMs M_j = X·(σ⊙I_j) are precomputed once; any number of
    samples costs only a [B·N,16]×[16,R] mixing matmul.  R-independent:
    ≈17·MVM total.  This exploits the rank-16 joint structure the
    shared selection lines create but the paper never uses.
  * ``moment`` — analytic mean/variance propagation, 2 MVMs, no
    sampling.  Diagonal-covariance approximation (ignores the rank-16
    cross-cell covariance); cheap UQ fallback and ablation.

All functions are pure-jnp oracles; kernels/bayes_mvm.py implements the
fused versions with the CIM 6-bit-ADC numeric path.

Degraded chip instances (repro/hw): when ``cfg.grng.read_sigma > 0``
(cycle-to-cycle read noise, see hw/device.py) the per-read noise term is
full-rank per sample, so it cannot ride the 16 basis MVMs.  ``paper``
mode materializes it per cell (bit-exact twin); ``rank16`` adds its
*projection* at the logit level — per-cell noise ν(k,n) of RMS
``read_sigma`` contributes N(0, read_sigma²·Σ_k x_k²σ_kn²) to logit
(b, n), drawn deterministically from a hash of the selection pattern.
The two modes then agree in distribution (tested statistically), not
sample-for-sample; with ``read_sigma == 0`` they remain bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import clt_grng as g
from repro.core import quant as q
from repro.core.offset import compensate_mu


@dataclasses.dataclass(frozen=True)
class BayesHeadConfig:
    num_samples: int = 20            # paper R = 20
    mode: str = "rank16"             # 'paper' | 'rank16' | 'moment'
    grng: g.GRNGConfig = dataclasses.field(default_factory=g.GRNGConfig)
    quant: q.QuantConfig = dataclasses.field(
        default_factory=lambda: q.QuantConfig(enabled=False))
    compute_dtype: Any = jnp.bfloat16
    # Serving-time memory/compute trade: materialize the 16 σ⊙I_j basis
    # matrices once at deployment (16× weight memory) so decode steps
    # never recompute the device-current hashes.  The hardware analogue:
    # the currents are *physically programmed* — reading them costs
    # nothing; recomputing the hash per decode step models a chip that
    # re-programs itself every inference, which is exactly wrong.
    hoist_basis: bool = False
    # Tiled/offloaded hoisting for vocab-scale heads: with
    # ``hoist_tile_n > 0`` the hoisted basis is stored as HOST-resident
    # numpy chunks of ``hoist_tile_n`` output columns, streamed to the
    # device one chunk at a time by ``activation_basis`` — peak device
    # memory is K·hoist_tile_n·16 instead of K·N·16, so an LM head no
    # longer pays 16× weight memory to skip per-step hash recompute.
    hoist_tile_n: int = 0
    # Calibration epoch of the head this config serves.  Bumped by
    # hw/redeploy.py on every recalibrate-and-hot-swap so a healed
    # head's jitted builders can NEVER alias a stale epoch's cache
    # entries (two calibrations of the same die may hash-equal when the
    # drift sits below measurement resolution), while epoch-free
    # builders (scatter, stats reset) stay cached across heals.
    calib_epoch: int = 0


def hoisted_sigma_basis(sigma: jnp.ndarray, grng_cfg: g.GRNGConfig,
                        compute_dtype, tile_n: int) -> dict:
    """The hoisted σ⊙I_j basis for a serving head, dense or tiled.

    ``tile_n > 0`` (and < d_out): returns {"sigma_basis_host": tuple of
    host numpy [K, ≤tile_n, 16] chunks} built column-block by column-
    block so the full basis never exists on device; otherwise
    {"sigma_basis": [K, N, 16]} on device.  Shared by
    ``prepare_serving_head`` (golden chip) and
    ``hw.calib.prepare_instance_head`` (degraded instance), which
    differ only in the ``grng_cfg`` supplying the device currents.
    """
    import numpy as np
    kdim, n = sigma.shape
    if tile_n and tile_n < n:
        chunks = []
        for c0 in range(0, n, tile_n):
            c1 = min(c0 + tile_n, n)
            cur = g.device_currents_grid(grng_cfg, kdim, c1 - c0,
                                         col0=c0)              # [K, cn, 16]
            blk = (sigma[:, c0:c1, None] * cur).astype(compute_dtype)
            chunks.append(np.asarray(blk))                     # -> host
        return {"sigma_basis_host": tuple(chunks)}
    currents = g.device_currents_grid(grng_cfg, kdim, n)       # [K, N, 16]
    return {"sigma_basis": (sigma[..., None] * currents).astype(
        compute_dtype)}


def prepare_serving_head(mu: jnp.ndarray, sigma: jnp.ndarray,
                         cfg: BayesHeadConfig,
                         hoist_tile_n: int | None = None) -> dict:
    """One-time deployment transform: offset compensation + quantization.

    mu/sigma: [d_in, d_out] variational parameters (σ already softplus'd).
    Returns the serving pytree {mu_prime, sigma} in compute dtype; with
    ``cfg.hoist_basis`` additionally ``sigma_basis`` [d_in, d_out, 16] —
    the fixed σ⊙I_j matrices the rank-16 sampling path mixes, hoisted so
    a serving engine reuses them across every decode step
    (serving/engine.py).

    ``hoist_tile_n`` (overrides ``cfg.hoist_tile_n``): store the hoisted
    basis as host-resident numpy chunks of that many output columns
    instead of one [K, N, 16] device array — vocab-scale heads hoist
    without 16× device weight memory; ``activation_basis`` streams the
    chunks.  The chunks are built column-block by column-block so the
    full basis never exists on device even transiently.
    """
    tile_n = cfg.hoist_tile_n if hoist_tile_n is None else hoist_tile_n
    mu_p = compensate_mu(mu, sigma, cfg.grng, exact=True)
    if cfg.quant.enabled:
        mu_p, _ = q.quantize_mu(mu_p, cfg.quant)
        sigma, _ = q.quantize_sigma(sigma, cfg.quant)
    head = {
        "mu_prime": mu_p.astype(cfg.compute_dtype),
        "sigma": sigma.astype(cfg.compute_dtype),
    }
    if cfg.hoist_basis and cfg.mode == "rank16":
        head.update(hoisted_sigma_basis(sigma, cfg.grng, cfg.compute_dtype,
                                        tile_n))
    return head


def _sigma_eps_mvm(x, sigma, cfg: BayesHeadConfig, r0: int, num: int,
                   sel=None):
    """paper mode inner loop: [R] explicit X·(σ⊙ε_r) MVMs via scan."""
    k, n = sigma.shape
    grng = cfg.grng
    if sel is None:
        sel = g.selections(grng, num, r0)  # [R,16] (layer granularity)

    def body(_, xs):
        sel_r, r_abs = xs
        currents = g.device_currents_grid(grng, k, n)  # fused by XLA
        raw = jnp.einsum("knj,j->kn", currents, sel_r)
        if grng.read_sigma:
            rows = jnp.arange(k, dtype=jnp.uint32)[:, None]
            cols = jnp.arange(n, dtype=jnp.uint32)[None, :]
            raw = raw + g.read_noise_at(grng, rows, cols, r_abs)
        eps_r = ((raw - grng.sum_mean) / grng.sum_std).astype(x.dtype)
        y = x @ (sigma * eps_r)
        return 0, y

    if grng.granularity == "layer":
        r_abs = r0 + jnp.arange(sel.shape[0], dtype=jnp.uint32)
        _, ys = lax.scan(body, 0, (sel, r_abs))
        return ys  # [R, B, N]
    # tile/cell granularities: materialize ε per sample (oracle path).
    def body2(_, eps_r):
        return 0, x @ (sigma * eps_r.astype(x.dtype))
    _, ys = lax.scan(body2, 0, g.eps(grng, k, n, num, r0))
    return ys


def logit_samples_paper(head: dict, x: jnp.ndarray, cfg: BayesHeadConfig,
                        num_samples: int | None = None, sample0: int = 0,
                        sel=None):
    """Faithful R-pass sampling. x: [B, K] -> [R, B, N]."""
    num = num_samples or cfg.num_samples
    y_mu = x @ head["mu_prime"]
    ys = _sigma_eps_mvm(x, head["sigma"], cfg, sample0, num, sel)
    return y_mu[None] + ys


def activation_basis(head: dict, x: jnp.ndarray, cfg: BayesHeadConfig) -> dict:
    """Per-activation rank-16 basis cache: the expensive part of sampling.

    Computes y_mu = X·µ', x_sigma = X·σ and the 16 basis products
    M_j = X·(σ⊙I_j) once for a batch of activations.  After this, ANY
    number of additional samples — including escalations at later
    ``sample0`` offsets — costs only a [R,16]×[16,·] mixing contraction
    (``mix_samples``).  This is the serving engine's per-slot cache: the
    Bayesian-head analogue of a KV cache.

    Returns {"y_mu": [B,N], "x_sigma": [B,N], "m": [B,N,16]}; on a
    degraded chip instance (``cfg.grng.read_sigma > 0``) additionally
    ``x_sigsq = (x²)·(σ²)`` [B,N] — the read-noise projection variance
    ``mix_samples`` needs.  Heads hoisted with ``hoist_tile_n`` carry
    ``sigma_basis_host`` (numpy column chunks): each chunk is streamed
    to the device, contracted, and offloaded straight back, so the
    basis is returned as HOST chunks ``m_host`` (tuple of numpy
    [B, ≤tile_n, 16]) and peak device memory stays K·tile_n·16 — the
    full [B, N, 16] activation basis never exists on device either.
    ``mix_samples``/``update_stats_streamed`` consume ``m_host`` chunk
    by chunk.  This path only exists OUTSIDE jit; under tracing the
    chunks become baked-in constants anyway, so the dense ``m`` concat
    is kept there (chunk-hoisted heads still serve through the jitted
    engines, without the memory saving).
    """
    assert cfg.grng.granularity == "layer", "rank16 requires shared selection"
    sigma = head["sigma"]
    y_mu = x @ head["mu_prime"]                     # [B, N]
    x_sigma = x @ sigma                             # [B, N]
    if "sigma_basis" in head:                       # hoisted at deployment
        m = jnp.einsum("bk,knj->bnj", x,
                       head["sigma_basis"].astype(x.dtype))
    elif "sigma_basis_host" in head:                # tiled/offloaded hoist
        import numpy as np
        if isinstance(x, jax.core.Tracer):
            # Inside jit (e.g. an engine's featurize) the chunks cannot
            # be offloaded back to host — keep the dense on-device
            # concat so chunk-hoisted heads still serve; the memory
            # saving needs the outside-jit path below.
            m = jnp.concatenate(
                [jnp.einsum("bk,knj->bnj", x, jnp.asarray(blk, x.dtype))
                 for blk in head["sigma_basis_host"]], axis=1)
        else:
            m = tuple(
                np.asarray(jnp.einsum("bk,knj->bnj", x,
                                      jnp.asarray(blk, x.dtype)))
                for blk in head["sigma_basis_host"])  # -> host, per chunk
            ab = {"y_mu": y_mu, "x_sigma": x_sigma, "m_host": m}
            if cfg.grng.read_sigma:
                ab["x_sigsq"] = (x * x) @ (sigma * sigma)
            return ab
    else:
        kdim, n = sigma.shape

        def basis_mvm(_, j):
            i_j = g.device_current_j(
                cfg.grng,
                jnp.arange(kdim, dtype=jnp.uint32)[:, None],
                jnp.arange(n, dtype=jnp.uint32)[None, :], j).astype(x.dtype)
            return 0, x @ (sigma * i_j)             # [B, N]

        _, m = lax.scan(basis_mvm, 0, jnp.arange(16))   # [16, B, N]
        m = jnp.moveaxis(m, 0, -1)                      # [B, N, 16]
    ab = {"y_mu": y_mu, "x_sigma": x_sigma, "m": m}
    if cfg.grng.read_sigma:
        ab["x_sigsq"] = (x * x) @ (sigma * sigma)   # [B, N]
    return ab


def _noise_key(sel: jnp.ndarray, sample_idx) -> jnp.ndarray:
    """[R, B|1] uint32 read-noise hash key: the absolute stream indices
    when given, else the packed selection pattern (see mix_samples)."""
    if sample_idx is None:
        pow2 = (jnp.uint32(1) << jnp.arange(16, dtype=jnp.uint32))
        key = (sel.astype(jnp.uint32) * pow2).sum(-1)       # [R] or [R,B]
    else:
        key = jnp.asarray(sample_idx, jnp.uint32)           # [R] or [R,B]
    return key[:, None] if key.ndim == 1 else key


def _mix_block(m, y_mu, x_sigma, x_sigsq, sel, cfg: BayesHeadConfig,
               key, col0: int = 0):
    """[R, B, cn] logit samples for one column block of the basis.

    ``col0``: the block's global column origin — the read-noise hash is
    keyed on GLOBAL (slot, column) coordinates, so chunked mixing
    reproduces the dense draw exactly.
    """
    gstd, gmean = cfg.grng.sum_std, cfg.grng.sum_mean
    if sel.ndim == 2:
        mix = jnp.einsum("rj,bnj->rbn", sel.astype(m.dtype), m)
    else:
        mix = jnp.einsum("rbj,bnj->rbn", sel.astype(m.dtype), m)
    out = mix - gmean * x_sigma[None]
    if cfg.grng.read_sigma:
        from repro.core.hashing import gaussianish, hash3
        b, cn = x_sigma.shape
        h = hash3(key[..., None],                           # [R,(B|1),1]
                  jnp.arange(b, dtype=jnp.uint32)[None, :, None],
                  col0 + jnp.arange(cn, dtype=jnp.uint32)[None, None, :],
                  cfg.grng.noise_seed)                      # [R, B, cn]
        sigma_read = cfg.grng.read_sigma * jnp.sqrt(
            jnp.maximum(x_sigsq, 0.0)).astype(out.dtype)
        out = out + gaussianish(h).astype(out.dtype) * sigma_read[None]
    return y_mu[None] + out / gstd


def basis_blocks(abasis: dict):
    """Yield (m_block, col0, col1) over an activation basis — a single
    full-width block for dense ``m``, the streamed host chunks for
    ``m_host`` (each materialized on device only for its turn)."""
    if "m_host" in abasis:
        c0 = 0
        for blk in abasis["m_host"]:
            m = jnp.asarray(blk)
            yield m, c0, c0 + m.shape[1]
            c0 += m.shape[1]
    else:
        yield abasis["m"], 0, abasis["m"].shape[1]


def mix_samples(abasis: dict, sel: jnp.ndarray, cfg: BayesHeadConfig,
                sample_idx: jnp.ndarray | None = None):
    """Turn selection vectors into logit samples against a basis cache.

    sel: [R, 16] (shared stream) or [R, B, 16] (per-slot streams — a
    serving pool whose slots sit at different stream offsets).
    Returns [R, B, N] samples, exact w.r.t. the paper dataflow.

    A chunk-hoisted basis (``m_host``, see ``activation_basis``) is
    mixed chunk by chunk with the mixing folded into the chunk loop —
    peak device memory holds one [B, tile_n, 16] chunk plus the
    [R, B, N] samples, never the full basis (call outside jit).  For
    sample-free consumers, ``serving.adaptive.update_stats_streamed``
    avoids the [R, B, N] term as well.

    On a degraded instance (``cfg.grng.read_sigma > 0``) each sample
    additionally carries the projected cycle-to-cycle read noise,
    N(0, read_sigma²·x_sigsq) per logit, hash-keyed by ``sample_idx`` —
    the absolute selection-stream indices of ``sel`` ([R] or [R, B],
    what ``adaptive.stream_indices`` computes), so every stream
    position draws fresh noise and re-reading a region reproduces it.
    Without ``sample_idx`` the key falls back to the packed selection
    pattern: still deterministic, but two positions that collide on the
    same 8-of-16 pattern then share their noise draw (~1.5% per
    20-sample decision) — prefer passing the indices.
    """
    key = (_noise_key(sel, sample_idx) if cfg.grng.read_sigma else None)
    y_mu, x_sigma = abasis["y_mu"], abasis["x_sigma"]
    x_sigsq = abasis.get("x_sigsq")
    parts = [
        _mix_block(m, y_mu[:, c0:c1], x_sigma[:, c0:c1],
                   None if x_sigsq is None else x_sigsq[:, c0:c1],
                   sel, cfg, key, col0=c0)
        for m, c0, c1 in basis_blocks(abasis)]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, -1)


def logit_samples_rank16(head: dict, x: jnp.ndarray, cfg: BayesHeadConfig,
                         num_samples: int | None = None, sample0: int = 0,
                         sel=None):
    """Exact rank-16 sampling: 16 basis MVMs + tiny mixing matmul.

    Requires layer-granularity shared selection (the hardware default).
    Produces samples bit-identical in distribution to ``paper`` mode.
    With a ``sigma_basis``-hoisted head (prepare_serving_head with
    ``hoist_basis``) the device-current hashes are never recomputed.
    """
    assert cfg.grng.granularity == "layer", "rank16 requires shared selection"
    num = num_samples or cfg.num_samples
    if sel is None:
        sel = g.selections(cfg.grng, num, sample0)  # [R, 16]
    idx = sample0 + jnp.arange(sel.shape[0], dtype=jnp.uint32)
    return mix_samples(activation_basis(head, x, cfg), sel, cfg,
                       sample_idx=idx)


def logit_moments(head: dict, x: jnp.ndarray, cfg: BayesHeadConfig):
    """Analytic (mean, variance) of the logits. x: [B,K] -> two [B,N].

    Per-cell ε variance under uniform 8-of-16 subset selection of fixed
    currents is hypergeometric:
        Var[ε(k,n)] = k(1−k/n)·(n/(n−1))·var_j(I(k,n,j)) / ĝ²
    Cross-cell covariance (rank-16, from shared selection) is dropped —
    documented approximation.
    """
    kdim, n = head["sigma"].shape
    grng = cfg.grng
    rows = jnp.arange(kdim, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(n, dtype=jnp.uint32)[None, :]
    currents = g.device_currents(grng, rows, cols)          # [K,N,16]
    var_i = currents.var(axis=-1)
    ksel, nd = grng.k_select, grng.n_devices
    var_eps = ((ksel * (1 - ksel / nd) * (nd / (nd - 1)) * var_i
                + grng.read_sigma**2)
               / grng.sum_std**2).astype(x.dtype)
    mean = x @ head["mu_prime"]
    var = (x * x) @ ((head["sigma"] ** 2) * var_eps)
    return mean, var


def logit_samples(head: dict, x: jnp.ndarray, cfg: BayesHeadConfig,
                  num_samples: int | None = None, sample0: int = 0,
                  key: jax.Array | None = None, sel=None):
    """Dispatch on cfg.mode. 'moment' draws diagonal-Gaussian samples
    from the analytic moments (needs ``key``).  ``sel`` [R,16] overrides
    the selection stream (decode loops with traced positions)."""
    if cfg.mode == "paper":
        return logit_samples_paper(head, x, cfg, num_samples, sample0, sel)
    if cfg.mode == "rank16":
        return logit_samples_rank16(head, x, cfg, num_samples, sample0, sel)
    if cfg.mode == "moment":
        num = num_samples or cfg.num_samples
        mean, var = logit_moments(head, x, cfg)
        if key is None:
            key = jax.random.PRNGKey(sample0)
        z = jax.random.normal(key, (num,) + mean.shape, dtype=mean.dtype)
        return mean[None] + jnp.sqrt(jnp.maximum(var, 0.0))[None] * z
    raise ValueError(cfg.mode)
