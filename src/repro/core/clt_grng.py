"""Write-free CLT-GRNG — the paper's core contribution, in JAX.

Each Bayesian weight cell (k, n) owns 16 *virtual devices* whose
currents are fixed deterministic hashes of the coordinate (see
core/hashing.py — the TPU analogue of "programmed once, never
rewritten").  A sample of the standard-normal surrogate ε is produced by
summing the currents of 8 of the 16 devices, the subset chosen by the
LFSR + swapper network of core/lfsr.py:

    raw(k, n, r)  =  Σ_j  s_r[j] · I(k, n, j)
    ε(k, n, r)    =  (raw − sum_mean) / sum_std

Device model (paper Fig. 5/6: minimum-size FeFETs are *binary* with
abrupt switching plus analog variation):

    I(k,n,j) = i_lo + Δi · b(k,n,j) + γ · v(k,n,j)      [µA]

with b a hash bit (high-/low-V_t state, p=1/2) and v ≈ N(0,1) from
popcount-CLT.  Defaults are fitted to the paper's measured Fig. 9
statistics: 8-device sum mean 10.1 µA, SD 0.993 µA
(E[raw] = 8(i_lo + Δi/2),  Var[raw] ≈ 8(Δi²/4 + γ²)).

Selection granularity mirrors the hardware's shared selection lines:
  * 'layer' — one selection vector per sample shared by every cell in
    the layer (macro-level sharing; enables the exact rank-16 sampling
    path in core/sampling.py).
  * 'tile'  — one selector per 64×64 tile (per-macro sharing).
  * 'cell'  — idealized independent selections (ablation).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import lfsr as lfsr_mod
from repro.core.hashing import gaussianish, hash3, uniform_bit


@dataclasses.dataclass(frozen=True)
class GRNGConfig:
    n_devices: int = 16
    k_select: int = 8
    # Device current model [µA] — fitted to paper Fig. 9 statistics.
    i_lo: float = 0.926
    delta_i: float = 0.673
    gamma: float = 0.100
    # Fig. 9 measured sum statistics used for standardization.
    sum_mean: float = 10.1
    sum_std: float = 0.993
    # Entropy source seeds ("programming" seed / selector seed).
    seed: int = 0xC1A0
    lfsr_seed: int = 0xACE1
    # Selection sharing: 'layer' | 'tile' | 'cell'.
    granularity: str = "layer"
    tile: int = 64
    # ------------------------------------------------------------------
    # Nonideality knobs (repro/hw digital twin; defaults = ideal chip).
    # Per-chip Vth variation and temperature drift need NO extra fields:
    # a chip instance re-draws the programmed device states through a
    # chip-specific ``seed`` and folds uniform current drift into
    # (i_lo, delta_i, gamma) — see hw/device.py / hw/instance.py.
    # Cycle-to-cycle read noise cannot be folded into the static device
    # model: each *read* of a cell's 8-device sum carries fresh additive
    # noise of ``read_sigma`` µA RMS, hash-keyed by the absolute sample
    # index so escalation at later sample0 offsets still extends the
    # stream bit-exactly (serving/adaptive.py relies on this).
    # ------------------------------------------------------------------
    read_sigma: float = 0.0
    noise_seed: int = 0x51CE
    # Aging imprint (hw/aging.py): the accumulated per-DEVICE Vth walk
    # of a field-aged die — an additive hash-frozen Gaussian per device
    # keyed by ``imprint_seed``, magnitude ``imprint`` µA RMS.  Unlike
    # the uniform drift axis this cannot fold into (i_lo, delta_i,
    # gamma): it decorrelates every cell's mean offset from its
    # calibration-time value, which is exactly why aged dies need
    # recalibration (hw/redeploy.py).  Zero = fresh die; the term is
    # compiled out and every existing path is bit-identical.
    imprint: float = 0.0
    imprint_seed: int = 0x1A9E

    def analytic_sum_stats(self) -> tuple[float, float]:
        """Closed-form mean/SD of the 8-device sum under the device model
        (including cycle-to-cycle read noise)."""
        mean = self.k_select * (self.i_lo + 0.5 * self.delta_i)
        var = (self.k_select * (self.delta_i**2 / 4.0 + self.gamma**2
                                + self.imprint**2)
               + self.read_sigma**2)
        return mean, float(np.sqrt(var))


def device_currents(cfg: GRNGConfig, rows: jnp.ndarray, cols: jnp.ndarray) -> jnp.ndarray:
    """Virtual device currents I(k, n, j) for given global coordinates.

    rows: [...]/int32 global row ids; cols broadcastable. Returns
    float32 [..., n_devices].  Pure function of coordinates — fusable,
    shardable, no storage.
    """
    j = jnp.arange(cfg.n_devices, dtype=jnp.uint32)
    h = hash3(rows[..., None], cols[..., None], j, cfg.seed)
    b = uniform_bit(h)
    v = gaussianish(h)
    out = cfg.i_lo + cfg.delta_i * b + cfg.gamma * v
    if cfg.imprint:
        hi = hash3(rows[..., None], cols[..., None], j, cfg.imprint_seed)
        out = out + cfg.imprint * gaussianish(hi)
    return out


def device_current_j(cfg: GRNGConfig, rows: jnp.ndarray, cols: jnp.ndarray,
                     j) -> jnp.ndarray:
    """Single virtual-device current I(k, n, j) — one hash per cell.

    The scan-friendly slice of ``device_currents`` (used by the rank-16
    basis construction in core/sampling.py, which visits devices one at
    a time to bound peak memory)."""
    h = hash3(rows, cols, jnp.asarray(j, jnp.uint32), cfg.seed)
    out = cfg.i_lo + cfg.delta_i * uniform_bit(h) + cfg.gamma * gaussianish(h)
    if cfg.imprint:
        hi = hash3(rows, cols, jnp.asarray(j, jnp.uint32), cfg.imprint_seed)
        out = out + cfg.imprint * gaussianish(hi)
    return out


def device_currents_grid(cfg: GRNGConfig, n_rows: int, n_cols: int,
                         row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """[n_rows, n_cols, n_devices] device currents for a coordinate block."""
    rows = row0 + jnp.arange(n_rows, dtype=jnp.uint32)[:, None]
    cols = col0 + jnp.arange(n_cols, dtype=jnp.uint32)[None, :]
    return device_currents(cfg, rows, cols)


def selections(cfg: GRNGConfig, num_samples: int, sample0: int = 0,
               n_rows: int | None = None, n_cols: int | None = None) -> jnp.ndarray:
    """Selection vectors for ``num_samples`` consecutive samples.

    Returns:
      granularity 'layer': [R, 16]
      granularity 'tile' : [R, ceil(n_rows/tile), ceil(n_cols/tile), 16]
    ('cell' is handled inline by ``eps`` since it has no shared stream.)
    """
    if cfg.granularity == "layer":
        states = lfsr_mod.lfsr_states(cfg.lfsr_seed, sample0 + num_samples)
        return lfsr_mod.swapper_select(states[sample0:])
    if cfg.granularity == "tile":
        assert n_rows is not None and n_cols is not None
        nt_r = -(-n_rows // cfg.tile)
        nt_c = -(-n_cols // cfg.tile)
        seeds = lfsr_mod.tile_seeds(cfg.lfsr_seed, nt_r * nt_c).reshape(nt_r, nt_c)
        states = jax.vmap(
            jax.vmap(lambda s: lfsr_mod.lfsr_states(s, sample0 + num_samples))
        )(seeds)  # [nt_r, nt_c, R0+R]
        states = jnp.moveaxis(states[..., sample0:], -1, 0)  # [R, nt_r, nt_c]
        return lfsr_mod.swapper_select(states)
    raise ValueError(f"selections() not defined for granularity={cfg.granularity}")


def _expand_tile_sel(sel_t: jnp.ndarray, n_rows: int, n_cols: int, tile: int) -> jnp.ndarray:
    """[.., nt_r, nt_c, 16] -> [.., n_rows, n_cols, 16] by tile broadcast."""
    s = jnp.repeat(sel_t, tile, axis=-3)[..., :n_rows, :, :]
    s = jnp.repeat(s, tile, axis=-2)[..., :, :n_cols, :]
    return s


def read_noise_at(cfg: GRNGConfig, rows: jnp.ndarray, cols: jnp.ndarray,
                  r_abs) -> jnp.ndarray:
    """Read noise for broadcastable (cell, absolute-sample) coordinates."""
    h = hash3(rows, cols, jnp.asarray(r_abs, jnp.uint32), cfg.noise_seed)
    return cfg.read_sigma * gaussianish(h)


def read_noise(cfg: GRNGConfig, n_rows: int, n_cols: int, num_samples: int,
               sample0: int = 0, row0: int = 0,
               col0: int = 0) -> jnp.ndarray:
    """Cycle-to-cycle read noise on the raw 8-device sum (µA).

    -> [R, n_rows, n_cols].  Hash-keyed by (cell, ABSOLUTE sample index)
    so a draw at ``sample0 = s`` reproduces sample ``s`` of a larger
    draw — read noise never breaks stream extension.  Zero-mean, so the
    static offset compensation (``cell_mean_offset``) is unaffected.
    """
    rows = row0 + jnp.arange(n_rows, dtype=jnp.uint32)[None, :, None]
    cols = col0 + jnp.arange(n_cols, dtype=jnp.uint32)[None, None, :]
    r_abs = sample0 + jnp.arange(num_samples, dtype=jnp.uint32)[:, None, None]
    return read_noise_at(cfg, rows, cols, r_abs)


def raw_sums(cfg: GRNGConfig, n_rows: int, n_cols: int, num_samples: int,
             sample0: int = 0, row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """Un-standardized subset sums. -> [R, n_rows, n_cols] (µA)."""
    currents = device_currents_grid(cfg, n_rows, n_cols, row0, col0)  # [K,N,16]
    if cfg.granularity == "layer":
        sel = selections(cfg, num_samples, sample0)  # [R,16]
        raw = jnp.einsum("rj,knj->rkn", sel, currents)
    elif cfg.granularity == "tile":
        sel = selections(cfg, num_samples, sample0, n_rows, n_cols)  # [R,t,t,16]
        sel_full = _expand_tile_sel(sel, n_rows, n_cols, cfg.tile)  # [R,K,N,16]
        raw = jnp.einsum("rknj,knj->rkn", sel_full, currents)
    elif cfg.granularity == "cell":
        rows = row0 + jnp.arange(n_rows, dtype=jnp.uint32)[:, None]
        cols = col0 + jnp.arange(n_cols, dtype=jnp.uint32)[None, :]

        def one_sample(r):
            sel = lfsr_mod.cell_selections(rows, cols, r, cfg.lfsr_seed)  # [K,N,16]
            return jnp.einsum("knj,knj->kn", sel, currents)

        rs = sample0 + jnp.arange(num_samples, dtype=jnp.uint32)
        raw = jax.vmap(one_sample)(rs)
    else:
        raise ValueError(cfg.granularity)
    if cfg.read_sigma:
        raw = raw + read_noise(cfg, n_rows, n_cols, num_samples, sample0,
                               row0, col0)
    return raw


def eps(cfg: GRNGConfig, n_rows: int, n_cols: int, num_samples: int,
        sample0: int = 0, row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """Standardized ε samples. -> [R, n_rows, n_cols]."""
    raw = raw_sums(cfg, n_rows, n_cols, num_samples, sample0, row0, col0)
    return (raw - cfg.sum_mean) / cfg.sum_std


def cell_mean_offset(cfg: GRNGConfig, n_rows: int, n_cols: int,
                     row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """Exact static per-cell offset Δε (paper §III-B1), closed form.

    The swapper network selects every position with probability 1/2
    under a uniform control stream (verified in tests), so
    E_sel[raw] = (k/n)·Σ_j I_j = Σ_j I_j / 2.  The hardware must
    *measure* this with N samples (54 + 458N pJ); the virtual-device
    formulation lets us evaluate it exactly — and also mimic the
    measured variant, see ``estimate_mean_offset``.
    """
    currents = device_currents_grid(cfg, n_rows, n_cols, row0, col0)
    expect_raw = currents.sum(-1) * (cfg.k_select / cfg.n_devices)
    return (expect_raw - cfg.sum_mean) / cfg.sum_std


def estimate_mean_offset(cfg: GRNGConfig, n_rows: int, n_cols: int,
                         num_samples: int, sample0: int = 0) -> jnp.ndarray:
    """N-sample estimate of Δε — the paper's measurement procedure."""
    return eps(cfg, n_rows, n_cols, num_samples, sample0).mean(axis=0)


@partial(jax.jit, static_argnums=(0, 1, 2))
def calibrate(cfg: GRNGConfig, n_cells: int = 4096, num_samples: int = 64):
    """Empirically estimate (sum_mean, sum_std) across cells × samples.

    One-time calibration, mirroring the paper's Fig. 9 measurement.
    Returns (mean, std) of raw sums in µA.
    """
    raw = raw_sums(cfg, n_cells, 1, num_samples)
    return raw.mean(), raw.std()


def distribution_sample(cfg: GRNGConfig, n_cells: int, num_samples: int) -> np.ndarray:
    """Flat array of ε draws for distribution-quality analysis (Fig. 9)."""
    e = eps(cfg, n_cells, 1, num_samples)
    return np.asarray(e).reshape(-1)
