"""16-bit LFSR + two-layer swapper selection network (paper Fig. 10).

The hardware drives every CLT-GRNG cell in a tile from ONE 16-bit LFSR
through two layers of wire swappers.  A fixed input vector containing
exactly eight 1s is permuted by the swappers, so exactly 8 of the 16
FeFETs are enabled each cycle regardless of the LFSR state.

  * layer 1: swap adjacent bits (2n, 2n+1) when control c1[n] is set
  * layer 2: swap bit n with bit n+8 when control c2[n] is set
  * controls: low 8 LFSR bits -> layer 1, high 8 bits -> layer 2

We use the alternating fixed input [1,0,1,0,...] so that layer 1 is
meaningful (each adjacent pair holds exactly one 1; with the all-ones-
first layout layer 1 would be a no-op).  The permutation network
preserves the multiset, so the exactly-8-selected invariant holds by
construction — property-tested in tests/test_lfsr.py.

The LFSR is a Galois-form maximal-length x^16+x^14+x^13+x^11+1
(feedback mask 0xB400), period 65535 for any nonzero seed.

Note on reachability: the two swapper layers can reach at most 2^16
selection patterns, a structured subset of the C(16,8)=12870 possible
8-of-16 subsets.  ``enumerate_reachable()`` measures the actual count —
this is an analysis the paper does not report, surfaced in
benchmarks/fig10_selection.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.hashing import mix32

LFSR_MASK = 0xB400  # taps 16,14,13,11 (maximal length)
FIXED_INPUT = tuple([1, 0] * 8)  # eight 1s, alternating


def lfsr_next(state: jnp.ndarray) -> jnp.ndarray:
    """One Galois LFSR step. ``state`` is uint32 holding a 16-bit value."""
    state = jnp.asarray(state, jnp.uint32)
    lsb = state & jnp.uint32(1)
    shifted = state >> jnp.uint32(1)
    return jnp.where(lsb == 1, shifted ^ jnp.uint32(LFSR_MASK), shifted)


def lfsr_states(seed: int | jnp.ndarray, num: int) -> jnp.ndarray:
    """Generate ``num`` successive LFSR states from ``seed``. -> [num] u32."""
    seed = jnp.asarray(seed, jnp.uint32) & jnp.uint32(0xFFFF)
    seed = jnp.where(seed == 0, jnp.uint32(0xACE1), seed)  # 0 is a fixed point

    def step(s, _):
        nxt = lfsr_next(s)
        return nxt, s

    _, states = lax.scan(step, seed, None, length=num)
    return states


def swapper_select(state: jnp.ndarray) -> jnp.ndarray:
    """Map LFSR state(s) -> selection vector(s) in {0,1}^16, exactly 8 ones.

    ``state``: uint32 array of any shape S. Returns float32 [*S, 16].
    Pure arithmetic (no gathers) so it vectorizes on the VPU and is
    reproduced verbatim inside the Pallas kernels.
    """
    state = jnp.asarray(state, jnp.uint32)
    c1 = ((state[..., None] >> jnp.arange(8, dtype=jnp.uint32)) & 1).astype(
        jnp.float32
    )  # [*S, 8]
    c2 = ((state[..., None] >> (8 + jnp.arange(8, dtype=jnp.uint32))) & 1).astype(
        jnp.float32
    )  # [*S, 8]

    v = jnp.asarray(FIXED_INPUT, jnp.float32)
    v = jnp.broadcast_to(v, state.shape + (16,))

    # Layer 1: swap within adjacent pairs (2n, 2n+1).
    pairs = v.reshape(state.shape + (8, 2))
    a, b = pairs[..., 0], pairs[..., 1]
    a1 = a + c1 * (b - a)
    b1 = b + c1 * (a - b)
    v1 = jnp.stack([a1, b1], axis=-1).reshape(state.shape + (16,))

    # Layer 2: swap bit n with bit n+8.
    lo, hi = v1[..., :8], v1[..., 8:]
    lo2 = lo + c2 * (hi - lo)
    hi2 = hi + c2 * (lo - hi)
    return jnp.concatenate([lo2, hi2], axis=-1)


def selection_stream(seed: int, num: int) -> jnp.ndarray:
    """``num`` successive selection vectors. -> float32 [num, 16]."""
    return swapper_select(lfsr_states(seed, num))


def tile_seeds(base_seed: int, n_tiles: int) -> jnp.ndarray:
    """Derive per-tile LFSR seeds (hardware: per-macro selector instances)."""
    h = mix32(jnp.arange(n_tiles, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9)
              + jnp.uint32(base_seed))
    s = h & jnp.uint32(0xFFFF)
    return jnp.where(s == 0, jnp.uint32(0xACE1), s)


def cell_selections(rows: jnp.ndarray, cols: jnp.ndarray, r, seed) -> jnp.ndarray:
    """Idealized per-cell independent selections (granularity='cell').

    Uses the swapper network with a hash-derived per-(cell, sample) state,
    so the exactly-8 invariant still holds but cells are decorrelated.
    rows/cols broadcast; returns float32 [..., 16].
    """
    from repro.core.hashing import hash3  # local import to avoid cycle

    h = hash3(rows, cols, jnp.asarray(r, jnp.uint32), seed)
    s = h & jnp.uint32(0xFFFF)
    s = jnp.where(s == 0, jnp.uint32(0xACE1), s)
    return swapper_select(s)


def indexed_states(seed, idx: jnp.ndarray) -> jnp.ndarray:
    """Random-access selection states for traced sample indices.

    The hardware streams its LFSR sequentially; for decode loops with a
    *traced* position we need O(1) random access into an equivalent
    stream.  We hash the sample index into a 16-bit state and reuse the
    same swapper network — still write-free, still exactly-8-of-16.
    """
    h = mix32(jnp.asarray(idx, jnp.uint32) * jnp.uint32(0x9E3779B9)
              + jnp.uint32(seed))
    s = h & jnp.uint32(0xFFFF)
    return jnp.where(s == 0, jnp.uint32(0xACE1), s)


def indexed_selections(seed, idx: jnp.ndarray) -> jnp.ndarray:
    """Selection vectors for arbitrary (traced) sample indices. [*,16]."""
    return swapper_select(indexed_states(seed, idx))


def enumerate_reachable() -> tuple[int, jnp.ndarray]:
    """Count distinct selection patterns over all 2^16 LFSR states.

    Returns (count, per-position selection frequency [16]).
    """
    states = jnp.arange(1, 1 << 16, dtype=jnp.uint32)
    sels = swapper_select(states)  # [65535, 16]
    codes = (sels.astype(jnp.uint32) * (jnp.uint32(1) << jnp.arange(16, dtype=jnp.uint32))).sum(
        axis=-1
    )
    count = int(jnp.unique(codes).shape[0])
    freq = sels.mean(axis=0)
    return count, freq
