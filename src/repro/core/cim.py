"""CIM tile execution semantics (paper §IV) in pure JAX.

The physical tile computes a 64×64 MVM in one shot: 8-bit IDAC inputs
drive wordlines, bitline charge is the analog 64-product partial sum,
and a pitch-matched 6-bit SAR ADC digitizes each column.  A large
logical matmul [B,K]×[K,N] therefore decomposes into ceil(K/64) analog
chunks whose partial sums are *individually* quantized to 6 bits before
digital accumulation — that chunked-ADC path is the part of the paper's
numeric behaviour that must be simulated faithfully (it is where
accuracy could be lost, and the paper's §V-B claims it is not).

This module is the pure-jnp oracle; kernels/cim_mvm.py implements the
same semantics as a blocked Pallas TPU kernel.  Intended for the SAR
application model and for tests; LM-scale trunks run in bf16 unless
``cim`` execution is explicitly requested.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as q


def cim_matmul(x: jnp.ndarray, w: jnp.ndarray, cfg: q.QuantConfig) -> jnp.ndarray:
    """Quantized CIM matmul with per-64-chunk 6-bit ADC.

    x: [B, K] activations, w: [K, N] weights. Returns [B, N] float32.
    """
    if not cfg.enabled:
        return x @ w

    xq, _ = q.quantize_input(x, cfg)
    wq, _ = q.quantize_mu(w, cfg)

    k = x.shape[-1]
    chunk = cfg.chunk
    pad = (-k) % chunk
    if pad:
        xq = jnp.pad(xq, ((0, 0), (0, pad)))
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
    kc = xq.shape[-1] // chunk

    xb = xq.reshape(x.shape[0], kc, chunk)
    wb = wq.reshape(kc, chunk, w.shape[1])
    # Analog per-chunk partial sums: [B, kc, N].
    psums = jnp.einsum("bkc,kcn->bkn", xb, wb)

    # ADC full-scale from the MEASURED partial-sum RMS (the hardware's
    # one-time range calibration).  The independence model
    # (√chunk·rms(x)·rms(w)) breaks for ReLU-correlated activations and
    # zero-padded im2col chunks — measured: 2.7× under-scale ⇒ heavy
    # clipping ⇒ −14% SAR accuracy.  Data calibration restores it.
    fs = cfg.adc_clip_sigmas * jnp.sqrt(
        jnp.mean(jax.lax.stop_gradient(psums) ** 2) + 1e-12)
    psums = q.adc_quantize(psums, fs, cfg)
    return psums.sum(axis=1)


def cim_dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None,
              cfg: q.QuantConfig) -> jnp.ndarray:
    """Dense layer through the CIM path; leading dims of x are batch."""
    lead = x.shape[:-1]
    y = cim_matmul(x.reshape(-1, x.shape[-1]), w, cfg)
    y = y.reshape(*lead, w.shape[-1])
    if b is not None:
        y = y + b
    return y


def adc_snr_db(x: jnp.ndarray, w: jnp.ndarray, cfg: q.QuantConfig) -> jnp.ndarray:
    """SNR of the CIM path vs exact matmul — used in quantization tests."""
    exact = x @ w
    approx = cim_matmul(x, w, cfg)
    err = approx - exact
    return 10.0 * jnp.log10(jnp.mean(exact**2) / (jnp.mean(err**2) + 1e-20))
