"""repro.core — the paper's contribution as composable JAX modules.

Layout:
  hashing.py      counter-based entropy (the write-free substrate)
  lfsr.py         16-bit LFSR + swapper selection network (Fig. 10)
  clt_grng.py     subset-sum Gaussian sampling (Fig. 8/9)
  offset.py       static offset compensation (§III-B1)
  quant.py        8b µ / 4b σ / 8b IDAC / 6b ADC numeric path (§IV)
  cim.py          64-deep chunked-ADC CIM matmul oracle
  bayes_layer.py  variational training layer (Bayes-by-backprop)
  sampling.py     serving modes: paper | rank16 | moment
  uncertainty.py  AURC / AECE / AMCE / risk-coverage (§V-B2)
  energy.py       analytic hardware model (Table I, §V-A)
"""

from repro.core.clt_grng import GRNGConfig
from repro.core.quant import QuantConfig
from repro.core.sampling import BayesHeadConfig
from repro.core.bayes_layer import BayesDenseConfig

__all__ = ["GRNGConfig", "QuantConfig", "BayesHeadConfig", "BayesDenseConfig"]
