"""Analytic hardware energy/latency/area model (paper §V-A, Table I).

TPU silicon cannot reproduce femtojoule analog measurements, so the
paper's energy claims are reproduced *analytically* from its own
component constants, with every derived headline number cross-checked
against the printed value in benchmarks/table1_comparison.py and
benchmarks/sec5a_energy.py.  Quantities the paper states directly are
tagged PAPER; quantities we deduce to make the numbers mutually
consistent are tagged DEDUCED (with derivation).

Units: joules, seconds, mm², unless suffixed.
"""

from __future__ import annotations

import dataclasses
import math

# ----------------------------------------------------------------------
# PAPER constants (§III, §V-A, Table I)
# ----------------------------------------------------------------------
GRNG_ENERGY_PER_SAMPLE = 640e-18        # PAPER: 640 aJ/sample incl. selection
GRNG_SELECTION_SHARE = 134e-18          # PAPER: amortized selection logic
SELECTION_BLOCK_ENERGY_PER_CYCLE = 550e-15  # PAPER: global selector, 550 fJ
TILE_MVM_ENERGY = 688e-12               # PAPER: full-tile MVM, worst case
SIGMA_MVM_ENERGY = 230e-12              # PAPER: σε-subarray-only MVM
ADC_READ_ENERGY_SHARE = 0.99            # PAPER: ADCs = 99 % of read energy
GRNG_TILE_ENERGY_SHARE = 0.004          # PAPER: GRNG = 0.4 % of tile energy
GRNG_SIGMA_ENERGY_SHARE = 0.007         # PAPER: 0.7 % of σε-only energy
ADC_EFF_PER_CONV_STEP = 14e-15          # PAPER: 14 fJ/conv-step, 6-bit SAR
WRITE_ENERGY_MU = 92.7e-12              # PAPER: µ subarray write @4.0 V
WRITE_ENERGY_SIGMA = 46.3e-12           # PAPER: σε subarray write
TILE_AREA_MM2 = 0.0964                  # PAPER
SIGMA_SUBARRAY_AREA_SHARE = 0.601       # PAPER: σε subarray share of tile
SIGMA_BITCELL_AREA_SHARE = 0.631        # PAPER: bitcells within σε subarray
GRNG_CELL_AREA_SHARE = 0.361            # PAPER: GRNG cells within σε subarray
MU_CELL_AREA_SHARE = 0.102              # PAPER: µ cells within µ subarray
GRNG_AREA_UM2 = 5.11                    # PAPER: Table I
TILE_EFFICIENCY_TOPS_W = 17.8           # PAPER: Table I
COMPUTE_DENSITY_TOPS_MM2 = 1.27         # PAPER: Table I
EFFICIENCY_DENSITY = 185.0              # PAPER: title, TOPS/W/mm²
GRNG_THROUGHPUT_GSAS = 40.96            # PAPER: Table I
CLOCK_HZ = 100e6                        # PAPER: both subarrays at 100 MHz
TILE_DIM = 64                           # PAPER: 64×64 subarrays
DIGITAL_BNN_OVERHEAD_PER_R = 6.2        # PAPER: 6.2·R× vs INT8 deterministic [20]
OFFSET_COMP_E0, OFFSET_COMP_E1 = 54e-12, 458e-12    # PAPER: 54 + 458·N pJ
OFFSET_COMP_T0, OFFSET_COMP_T1 = 12.8e-6, 0.64e-6   # PAPER: 12.8 + 0.64·N µs
ENDURANCE_CYCLES_OPTIMISTIC = 1e12      # PAPER: generous FeFET endurance
RANGE_COLLAPSE_CYCLES = 30_000          # PAPER: 50 % output-range collapse
FEFET_WRITE_TIME = 100e-9               # PAPER: 100 ns write
SOTA_GRNG_ENERGY = 360e-15              # PAPER: [12], 360 fJ/Sa -> 560× claim

# Paper §V-B deployment (YOLO26n + Bayesian last layer)
DEPLOY_BAYES_TILES = 24                 # PAPER
DEPLOY_MU_SUBARRAYS = 1659              # PAPER
DEPLOY_AREA_MM2 = 76.0                  # PAPER
DEPLOY_ENERGY_J = 3.70e-3               # PAPER: end-to-end macro energy
DEPLOY_LATENCY_S = 13.8e-3              # PAPER: 72.2 FPS
DEPLOY_POWER_24FPS_W = 88.7e-3          # PAPER
DEPLOY_R = 20                           # PAPER: samples per inference

# ----------------------------------------------------------------------
# DEDUCED constants (derivations in comments; validated in benchmarks)
# ----------------------------------------------------------------------
# GRNG throughput 40.96 GSa/s over 64×64=4096 concurrent cells implies a
# 100 ns sample period (10 cycles @ 100 MHz — the SAR conversion pipeline):
#     4096 cells / 100 ns = 40.96 GSa/s.
GRNG_SAMPLE_PERIOD = TILE_DIM * TILE_DIM / (GRNG_THROUGHPUT_GSAS * 1e9)
# Compute density 1.27 TOPS/mm² over 2 subarrays × 2·64² ops implies an
# effective MVM latency of ~134 ns (ADC + accumulation pipeline):
#     16384 ops / (1.27e12 ops/s/mm² × 0.0964 mm²) = 133.8 ns.
TILE_OPS_PER_MVM = 2 * 2 * TILE_DIM * TILE_DIM   # both subarrays, MAC=2 ops
MVM_LATENCY = TILE_OPS_PER_MVM / (COMPUTE_DENSITY_TOPS_MM2 * 1e12 * TILE_AREA_MM2)


# ----------------------------------------------------------------------
# Derived / cross-checked quantities
# ----------------------------------------------------------------------
def tile_efficiency_tops_w() -> float:
    """2·64² MACs in each subarray per MVM over the measured energies.

    (688 + 230) pJ for a concurrent µ + σε MVM -> 17.8 TOPS/W (Table I).
    """
    return TILE_OPS_PER_MVM / (TILE_MVM_ENERGY + SIGMA_MVM_ENERGY) / 1e12


def efficiency_density() -> float:
    """TOPS/W/mm² headline: tile efficiency / tile area ≈ 185."""
    return tile_efficiency_tops_w() / TILE_AREA_MM2


def grng_throughput_gsas() -> float:
    return TILE_DIM * TILE_DIM / GRNG_SAMPLE_PERIOD / 1e9


def grng_energy_improvement() -> float:
    """vs SOTA BNN GRNG [12]: 360 fJ / 640 aJ = 562×."""
    return SOTA_GRNG_ENERGY / GRNG_ENERGY_PER_SAMPLE


def adc_energy_per_mvm(bits: int = 6, columns: int = TILE_DIM) -> float:
    """SAR ADC energy: 14 fJ/conv-step × 2^bits steps × columns."""
    return ADC_EFF_PER_CONV_STEP * (2**bits) * columns


def offset_compensation_cost(n_samples: int) -> tuple[float, float]:
    """(energy J, time s) of §III-B1 calibration with N samples."""
    return (OFFSET_COMP_E0 + OFFSET_COMP_E1 * n_samples,
            OFFSET_COMP_T0 + OFFSET_COMP_T1 * n_samples)


def endurance_hours(write_rate_hz: float,
                    endurance_cycles: float = ENDURANCE_CYCLES_OPTIMISTIC) -> float:
    """Lifetime of a REWRITE-based GRNG (paper §III-B: ~30 h at 10 MHz)."""
    return endurance_cycles / write_rate_hz / 3600.0


def writefree_lifetime_hours() -> float:
    return math.inf  # the point of the paper


# ----------------------------------------------------------------------
# Deployment model: map a network onto tiles (paper §V-B1)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerShape:
    d_in: int
    d_out: int
    bayesian: bool = False


def tiles_for_layer(l: LayerShape) -> int:
    return math.ceil(l.d_in / TILE_DIM) * math.ceil(l.d_out / TILE_DIM)


def inference_energy(layers: list[LayerShape], r_samples: int = DEPLOY_R,
                     batch: int = 1) -> dict:
    """Analytic energy/latency for one batched inference.

    Deterministic layers: one µ-subarray MVM per tile per input.
    Bayesian layers: one µ MVM + r σε MVMs per tile per input (the
    σε subarray re-samples; X·µ is computed once — paper §IV).
    """
    e_det = e_bayes = 0.0
    t_serial = 0.0
    n_grng_samples = 0
    for l in layers:
        nt = tiles_for_layer(l)
        if l.bayesian:
            e_bayes += batch * nt * (TILE_MVM_ENERGY + r_samples * SIGMA_MVM_ENERGY)
            t_serial += (1 + r_samples) * MVM_LATENCY
            n_grng_samples += batch * nt * TILE_DIM * TILE_DIM * r_samples
        else:
            e_det += batch * nt * TILE_MVM_ENERGY
            t_serial += MVM_LATENCY
    total = e_det + e_bayes
    return {
        "energy_J": total,
        "energy_det_J": e_det,
        "energy_bayes_J": e_bayes,
        "latency_s": t_serial,           # tiles within a layer are parallel
        "grng_samples": n_grng_samples,
        "grng_energy_J": n_grng_samples * GRNG_ENERGY_PER_SAMPLE,
    }


def grid_inference_energy(*, n_det_tiles: int, n_bayes_tiles: int,
                          r_samples: int = DEPLOY_R, batch: int = 1,
                          n_passes: int = 1, n_bayes_passes: int = 1,
                          physical_tiles: int | None = None,
                          utilization: float = 1.0,
                          r_latency: int | None = None) -> dict:
    """Tile-compiler-aware energy/latency/area (hw/tilemap.py reports).

    Unlike ``inference_energy`` (which counts *logical* tiles per
    layer), this takes the compiler's placed-block counts, so padding
    waste inside partially-filled tiles is charged — a placed block
    burns a full tile MVM regardless of how many cells it maps.  Passes
    serialize: a time-multiplexed network pays one MVM latency per pass
    plus ``r_latency`` serial σε re-reads for every pass containing
    Bayesian blocks (``r_latency`` < r_samples when the compiler
    replicated Bayesian blocks into free tiles: the R samples split
    across concurrent replicas, same total energy, shorter serial
    chain).  Area is the *physical* tiles allocated; the headline
    TOPS/W/mm² scales by the compiler's utilization — the deployed
    number, vs Table I's ideal 185.
    """
    e_det = batch * n_det_tiles * TILE_MVM_ENERGY
    e_bayes = batch * n_bayes_tiles * (
        TILE_MVM_ENERGY + r_samples * SIGMA_MVM_ENERGY)
    grng_samples = batch * n_bayes_tiles * TILE_DIM**2 * r_samples
    phys = (physical_tiles if physical_tiles is not None
            else n_det_tiles + n_bayes_tiles)
    r_lat = r_samples if r_latency is None else r_latency
    latency = (n_passes + r_lat * n_bayes_passes) * MVM_LATENCY
    return {
        "energy_J": e_det + e_bayes,
        "energy_det_J": e_det,
        "energy_bayes_J": e_bayes,
        "grng_samples": grng_samples,
        "grng_energy_J": grng_samples * GRNG_ENERGY_PER_SAMPLE,
        "latency_s": latency,
        "area_mm2": phys * TILE_AREA_MM2,
        "utilization": utilization,
        "tops_w_mm2_effective": efficiency_density() * utilization,
    }


def digital_baseline_energy(layers: list[LayerShape], r_samples: int = DEPLOY_R,
                            batch: int = 1) -> float:
    """SOTA digital BNN cost model: 6.2·R× per op on Bayesian layers [20]."""
    int8_op = TILE_MVM_ENERGY / (2 * TILE_DIM * TILE_DIM)  # per-MAC from our tile
    e = 0.0
    for l in layers:
        macs = batch * l.d_in * l.d_out
        mult = DIGITAL_BNN_OVERHEAD_PER_R * r_samples if l.bayesian else 1.0
        e += macs * 2 * int8_op * mult
    return e
