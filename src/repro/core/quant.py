"""CIM numeric-path quantization: 8-bit µ, 4-bit σ, 8-bit IDAC, 6-bit ADC.

Reproduces the paper's split-precision tile arithmetic (§IV):
  * µ subarray stores signed 8-bit weights (differential FeFET pairs),
    effective precision 6.54 bits after offset compensation (§III-B1);
  * σε subarray stores unsigned 4-bit deviations;
  * inputs enter through 8-bit IDACs;
  * every 64-deep analog partial sum is digitized by a 6-bit SAR ADC
    before digital accumulation (the tile is 64×64 — column sums never
    exceed 64 products in the analog domain).

All quantizers come in straight-through (STE) flavours for QAT.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mu_bits: int = 8
    sigma_bits: int = 4
    input_bits: int = 8
    adc_bits: int = 6
    # ADC full-scale as a multiple of the partial-sum RMS (calibrated).
    adc_clip_sigmas: float = 4.0
    # Depth of the analog accumulation before ADC digitization.
    chunk: int = 64
    enabled: bool = True


def symmetric_scale(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Max-abs scale so that x/scale fits signed ``bits`` integers."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-12) / qmax


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits: int, signed: bool = True):
    """Round-to-nearest integer code."""
    if signed:
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    else:
        lo, hi = 0, 2**bits - 1
    return jnp.clip(jnp.round(x / scale), lo, hi)


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, bits: int, signed: bool = True):
    return quantize(x, scale, bits, signed) * scale


def ste(x: jnp.ndarray, xq: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward xq, backward identity."""
    return x + jax.lax.stop_gradient(xq - x)


def fake_quant_ste(x, scale, bits, signed: bool = True):
    return ste(x, fake_quant(x, scale, bits, signed))


def quantize_mu(mu: jnp.ndarray, cfg: QuantConfig, per_channel: bool = True):
    """Quantize mean weights (per-output-channel scale). Returns (muq, scale)."""
    axis = tuple(range(mu.ndim - 1)) if per_channel else None
    scale = symmetric_scale(mu, cfg.mu_bits, axis=axis)
    return fake_quant(mu, scale, cfg.mu_bits), scale


def quantize_sigma(sigma: jnp.ndarray, cfg: QuantConfig, per_channel: bool = True):
    """Quantize σ ≥ 0 to unsigned 4-bit codes. Returns (σq, scale)."""
    axis = tuple(range(sigma.ndim - 1)) if per_channel else None
    qmax = 2**cfg.sigma_bits - 1
    amax = jnp.max(sigma, axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-12) / qmax
    return quantize(sigma, scale, cfg.sigma_bits, signed=False) * scale, scale


def quantize_input(x: jnp.ndarray, cfg: QuantConfig):
    """IDAC path: per-tensor symmetric 8-bit."""
    scale = symmetric_scale(x, cfg.input_bits)
    return fake_quant(x, scale, cfg.input_bits), scale


def adc_quantize(psum: jnp.ndarray, full_scale: jnp.ndarray, cfg: QuantConfig):
    """6-bit mid-tread ADC on an analog partial sum.

    ``full_scale`` is the calibrated ±range of the bitline swing.  Codes
    saturate (clip) exactly as a SAR ADC does.
    """
    levels = 2 ** (cfg.adc_bits - 1) - 1
    lsb = full_scale / levels
    code = jnp.clip(jnp.round(psum / lsb), -levels - 1, levels)
    return code * lsb


def adc_full_scale(x_rms: jnp.ndarray, w_rms: jnp.ndarray, cfg: QuantConfig):
    """Calibrated ADC range: clip_sigmas × RMS of a 64-product sum.

    For x, w zero-mean independent, Var[Σ_{64} x·w] = 64·σx²·σw².
    """
    return cfg.adc_clip_sigmas * jnp.sqrt(float(cfg.chunk)) * x_rms * w_rms
