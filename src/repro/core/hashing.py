"""Counter-based integer hashing — the TPU analogue of "programmed once".

The paper's CLT-GRNG derives its entropy from FeFETs that are programmed
*once* to random threshold-voltage states and then only ever read.  On
TPU we realize "fixed random device state, zero storage, zero writes" as
a pure deterministic hash of the device coordinate: the virtual current
of device ``j`` in cell ``(k, n)`` is a function of ``mix32`` applied to
``(k, n, j, seed)``.  Every shard of a distributed model regenerates
bit-identical device states with no communication and no HBM traffic —
stronger than the hardware, which must physically ship its array.

``mix32`` is the "lowbias32" finalizer (Wellons): three rounds of
xorshift-multiply.  It is transcendental-free (VPU integer ops only) and
implemented identically here (jnp) and inside the Pallas kernels, which
lets the kernel tests assert bit-exact agreement with the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

# Knuth/Weyl multiplicative constants for coordinate folding.
_C1 = jnp.uint32(0x9E3779B9)
_C2 = jnp.uint32(0x85EBCA6B)
_C3 = jnp.uint32(0xC2B2AE35)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """lowbias32 finalizer. Input/output uint32 arrays."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash3(k: jnp.ndarray, n: jnp.ndarray, j: jnp.ndarray, seed) -> jnp.ndarray:
    """Hash a 3-D coordinate + seed into 32 uniform bits.

    Arguments broadcast against each other; any integer dtype accepted.
    """
    k = jnp.asarray(k, jnp.uint32)
    n = jnp.asarray(n, jnp.uint32)
    j = jnp.asarray(j, jnp.uint32)
    s = jnp.uint32(seed)
    h = mix32(j * _C3 + s)
    h = mix32(n * _C2 + h)
    h = mix32(k * _C1 + h)
    return h


def hash2(a: jnp.ndarray, b: jnp.ndarray, seed) -> jnp.ndarray:
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    h = mix32(b * _C2 + jnp.uint32(seed))
    h = mix32(a * _C1 + h)
    return h


def uniform_bit(h: jnp.ndarray, bit: int = 31) -> jnp.ndarray:
    """Extract one Bernoulli(1/2) bit from a hash word (float 0/1)."""
    return ((h >> jnp.uint32(bit)) & jnp.uint32(1)).astype(jnp.float32)


def gaussianish(h: jnp.ndarray) -> jnp.ndarray:
    """CLT-of-bytes standard-normal surrogate — transcendental-free.

    Sum of the three low bytes of a hash word (Irwin–Hall with n=3):
    mean 3·127.5, variance 3·(256²−1)/12 ⇒ std ≈ 127.99.  Standardized
    it is approximately N(0,1) — itself a tiny CLT-GRNG, the same trick
    the paper plays with FeFET currents replayed at the bit level to
    model per-device analog variation.  Chosen over popcount for finer
    granularity (1/128 lattice) and guaranteed Mosaic lowering (adds and
    shifts only).
    """
    b0 = (h & jnp.uint32(0xFF)).astype(jnp.float32)
    b1 = ((h >> jnp.uint32(8)) & jnp.uint32(0xFF)).astype(jnp.float32)
    b2 = ((h >> jnp.uint32(16)) & jnp.uint32(0xFF)).astype(jnp.float32)
    return (b0 + b1 + b2 - 382.5) * (1.0 / 127.99316)
