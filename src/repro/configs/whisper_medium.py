"""whisper-medium [arXiv:2212.04356].

24L enc + 24L dec, d_model=1024 16H (kv=16, MHA) d_ff=4096 vocab=51865.
Enc-dec with LayerNorm/GELU, learned positions, no RoPE.  The conv
audio frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings [B, 1500, d_model].

The learned-position table is resized per shape cell by the launcher
(whisper's native 448 ceiling is a frontend property, not a backbone
one — noted in DESIGN.md §6).
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865, norm="ln", mlp="gelu", use_rope=False,
    learned_pos=448, encoder_layers=24, n_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, vocab_pad_multiple=64, norm="ln", mlp="gelu",
    use_rope=False, learned_pos=64, encoder_layers=2, n_frames=24,
    uq_samples=3,
)
