"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th
layer is a dedicated cross-attention layer (with its own MLP, llama-3.2
style) reading stubbed image patch embeddings [B, 1601, d_model].
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, rope_theta=5e5,
    cross_attn_every=5, n_image_tokens=1601,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, vocab_pad_multiple=64,
    cross_attn_every=2, n_image_tokens=18, uq_samples=3,
)
