"""mixtral-8x7b [arXiv:2401.04088; hf-verified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8 experts
top-2, sliding-window attention (window 4096) — the SWA makes this the
one attention arch assigned to long_500k (rolling window cache).
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, rope_theta=1e6, swa_window=4096,
    n_experts=8, top_k=2,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, vocab_pad_multiple=64, swa_window=16,
    n_experts=4, top_k=2, uq_samples=3,
)
