"""Assigned input-shape presets (the 4 columns of the dry-run grid)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic sequence mixing: SSM/hybrid state is
# O(1); mixtral's sliding-window attention needs only a rolling
# window-sized cache.  Pure full-attention archs skip it (DESIGN.md §5).
LONG_CONTEXT_ARCHS = ("mamba2-130m", "zamba2-2.7b", "mixtral-8x7b")


def cells_for(arch: str) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return cells
