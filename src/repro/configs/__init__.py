"""Architecture config registry: ``--arch <id>`` resolution."""

from repro.configs import (llama_3_2_vision_11b, mamba2_130m, mixtral_8x7b,
                           qwen1_5_110b, qwen3_0_6b, qwen3_1_7b,
                           qwen3_moe_235b_a22b, whisper_medium, yi_9b,
                           zamba2_2_7b)
from repro.configs.shapes import LONG_CONTEXT_ARCHS, SHAPES, cells_for

_MODULES = {
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "mixtral-8x7b": mixtral_8x7b,
    "qwen3-0.6b": qwen3_0_6b,
    "qwen1.5-110b": qwen1_5_110b,
    "yi-9b": yi_9b,
    "qwen3-1.7b": qwen3_1_7b,
    "mamba2-130m": mamba2_130m,
    "whisper-medium": whisper_medium,
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "zamba2-2.7b": zamba2_2_7b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False):
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = ["ARCHS", "SHAPES", "LONG_CONTEXT_ARCHS", "cells_for", "get_config"]
