"""qwen3-1.7b [hf:Qwen/Qwen3-1.7B; hf-verified].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, qk-norm.
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936, qk_norm=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-1.7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, vocab_pad_multiple=64, qk_norm=True, uq_samples=3,
)
