"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B family; hf-verified].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
MoE 128 experts top-8, qk-norm.  The largest assigned arch: EP=16 on the
model axis (8 experts/device) + FSDP on data.
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, vocab_pad_multiple=64, qk_norm=True,
    n_experts=8, top_k=2, uq_samples=3,
)
