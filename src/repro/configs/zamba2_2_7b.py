"""zamba2-2.7b [arXiv:2411.15242; hf-verified].

54L d_model=2560 hybrid: mamba2 trunk (ssm_state=64) with ONE shared
attention block (32H, kv=32, d_ff=10240) applied every 6 mamba layers
(9 sites, zamba2's parameter-shared global block with embedding skip).
O(1) SSM decode state ⇒ runs long_500k (shared-attn sites keep full KV).
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000, ssm_state=64, ssm_expand=2, ssm_headdim=64,
    ssm_ngroups=1, ssm_chunk=256, hybrid_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, vocab_pad_multiple=64, ssm_state=16,
    ssm_expand=2, ssm_headdim=16, ssm_ngroups=1, ssm_chunk=16,
    hybrid_attn_every=2, uq_samples=3,
)
