"""yi-9b [arXiv:2403.04652; hf-verified].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 — llama-arch GQA.
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, vocab_pad_multiple=64, uq_samples=3,
)
