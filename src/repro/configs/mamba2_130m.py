"""mamba2-130m [arXiv:2405.21060].

24L d_model=768 attention-free, ssm_state=128, vocab=50280 — SSD
(state-space duality).  O(1) decode state ⇒ runs long_500k.
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_ngroups=1, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
    vocab=512, vocab_pad_multiple=64, ssm_state=16, ssm_expand=2,
    ssm_headdim=16, ssm_ngroups=1, ssm_chunk=16, uq_samples=3,
)
