from repro.utils.trees import (
    tree_bytes,
    tree_count,
    tree_map_with_name,
    tree_paths,
    flatten_dict,
    unflatten_dict,
)

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_map_with_name",
    "tree_paths",
    "flatten_dict",
    "unflatten_dict",
]
