"""Pytree utilities shared across the framework.

We deliberately avoid flax/optax — parameter collections are plain nested
dicts of jnp arrays, and these helpers provide the small amount of tree
plumbing the rest of the framework needs (path-aware maps for sharding
rules, size accounting for the roofline/energy models, and dict
flattening for checkpoint serialization).
"""

from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import numpy as np


def _path_str(path) -> str:
    """Render a jax key path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # FlattenedIndexKey and friends
            parts.append(str(getattr(p, "key", p)))
    return "/".join(parts)


def tree_map_with_name(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path_string, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_str(path), leaf), tree
    )


def tree_paths(tree: Any) -> list[str]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_path_str(path) for path, _ in leaves]


def tree_count(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def flatten_dict(tree: Mapping[str, Any], sep: str = "/") -> dict[str, Any]:
    """Flatten nested dicts AND lists/tuples (list indices become
    '#<i>' segments so unflatten can reconstruct the container type)."""
    out: dict[str, Any] = {}

    def rec(prefix: str, node: Any) -> None:
        if isinstance(node, Mapping):
            for k, v in node.items():
                rec(f"{prefix}{sep}{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                key = f"#{i}"
                rec(f"{prefix}{sep}{key}" if prefix else key, v)
        else:
            out[prefix] = node

    rec("", tree)
    return out


def unflatten_dict(flat: Mapping[str, Any], sep: str = "/") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for key, value in flat.items():
        parts = key.split(sep)
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    def fix(node):
        if isinstance(node, dict):
            if node and all(k.startswith("#") for k in node):
                return [fix(node[f"#{i}"]) for i in range(len(node))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(out)
