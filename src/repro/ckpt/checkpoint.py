"""Fault-tolerant checkpointing: atomic, checksummed, async, resumable.

Format: one directory per step containing
  * ``manifest.msgpack`` — path → (shape, dtype, crc32, byte offset/len)
  * ``shard_<i>.bin.zst`` — zstd-compressed concatenated leaf buffers

Safety properties:
  * atomic publish: written to ``<step>.tmp`` then os.rename'd — a crash
    mid-write never corrupts the latest checkpoint;
  * integrity: per-leaf crc32 verified on restore (bit-rot detection);
  * async: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and serializes on a background thread, so the train loop
    stalls only for the device→host copy;
  * bounded retention: keep_last garbage collection;
  * exact resume: restore returns (tree, step); the stateless data
    pipeline (data/tokens.py) replays from any step bit-identically.

On a real multi-host pod each host writes only its addressable shards
(jax.experimental.multihost_utils); this single-host implementation
gathers — the format and protocol are host-count agnostic.
"""

from __future__ import annotations

import os
import shutil
import threading
import zlib
from pathlib import Path

import jax
import msgpack
import numpy as np

try:  # zstd preferred; fall back to stdlib zlib when absent.
    import zstandard
except ImportError:  # pragma: no cover - environment dependent
    zstandard = None

from repro.utils.trees import flatten_dict, unflatten_dict

_MANIFEST = "manifest.msgpack"
_SHARD = "shard_0.bin.zst"
_CODEC = "zstd" if zstandard is not None else "zlib"


class _ZlibWriter:
    """Minimal stream_writer-compatible zlib compressor."""

    def __init__(self, f, level: int = 3):
        self._f = f
        self._c = zlib.compressobj(level)

    def write(self, buf: bytes) -> None:
        self._f.write(self._c.compress(buf))

    def flush(self, *_args) -> None:
        self._f.write(self._c.flush(zlib.Z_SYNC_FLUSH))

    def close(self) -> None:
        self._f.write(self._c.flush())


def _shard_writer(f, codec: str):
    if codec == "zstd":
        return zstandard.ZstdCompressor(level=3).stream_writer(f)
    return _ZlibWriter(f)


def _shard_decompress(data: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise ImportError(
                "checkpoint was written with zstd but the 'zstandard' "
                "package is not installed")
        return zstandard.ZstdDecompressor().decompress(
            data, max_output_size=1 << 38)
    return zlib.decompress(data)


def save(ckpt_dir: str | Path, step: int, tree, keep_last: int = 3) -> Path:
    """Synchronous checkpoint write. Returns the published directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f"step_{step:010d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = flatten_dict(tree)
    manifest = {"step": step, "codec": _CODEC, "leaves": {}}
    offset = 0
    with open(tmp / _SHARD, "wb") as f:
        writer = _shard_writer(f, _CODEC)
        for path, leaf in sorted(flat.items()):
            arr = np.asarray(leaf)
            buf = arr.tobytes()
            manifest["leaves"][path] = {
                "shape": list(arr.shape),
                "dtype": arr.dtype.str,
                "crc32": zlib.crc32(buf),
                "offset": offset,
                "nbytes": len(buf),
            }
            writer.write(buf)
            offset += len(buf)
        writer.close()
    (tmp / _MANIFEST).write_bytes(msgpack.packb(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic publish
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: Path, keep_last: int) -> None:
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p)


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int | None = None):
    """Returns (tree, step). Verifies per-leaf crc32."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}"
    manifest = msgpack.unpackb((d / _MANIFEST).read_bytes())
    raw = _shard_decompress((d / _SHARD).read_bytes(),
                            manifest.get("codec", "zstd"))
    flat = {}
    for path, meta in manifest["leaves"].items():
        buf = raw[meta["offset"]:meta["offset"] + meta["nbytes"]]
        if zlib.crc32(buf) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {path} in {d}")
        flat[path] = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])).reshape(
            meta["shape"]).copy()
    return unflatten_dict(flat), manifest["step"]


class AsyncCheckpointer:
    """Overlap serialization with training.

    ``submit`` synchronously snapshots device arrays to host numpy
    (the only part that must see a consistent state), then hands the
    write to a daemon thread.  ``wait()`` joins the in-flight write
    (call before exit / before restoring).
    """

    def __init__(self, ckpt_dir: str | Path, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self.error: BaseException | None = None

    def submit(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, self.keep_last)
            except BaseException as e:  # noqa: BLE001
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err
