"""AdamW with global-norm clipping (pure JAX, f32 master weights).

Optimizer moments are plain pytrees sharded exactly like the parameters
(FSDP on 'data' + TP on 'model' — ZeRO-style), so a 235B model's Adam
state distributes at ~3.7 GB/device on the production mesh.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # §Perf I2: keep compute params in bf16 and the f32 master copy in
    # the optimizer state — halves FSDP all-gather wire bytes, gradient
    # all-reduce bytes, and per-step weight HBM reads.
    master_weights: bool = False


def init_opt_state(params, master_weights: bool = False) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if master_weights:
        # caller passes f32 init params; compute copy is cast afterwards
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics).

    With ``master_weights`` the f32 master in ``state`` is the source of
    truth; ``params`` (bf16) are regenerated from it each step.
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / c1
        vh = v / c2
        src = master if master is not None else p.astype(jnp.float32)
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * src
        new_master = src - lr * step
        return new_master.astype(p.dtype), m, v, new_master

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    flat_w = (tdef.flatten_up_to(state["master"])
              if "master" in state else [None] * len(flat_p))
    out = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                 "nu": tdef.unflatten([o[2] for o in out]),
                 "count": count}
    if "master" in state:
        new_state["master"] = tdef.unflatten([o[3] for o in out])
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
