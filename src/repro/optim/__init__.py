from repro.optim.adamw import AdamWConfig, adamw_update, global_norm, init_opt_state
from repro.optim.schedules import warmup_cosine

__all__ = ["AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
           "warmup_cosine"]
