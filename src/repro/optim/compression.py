"""Gradient compression with error feedback for slow-link reduction.

At multi-pod scale the 'pod' mesh axis crosses a DCN-class boundary that
is ~an order of magnitude slower than intra-pod ICI.  The standard
mitigation is lossy compression of the cross-pod gradient reduction with
*error feedback* (Seide et al.; Karimireddy et al.): the quantization
residual is carried into the next step, so the compressed SGD trajectory
provably tracks the exact one.

Implementation: per-leaf symmetric int8 quantization (max-abs scale).
``compress_tree``/``decompress_tree`` wrap an arbitrary reduction; the
error-feedback state lives beside the optimizer moments and shards the
same way.  Wire bytes for the pod axis drop 4× (f32→int8); the dry-run's
collective model picks the reduction up as an int8 all-reduce.

Convergence is validated in tests/test_compression.py (loss curve with
compression within a few percent of exact after a few hundred steps).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_leaf(g: jnp.ndarray):
    """f32 -> (int8 codes, scale)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_leaf(codes: jnp.ndarray, scale: jnp.ndarray):
    return codes.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Error-feedback compression: returns (compressed pytree of
    (codes, scale), new error state).

    codes+scale are what crosses the slow link; the residual
    (g + err) − dequant stays local.
    """
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        codes, scale = quantize_leaf(corrected)
        deq = dequantize_leaf(codes, scale)
        return (codes, scale), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    comp = tdef.unflatten([o[0] for o in out])
    new_err = tdef.unflatten([o[1] for o in out])
    return comp, new_err


def decompress_grads(comp):
    return jax.tree.map(lambda pair: dequantize_leaf(*pair), comp,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and hasattr(x[0], "dtype"))


def compressed_gradients(grads, err_state):
    """One-call helper: quantize→dequantize with error feedback.

    Under pjit the dequantized gradients are what the cross-pod
    all-reduce sees; XLA reduces the int8-rank payload because the
    dequant is element-wise fused.  Returns (grads', new_err_state).
    """
    comp, new_err = compress_grads(grads, err_state)
    return decompress_grads(comp), new_err
