"""Observability for the serving + mission stack.

The paper's value proposition is operational — write-free FeFET
sampling holds calibration over device lifetime while triage verdicts
gate costly UAV maneuvers — so the repo needs more than factory-time
conformance (hw/calib, test_hw_conformance): it needs to SEE a
deployment drift while serving.  This package adds that layer without
touching the device-resident fast path:

  telemetry  counters/histograms/GRNG sample moments carried as a
             pytree THROUGH the engines' lax.while_loop / lax.scan
             bodies and drained only at the existing retirement /
             die-group sync points (zero added host syncs, zero
             verdict changes — asserted in tests/test_obs.py)
  trace      per-request span tracing on time.perf_counter clocks,
             exported as Chrome-trace JSON (loadable in Perfetto)
  prof       performance observability: per-stage latency histograms
             over the serving loop, compile-event counters (per-builder
             executable constructions + process-wide XLA backend
             compiles), compiled-cost records (cost_analysis flops /
             bytes / peak-live per cached builder), and the
             programmatic jax.profiler capture behind ``--profile``
  drift      streaming conformance monitor: per-die z-scores of the
             served GRNG probe moments against the calibration-time
             Fig. 9 reference; emits recalibration advisories
  slo        request-lifecycle SLO tracking: time-to-verdict /
             queue-wait / service histograms folded at the existing
             host-sync points, SLO attainment + error-budget burn
             rate, and fleet queue/backpressure gauges
  alerts     unified alert bus: drift advisories, lifetime heal
             events, SLO burn breaches, and backpressure saturation
             as one typed advisory stream (logged + exported)
  registry   Prometheus-text / JSON metric exporters
  log        structured logger (REPRO_LOG_LEVEL / REPRO_LOG_JSON)
"""

from repro.obs.alerts import Advisory, AlertBus

from repro.obs.drift import (DriftGate, DriftMonitor, DriftReference,
                             DriftStatus, drift_status)
from repro.obs.log import get_logger
from repro.obs.prof import (NULL_PROFILER, CostRegistry, StageProfiler,
                            builder_builds, compile_counters,
                            compiled_cost, trace_capture,
                            xla_compile_events)
from repro.obs.registry import (MetricsRegistry, mission_registry,
                                quantile, serving_registry)
from repro.obs.slo import NULL_SLO, SLO, SloTracker
from repro.obs.telemetry import (TelemetryConfig, count_dispatch,
                                 init_telemetry, merge_snapshots,
                                 record_decisions, record_round,
                                 snapshot)
from repro.obs.trace import NULL_TRACER, Tracer, mission_trace

__all__ = [
    "Advisory", "AlertBus", "CostRegistry", "DriftGate", "DriftMonitor",
    "DriftReference", "DriftStatus", "MetricsRegistry", "NULL_PROFILER",
    "NULL_SLO", "NULL_TRACER", "SLO", "SloTracker", "StageProfiler",
    "TelemetryConfig", "Tracer", "builder_builds", "compile_counters",
    "compiled_cost", "count_dispatch", "drift_status", "get_logger",
    "init_telemetry", "merge_snapshots", "mission_registry",
    "mission_trace", "quantile", "record_decisions", "record_round",
    "serving_registry", "snapshot", "trace_capture",
    "xla_compile_events",
]
