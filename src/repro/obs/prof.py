"""Performance observability: stage profiler, compile-event counters,
and a compiled-cost registry for the serving/mission hot path.

The repo's headline numbers are performance claims (185 TOPS/W/mm²,
640 aJ/sample, ~0.05 host syncs/decision), so performance itself needs
the same treatment obs/telemetry gave correctness: measured, exported,
and diffable run over run.  Three instruments, all host-side — none of
them touches the device-resident fast path, adds a host sync, or
changes a compiled graph (asserted in tests/test_obs.py):

``StageProfiler``
    Per-stage latency histograms over the serving loop's phases —
    admission, featurize, dispatch, triage_loop (the blocking
    device→host verdict pull, i.e. where the device-resident escalation
    time actually shows up on the host), retirement — on log-spaced
    buckets.  Stages are open-ended strings so the mission driver can
    profile its own phases (detector / rollout / drain) through the
    same exporter.  Exported as Prometheus histograms via
    ``obs.registry.add_stage_profile``.

Compile-event counters
    ``count_build(name)`` ticks once per *executable construction* in
    ``serving/engine.py``'s ``lru_cache`` builders — two engines with
    identical frozen configs must tick each builder exactly once
    (tests/test_perf_obs.py).  A ``jax.monitoring`` listener
    additionally counts every XLA backend compile in the process
    (``xla_compile_events()`` / ``xla_compile_seconds()``), so a
    recompilation storm — shape drift re-jitting the pool functions
    80× — is a visible counter, not a silent slowdown.

``CostRegistry`` / ``compiled_cost``
    AOT-lowers a jitted function at given arg shapes and records XLA's
    own ``cost_analysis()`` (flops / bytes accessed) next to the
    loop-aware ``launch/hlo_analysis`` walk (flops, HBM bytes, largest
    live intermediate) and the compile wall time.  benchmarks/roofline
    charts these against peak; engines expose ``compiled_cost_records``
    so ``--profile`` runs capture the real deployed shapes.

``trace_capture``
    A context manager around ``jax.profiler.start_trace/stop_trace``
    (the programmatic XLA profiler): ``--profile DIR`` on
    ``launch/serve.py`` / ``launch/mission.py`` wraps the whole run and
    writes a TensorBoard-loadable trace directory.
"""

from __future__ import annotations

import contextlib
import time
from collections import Counter
from typing import Any, Callable

import numpy as np

# ----------------------------------------------------------------------
# stage profiler
# ----------------------------------------------------------------------
# The serving engines' hot-loop phases, in loop order.  StageProfiler
# accepts any stage string; this tuple just fixes the export order for
# the stages both engines share.
SERVING_STAGES = ("admission", "featurize", "dispatch", "triage_loop",
                  "retirement")

# Log-spaced latency edges: 1 µs .. 10 s, 4 buckets per decade.  Wide
# enough for interpret-mode CPU dispatches and tight enough that a TPU
# round's sub-ms latencies don't all land in one bin.
_EDGES = np.logspace(-6, 1, 29)


class StageProfiler:
    """Host-side per-stage latency histograms (perf_counter clocks).

    Purely host arithmetic on scalars already measured by the engine
    loop — no device interaction, so it cannot add host syncs or
    perturb compiled graphs.  ``snapshot()`` is JSON-ready and feeds
    ``obs.registry.add_stage_profile``.
    """

    edges = _EDGES

    def __init__(self):
        self._counts: dict[str, np.ndarray] = {}
        self._over: Counter = Counter()
        self._total_s: Counter = Counter()
        self._n: Counter = Counter()

    @property
    def enabled(self) -> bool:
        return True

    def observe(self, stage: str, dt_s: float) -> None:
        """Fold one latency observation into ``stage``'s histogram.

        NaN observations are dropped; negative ones clamp to 0; +inf
        lands in the overflow (+Inf) bucket — the registry exporter
        keeps ``_count`` exact either way."""
        if dt_s != dt_s:                               # NaN
            return
        dt_s = max(float(dt_s), 0.0)
        if stage not in self._counts:
            self._counts[stage] = np.zeros(len(_EDGES) - 1, np.int64)
        self._n[stage] += 1
        if np.isfinite(dt_s):
            self._total_s[stage] += dt_s
        if dt_s >= _EDGES[-1] or not np.isfinite(dt_s):
            self._over[stage] += 1
            return
        self._counts[stage][
            np.searchsorted(_EDGES, dt_s, side="right") - 1 if
            dt_s >= _EDGES[0] else 0] += 1

    @contextlib.contextmanager
    def span(self, stage: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(stage, time.perf_counter() - t0)

    def snapshot(self) -> dict[str, Any]:
        """{stage: {count, total_s, mean_s, p50/p95/p99_s, counts,
        overflow, edges}} — percentiles read straight from the binned
        histogram via the shared registry.quantile interpolator."""
        from repro.obs.registry import quantile
        out: dict[str, Any] = {}
        order = [s for s in SERVING_STAGES if s in self._counts]
        order += [s for s in self._counts if s not in SERVING_STAGES]
        for stage in order:
            n = int(self._n[stage])
            rec = {
                "count": n,
                "total_s": float(self._total_s[stage]),
                "mean_s": float(self._total_s[stage]) / n if n else
                float("nan"),
                "counts": self._counts[stage].tolist(),
                "overflow": int(self._over[stage]),
                "edges": _EDGES.tolist(),
            }
            rec["p50_s"] = quantile(rec, 0.50)
            rec["p95_s"] = quantile(rec, 0.95)
            rec["p99_s"] = quantile(rec, 0.99)
            out[stage] = rec
        return out


class _NullStageProfiler(StageProfiler):
    """No-op profiler so engine call sites never branch."""

    @property
    def enabled(self) -> bool:
        return False

    def observe(self, stage, dt_s):
        pass

    @contextlib.contextmanager
    def span(self, stage):
        yield

    def snapshot(self):
        return {}


NULL_PROFILER = _NullStageProfiler()


# ----------------------------------------------------------------------
# compile-event counters
# ----------------------------------------------------------------------
# Executable constructions per engine builder (lru_cache miss bodies in
# serving/engine.py tick these).  Process-wide on purpose: the compile
# cache being counted is process-wide too.
_BUILDS: Counter = Counter()

# XLA backend compiles seen by the jax.monitoring listener.
_XLA = {"events": 0, "seconds": 0.0, "installed": False}


def count_build(name: str) -> None:
    """Tick the executable-construction counter for a cached builder."""
    _BUILDS[name] += 1


def builder_builds() -> dict[str, int]:
    """Snapshot of builds per cached builder since process start."""
    return dict(_BUILDS)


def _on_event_duration(name: str, secs: float, **kw) -> None:
    if name == "/jax/core/compile/backend_compile_duration":
        _XLA["events"] += 1
        _XLA["seconds"] += float(secs)


def install_compile_listener() -> None:
    """Register the jax.monitoring backend-compile listener (idempotent).

    Listener dispatch is a python-list append per *compile*, not per
    call — zero steady-state cost.  Gated gracefully: jax builds
    without ``jax.monitoring`` just leave the counters at zero."""
    if _XLA["installed"]:
        return
    try:
        import jax.monitoring as monitoring
        monitoring.register_event_duration_secs_listener(_on_event_duration)
        _XLA["installed"] = True
    except Exception:  # noqa: BLE001 — monitoring API absent/renamed
        pass


def xla_compile_events() -> int:
    """XLA backend compiles observed since the listener was installed."""
    return int(_XLA["events"])


def xla_compile_seconds() -> float:
    return float(_XLA["seconds"])


def compile_counters() -> dict[str, Any]:
    """JSON-ready snapshot of all compile-event counters."""
    return {"builder_builds": builder_builds(),
            "xla_compile_events": xla_compile_events(),
            "xla_compile_seconds": xla_compile_seconds()}


# Installed at import: the engines import this module, and a counter
# that misses the first engine's compiles cannot gate a recompilation
# regression.
install_compile_listener()


# ----------------------------------------------------------------------
# compiled-cost registry
# ----------------------------------------------------------------------
def _xla_cost_analysis(compiled) -> dict[str, float]:
    """XLA's own cost_analysis, normalized to {flops, bytes_accessed}."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 — backend without cost analysis
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    out = {}
    if "flops" in ca:
        out["xla_flops"] = float(ca["flops"])
    if "bytes accessed" in ca:
        out["xla_bytes_accessed"] = float(ca["bytes accessed"])
    return out


def compiled_cost(name: str, fn: Callable, *args,
                  static_cost_only: bool = False, **kwargs) -> dict:
    """AOT-lower ``fn`` at ``args`` and record its compiled cost.

    Returns {name, compile_s, xla_flops, xla_bytes_accessed (XLA's
    cost_analysis), flops, hbm_bytes (loop-aware hlo_analysis walk),
    peak_live_bytes (largest materialized intermediate), backend}.
    ``fn`` must be a jitted function (has ``.lower``); args may be
    concrete arrays or ``jax.ShapeDtypeStruct``.  This compiles a fresh
    executable (AOT does not share the jit call cache) — call it from
    profiling/bench paths, never the serving loop.
    """
    import jax
    from repro.launch.hlo_analysis import analyze, \
        largest_intermediate_bytes
    t0 = time.perf_counter()
    compiled = fn.lower(*args, **kwargs).compile()
    compile_s = time.perf_counter() - t0
    txt = compiled.as_text()
    walk = analyze(txt, 1)
    rec = {
        "name": name,
        "compile_s": compile_s,
        "flops": walk["flops_per_device"],
        "hbm_bytes": walk["hbm_bytes_per_device"],
        "peak_live_bytes": largest_intermediate_bytes(txt),
        "backend": jax.default_backend(),
    }
    if not static_cost_only:
        rec.update(_xla_cost_analysis(compiled))
    return rec


class CostRegistry:
    """Ordered collection of compiled-cost records for one run."""

    def __init__(self):
        self.records: list[dict] = []

    def record(self, name: str, fn: Callable, *args, **kwargs) -> dict:
        rec = compiled_cost(name, fn, *args, **kwargs)
        self.records.append(rec)
        return rec

    def add(self, rec: dict) -> None:
        self.records.append(rec)

    def to_json(self) -> list[dict]:
        return list(self.records)


# ----------------------------------------------------------------------
# programmatic jax.profiler capture
# ----------------------------------------------------------------------
@contextlib.contextmanager
def trace_capture(log_dir: str | None):
    """Capture an XLA profiler trace into ``log_dir`` (TensorBoard /
    Perfetto-loadable).  ``None`` is a no-op so drivers can pass the
    CLI flag straight through; failures to start (no profiler in this
    jax build, port conflicts) degrade to a warning, never kill a
    serving run."""
    if not log_dir:
        yield
        return
    import jax
    from repro.obs.log import get_logger
    log = get_logger("prof")
    started = False
    try:
        jax.profiler.start_trace(log_dir)
        started = True
    except Exception as e:  # noqa: BLE001
        log.warning("jax.profiler trace capture unavailable", err=str(e))
    try:
        yield
    finally:
        if started:
            jax.profiler.stop_trace()
            log.info("profiler trace written", dir=log_dir)
