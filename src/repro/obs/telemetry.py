"""Device-resident serving telemetry.

The engines' fast path makes ~0.05 host syncs per decision: the SAR
engine runs its whole escalation ladder inside one ``lax.while_loop``
dispatch, and a mission episode is a single ``lax.scan`` pulled once
per die group.  Naive instrumentation (a host callback, an extra
``device_get`` per round) would destroy exactly the property the repo
measures.  So telemetry lives ON the device: a small pytree of int32
counters, histograms, and float32 GRNG sample moments that rides the
loop carries and crosses to the host only when the caller was already
syncing (retirement, die-group pull, end of bench).

Contents of the pytree (see :func:`init_telemetry`):

  rounds / dispatches / samples     scalar int32 counters
  verdicts[3]                       ACCEPT / ESCALATE / FLAG at retire
  r_hist[r_max+1]                   samples-at-verdict histogram
  conf_hist / ent_hist / mi_hist    decision-quality histograms
  grng_n, grng_sum, grng_sumsq      per-die Fig. 9 probe moments
  ent_max                           static log(n_classes), for edges

The GRNG probe re-reads the raw 16-cell array sums for a fixed block
of ``probe_cells`` stream slots each round — the same measurement
``hw/calib.measured_grng`` performs at calibration time — but riding
the serving stream, so ``obs/drift`` can z-test the deployment against
its calibration reference without any dedicated measurement pass.
Probing is a gather + tiny matmul over a [probe_cells, 16] constant:
far below the round's own ``sel`` / basis intermediates, so the HLO
largest-intermediate is unchanged (asserted in tests/test_obs.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clt_grng

Telemetry = dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static (hashable) telemetry shape — safe to key jit caches on.

    probe_cells: stream slots re-read per round for GRNG drift moments
                 (0 disables the probe; counters/histograms remain).
    conf_bins:   confidence histogram bins over [0, 1].
    ent_bins:    entropy and mutual-information bins over [0, ln K].
    """

    probe_cells: int = 32
    conf_bins: int = 16
    ent_bins: int = 16


def init_telemetry(tcfg: TelemetryConfig, r_max: int) -> Telemetry:
    """Zeroed telemetry pytree for a policy with ``r_max`` max samples."""
    return {
        "rounds": jnp.zeros((), jnp.int32),
        "dispatches": jnp.zeros((), jnp.int32),
        "samples": jnp.zeros((), jnp.int32),
        "verdicts": jnp.zeros((3,), jnp.int32),
        "r_hist": jnp.zeros((int(r_max) + 1,), jnp.int32),
        "conf_hist": jnp.zeros((tcfg.conf_bins,), jnp.int32),
        "ent_hist": jnp.zeros((tcfg.ent_bins,), jnp.int32),
        "mi_hist": jnp.zeros((tcfg.ent_bins,), jnp.int32),
        "grng_n": jnp.zeros((), jnp.float32),
        "grng_sum": jnp.zeros((), jnp.float32),
        "grng_sumsq": jnp.zeros((), jnp.float32),
        "ent_max": jnp.zeros((), jnp.float32),
    }


def _probe_raw(tcfg: TelemetryConfig, grng_cfg, sel: jax.Array,
               sample_idx: jax.Array, lane: jax.Array) -> jax.Array:
    """Raw 16-cell array-sum currents [r, probe_cells] (µA) for one lane.

    ``sel`` is the round's thermometer selections [r, B, 16] and
    ``sample_idx`` the absolute stream indices [r, B]; both are already
    materialized by the decision kernel, so the probe reuses them
    instead of regenerating streams.  The probe block is rows
    0..probe_cells-1, col 0 of the die — the same corner
    ``hw/calib.measured_grng`` measures first.
    """
    p = tcfg.probe_cells
    rows = jnp.arange(p, dtype=jnp.int32)
    currents = clt_grng.device_currents(grng_cfg, rows, jnp.zeros_like(rows))
    sel_lane = jnp.take(sel, lane, axis=1).astype(jnp.float32)  # [r, 16]
    raw = sel_lane @ currents.T  # [r, p]
    if grng_cfg.read_sigma > 0.0:
        idx_lane = jnp.take(sample_idx, lane, axis=1)  # [r]
        raw = raw + clt_grng.read_noise_at(
            grng_cfg, rows[None, :], jnp.zeros((1, p), jnp.int32),
            idx_lane[:, None].astype(jnp.int32))
    return raw


def record_round(telem: Telemetry, tcfg: TelemetryConfig, grng_cfg,
                 sel: jax.Array, sample_idx: jax.Array,
                 upd: jax.Array) -> Telemetry:
    """Fold one decision round into ``telem`` (in-graph, no syncs).

    ``upd`` marks slots whose statistics actually advance this round.
    The probe follows the FIRST updating lane: inactive slots' streams
    do not advance, so re-reading a stale lane each round would repeat
    the same selections and bias the measured variance low.  When no
    slot updates (fully idle round) the weight is 0 and the moments
    are unchanged.
    """
    r = sel.shape[0]
    any_upd = jnp.any(upd)
    w = any_upd.astype(jnp.float32)
    out = dict(telem)
    out["rounds"] = telem["rounds"] + any_upd.astype(jnp.int32)
    out["samples"] = telem["samples"] + r * jnp.sum(upd.astype(jnp.int32))
    if tcfg.probe_cells > 0:
        lane = jnp.argmax(upd)
        raw = _probe_raw(tcfg, grng_cfg, sel, sample_idx, lane)
        out["grng_n"] = telem["grng_n"] + w * raw.size
        out["grng_sum"] = telem["grng_sum"] + w * jnp.sum(raw)
        out["grng_sumsq"] = telem["grng_sumsq"] + w * jnp.sum(raw * raw)
    return out


def record_decisions(telem: Telemetry, tcfg: TelemetryConfig,
                     fin: dict[str, jax.Array], verdict: jax.Array,
                     decided: jax.Array) -> Telemetry:
    """Fold retiring decisions into verdict/R/quality histograms.

    ``decided`` masks the slots whose verdict is final this dispatch;
    each decision must be recorded exactly once, so callers pass e.g.
    ``active & (verdict != ESCALATE)`` after the escalation loop.
    """
    di = decided.astype(jnp.int32)
    n_classes = fin["probs"].shape[-1]
    ent_max = float(np.log(max(n_classes, 2)))
    out = dict(telem)
    out["verdicts"] = telem["verdicts"].at[jnp.clip(verdict, 0, 2)].add(di)
    out["r_hist"] = telem["r_hist"].at[
        jnp.clip(fin["n"], 0, telem["r_hist"].shape[0] - 1)].add(di)
    conf_bin = jnp.clip(
        (fin["confidence"] * tcfg.conf_bins).astype(jnp.int32),
        0, tcfg.conf_bins - 1)
    out["conf_hist"] = telem["conf_hist"].at[conf_bin].add(di)
    ent_bin = jnp.clip(
        (fin["predictive_entropy"] / ent_max * tcfg.ent_bins).astype(jnp.int32),
        0, tcfg.ent_bins - 1)
    out["ent_hist"] = telem["ent_hist"].at[ent_bin].add(di)
    mi_bin = jnp.clip(
        (fin["mutual_information"] / ent_max * tcfg.ent_bins).astype(jnp.int32),
        0, tcfg.ent_bins - 1)
    out["mi_hist"] = telem["mi_hist"].at[mi_bin].add(di)
    out["ent_max"] = jnp.maximum(telem["ent_max"], jnp.float32(ent_max))
    return out


def count_dispatch(telem: Telemetry) -> Telemetry:
    """Count one engine dispatch (one jitted call, however many rounds)."""
    out = dict(telem)
    out["dispatches"] = telem["dispatches"] + 1
    return out


def snapshot(telem: Telemetry, tcfg: TelemetryConfig) -> dict[str, Any]:
    """Pull ``telem`` to the host and derive summary statistics.

    This is the ONLY host sync in the module — call it at points that
    already sync (engine drain, end of bench).  Returns plain python /
    lists, JSON-ready.  GRNG raw moments are kept alongside the derived
    mean/std so streaming monitors can keep folding snapshots.
    """
    host = jax.device_get(telem)
    n = float(host["grng_n"])
    g_mean = float(host["grng_sum"]) / n if n > 0 else float("nan")
    if n > 1:
        var = (float(host["grng_sumsq"]) - n * g_mean * g_mean) / (n - 1.0)
        g_std = float(np.sqrt(max(var, 0.0)))
    else:
        g_std = float("nan")
    ent_max = float(host["ent_max"])
    if ent_max <= 0.0:
        ent_max = float("nan")
    verdicts = np.asarray(host["verdicts"], dtype=np.int64)
    return {
        "rounds": int(host["rounds"]),
        "dispatches": int(host["dispatches"]),
        "samples": int(host["samples"]),
        "decisions": int(verdicts.sum()),
        "verdicts": {"accept": int(verdicts[0]), "escalate": int(verdicts[1]),
                     "flag": int(verdicts[2])},
        "r_hist": np.asarray(host["r_hist"]).astype(int).tolist(),
        "conf_hist": np.asarray(host["conf_hist"]).astype(int).tolist(),
        "conf_edges": np.linspace(0.0, 1.0, tcfg.conf_bins + 1).tolist(),
        "ent_hist": np.asarray(host["ent_hist"]).astype(int).tolist(),
        "mi_hist": np.asarray(host["mi_hist"]).astype(int).tolist(),
        "ent_edges": (np.linspace(0.0, 1.0, tcfg.ent_bins + 1)
                      * (ent_max if np.isfinite(ent_max) else 1.0)).tolist(),
        "ent_max": ent_max,
        "grng": {
            "probe_cells": tcfg.probe_cells,
            "n": n,
            "sum": float(host["grng_sum"]),
            "sumsq": float(host["grng_sumsq"]),
            "sum_mean_uA": g_mean,
            "sum_std_uA": g_std,
        },
    }


def merge_snapshots(snaps: list[dict[str, Any]]) -> dict[str, Any]:
    """Combine host snapshots from several engines/groups of one die."""
    if not snaps:
        return {}
    out = {k: v for k, v in snaps[0].items()}
    for s in snaps[1:]:
        for k in ("rounds", "dispatches", "samples", "decisions"):
            out[k] = out[k] + s[k]
        out["verdicts"] = {k: out["verdicts"][k] + s["verdicts"][k]
                           for k in out["verdicts"]}
        for k in ("r_hist", "conf_hist", "ent_hist", "mi_hist"):
            a, b = out[k], s[k]
            if len(a) < len(b):
                a = a + [0] * (len(b) - len(a))
            out[k] = [x + (b[i] if i < len(b) else 0)
                      for i, x in enumerate(a)]
        g, h = out["grng"], s["grng"]
        out["grng"] = dict(g)
        for k in ("n", "sum", "sumsq"):
            out["grng"][k] = g[k] + h[k]
    g = out["grng"]
    n = g["n"]
    if n > 1:
        mean = g["sum"] / n
        var = (g["sumsq"] - n * mean * mean) / (n - 1.0)
        out["grng"]["sum_mean_uA"] = mean
        out["grng"]["sum_std_uA"] = float(np.sqrt(max(var, 0.0)))
    return out
