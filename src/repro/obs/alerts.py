"""Unified alert bus: one typed advisory stream for the whole stack.

Before this module each subsystem shouted in its own dialect — GRNG
drift advisories (obs/drift) as strings in summaries, lifetime heal
events (hw/redeploy) as dataclasses in lifetime dicts, and nothing at
all for latency or backpressure.  :class:`AlertBus` collects them as
:class:`Advisory` records with a shared ``(kind, severity, source,
message, fields)`` shape, logs each through :mod:`repro.obs.log` as it
arrives, and exports aggregate counters through
:func:`repro.obs.registry.add_alerts` (Prometheus text + JSON twin).

Feeding the bus is always post-hoc or host-side — it never touches a
jitted graph, so enabling it costs nothing at the decision level.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro.obs.log import get_logger

KINDS = ("drift", "heal", "slo_burn", "backpressure")
SEVERITIES = ("info", "warning", "critical")

_log = get_logger("repro.alerts")


@dataclasses.dataclass(frozen=True)
class Advisory:
    kind: str
    severity: str
    source: str
    message: str
    fields: dict[str, Any] = dataclasses.field(default_factory=dict)
    ts_s: float = 0.0

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


class AlertBus:
    """Collects advisories; query with :attr:`advisories` /
    :meth:`counts`, export with :meth:`to_json` or
    ``registry.add_alerts(reg, bus.to_json())``."""

    def __init__(self, clock=time.time, logger=None):
        self._clock = clock
        self._log = logger if logger is not None else _log
        self.advisories: list[Advisory] = []

    def __len__(self) -> int:
        return len(self.advisories)

    def emit(self, kind: str, severity: str, source: str, message: str,
             **fields) -> Advisory:
        adv = Advisory(kind=kind, severity=severity, source=source,
                       message=message, fields=dict(fields),
                       ts_s=float(self._clock()))
        self.advisories.append(adv)
        emit = self._log.error if severity == "critical" else (
            self._log.warning if severity == "warning" else self._log.info)
        emit(message, kind=kind, source=source,
             **{k: v for k, v in fields.items()
                if isinstance(v, (int, float, bool, str))})
        return adv

    # ---- feeders: one per subsystem dialect ----

    def observe_drift(self, status: dict[str, Any] | None,
                      source: str = "serving") -> None:
        """Feed an obs.drift status dict (``DriftStatus.to_dict()``)."""
        if not status or not status.get("drifted"):
            return
        self.emit("drift", "warning", source,
                  status.get("advisory") or "GRNG drift detected",
                  z_mean=status.get("z_mean"), z_std=status.get("z_std"),
                  n=status.get("n"))

    def observe_heal(self, event, source: str = "serving") -> None:
        """Feed a hw.redeploy HealEvent (or its dict form)."""
        d = event if isinstance(event, dict) else event.to_dict()
        self.emit("heal", "info", source,
                  "die recalibrated and head redeployed",
                  age_s=d.get("age_s"), calib_epoch=d.get("calib_epoch"),
                  z_mean=d.get("z_mean"), z_std=d.get("z_std"))

    def observe_slo(self, snap: dict[str, Any] | None,
                    source: str = "serving") -> None:
        """Feed an obs.slo snapshot: one critical advisory per SLO
        whose error-budget burn rate breached its alert threshold."""
        for s in (snap or {}).get("slos") or []:
            if s.get("breach"):
                self.emit(
                    "slo_burn", "critical", source,
                    f"SLO {s['name']} burning error budget at "
                    f"{s['burn_rate']:.1f}x (alert at "
                    f"{s['burn_alert']:g}x)",
                    slo=s["name"], burn_rate=s["burn_rate"],
                    violations=s["violations"], requests=s["requests"])

    def observe_backpressure(self, snap: dict[str, Any] | None,
                             source: str = "fleet") -> None:
        """Feed an obs.slo snapshot's fleet block: advise when routing
        saturated (ticks where every pool queue was full)."""
        fleet = (snap or {}).get("fleet") or {}
        bp = fleet.get("backpressure_ticks", 0)
        if not bp:
            return
        ticks = max(fleet.get("ticks", 1), 1)
        sev = "critical" if bp / ticks > 0.5 else "warning"
        self.emit("backpressure", sev, source,
                  f"router backpressured on {bp}/{ticks} ticks "
                  f"(backlog peak {fleet.get('backlog_peak', 0)})",
                  backpressure_ticks=bp, ticks=ticks,
                  backlog_peak=fleet.get("backlog_peak", 0))

    # ---- readout ----

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for a in self.advisories:
            out[a.kind] = out.get(a.kind, 0) + 1
        return out

    def worst_severity(self) -> str | None:
        worst = None
        for a in self.advisories:
            if worst is None or (SEVERITIES.index(a.severity)
                                 > SEVERITIES.index(worst)):
                worst = a.severity
        return worst

    def to_json(self) -> list[dict[str, Any]]:
        return [a.to_dict() for a in self.advisories]
