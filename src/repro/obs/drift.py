"""Online GRNG drift monitoring from serving-time telemetry.

``hw/calib.measured_grng`` measures a die's Fig. 9 array-sum statistics
once, at calibration time.  This module closes the loop: the telemetry
probe (obs/telemetry) keeps re-measuring the SAME probe block while the
die serves, and the monitor here z-tests the streamed moments against a
calibration-time reference so a deployment that drifts (temperature,
read disturb, aging — the reliability risk Bayes2IMC/FeBiM flag) raises
a recalibration advisory instead of silently degrading verdicts.

The reference must be MEASURED over the probe block, not taken from the
analytic ``sum_mean``/``sum_std`` constants: a finite probe block's
cells have fixed per-cell offsets (clt_grng.cell_mean_offset), so even
a golden die's probe mean sits ~0.1 µA off the population constant —
an analytic reference would false-fire at z≈9 with a few thousand
samples.  :meth:`DriftReference.measure` replays ``clt_grng.raw_sums``
over rows 0..P-1, col 0, exactly matching the serving-time probe.

Which config to measure the reference from is the deployment's BELIEF:
a calibrated deployment believes its measured instance config
(``hcfg.grng``); an uncalibrated one believes the golden factory config
(``cfg.grng``).  Drift is then "reality no longer matches belief" —
which is precisely the condition under which verdict quality decays.

CLI (used by the CI drift smoke): runs a golden and a degraded die
through the serving engine with telemetry on and asserts the monitor
separates them::

    python -m repro.obs.drift --severity 2.5 --out drift_report.json
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from repro.core import clt_grng


@dataclasses.dataclass(frozen=True)
class DriftReference:
    """Calibration-time probe-block statistics to z-test against."""

    sum_mean_uA: float
    sum_std_uA: float
    n: float
    probe_cells: int = 32

    @staticmethod
    def measure(grng_cfg, probe_cells: int = 32,
                n_samples: int = 256) -> "DriftReference":
        """Measure the probe block (rows 0..P-1, col 0) of ``grng_cfg``.

        Mirrors ``hw/calib.measured_grng`` but restricted to the block
        the serving-time probe reads, so reference and stream share the
        same per-cell offsets.
        """
        raw = np.asarray(clt_grng.raw_sums(grng_cfg, probe_cells, 1,
                                           n_samples), dtype=np.float64)
        return DriftReference(
            sum_mean_uA=float(raw.mean()),
            sum_std_uA=float(raw.std(ddof=1)),
            n=float(raw.size),
            probe_cells=int(probe_cells),
        )

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DriftGate:
    """Advisory thresholds. z≈5 keeps the false-fire rate negligible
    while a severity-2.5 die against a golden belief lands far beyond
    it; min_samples guards the small-n regime where the z statistics
    are noisy."""

    z_gate: float = 5.0
    min_samples: int = 256


@dataclasses.dataclass
class DriftStatus:
    """Outcome of one drift evaluation."""

    ok: bool
    drifted: bool
    z_mean: float
    z_std: float
    n: float
    measured_mean_uA: float
    measured_std_uA: float
    reference: DriftReference
    gate: DriftGate
    advisory: str | None = None

    def to_dict(self) -> dict[str, Any]:
        return {
            "ok": self.ok,
            "drifted": self.drifted,
            "z_mean": self.z_mean,
            "z_std": self.z_std,
            "n": self.n,
            "measured_mean_uA": self.measured_mean_uA,
            "measured_std_uA": self.measured_std_uA,
            "reference": self.reference.to_dict(),
            "gate": {"z_gate": self.gate.z_gate,
                     "min_samples": self.gate.min_samples},
            "advisory": self.advisory,
        }


def _evaluate(n: float, s: float, ssq: float, ref: DriftReference,
              gate: DriftGate) -> DriftStatus:
    if n < gate.min_samples or ref.n < 2:
        return DriftStatus(ok=True, drifted=False, z_mean=float("nan"),
                           z_std=float("nan"), n=n,
                           measured_mean_uA=float("nan"),
                           measured_std_uA=float("nan"),
                           reference=ref, gate=gate)
    mean = s / n
    var = max((ssq - n * mean * mean) / (n - 1.0), 1e-12)
    std = math.sqrt(var)
    ref_var = max(ref.sum_std_uA ** 2, 1e-12)
    # Two-sample z for the mean: both the stream estimate and the
    # measured reference carry sampling error.
    se_mean = ref.sum_std_uA * math.sqrt(1.0 / n + 1.0 / ref.n)
    z_mean = (mean - ref.sum_mean_uA) / max(se_mean, 1e-12)
    # Log-variance-ratio z: Var[ln s²] ≈ 2/(n-1) for near-normal sums.
    se_lv = math.sqrt(2.0 / max(n - 1.0, 1.0) + 2.0 / max(ref.n - 1.0, 1.0))
    z_std = math.log(var / ref_var) / max(se_lv, 1e-12)
    drifted = max(abs(z_mean), abs(z_std)) > gate.z_gate
    advisory = None
    if drifted:
        advisory = (
            f"GRNG drift detected on probe block ({ref.probe_cells} cells): "
            f"measured sum stats ({mean:.3f} ± {std:.3f}) µA vs reference "
            f"({ref.sum_mean_uA:.3f} ± {ref.sum_std_uA:.3f}) µA, "
            f"|z_mean|={abs(z_mean):.1f}, |z_std|={abs(z_std):.1f} "
            f"(gate {gate.z_gate:.1f}). Schedule hw/calib recalibration "
            f"(calib.measured_grng + prepare_instance_head) for this die."
        )
    return DriftStatus(ok=not drifted, drifted=drifted,
                       z_mean=float(z_mean), z_std=float(z_std), n=n,
                       measured_mean_uA=float(mean),
                       measured_std_uA=float(std),
                       reference=ref, gate=gate, advisory=advisory)


def drift_status(snapshot: dict[str, Any], ref: DriftReference,
                 gate: DriftGate | None = None) -> DriftStatus:
    """Evaluate a telemetry snapshot (or its ``grng`` sub-dict)."""
    gate = gate or DriftGate()
    g = snapshot.get("grng", snapshot)
    return _evaluate(float(g["n"]), float(g["sum"]), float(g["sumsq"]),
                     ref, gate)


class DriftMonitor:
    """Streaming monitor: fold snapshots in, ask for status anytime."""

    def __init__(self, ref: DriftReference, gate: DriftGate | None = None):
        self.ref = ref
        self.gate = gate or DriftGate()
        self.n = 0.0
        self.sum = 0.0
        self.sumsq = 0.0

    def observe(self, n: float, s: float, ssq: float) -> None:
        self.n += float(n)
        self.sum += float(s)
        self.sumsq += float(ssq)

    def observe_snapshot(self, snapshot: dict[str, Any]) -> None:
        g = snapshot.get("grng", snapshot)
        self.observe(g["n"], g["sum"], g["sumsq"])

    def status(self) -> DriftStatus:
        return _evaluate(self.n, self.sum, self.sumsq, self.ref, self.gate)


def reference_for(cfg, hcfg=None, *, calibrated: bool = True,
                  probe_cells: int = 32,
                  n_samples: int = 256) -> DriftReference:
    """Reference matching a deployment's belief about its GRNG.

    Calibrated deployments believe the measured instance config
    (``hcfg.grng``); uncalibrated ones (or pure-golden, hcfg=None)
    believe the factory config (``cfg.grng``).
    """
    grng = hcfg.grng if (calibrated and hcfg is not None) else cfg.grng
    return DriftReference.measure(grng, probe_cells=probe_cells,
                                  n_samples=n_samples)


def _main() -> int:
    import argparse
    import json
    import os

    from repro.obs.log import get_logger

    log = get_logger("obs:drift")
    ap = argparse.ArgumentParser(
        description="Drift-monitor smoke: serve a golden and a degraded "
                    "die with telemetry on; assert the monitor separates "
                    "them.")
    ap.add_argument("--severity", type=float, default=2.5)
    ap.add_argument("--chip-seed", type=int, default=11)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--z-gate", type=float, default=5.0)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    from repro.hw import VariationSpec, sample_instances
    from repro.launch.serve import serve_sar

    gate = DriftGate(z_gate=args.z_gate)
    report: dict[str, Any] = {"z_gate": args.z_gate,
                              "severity": args.severity, "dies": {}}

    def _serve_and_judge(tag: str, **kw) -> DriftStatus:
        out = serve_sar(n_requests=args.requests, n_slots=args.slots, **kw)
        # serve_sar measured the belief reference already; re-judge the
        # streamed moments under this CLI's gate.
        ref = DriftReference(**out["drift"]["reference"])
        st = drift_status(out["telemetry"], ref, gate)
        report["dies"][tag] = {"status": st.to_dict(),
                               "decisions": out["telemetry"]["decisions"]}
        return st

    # Golden die: reality matches the factory belief — expect healthy.
    st_g = _serve_and_judge("golden")
    log.info("golden die", drifted=st_g.drifted,
             z_mean=round(st_g.z_mean, 2), z_std=round(st_g.z_std, 2))

    # Degraded, uncalibrated die: physics drifted but the deployment
    # still believes the golden config — expect an advisory.
    inst = sample_instances(args.chip_seed, 1,
                            VariationSpec().scaled(args.severity))[0]
    st_d = _serve_and_judge("degraded_uncalibrated", chip_instance=inst,
                            calibrated=False)
    if st_d.advisory:
        log.warning(st_d.advisory)
    log.info("degraded die", drifted=st_d.drifted,
             z_mean=round(st_d.z_mean, 2), z_std=round(st_d.z_std, 2))

    separated = (not st_g.drifted) and st_d.drifted
    report["separated"] = separated
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        log.info("report written", path=args.out, separated=separated)
    if not separated:
        log.error("drift monitor failed to separate golden from degraded",
                  golden_drifted=st_g.drifted, degraded_drifted=st_d.drifted)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
