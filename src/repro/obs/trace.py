"""Per-request span tracing with Chrome-trace / Perfetto JSON export.

Spans are recorded on ``time.perf_counter`` (monotonic — the satellite
fix for latencies going negative under clock adjustment) relative to
the tracer's construction time, and exported in the Chrome trace-event
format: open the JSON in https://ui.perfetto.dev or
``chrome://tracing``.

Track layout for serving: tid 0 is the engine loop (admit / featurize
/ sar_rounds / lm_token / retire spans); tids 1..n_slots are request
tracks, one complete span per request from admit to retirement with
verdict / sample-count args.  :func:`mission_trace` builds the same
format post-hoc from mission logs on the SIMULATED mission clock.

Fleet runs stitch all pools into ONE timeline: pid 0 is the router
(fleet_tick spans + flow starts), pid p+1 is pool p (gang-dispatch
track at tid 0 plus that pool's slot tracks).  Each request carries a
Perfetto flow (ph "s"/"f" keyed by rid) from the router tick that
routed it to the slot span where its verdict landed, so one request is
followable router → pool → slot across tracks.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any

import numpy as np


class Tracer:
    """Collects trace events; export with :meth:`export` / :meth:`to_chrome`."""

    def __init__(self, process_name: str = "repro-serving"):
        self.t0 = time.perf_counter()
        self.process_name = process_name
        self.events: list[dict[str, Any]] = []
        self._thread_names: dict[tuple[int, int], str] = {}
        self._process_names: dict[int, str] = {}

    @property
    def enabled(self) -> bool:
        return True

    def now(self) -> float:
        """Seconds since tracer start (monotonic)."""
        return time.perf_counter() - self.t0

    def name_thread(self, tid: int, name: str, pid: int = 0) -> None:
        self._thread_names[(int(pid), int(tid))] = name

    def name_process(self, pid: int, name: str) -> None:
        self._process_names[int(pid)] = name

    def complete(self, name: str, ts_s: float, dur_s: float, *,
                 tid: int = 0, pid: int = 0, **args) -> None:
        """Record a complete ("X") span at ``ts_s`` lasting ``dur_s`` (s)."""
        self.events.append({
            "name": name, "ph": "X", "pid": int(pid), "tid": int(tid),
            "ts": float(ts_s) * 1e6, "dur": max(float(dur_s), 0.0) * 1e6,
            "args": {k: _plain(v) for k, v in args.items()},
        })

    def instant(self, name: str, ts_s: float | None = None, *,
                tid: int = 0, pid: int = 0, **args) -> None:
        if ts_s is None:
            ts_s = self.now()
        self.events.append({
            "name": name, "ph": "i", "s": "t", "pid": int(pid),
            "tid": int(tid), "ts": float(ts_s) * 1e6,
            "args": {k: _plain(v) for k, v in args.items()},
        })

    @contextmanager
    def span(self, name: str, *, tid: int = 0, pid: int = 0, **args):
        start = self.now()
        try:
            yield
        finally:
            self.complete(name, start, self.now() - start,
                          tid=tid, pid=pid, **args)

    def _flow(self, ph: str, name: str, flow_id: int, ts_s: float, *,
              tid: int, pid: int, cat: str) -> None:
        ev = {"name": name, "ph": ph, "cat": cat, "id": int(flow_id),
              "pid": int(pid), "tid": int(tid), "ts": float(ts_s) * 1e6}
        if ph == "f":
            ev["bp"] = "e"  # bind to the enclosing slice, not the next
        self.events.append(ev)

    def flow_start(self, name: str, flow_id: int,
                   ts_s: float | None = None, *, tid: int = 0,
                   pid: int = 0, cat: str = "req") -> None:
        """Open a Perfetto flow arrow at ``ts_s`` (must land inside a
        slice on that track; Perfetto draws the arrow slice-to-slice)."""
        self._flow("s", name, flow_id, self.now() if ts_s is None
                   else ts_s, tid=tid, pid=pid, cat=cat)

    def flow_step(self, name: str, flow_id: int,
                  ts_s: float | None = None, *, tid: int = 0,
                  pid: int = 0, cat: str = "req") -> None:
        self._flow("t", name, flow_id, self.now() if ts_s is None
                   else ts_s, tid=tid, pid=pid, cat=cat)

    def flow_end(self, name: str, flow_id: int,
                 ts_s: float | None = None, *, tid: int = 0,
                 pid: int = 0, cat: str = "req") -> None:
        self._flow("f", name, flow_id, self.now() if ts_s is None
                   else ts_s, tid=tid, pid=pid, cat=cat)

    def to_chrome(self) -> dict[str, Any]:
        pnames = dict(self._process_names)
        pnames.setdefault(0, self.process_name)
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": name}}
                for pid, name in sorted(pnames.items())]
        for (pid, tid), name in sorted(self._thread_names.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": name}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


class _NullTracer(Tracer):
    """No-op tracer so call sites never branch on ``tracer is None``."""

    def __init__(self):
        super().__init__("null")

    @property
    def enabled(self) -> bool:
        return False

    def name_thread(self, tid, name, pid=0):
        pass

    def name_process(self, pid, name):
        pass

    def complete(self, *a, **k):
        pass

    def instant(self, *a, **k):
        pass

    def _flow(self, *a, **k):
        pass

    @contextmanager
    def span(self, *a, **k):
        yield


NULL_TRACER = _NullTracer()


def _plain(v):
    if isinstance(v, (np.generic,)):
        return v.item()
    if isinstance(v, (int, float, bool, str, type(None))):
        return v
    return str(v)


def mission_trace(logs: dict[str, Any],
                  process_name: str = "repro-mission") -> dict[str, Any]:
    """Chrome trace of a mission rollout on the simulated clock.

    ``logs`` is ``MissionResult.logs``: arrays shaped [steps, drones]
    (``time_s`` gives each step's simulated end time).  One track per
    drone; each step becomes a span named by what happened there
    (found / verify / orbit / look) carrying verdict / spent /
    confidence args.  Purely post-hoc — no serving-path cost.
    """
    t = np.asarray(logs["time_s"], dtype=np.float64)
    steps, drones = t.shape
    tr = Tracer(process_name)
    for d in range(drones):
        tr.name_thread(d + 1, f"drone {d}")
    prev = np.zeros(drones)
    active = np.asarray(logs["active"], dtype=bool)
    verdict = np.asarray(logs["verdict"])
    spent = np.asarray(logs["spent"])
    conf = np.asarray(logs["confidence"], dtype=np.float64)
    found = np.asarray(logs.get("found", np.zeros_like(active)), dtype=bool)
    verify = np.asarray(logs.get("verify", np.zeros_like(active)), dtype=bool)
    orbited = np.asarray(logs.get("orbited", np.zeros_like(active)),
                         dtype=bool)
    for s in range(steps):
        for d in range(drones):
            if not active[s, d]:
                continue
            if found[s, d]:
                name = "found"
            elif verify[s, d]:
                name = "verify"
            elif orbited[s, d]:
                name = "orbit"
            else:
                name = "look"
            dur = max(float(t[s, d]) - float(prev[d]), 0.0)
            tr.complete(name, float(prev[d]), dur, tid=d + 1,
                        step=s, cell=int(np.asarray(logs["cell"])[s, d]),
                        verdict=int(verdict[s, d]), spent=int(spent[s, d]),
                        confidence=round(float(conf[s, d]), 4))
            prev[d] = t[s, d]
    return tr.to_chrome()
