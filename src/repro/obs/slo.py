"""Fleet SLO observability: streaming time-to-verdict tracking.

The serving loop's only latency number used to be a bench-side mean.
This module makes per-request **time-to-verdict** — the quantity SAR
operations actually care about — a first-class, continuously-monitored
stream:

- :class:`SloTracker` folds every retired
  :class:`~repro.serving.metrics.RequestRecord` into log-spaced
  latency histograms (overall, per-verdict, per-R-at-verdict, plus the
  queue-wait / service decomposition) and tracks violations against
  declared :class:`SLO` objects with error-budget burn-rate
  accounting.
- Fleet-path hooks record router decision latency, per-pool
  queue-depth / backlog-occupancy gauges sampled per tick, and
  backpressure events.

Everything here is host-side bookkeeping performed at the engine's
EXISTING host-sync points (the same discipline as
:mod:`repro.obs.prof`): no jitted graph ever sees the tracker, so
verdicts stay bit-identical and host-syncs/decision is unchanged
whether tracking is on or off — tests/test_slo.py asserts exactly
that.  :data:`NULL_SLO` is the no-op twin so call sites never branch.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable

import numpy as np

from repro.obs.registry import quantile

# Log-spaced latency edges: 1 µs .. 100 s, 4 buckets per decade — one
# decade wider at the top than obs/prof's stage edges because queue
# delays under overload legitimately reach tens of seconds.
_EDGES = np.logspace(-6, 2, 33)

# Triage verdict codes (serving/triage.py: ACCEPT/ESCALATE/FLAG).
# Spelled out rather than imported so obs stays importable while the
# serving package is still mid-initialisation (engine.py imports obs).
_VERDICTS = {0: "accept", 1: "escalate", 2: "flag"}


def _percentile(tag: str) -> float:
    """``"p99"`` / ``"99"`` / ``"0.99"`` → 0.99."""
    v = float(tag.lower().lstrip("p"))
    return v / 100.0 if v > 1.0 else v


@dataclasses.dataclass(frozen=True)
class SLO:
    """A latency objective: ``percentile`` of requests must see a
    verdict within ``target_s``.  The error budget is the allowed miss
    fraction (1 - percentile); ``burn_rate`` is observed-miss-rate over
    that budget, and a breach fires when it exceeds ``burn_alert``."""

    target_s: float
    percentile: float = 0.99
    burn_alert: float = 2.0

    @classmethod
    def parse(cls, spec: str) -> "SLO":
        """Parse ``"0.25:p99"`` / ``"0.25:p99:2.0"`` / ``"0.25"``."""
        parts = [p for p in str(spec).split(":") if p]
        target = float(parts[0])
        pct = _percentile(parts[1]) if len(parts) > 1 else 0.99
        burn = float(parts[2]) if len(parts) > 2 else 2.0
        return cls(target_s=target, percentile=pct, burn_alert=burn)

    @property
    def error_budget(self) -> float:
        return max(1.0 - self.percentile, 1e-9)

    @property
    def name(self) -> str:
        return f"p{self.percentile * 100.0:g}<={self.target_s:g}s"

    def evaluate(self, violations: int, n: int) -> dict[str, Any]:
        miss = violations / n if n else float("nan")
        burn = miss / self.error_budget if n else float("nan")
        return {
            "name": self.name,
            "target_s": self.target_s,
            "percentile": self.percentile,
            "burn_alert": self.burn_alert,
            "requests": int(n),
            "violations": int(violations),
            "attainment": 1.0 - miss if n else float("nan"),
            "error_budget": self.error_budget,
            "burn_rate": burn,
            "breach": bool(n and burn > self.burn_alert),
        }


class _Hist:
    """One streaming log-spaced histogram (same bin semantics as
    StageProfiler: NaN dropped, negatives clamp to the first bin,
    observations past the last edge land in ``overflow``)."""

    __slots__ = ("counts", "overflow", "total_s", "n")

    def __init__(self):
        self.counts = np.zeros(len(_EDGES) - 1, dtype=np.int64)
        self.overflow = 0
        self.total_s = 0.0
        self.n = 0

    def observe(self, dt_s: float) -> None:
        dt = float(dt_s)
        if math.isnan(dt):
            return
        dt = max(dt, 0.0)
        self.total_s += dt
        self.n += 1
        if dt >= _EDGES[-1]:
            self.overflow += 1
            return
        i = int(np.searchsorted(_EDGES, dt, side="right")) - 1
        self.counts[max(i, 0)] += 1

    def to_dict(self) -> dict[str, Any]:
        return {"count": int(self.n), "total_s": self.total_s,
                "counts": self.counts.tolist(),
                "overflow": int(self.overflow),
                "edges": _EDGES.tolist()}


class SloTracker:
    """Streams retired requests into TTV histograms and SLO ledgers."""

    edges = _EDGES

    def __init__(self, slos: Iterable[SLO | str] = ()):
        self.slos: list[SLO] = [
            SLO.parse(s) if isinstance(s, str) else s for s in slos]
        self._violations = [0] * len(self.slos)
        self._ttv = _Hist()
        self._queue = _Hist()
        self._service = _Hist()
        self._router = _Hist()
        self._by_verdict: dict[str, _Hist] = {}
        self._by_r: dict[int, _Hist] = {}
        self._n = 0
        self._first_arrival = math.inf
        self._last_done = -math.inf
        # fleet-path gauges (per-tick samples)
        self._ticks = 0
        self.backpressure_ticks = 0
        self.backlog_peak = 0
        self._backlog_sum = 0
        self._pool_depth_peak: list[int] = []
        self._pool_depth_sum: list[int] = []
        self._active_sum = 0

    @property
    def enabled(self) -> bool:
        return True

    def add_slo(self, slo: SLO | str) -> None:
        self.slos.append(SLO.parse(slo) if isinstance(slo, str) else slo)
        self._violations.append(0)

    # ---- request path (called at existing host-sync points) ----

    def observe(self, rec) -> None:
        """Fold one retired RequestRecord into the stream."""
        t = rec.verdict_latency_s
        if math.isnan(t):
            t = rec.latency_s
        self._n += 1
        self._ttv.observe(t)
        self._queue.observe(rec.queue_latency_s)
        self._service.observe(rec.service_latency_s)
        name = _VERDICTS.get(int(rec.verdict), str(int(rec.verdict)))
        h = self._by_verdict.get(name)
        if h is None:
            h = self._by_verdict[name] = _Hist()
        h.observe(t)
        r = int(round(rec.n_samples / max(rec.n_decisions, 1)))
        hr = self._by_r.get(r)
        if hr is None:
            hr = self._by_r[r] = _Hist()
        hr.observe(t)
        for k, slo in enumerate(self.slos):
            if t > slo.target_s:
                self._violations[k] += 1
        arr = rec.arrival_pc
        if math.isnan(arr):
            arr = rec.arrival_s
        self._first_arrival = min(self._first_arrival, arr)
        self._last_done = max(self._last_done, rec.done_s)

    # ---- fleet path ----

    def observe_router(self, dt_s: float) -> None:
        self._router.observe(dt_s)

    def sample_queues(self, depths: Iterable[int], active: Iterable[int],
                      backlog: int) -> None:
        """Per-tick gauge sample: per-pool admission-queue depths,
        per-pool active-slot counts, and the fleet backlog depth."""
        self._ticks += 1
        depths = list(depths)
        while len(self._pool_depth_peak) < len(depths):
            self._pool_depth_peak.append(0)
            self._pool_depth_sum.append(0)
        for p, d in enumerate(depths):
            d = int(d)
            self._pool_depth_peak[p] = max(self._pool_depth_peak[p], d)
            self._pool_depth_sum[p] += d
        self._active_sum += int(sum(active))
        backlog = int(backlog)
        self.backlog_peak = max(self.backlog_peak, backlog)
        self._backlog_sum += backlog

    def backpressure(self, backlog_depth: int) -> None:
        """One fleet tick where routing left requests in the backlog
        because every pool's bounded queue was full."""
        self.backpressure_ticks += 1
        self.backlog_peak = max(self.backlog_peak, int(backlog_depth))

    # ---- readout ----

    def snapshot(self) -> dict[str, Any]:
        """JSON-ready snapshot: histograms + quantiles + SLO ledgers.
        Empty dict when nothing was observed (so summaries stay clean
        on untracked runs)."""
        if self._n == 0 and self._ticks == 0:
            return {}
        ttv = self._ttv.to_dict()
        qsum, ssum = self._queue.total_s, self._service.total_s
        out: dict[str, Any] = {
            "requests": self._n,
            "time_to_verdict": ttv,
            "queue_wait": self._queue.to_dict(),
            "service": self._service.to_dict(),
            "by_verdict": {k: h.to_dict()
                           for k, h in sorted(self._by_verdict.items())},
            "by_r": {str(r): h.to_dict()
                     for r, h in sorted(self._by_r.items())},
            "p50_s": quantile(ttv, 0.50),
            "p95_s": quantile(ttv, 0.95),
            "p99_s": quantile(ttv, 0.99),
            "mean_s": ttv["total_s"] / max(self._n, 1),
            "queue_wait_share": qsum / (qsum + ssum)
                                if (qsum + ssum) > 0 else 0.0,
            "span_s": (self._last_done - self._first_arrival)
                      if self._n else float("nan"),
            "slos": [slo.evaluate(v, self._n)
                     for slo, v in zip(self.slos, self._violations)],
        }
        if self._router.n:
            out["router"] = self._router.to_dict()
        if self._ticks:
            t = self._ticks
            out["fleet"] = {
                "ticks": t,
                "backpressure_ticks": self.backpressure_ticks,
                "backlog_peak": self.backlog_peak,
                "backlog_mean": self._backlog_sum / t,
                "queue_depth_peak": list(self._pool_depth_peak),
                "queue_depth_mean": [s / t for s in self._pool_depth_sum],
                "mean_active_slots": self._active_sum / t,
            }
        return out


class _NullSloTracker(SloTracker):
    """No-op twin so call sites never branch on ``slo is None``."""

    def __init__(self):
        super().__init__()

    @property
    def enabled(self) -> bool:
        return False

    def observe(self, rec) -> None:
        pass

    def observe_router(self, dt_s) -> None:
        pass

    def sample_queues(self, depths, active, backlog) -> None:
        pass

    def backpressure(self, backlog_depth) -> None:
        pass

    def snapshot(self) -> dict[str, Any]:
        return {}


NULL_SLO = _NullSloTracker()
