"""Metric registry with Prometheus-text and JSON exporters.

Nothing here talks to a network — the serving loop is a benchmark
process, not a daemon — so "export" means writing the standard
Prometheus text exposition format (and a JSON twin) to files that CI
uploads as artifacts and operators can scrape or diff.  Histograms are
emitted with cumulative ``_bucket{le=...}`` counts per the exposition
spec; ``_sum`` is approximated from bin midpoints since the
device-resident histograms bin on device and never keep raw values.
"""

from __future__ import annotations

import json
import math
from typing import Any

_BAD = {ord(c): "_" for c in "-. /"}


def _name(n: str) -> str:
    return n.translate(_BAD)


def _escape(v: Any) -> str:
    """Escape a label VALUE per the exposition spec: backslash, double
    quote, and newline must be backslash-escaped inside the quotes."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labels(labels: dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_name(str(k))}="{_escape(v)}"'
                     for k, v in labels.items())
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


class MetricsRegistry:
    """Ordered collection of counters, gauges, and histograms."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._metrics: list[dict[str, Any]] = []

    def _add(self, kind: str, name: str, value, help_: str,
             labels: dict[str, Any]) -> None:
        self._metrics.append({
            "kind": kind, "name": f"{self.namespace}_{_name(name)}",
            "value": value, "help": help_, "labels": dict(labels or {})})

    def counter(self, name: str, value: float, help: str = "",
                **labels) -> None:
        self._add("counter", name, float(value), help, labels)

    def gauge(self, name: str, value: float, help: str = "",
              **labels) -> None:
        self._add("gauge", name, float(value), help, labels)

    def histogram(self, name: str, counts: list, edges: list,
                  help: str = "", overflow: int = 0, sum: float | None = None,
                  **labels) -> None:
        """``counts`` has len(edges)-1 bins; edges are ascending.

        ``overflow`` counts observations above the last edge (they land
        only in the ``+Inf`` bucket); ``sum`` overrides the midpoint
        approximation of ``_sum`` when the true total is known (e.g.
        StageProfiler tracks total_s exactly).  Non-finite bin counts
        (NaN propagated through a device histogram) sanitize to 0 so
        the exposition stays parseable."""
        clean = [int(c) if c == c and abs(c) != float("inf") else 0
                 for c in counts]
        self._add("histogram", name,
                  {"counts": clean,
                   "edges": [float(e) for e in edges],
                   "overflow": int(overflow),
                   "sum": None if sum is None else float(sum)},
                  help, labels)

    def to_json(self) -> dict[str, Any]:
        return {"namespace": self.namespace, "metrics": self._metrics}

    def to_prometheus(self) -> str:
        lines: list[str] = []
        seen_header: set[str] = set()
        for m in self._metrics:
            name, kind = m["name"], m["kind"]
            if name not in seen_header:
                if m["help"]:
                    lines.append(f"# HELP {name} {m['help']}")
                lines.append(f"# TYPE {name} {kind}")
                seen_header.add(name)
            if kind in ("counter", "gauge"):
                lines.append(f"{name}{_labels(m['labels'])} "
                             f"{_fmt(m['value'])}")
                continue
            counts, edges = m["value"]["counts"], m["value"]["edges"]
            over = m["value"].get("overflow", 0)
            cum, approx_sum = 0, 0.0
            for i, c in enumerate(counts):
                cum += c
                mid = 0.5 * (edges[i] + edges[i + 1])
                if math.isfinite(mid):
                    approx_sum += c * mid
                lb = dict(m["labels"]);  lb["le"] = _fmt(float(edges[i + 1]))
                lines.append(f"{name}_bucket{_labels(lb)} {cum}")
            total = cum + over
            if over and edges and math.isfinite(edges[-1]):
                approx_sum += over * edges[-1]
            lb = dict(m["labels"]);  lb["le"] = "+Inf"
            lines.append(f"{name}_bucket{_labels(lb)} {total}")
            true_sum = m["value"].get("sum")
            lines.append(f"{name}_sum{_labels(m['labels'])} "
                         f"{_fmt(approx_sum if true_sum is None else true_sum)}")
            lines.append(f"{name}_count{_labels(m['labels'])} {total}")
        return "\n".join(lines) + "\n"

    def write(self, prefix: str) -> tuple[str, str]:
        """Write ``<prefix>.prom`` and ``<prefix>.json``; return paths."""
        import os
        d = os.path.dirname(prefix)
        if d:
            os.makedirs(d, exist_ok=True)
        prom, js = f"{prefix}.prom", f"{prefix}.json"
        with open(prom, "w") as f:
            f.write(self.to_prometheus())
        with open(js, "w") as f:
            json.dump(self.to_json(), f, indent=2)
        return prom, js


def quantile(hist: dict[str, Any], q: float) -> float:
    """Estimate the ``q``-quantile (0..1) of a binned histogram.

    ``hist`` is the shared snapshot shape: ``counts`` (len(edges)-1
    bins), ``edges`` (ascending), optional ``overflow`` above the last
    edge.  Within the landing bin the mass is interpolated
    geometrically when both edges are positive (the edges are
    log-spaced, so log-linear interpolation is the unbiased choice),
    linearly otherwise.  Overflow mass resolves to the last edge — a
    deliberate underestimate that keeps the readout monotone.  NaN on
    an empty histogram.
    """
    counts = [float(c) for c in hist["counts"]]
    edges = [float(e) for e in hist["edges"]]
    over = float(hist.get("overflow", 0))
    total = sum(counts) + over
    if total <= 0:
        return float("nan")
    target = min(max(float(q), 0.0), 1.0) * total
    cum = 0.0
    for i, c in enumerate(counts):
        if c > 0 and cum + c >= target:
            frac = (target - cum) / c
            lo, hi = edges[i], edges[i + 1]
            if lo > 0 and hi > 0:
                return lo * (hi / lo) ** frac
            return lo + (hi - lo) * frac
        cum += c
    return edges[-1]


def add_summary(reg: MetricsRegistry, summary: dict[str, Any],
                **labels) -> None:
    """Map a ServingMetrics / mission summary's scalars to gauges."""
    for k, v in summary.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        reg.gauge(k, float(v), **labels)


def add_telemetry(reg: MetricsRegistry, snap: dict[str, Any],
                  **labels) -> None:
    """Map an obs.telemetry snapshot into the registry."""
    if not snap:
        return
    for k in ("rounds", "dispatches", "samples", "decisions"):
        reg.counter(f"telemetry_{k}_total", snap[k], **labels)
    for verdict, c in snap["verdicts"].items():
        reg.counter("telemetry_verdicts_total", c, verdict=verdict,
                    **labels)
    r_hist = snap["r_hist"]
    reg.histogram("telemetry_samples_at_verdict", r_hist,
                  list(range(len(r_hist) + 1)),
                  help="GRNG samples spent when the verdict landed",
                  **labels)
    reg.histogram("telemetry_confidence", snap["conf_hist"],
                  snap["conf_edges"], **labels)
    reg.histogram("telemetry_predictive_entropy", snap["ent_hist"],
                  snap["ent_edges"], **labels)
    reg.histogram("telemetry_mutual_information", snap["mi_hist"],
                  snap["ent_edges"], **labels)
    g = snap["grng"]
    reg.gauge("grng_probe_samples", g["n"], **labels)
    reg.gauge("grng_probe_sum_mean_uA", g["sum_mean_uA"], **labels)
    reg.gauge("grng_probe_sum_std_uA", g["sum_std_uA"], **labels)


def add_drift(reg: MetricsRegistry, status: dict[str, Any],
              **labels) -> None:
    """Map an obs.drift status dict into the registry."""
    if not status:
        return
    reg.gauge("grng_drift_z_mean", status["z_mean"], **labels)
    reg.gauge("grng_drift_z_std", status["z_std"], **labels)
    reg.gauge("grng_drift_advisory", 1.0 if status["drifted"] else 0.0,
              help="1 when recalibration is advised", **labels)


def add_stage_profile(reg: MetricsRegistry, snap: dict[str, Any],
                      **labels) -> None:
    """Map an obs.prof.StageProfiler snapshot into the registry: one
    ``stage_latency_seconds`` histogram per stage plus an exact-count
    counter (the histogram's +Inf bucket carries overflow)."""
    for stage, rec in (snap or {}).items():
        reg.counter("stage_total", rec["count"],
                    help="loop-stage executions", stage=stage, **labels)
        reg.histogram("stage_latency_seconds", rec["counts"],
                      rec["edges"], overflow=rec.get("overflow", 0),
                      sum=rec.get("total_s"),
                      help="host-side latency of one serving-loop stage",
                      stage=stage, **labels)


def add_compile_counters(reg: MetricsRegistry, counters: dict[str, Any],
                         **labels) -> None:
    """Map an obs.prof.compile_counters() snapshot into the registry."""
    if not counters:
        return
    for builder, n in sorted(counters.get("builder_builds", {}).items()):
        reg.counter("engine_builder_builds_total", n,
                    help="executable constructions per cached engine "
                         "builder (lru_cache misses)",
                    builder=builder, **labels)
    reg.counter("xla_compile_events_total",
                counters.get("xla_compile_events", 0),
                help="XLA backend compiles observed in this process",
                **labels)
    reg.counter("xla_compile_seconds_total",
                counters.get("xla_compile_seconds", 0.0), **labels)


def add_compiled_costs(reg: MetricsRegistry, records: list,
                       **labels) -> None:
    """Map obs.prof.CostRegistry records into per-function gauges."""
    for rec in records or []:
        lb = dict(labels, fn=rec["name"])
        for k in ("flops", "hbm_bytes", "peak_live_bytes", "compile_s",
                  "xla_flops", "xla_bytes_accessed"):
            if k in rec:
                reg.gauge(f"compiled_{k}", rec[k], **lb)


def add_slo(reg: MetricsRegistry, snap: dict[str, Any],
            **labels) -> None:
    """Map an obs.slo.SloTracker snapshot into the registry."""
    if not snap:
        return
    reg.counter("slo_requests_total", snap.get("requests", 0), **labels)
    for key, metric in (("time_to_verdict", "slo_time_to_verdict_seconds"),
                        ("queue_wait", "slo_queue_wait_seconds"),
                        ("service", "slo_service_seconds"),
                        ("router", "slo_router_decision_seconds")):
        h = snap.get(key)
        if h and h.get("count"):
            reg.histogram(metric, h["counts"], h["edges"],
                          overflow=h.get("overflow", 0),
                          sum=h.get("total_s"), **labels)
    for verdict, h in (snap.get("by_verdict") or {}).items():
        reg.histogram("slo_time_to_verdict_seconds", h["counts"],
                      h["edges"], overflow=h.get("overflow", 0),
                      sum=h.get("total_s"), verdict=verdict, **labels)
    for r, h in (snap.get("by_r") or {}).items():
        reg.gauge("slo_ttv_p99_seconds", quantile(h, 0.99),
                  help="p99 time-to-verdict by samples-at-verdict",
                  r_at_verdict=r, **labels)
        reg.counter("slo_requests_by_r_total", h["count"],
                    r_at_verdict=r, **labels)
    for k in ("p50_s", "p95_s", "p99_s", "mean_s", "queue_wait_share"):
        if k in snap:
            reg.gauge(f"slo_ttv_{k}" if k.endswith("_s") else f"slo_{k}",
                      snap[k], **labels)
    for s in snap.get("slos") or []:
        lb = dict(labels, slo=s["name"])
        reg.gauge("slo_attainment", s["attainment"],
                  help="fraction of requests within the SLO target", **lb)
        reg.gauge("slo_burn_rate", s["burn_rate"],
                  help="observed miss rate over the error budget", **lb)
        reg.gauge("slo_breach", 1.0 if s["breach"] else 0.0, **lb)
    fleet = snap.get("fleet")
    if fleet:
        reg.counter("fleet_ticks_total", fleet["ticks"], **labels)
        reg.counter("fleet_backpressure_ticks_total",
                    fleet["backpressure_ticks"],
                    help="fleet ticks where routing left backlog behind",
                    **labels)
        reg.gauge("fleet_backlog_peak", fleet["backlog_peak"], **labels)
        reg.gauge("fleet_backlog_mean", fleet["backlog_mean"], **labels)
        for p, peak in enumerate(fleet.get("queue_depth_peak", [])):
            reg.gauge("fleet_queue_depth_peak", peak, pool=p, **labels)
        for p, mean in enumerate(fleet.get("queue_depth_mean", [])):
            reg.gauge("fleet_queue_depth_mean", mean, pool=p, **labels)


def add_alerts(reg: MetricsRegistry, advisories: list,
               **labels) -> None:
    """Map an obs.alerts advisory stream into the registry: counters
    per (kind, severity) plus the last-event timestamp per kind."""
    if not advisories:
        return
    counts: dict[tuple, int] = {}
    last_ts: dict[str, float] = {}
    for a in advisories:
        d = a if isinstance(a, dict) else a.to_dict()
        counts[(d["kind"], d["severity"])] = \
            counts.get((d["kind"], d["severity"]), 0) + 1
        last_ts[d["kind"]] = max(last_ts.get(d["kind"], 0.0),
                                 float(d.get("ts_s", 0.0)))
    for (kind, sev), n in sorted(counts.items()):
        reg.counter("alerts_total", n, kind=kind, severity=sev, **labels)
    for kind, ts in sorted(last_ts.items()):
        reg.gauge("alert_last_ts_seconds", ts, kind=kind, **labels)


def serving_registry(summary: dict[str, Any], *,
                     telemetry: dict[str, Any] | None = None,
                     drift: dict[str, Any] | None = None,
                     profile: dict[str, Any] | None = None,
                     compile_counters: dict[str, Any] | None = None,
                     compiled_costs: list | None = None,
                     slo: dict[str, Any] | None = None,
                     alerts: list | None = None,
                     **labels) -> MetricsRegistry:
    """One-call registry for a serving run's summary + telemetry.

    ``profile`` / ``compile_counters`` default to what the engine
    attached to the summary (``stage_profile`` / ``compile_counters``
    keys), so callers that just forward the run dict get the perf
    exposition for free."""
    reg = MetricsRegistry()
    add_summary(reg, summary, job="serving", **labels)
    if telemetry:
        add_telemetry(reg, telemetry, job="serving", **labels)
    if drift:
        add_drift(reg, drift, job="serving", **labels)
    profile = profile if profile is not None else \
        summary.get("stage_profile")
    if profile:
        add_stage_profile(reg, profile, job="serving", **labels)
    compile_counters = compile_counters if compile_counters is not None \
        else summary.get("compile_counters")
    if compile_counters:
        add_compile_counters(reg, compile_counters, job="serving",
                             **labels)
    if compiled_costs:
        add_compiled_costs(reg, compiled_costs, job="serving", **labels)
    slo = slo if slo is not None else summary.get("slo")
    if slo:
        add_slo(reg, slo, job="serving", **labels)
    if alerts:
        add_alerts(reg, alerts, job="serving", **labels)
    return reg


def mission_registry(summary: dict[str, Any], *,
                     telemetry: dict[str, Any] | None = None,
                     alerts: list | None = None,
                     **labels) -> MetricsRegistry:
    """Registry for a mission run; ``telemetry`` maps group name →
    {"telemetry": snapshot, "drift": status}."""
    reg = MetricsRegistry()
    add_summary(reg, summary, job="mission", **labels)
    for group, t in (telemetry or {}).items():
        if t.get("telemetry"):
            add_telemetry(reg, t["telemetry"], job="mission",
                          die_group=group, **labels)
        if t.get("drift"):
            add_drift(reg, t["drift"], job="mission", die_group=group,
                      **labels)
    if alerts:
        add_alerts(reg, alerts, job="mission", **labels)
    return reg
