"""Structured logging for the launch drivers and benches.

The repo's CLIs used ad-hoc ``print()``; this is the drop-in
replacement: leveled, optionally JSON-lines (one object per line, for
log shippers), tunable via environment so CI and operators control
verbosity without touching code.

  REPRO_LOG_LEVEL   debug | info | warning | error   (default info)
  REPRO_LOG_JSON    1/true → JSON-lines records on stdout

Text mode keeps the old ``[component] message`` shape so existing CI
log greps and humans see what they always saw.  The env knobs are read
at EMIT time (cheap dict lookups), so tests and long-lived processes
can flip them without rebuilding loggers.
"""

from __future__ import annotations

import json
import os
import sys
import time

_LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


def _threshold() -> int:
    name = os.environ.get("REPRO_LOG_LEVEL", "info").strip().lower()
    return _LEVELS.get(name, _LEVELS["info"])


def _json_mode() -> bool:
    raw = os.environ.get("REPRO_LOG_JSON", "").strip().lower()
    return raw in ("1", "true", "yes", "on")


class Logger:
    """Leveled logger with key=value structured fields.

    ``log.info("served", decisions=192)`` renders as
    ``[name] served decisions=192`` in text mode and as a JSON object
    in JSON-lines mode.  Numeric/bool/None fields pass through to JSON
    verbatim; everything else is stringified.
    """

    def __init__(self, name: str, stream=None):
        self.name = name
        self._stream = stream

    @property
    def stream(self):
        return self._stream if self._stream is not None else sys.stdout

    def _emit(self, level: str, msg: str, fields: dict) -> None:
        if _LEVELS[level] < _threshold():
            return
        if _json_mode():
            rec = {"ts": time.time(), "level": level, "logger": self.name,
                   "msg": msg}
            for k, v in fields.items():
                rec[k] = v if isinstance(v, (int, float, bool, str,
                                             type(None))) else str(v)
            print(json.dumps(rec), file=self.stream, flush=True)
            return
        kv = " ".join(f"{k}={v}" for k, v in fields.items())
        tag = f"[{self.name}] " if self.name else ""
        line = f"{tag}{msg}"
        if kv:
            line = f"{line} {kv}"
        print(line, file=self.stream, flush=True)

    def debug(self, msg: str, **fields) -> None:
        self._emit("debug", msg, fields)

    def info(self, msg: str, **fields) -> None:
        self._emit("info", msg, fields)

    def warning(self, msg: str, **fields) -> None:
        self._emit("warning", msg, fields)

    def error(self, msg: str, **fields) -> None:
        self._emit("error", msg, fields)


_LOGGERS: dict[str, Logger] = {}


def get_logger(name: str) -> Logger:
    """Process-cached logger for ``name`` (the ``[name]`` text prefix)."""
    if name not in _LOGGERS:
        _LOGGERS[name] = Logger(name)
    return _LOGGERS[name]
