from repro.runtime.straggler import StragglerConfig, StragglerMonitor
from repro.runtime.elastic import (make_elastic_mesh, remesh_train_state,
                                   remesh_tree, shrink_mesh)

__all__ = ["StragglerConfig", "StragglerMonitor", "make_elastic_mesh",
           "remesh_train_state", "remesh_tree", "shrink_mesh"]
