"""Straggler detection and mitigation policy.

At 1000+ nodes, per-step time is gated by the slowest participant.  The
monitor tracks an EMA of step durations and flags outliers; the policy
layer decides what to do — in this framework:

  * ``log``      — record only (default; feeds the metrics stream),
  * ``rebatch``  — shrink the straggler's microbatch share (cooperating
    with gradient accumulation),
  * ``exclude``  — vote the node out and trigger an elastic re-mesh
    (runtime/elastic.py) from the last checkpoint.

On a single-host dev box the monitor sees jitted step times; the unit
tests drive it with synthetic timings.  The decision logic is identical
at scale — detection is host-local and cheap (no collective).
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StragglerConfig:
    ema_decay: float = 0.9
    threshold: float = 2.0      # flag when step > threshold × EMA
    patience: int = 3           # consecutive flags before escalation
    policy: str = "log"         # log | rebatch | exclude


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.ema: float | None = None
        self.flags = 0
        self.events: list[dict] = []
        self._t0: float | None = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int, duration: float | None = None) -> dict:
        """Record a step; returns {'flagged': bool, 'action': str|None}."""
        if duration is None:
            duration = time.monotonic() - (self._t0 or time.monotonic())
        out = {"step": step, "duration": duration, "flagged": False,
               "action": None}
        if self.ema is None:
            self.ema = duration
            return out
        if duration > self.cfg.threshold * self.ema:
            self.flags += 1
            out["flagged"] = True
            if self.flags >= self.cfg.patience:
                out["action"] = self.cfg.policy
                self.events.append(out)
                self.flags = 0
        else:
            self.flags = 0
        # EMA excludes flagged steps so a long stall doesn't poison it.
        if not out["flagged"]:
            d = self.cfg.ema_decay
            self.ema = d * self.ema + (1 - d) * duration
        return out

    def microbatch_share(self, base: int) -> int:
        """rebatch policy: halve this node's microbatch after escalation."""
        if self.cfg.policy != "rebatch" or not self.events:
            return base
        return max(1, base // 2)
