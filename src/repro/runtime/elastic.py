"""Elastic scaling: re-mesh live state when the device pool changes.

Node loss (or growth) at scale means the mesh shape changes.  The
recovery path implemented here:

  1. ``shrink_mesh``/``make_elastic_mesh`` builds a new mesh over the
     surviving devices (keeping the 'model' extent if possible — TP
     degree is baked into weight shapes' divisibility, DP is not);
  2. ``remesh_tree`` re-shards a live pytree onto the new mesh with
     freshly resolved specs (the divisibility-aware rules in
     sharding/specs.py re-evaluate against the new axis sizes);
  3. the launcher re-jits its step for the new mesh and continues from
     the in-memory state — no checkpoint round-trip needed when the
     state survived; otherwise ckpt.restore provides it.

Tested by training on a mesh over N fake devices, shrinking to N/2,
and asserting loss continuity (tests/test_elastic.py).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.launch.mesh import axis_types_kwargs
from repro.sharding import specs as S


def make_elastic_mesh(devices=None, model_parallel: int | None = None) -> Mesh:
    """Mesh over an arbitrary device list: ('data', 'model')."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if model_parallel is None:
        model_parallel = 1
        for cand in (16, 8, 4, 2):
            if n % cand == 0:
                model_parallel = cand
                break
    assert n % model_parallel == 0
    arr = np.array(devices).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, ("data", "model"), **axis_types_kwargs(2))


def shrink_mesh(mesh: Mesh, lost_devices: set) -> Mesh:
    """Rebuild the mesh without the lost devices (same axis names)."""
    survivors = [d for d in mesh.devices.flatten() if d.id not in lost_devices]
    model = mesh.shape.get("model", 1)
    while model > 1 and len(survivors) % model != 0:
        model //= 2
    usable = (len(survivors) // model) * model
    return make_elastic_mesh(survivors[:usable], model_parallel=model)


def remesh_tree(tree, new_mesh: Mesh, spec_fn=S.param_specs):
    """Re-shard a live pytree onto a new mesh.

    Device buffers are pulled to host implicitly by jax.device_put when
    source and destination shardings differ; at multi-host scale this
    becomes a resharding transfer — the API is the same.
    """
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    new_specs = spec_fn(abstract, new_mesh)
    named = S.to_named(new_specs, new_mesh)
    return jax.device_put(tree, named)


def remesh_train_state(params, opt_state, new_mesh: Mesh):
    params = remesh_tree(params, new_mesh, S.param_specs)
    opt_state = remesh_tree(opt_state, new_mesh, S.opt_state_specs)
    return params, opt_state
