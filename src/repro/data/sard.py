"""Synthetic SARD: aerial search-and-rescue imagery stand-in (§V-B).

The paper evaluates on the (non-redistributable) SARD dataset.  We
reproduce the *experiment design* on a procedurally generated analogue
with matched difficulty knobs:

  * aerial background: smooth multi-octave clutter (terrain),
  * victims: small elongated Gaussian blobs (lying/kneeling poses) whose
    size shrinks with simulated altitude (the paper's 15–75 m range),
  * distractors: rock-like compact blobs that confuse the detector
    (the source of overconfident false positives the paper targets),
  * Corr partitions: fog / frost / motion / snow corruptions (Fig. 17).

Task: patch-level victim classification (victim present / absent).
Labels are balanced; each image is a pure function of (seed, index).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SardConfig:
    image_size: int = 32
    seed: int = 0
    victim_intensity: float = 2.4
    distractor_intensity: float = 1.3   # close to victims: hard negatives
    altitude_range: tuple = (0.6, 1.4)  # scales blob size (15–75 m proxy)
    clutter: float = 0.8


def _smooth_noise(key, n, octaves=3):
    """Multi-octave smooth clutter [n, n]."""
    img = jnp.zeros((n, n))
    for o in range(octaves):
        k = jax.random.fold_in(key, o)
        size = max(2, n // (2 ** (octaves - o)))
        coarse = jax.random.normal(k, (size, size))
        img = img + jax.image.resize(coarse, (n, n), "bilinear") / (2 ** o)
    return img


def _blob(n, cy, cx, sy, sx, theta):
    """Anisotropic Gaussian blob (elongation ~ lying pose)."""
    y = jnp.arange(n)[:, None] - cy
    x = jnp.arange(n)[None, :] - cx
    ct, st = jnp.cos(theta), jnp.sin(theta)
    u = ct * y + st * x
    v = -st * y + ct * x
    return jnp.exp(-0.5 * ((u / sy) ** 2 + (v / sx) ** 2))


def make_image(cfg: SardConfig, key, has_victim) -> jnp.ndarray:
    n = cfg.image_size
    ks = jax.random.split(key, 10)
    img = cfg.clutter * _smooth_noise(ks[0], n)
    altitude = jax.random.uniform(ks[1], (), minval=cfg.altitude_range[0],
                                  maxval=cfg.altitude_range[1])
    # distractor rock (always present — the hard negative)
    dc = jax.random.uniform(ks[2], (2,), minval=4.0, maxval=n - 4.0)
    img = img + cfg.distractor_intensity * _blob(
        n, dc[0], dc[1], 1.5 / altitude, 1.5 / altitude, 0.0)
    # victim blob (elongated, pose angle random)
    vc = jax.random.uniform(ks[3], (2,), minval=4.0, maxval=n - 4.0)
    theta = jax.random.uniform(ks[4], (), maxval=np.pi)
    victim = cfg.victim_intensity * _blob(
        n, vc[0], vc[1], 2.5 / altitude, 1.0 / altitude, theta)
    img = img + has_victim * victim
    img = img + 0.1 * jax.random.normal(ks[5], (n, n))   # sensor noise
    return img[..., None]                                 # [n, n, 1]


@partial(jax.jit, static_argnums=(0, 2))
def make_batch(cfg: SardConfig, key, batch: int) -> dict:
    kl, ki = jax.random.split(key)
    labels = (jnp.arange(batch) % 2).astype(jnp.int32)   # balanced
    labels = jax.random.permutation(kl, labels)
    keys = jax.random.split(ki, batch)
    images = jax.vmap(lambda k, y: make_image(cfg, k, y.astype(jnp.float32))
                      )(keys, labels)
    return {"images": images, "labels": labels}


def batch_at(cfg: SardConfig, step: int, batch: int) -> dict:
    return make_batch(cfg, jax.random.fold_in(
        jax.random.PRNGKey(cfg.seed), step), batch)


# ----------------------------------------------------------------------
# Corr partitions (paper Fig. 17): fog / frost / motion / snow
# ----------------------------------------------------------------------
def corrupt_fog(images, key, severity=1.0):
    haze = 0.7 * severity
    return images * (1 - haze) + haze * 1.2


def corrupt_frost(images, key, severity=1.0):
    n = images.shape[1]
    mask = _smooth_noise(key, n, octaves=2)[None, ..., None]
    frost = (mask > 0.7).astype(images.dtype)
    return images * (1 - 0.8 * severity * frost) + 1.5 * severity * frost


def corrupt_motion(images, key, severity=1.0):
    """Directional box blur (horizontal camera motion)."""
    taps = int(2 + 3 * severity)
    out = jnp.zeros_like(images)
    for i in range(taps):
        out = out + jnp.roll(images, i - taps // 2, axis=2)
    return out / taps


def corrupt_snow(images, key, severity=1.0):
    specks = jax.random.bernoulli(key, 0.04 * severity, images.shape)
    return jnp.where(specks, 2.0, images)


CORRUPTIONS = {
    "fog": corrupt_fog,
    "frost": corrupt_frost,
    "motion": corrupt_motion,
    "snow": corrupt_snow,
}


def corrupted_batch(cfg: SardConfig, step: int, batch: int,
                    corruption: str, severity: float = 1.0) -> dict:
    data = batch_at(cfg, step, batch)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0xC0DE), step)
    images = CORRUPTIONS[corruption](data["images"], key, severity)
    return {"images": images, "labels": data["labels"]}
