"""Synthetic SARD: aerial search-and-rescue imagery stand-in (§V-B).

The paper evaluates on the (non-redistributable) SARD dataset.  We
reproduce the *experiment design* on a procedurally generated analogue
with matched difficulty knobs:

  * aerial background: smooth multi-octave clutter (terrain),
  * victims: small elongated Gaussian blobs (lying/kneeling poses) whose
    size shrinks with simulated altitude (the paper's 15–75 m range),
  * distractors: rock-like compact blobs that confuse the detector
    (the source of overconfident false positives the paper targets),
  * Corr partitions: fog / frost / motion / snow corruptions (Fig. 17).

Task: patch-level victim classification (victim present / absent).
Labels are balanced; each image is a pure function of (seed, index).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SardConfig:
    image_size: int = 32
    seed: int = 0
    victim_intensity: float = 2.4
    distractor_intensity: float = 1.3   # close to victims: hard negatives
    altitude_range: tuple = (0.6, 1.4)  # scales blob size (15–75 m proxy)
    clutter: float = 0.8


def _smooth_noise(key, n, octaves=3):
    """Multi-octave smooth clutter [n, n]."""
    img = jnp.zeros((n, n))
    for o in range(octaves):
        k = jax.random.fold_in(key, o)
        size = max(2, n // (2 ** (octaves - o)))
        coarse = jax.random.normal(k, (size, size))
        img = img + jax.image.resize(coarse, (n, n), "bilinear") / (2 ** o)
    return img


def _blob(n, cy, cx, sy, sx, theta):
    """Anisotropic Gaussian blob (elongation ~ lying pose)."""
    y = jnp.arange(n)[:, None] - cy
    x = jnp.arange(n)[None, :] - cx
    ct, st = jnp.cos(theta), jnp.sin(theta)
    u = ct * y + st * x
    v = -st * y + ct * x
    return jnp.exp(-0.5 * ((u / sy) ** 2 + (v / sx) ** 2))


def make_image(cfg: SardConfig, key, has_victim,
               noise_key=None) -> jnp.ndarray:
    """One patch.  ``key`` fixes the SCENE (terrain, distractor, victim
    placement/pose); ``noise_key`` (default: derived from ``key``, the
    historical behaviour) draws the per-exposure sensor noise — a
    re-observation of the same scene passes a fresh ``noise_key`` and
    sees the same ground truth under new noise (mission orbit looks)."""
    n = cfg.image_size
    ks = jax.random.split(key, 10)
    if noise_key is None:
        noise_key = ks[5]
    img = cfg.clutter * _smooth_noise(ks[0], n)
    altitude = jax.random.uniform(ks[1], (), minval=cfg.altitude_range[0],
                                  maxval=cfg.altitude_range[1])
    # distractor rock (always present — the hard negative)
    dc = jax.random.uniform(ks[2], (2,), minval=4.0, maxval=n - 4.0)
    img = img + cfg.distractor_intensity * _blob(
        n, dc[0], dc[1], 1.5 / altitude, 1.5 / altitude, 0.0)
    # victim blob (elongated, pose angle random)
    vc = jax.random.uniform(ks[3], (2,), minval=4.0, maxval=n - 4.0)
    theta = jax.random.uniform(ks[4], (), maxval=np.pi)
    victim = cfg.victim_intensity * _blob(
        n, vc[0], vc[1], 2.5 / altitude, 1.0 / altitude, theta)
    img = img + has_victim * victim
    img = img + 0.1 * jax.random.normal(noise_key, (n, n))  # sensor noise
    return img[..., None]                                 # [n, n, 1]


@partial(jax.jit, static_argnums=(0, 2))
def make_batch(cfg: SardConfig, key, batch: int) -> dict:
    kl, ki = jax.random.split(key)
    labels = (jnp.arange(batch) % 2).astype(jnp.int32)   # balanced
    labels = jax.random.permutation(kl, labels)
    keys = jax.random.split(ki, batch)
    images = jax.vmap(lambda k, y: make_image(cfg, k, y.astype(jnp.float32))
                      )(keys, labels)
    return {"images": images, "labels": labels}


def batch_at(cfg: SardConfig, step: int, batch: int) -> dict:
    return make_batch(cfg, jax.random.fold_in(
        jax.random.PRNGKey(cfg.seed), step), batch)


# ----------------------------------------------------------------------
# Corr partitions (paper Fig. 17): fog / frost / motion / snow
# ----------------------------------------------------------------------
def corrupt_fog(images, key, severity=1.0):
    haze = 0.7 * severity
    return images * (1 - haze) + haze * 1.2


def corrupt_frost(images, key, severity=1.0):
    n = images.shape[1]
    mask = _smooth_noise(key, n, octaves=2)[None, ..., None]
    frost = (mask > 0.7).astype(images.dtype)
    return images * (1 - 0.8 * severity * frost) + 1.5 * severity * frost


def corrupt_motion(images, key, severity=1.0):
    """Directional box blur (horizontal camera motion)."""
    taps = int(2 + 3 * severity)
    out = jnp.zeros_like(images)
    for i in range(taps):
        out = out + jnp.roll(images, i - taps // 2, axis=2)
    return out / taps


def corrupt_snow(images, key, severity=1.0):
    specks = jax.random.bernoulli(key, 0.04 * severity, images.shape)
    return jnp.where(specks, 2.0, images)


CORRUPTIONS = {
    "fog": corrupt_fog,
    "frost": corrupt_frost,
    "motion": corrupt_motion,
    "snow": corrupt_snow,
}


# ----------------------------------------------------------------------
# Severity-field API: per-image severity within one batch
# ----------------------------------------------------------------------
# The mission simulator (repro/mission) renders a *spatially correlated*
# corruption field over its grid world: each observed patch carries the
# severity of its map cell, so one batch of detector inputs mixes
# severities.  The batch functions above take one scalar severity — the
# per-image twins below take a severity PER IMAGE (and a key per image,
# so weather is a pure function of the map cell).  The scalar batch
# path is untouched: ``corrupt`` only routes to the per-image twins
# when handed a severity array.

# Motion blur re-derives the tap count in-graph (the batch fn bakes it
# into the Python loop).  Taps are capped so the unrolled loop has a
# static length; severities above the cap saturate at MOTION_TAPS_CAP
# taps (= the scalar path at severity 5).
MOTION_TAPS_CAP = 17


def _corrupt_fog_image(image, key, severity):
    haze = 0.7 * severity
    return image * (1 - haze) + haze * 1.2


def _corrupt_frost_image(image, key, severity):
    n = image.shape[0]
    mask = _smooth_noise(key, n, octaves=2)[..., None]
    frost = (mask > 0.7).astype(image.dtype)
    return image * (1 - 0.8 * severity * frost) + 1.5 * severity * frost


def _corrupt_motion_image(image, key, severity):
    """[H, W, C] directional blur; taps = int(2 + 3·severity), capped."""
    taps = jnp.clip(jnp.floor(2 + 3 * severity).astype(jnp.int32), 2,
                    MOTION_TAPS_CAP)
    out = jnp.zeros_like(image)
    for i in range(MOTION_TAPS_CAP):
        rolled = jnp.roll(image, i - taps // 2, axis=1)   # W axis
        out = out + jnp.where(i < taps, rolled, 0.0)
    return out / taps


def _corrupt_snow_image(image, key, severity):
    specks = jax.random.bernoulli(key, 0.04 * severity, image.shape)
    return jnp.where(specks, 2.0, image)


CORRUPTIONS_IMAGE = {
    "fog": _corrupt_fog_image,
    "frost": _corrupt_frost_image,
    "motion": _corrupt_motion_image,
    "snow": _corrupt_snow_image,
}


def corrupt(images, key, severity, corruption: str = "fog"):
    """Corrupt a batch with scalar OR per-image severity.

    ``severity`` a Python/0-d scalar (traced included): delegates to
    the original batch function — bit-identical to the pre-field
    behaviour, one shared weather key for the batch.  (Exception: a
    TRACED scalar for ``motion`` raises — its tap count is
    shape-determining; pass a concrete scalar or a [B] array.)
    ``severity`` a [B] array (traced or concrete): each image is
    corrupted at its own severity through the per-image twins, with
    ``key`` split per image (frost masks and snow draws then differ
    across the batch, matching independent weather per patch).
    """
    if jnp.ndim(severity) == 0:
        if isinstance(severity, jax.core.Tracer):
            if corruption == "motion":
                raise ValueError(
                    "corrupt('motion', ...) cannot take a traced "
                    "scalar severity (the tap count is shape-"
                    "determining); pass a concrete scalar or a "
                    "per-image [B] severity array")
            return CORRUPTIONS[corruption](images, key, severity)
        return CORRUPTIONS[corruption](images, key, float(severity))
    sev = jnp.asarray(severity, jnp.float32)
    keys = jax.random.split(key, images.shape[0])
    return jax.vmap(CORRUPTIONS_IMAGE[corruption])(images, keys, sev)


def corrupted_batch(cfg: SardConfig, step: int, batch: int,
                    corruption: str, severity: float = 1.0) -> dict:
    data = batch_at(cfg, step, batch)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0xC0DE), step)
    images = corrupt(data["images"], key, severity, corruption)
    return {"images": images, "labels": data["labels"]}
