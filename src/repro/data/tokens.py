"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step) — the pipeline needs no
state, which makes mid-epoch checkpoint resume *exact*: restart at step
k and you see the same batches a never-failed run would have seen.
That property is load-bearing for the fault-tolerance tests.

The synthetic "language" has learnable structure: a noisy affine bigram
(next ≈ (a·tok + c) mod V with Zipf-flavoured resets), so training loss
measurably falls within a few hundred steps of the example drivers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1        # fraction of random transitions
    a: int = 31337            # bigram multiplier
    c: int = 17               # bigram offset


def batch_at(cfg: TokenPipelineConfig, step: int) -> dict:
    """The batch for a given step — pure, stateless, resumable."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k0, k1, k2 = jax.random.split(key, 3)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab

    start = jax.random.randint(k0, (b, 1), 0, v)
    noise_mask = jax.random.bernoulli(k1, cfg.noise, (b, s))
    noise_tok = jax.random.randint(k2, (b, s), 0, v)

    def step_fn(tok, inputs):
        nmask, ntok = inputs
        nxt = (tok * cfg.a + cfg.c) % v
        nxt = jnp.where(nmask, ntok, nxt)
        return nxt, nxt

    _, seq = jax.lax.scan(step_fn, start[:, 0],
                          (noise_mask.T, noise_tok.T))
    tokens = jnp.concatenate([start, seq.T[:, :-1]], axis=1)
    labels = seq.T
    return {"tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}


def batch_iterator(cfg: TokenPipelineConfig, start_step: int = 0):
    step = start_step
    while True:
        yield step, batch_at(cfg, step)
        step += 1


def host_shard(batch: dict, host_id: int, num_hosts: int) -> dict:
    """Per-host slice of the global batch (multi-host data loading)."""
    def slc(x):
        per = x.shape[0] // num_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(slc, batch)


def stub_frames(cfg, n_frames: int, d_model: int, step: int,
                batch: int) -> jnp.ndarray:
    """Stub audio-frontend embeddings (whisper assignment: frontend STUB)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0xF0), step)
    return jax.random.normal(key, (batch, n_frames, d_model), jnp.float32)


def stub_image_embeds(cfg, n_tokens: int, d_model: int, step: int,
                      batch: int) -> jnp.ndarray:
    """Stub vision-frontend patch embeddings (llama-vision assignment)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0xF1), step)
    return jax.random.normal(key, (batch, n_tokens, d_model), jnp.float32)
