from repro.data.tokens import TokenPipelineConfig, batch_at, batch_iterator
from repro.data.sard import SardConfig, CORRUPTIONS, corrupted_batch

__all__ = ["TokenPipelineConfig", "batch_at", "batch_iterator",
           "SardConfig", "CORRUPTIONS", "corrupted_batch"]
