"""Recalibrate-and-redeploy: act on drift advisories without stopping.

obs/drift.py raises a recalibration advisory when the telemetry probe's
streamed GRNG moments z-fail against the deployment's belief.  This
module is the actuator: it re-runs the paper's §III-B1 calibration
(``calib.measured_grng`` + ``calib.prepare_instance_head``) against the
*aged* die and hands back a head + config that a running engine can
hot-swap (``SarServingEngine.swap_head``) between dispatches.

Three layers:

  * :func:`aged_belief_view` — the STALE deployment on an aged die:
    physics follows ``hw/aging`` but the head still carries the
    calibration-time standardization constants and µ' compensation.
    This is what serving "feels" as drift arrives mid-stream.
  * :func:`recalibrate` — fresh measurement + compensation on the aged
    instance, with the ``BayesHeadConfig.calib_epoch`` bumped so the
    healed head's jitted builders never alias a stale epoch's cache
    entries while epoch-free builders (scatter, stats reset) survive.
  * :class:`SelfHealingController` — owns one die's lifetime: birth
    instance, deployed belief, streaming :class:`DriftMonitor`;
    advances simulated age, folds telemetry deltas, heals on advisory.
    launch/serve.py and mission/rollout.py both drive their loops
    through it.

The controller never mutates the birth instance — an age is always
absolute (``birth.at_age(t)``), so the same (die, t) is bit-identical
whether it was reached in one jump or across twenty serve segments.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.sampling import BayesHeadConfig, hoisted_sigma_basis
from repro.hw.aging import AgingSpec, at_age
from repro.hw.calib import prepare_instance_head
from repro.hw.instance import ChipInstance
from repro.obs.drift import (DriftGate, DriftMonitor, DriftStatus,
                             reference_for)


@dataclasses.dataclass(frozen=True)
class LifetimeConfig:
    """How a serve stream / mission ages its dies.

    ``age_rate`` is simulated field-seconds per decision (serve) or per
    mission step (rollout): benches compress a month of field time into
    one run by passing large rates.  ``epochs`` is how many age/heal
    checkpoints the stream is cut into; 0 age_rate disables aging and
    the callers take their exact pre-lifetime path (bit-identical
    results, unchanged host-sync counts)."""

    age_rate: float = 0.0
    epochs: int = 4
    auto_recalibrate: bool = False
    spec: AgingSpec = dataclasses.field(default_factory=AgingSpec)
    gate: DriftGate = dataclasses.field(default_factory=DriftGate)

    @property
    def active(self) -> bool:
        return self.age_rate > 0.0 and self.epochs > 0


@dataclasses.dataclass(frozen=True)
class HealEvent:
    """One recalibrate-and-redeploy, for reports and bench JSONs."""

    age_s: float
    calib_epoch: int
    z_mean: float
    z_std: float
    n: float
    advisory: str | None

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def aged_belief_view(head: dict, hcfg: BayesHeadConfig,
                     aged: ChipInstance,
                     base_grng) -> tuple[dict, BayesHeadConfig]:
    """The deployed head served on aged physics with a stale belief.

    Physics moves, belief does not: the returned config's GRNG carries
    the aged instance's physical params (currents, read σ) but the
    *deployment-time* standardization constants, and the head's µ'/σ
    arrays are untouched — write-free hardware cannot rewrite them.
    The one head leaf that does change is the hoisted σ⊙I_j basis: it
    is a cache of physically-read device currents, and an aged die
    reads aged currents.  ``base_grng`` is the factory golden config
    the instance's physical view folds over (``cfg.grng``)."""
    phys = aged.grng(base_grng)
    view_grng = dataclasses.replace(
        phys, sum_mean=hcfg.grng.sum_mean, sum_std=hcfg.grng.sum_std)
    hcfg_view = dataclasses.replace(hcfg, grng=view_grng)
    head_view = dict(head)
    if "sigma_basis" in head or "sigma_basis_host" in head:
        head_view.pop("sigma_basis", None)
        head_view.pop("sigma_basis_host", None)
        head_view.update(hoisted_sigma_basis(
            head["sigma"], view_grng, hcfg.compute_dtype,
            hcfg.hoist_tile_n))
    return head_view, hcfg_view


def recalibrate(mu, sigma, base_hcfg: BayesHeadConfig,
                aged: ChipInstance, *, epoch: int,
                n_offset_samples: int = 64
                ) -> tuple[dict, BayesHeadConfig]:
    """§III-B1 calibration against the aged die, at ``calib_epoch``.

    Re-measures the drifted sum statistics, re-compensates µ' against
    the aged offsets, and rebuilds the hoisted basis — the full
    ``prepare_instance_head`` path, so a healed head is bit-identical
    to a cold deployment onto the same aged instance."""
    base = dataclasses.replace(base_hcfg, calib_epoch=int(epoch))
    return prepare_instance_head(mu, sigma, base, aged, calibrated=True,
                                 n_offset_samples=n_offset_samples)


class SelfHealingController:
    """One die's lifetime: age advance, drift watch, heal on advisory.

    Holds the birth instance plus the (µ, σ) the trunk wants deployed;
    ``advance(t)`` returns the stale-belief (head, hcfg) view at age t,
    ``observe_snapshot`` folds a cumulative telemetry snapshot's delta
    into the streaming monitor, and ``heal()`` recalibrates at the
    current age, bumps ``calib_epoch``, and re-references the monitor.
    """

    def __init__(self, chip: ChipInstance, mu, sigma,
                 base_hcfg: BayesHeadConfig, *, calibrated: bool = True,
                 spec: AgingSpec | None = None,
                 gate: DriftGate | None = None,
                 probe_cells: int = 32, n_offset_samples: int = 64):
        if chip.age_s != 0.0:
            raise ValueError("SelfHealingController owns a die from "
                             "birth; pass the age-0 instance")
        self.chip = chip
        self.mu, self.sigma = mu, sigma
        self.base_hcfg = base_hcfg
        self.calibrated = bool(calibrated)
        self.spec = spec or AgingSpec()
        self.gate = gate or DriftGate()
        self.probe_cells = int(probe_cells)
        self.n_offset_samples = int(n_offset_samples)
        self.epoch = 0
        self.age_s = 0.0
        self._belief_age_s = 0.0   # die age the deployed head was
        self._last = (0.0, 0.0, 0.0)  # measured at (0 = birth calib)
        self.events: list[HealEvent] = []
        self.head, self.hcfg = prepare_instance_head(
            mu, sigma, base_hcfg, chip, calibrated=calibrated,
            n_offset_samples=n_offset_samples)
        self.monitor = DriftMonitor(self._belief_reference(), self.gate)

    def _belief_reference(self):
        return reference_for(self.base_hcfg, self.hcfg,
                             calibrated=self.calibrated,
                             probe_cells=self.probe_cells)

    # -- age ------------------------------------------------------------
    def view(self) -> tuple[dict, BayesHeadConfig]:
        """(head, hcfg) the engine should serve at the current age."""
        if self.age_s == self._belief_age_s:
            return self.head, self.hcfg
        aged = at_age(self.chip, self.age_s, self.spec)
        return aged_belief_view(self.head, self.hcfg, aged,
                                self.base_hcfg.grng)

    def advance(self, t_s: float) -> tuple[dict, BayesHeadConfig]:
        """Move the die to absolute field age ``t_s`` (monotone)."""
        t_s = float(t_s)
        if t_s < self.age_s:
            raise ValueError(f"age runs forward: {t_s} < {self.age_s}")
        self.age_s = t_s
        return self.view()

    # -- watch ----------------------------------------------------------
    def observe_snapshot(self, snapshot: dict[str, Any]) -> DriftStatus:
        """Fold a CUMULATIVE telemetry snapshot; returns fresh status."""
        g = snapshot.get("grng", snapshot)
        n, s, ssq = float(g["n"]), float(g["sum"]), float(g["sumsq"])
        ln, ls, lssq = self._last
        self._last = (n, s, ssq)
        if n > ln:
            self.monitor.observe(n - ln, s - ls, ssq - lssq)
        return self.monitor.status()

    # -- heal -----------------------------------------------------------
    def heal(self, status: DriftStatus | None = None) -> HealEvent:
        """Recalibrate at the current age and redeploy the belief."""
        status = status or self.monitor.status()
        aged = at_age(self.chip, self.age_s, self.spec)
        self.epoch += 1
        self.head, self.hcfg = recalibrate(
            self.mu, self.sigma, self.base_hcfg, aged, epoch=self.epoch,
            n_offset_samples=self.n_offset_samples)
        self.calibrated = True
        self._belief_age_s = self.age_s
        self.monitor = DriftMonitor(self._belief_reference(), self.gate)
        ev = HealEvent(age_s=self.age_s, calib_epoch=self.epoch,
                       z_mean=status.z_mean, z_std=status.z_std,
                       n=status.n, advisory=status.advisory)
        self.events.append(ev)
        return ev

    def maybe_heal(self, status: DriftStatus) -> HealEvent | None:
        """Heal iff the status carries an advisory."""
        return self.heal(status) if status.drifted else None

    def report(self) -> dict[str, Any]:
        return {
            "age_s": self.age_s,
            "calib_epoch": self.epoch,
            "heals": len(self.events),
            "events": [e.to_dict() for e in self.events],
            "status": self.monitor.status().to_dict(),
        }
