"""Parameterized FeFET nonideality model (the digital twin's physics).

The repo's ideal device model (core/clt_grng.py) is one *golden* chip:
currents I(k,n,j) = i_lo + Δi·b + γ·v hashed from the coordinate, with
the paper's fitted Fig. 9 parameters.  A real deployment sees a
*population* of chips, each differing from golden along five measured
axes (cf. Bayes2IMC / FeBiM, which find exactly these terms dominate
deployed accuracy):

  1. **Per-chip Vth variation of the programmed-once GRNG arrays** —
     each chip's one-time programming draws its own device states.  In
     the hash formulation this is a chip-specific ``seed``: the virtual
     devices are redrawn, frozen, and never rewritten.  No new math.
  2. **Corner spread** — lot-to-lot shifts of the current-model
     parameters (i_lo, Δi, γ), modeled as per-chip fractional
     multipliers around 1.
  3. **Temperature / aging drift** — a uniform multiplicative current
     drift.  Uniform drift commutes with the device model
     (d·(i_lo + Δi·b + γ·v) = (d·i_lo) + (d·Δi)·b + (d·γ)·v), so it
     folds into the same three parameters — every downstream consumer
     (offset closed form, rank-16 basis, Pallas kernels) stays exact
     with zero extra plumbing.
  4. **Cycle-to-cycle read noise** — fresh additive noise on every read
     of a cell's 8-device sum.  This is the one term that cannot fold
     into static parameters; it is ``GRNGConfig.read_sigma`` (see
     core/clt_grng.read_noise and the mix_samples projection in
     core/sampling.py).
  5. **Peripheral nonidealities** — per-column ADC gain/offset error
     (kernels/cim_mvm.py nonideal path) and conductance programming
     error on written weights (hw/instance.program_weights), built on
     the core/quant.py numeric path.

``VariationSpec`` holds the population statistics; hw/instance.py draws
frozen chips from it; hw/calib.py measures individual chips back.
"""

from __future__ import annotations

import dataclasses

from repro.core.clt_grng import GRNGConfig

# Reference temperature of the paper's Fig. 9 fit.
T_NOMINAL_C = 25.0


@dataclasses.dataclass(frozen=True)
class VariationSpec:
    """Population statistics a chip instance is drawn from.

    Defaults are a plausible mid-severity corner for a 28 nm FeFET
    process (fractional spreads); ``scaled`` sweeps severity for the
    hw_variation Monte-Carlo benchmark.
    """
    # Corner spread: per-chip fractional sigma of the current model.
    sigma_i_lo: float = 0.02
    sigma_delta_i: float = 0.03
    sigma_gamma: float = 0.15
    # Cycle-to-cycle read noise on the 8-device sum [µA RMS]: per-chip
    # magnitude ~ |N(mean, mean·spread)|.
    read_sigma_mean: float = 0.08
    read_sigma_spread: float = 0.5
    # Temperature: per-chip operating point ~ N(temp_mean, temp_spread),
    # currents drift by ``tc_current`` per °C away from 25 °C.
    temp_mean_c: float = 25.0
    temp_spread_c: float = 15.0
    tc_current: float = -2.2e-3
    # SAR ADC column front-end.
    adc_gain_sigma: float = 0.01
    adc_offset_sigma_lsb: float = 0.3
    # Conductance programming error (fractional, per written cell).
    program_sigma: float = 0.01

    def scaled(self, severity: float) -> "VariationSpec":
        """All variation magnitudes multiplied by ``severity``
        (1 = nominal population, >1 = worst case).  severity 0 zeroes
        the corner/noise/ADC/programming terms but instances keep their
        chip-specific device and noise seeds — a severity-0 chip is a
        *different die with golden statistics*, not the golden chip
        itself (its per-cell offsets still differ until calibrated)."""
        return dataclasses.replace(
            self,
            sigma_i_lo=self.sigma_i_lo * severity,
            sigma_delta_i=self.sigma_delta_i * severity,
            sigma_gamma=self.sigma_gamma * severity,
            read_sigma_mean=self.read_sigma_mean * severity,
            temp_spread_c=self.temp_spread_c * severity,
            adc_gain_sigma=self.adc_gain_sigma * severity,
            adc_offset_sigma_lsb=self.adc_offset_sigma_lsb * severity,
            program_sigma=self.program_sigma * severity,
        )


def drift_factor(tc_current: float, temp_c: float) -> float:
    """Uniform current drift at ``temp_c`` relative to the 25 °C fit."""
    return 1.0 + tc_current * (temp_c - T_NOMINAL_C)


def retention_decades(t_s: float, t0_s: float) -> float:
    """Retention-loss clock: ln(1 + t/t0) elapsed "decades" at age t.

    FeFET polarization retention is log-linear in time (the write-free
    endurance story: the loss per ln-decade is small, but it never
    stops).  ``log1p`` pins age 0 to exactly 0.0 decades so an un-aged
    die is bit-identical to its birth state; ``t0_s`` is the knee below
    which the die is effectively fresh.  Pure math — hw/aging.py turns
    decades into per-die parameter drift."""
    if t_s < 0.0:
        raise ValueError(f"age must be >= 0, got {t_s}")
    import math
    return math.log1p(t_s / t0_s)


def degraded_grng(base: GRNGConfig, *, device_seed: int, noise_seed: int,
                  f_i_lo: float = 1.0, f_delta_i: float = 1.0,
                  f_gamma: float = 1.0, drift: float = 1.0,
                  read_sigma: float = 0.0, imprint: float = 0.0,
                  imprint_seed: int | None = None) -> GRNGConfig:
    """The chip's physical GRNG: redrawn devices, shifted corner,
    drifted currents, read noise — with the *nominal* standardization
    constants (what an uncalibrated deployment believes).  hw/calib.py
    replaces the constants with per-chip measured values.

    ``imprint`` is the sixth, AGE-ONLY axis (hw/aging.py): a frozen
    additive per-device Vth walk of magnitude ``imprint`` µA RMS keyed
    by ``imprint_seed``.  It cannot fold into the three parameters —
    it shifts every cell's mean offset away from the calibration-time
    value, which is what makes an aged die need re-measurement."""
    return dataclasses.replace(
        base,
        seed=device_seed,
        i_lo=base.i_lo * f_i_lo * drift,
        delta_i=base.delta_i * f_delta_i * drift,
        gamma=base.gamma * f_gamma * drift,
        read_sigma=read_sigma,
        noise_seed=noise_seed,
        imprint=imprint,
        imprint_seed=(base.imprint_seed if imprint_seed is None
                      else imprint_seed),
    )
