"""Time-evolving FeFET aging: retention-loss drift that ARRIVES in the
field instead of being frozen at die creation.

The paper's GRNG arrays are programmed once and read forever, so the
nonideality budget is not static: polarization retention loss slowly
discharges the programmed Vth states (mean current droop), imprint
spreads the device-to-device distribution (γ growth), and read-disturb
accumulation raises the cycle-to-cycle noise floor — the exact aging
terms Bayes2IMC and FeBiM flag as the threat to in-memory Bayesian
inference.  ``hw/instance.py`` samples a die's *birth* corner;
this module evolves it:

    aged = chip.at_age(t_s)          # ChipInstance at field age t_s

All four laws are log-linear in time (``device.retention_decades``:
``dec = ln(1 + t/t0)``), the standard FeFET retention signature — fast
early drift, never saturating.  Per-die aging *rates* are drawn from a
NumPy PRNG keyed purely by the die's serialized seeds, so:

  * aging is deterministic in (die, t): same seed + same age →
    bit-identical instance, on any host, any process;
  * ``at_age(0)`` IS the birth instance (dec = exactly 0.0);
  * aging commutes with ``to_tree``/``from_tree`` round-trips — the
    rates are a pure function of fields that serialize exactly.

Aging scopes to the GRNG subarrays only (current params + read σ +
imprint): the trunk's ADC front-ends and written conductances are
standard FeFET weight cells whose retention the paper's §III
write-verify margins cover, while the GRNG cells are *biased into* the
stochastic regime and live with tiny margins — they age first.
Mechanically the uniform laws fold through ``device.degraded_grng``'s
(f_i_lo, f_delta_i, f_gamma, read_sigma) channel, and the per-device
Vth walk rides the core model's ``imprint`` term — so every downstream
consumer (offset closed form, rank-16 basis, fused kernels, telemetry
probe) sees the aged physics with zero new plumbing, and recalibration
(hw/calib + hw/redeploy) can measure it right back out.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.hw import device as dev

# Tag mixed into the aging-rate PRNG key so the rate draw never aliases
# the die's device/noise/weight streams.
_SEED_AGE = 0xA6ED


@dataclasses.dataclass(frozen=True)
class AgingSpec:
    """Population statistics of the aging laws (per ln-decade rates).

    Defaults follow published FeFET retention corners: a fraction of a
    percent of mean current lost per ln-decade after a ~1 h knee, plus
    a per-device imprint walk.  At 30 field-days (t=2.6e6 s, ~6.6
    decades) a severity-2.5 die has drifted ~3% in mean current and
    ~0.4 µA RMS in imprint — far past the |z|>5 drift gate against its
    calibration-time belief (measured |z_mean| ≈ 25) and enough to
    visibly degrade verdicts (clean accuracy-vs-golden deviation
    ~0.06 stale vs ~0.01 recalibrated; benchmarks/lifetime_bench.py
    measures both).
    """

    t0_s: float = 3600.0            # retention knee [s]
    # Mean fractional current droop per ln-decade (negative: retention
    # LOSS), applied to i_lo and Δi — a uniform multiplicative drift,
    # so it folds exactly (hw/device.py axis 3).
    drift_per_decade: float = -0.005
    # Device-to-device spread γ grows per ln-decade (fractional).
    gamma_per_decade: float = 0.008
    # Read-disturb accumulation: σ_read grows per ln-decade [µA].
    read_sigma_per_decade: float = 0.004
    # Imprint: each device's Vth walks away from its programmed state,
    # an ADDITIVE per-device Gaussian of this RMS per ln-decade [µA]
    # (GRNGConfig.imprint).  The only axis that decorrelates per-cell
    # mean offsets from their calibration-time values — uniform droop
    # cancels in the class softmax, imprint is what actually degrades
    # verdicts and what recalibration measures back out.
    imprint_per_decade: float = 0.06
    # Per-die lognormal-ish spread of all four rates around the mean.
    rate_spread: float = 0.3


def die_rates(device_seed: int, noise_seed: int,
              spec: AgingSpec | None = None
              ) -> tuple[float, float, float, float]:
    """(drift, γ-growth, σ_read-growth, imprint) per-decade rates for
    one die.

    Keyed only by the die's serialized seeds — never stored on the
    instance — so save/load round-trips cannot desynchronize a die from
    its own aging trajectory."""
    spec = spec or AgingSpec()
    rng = np.random.default_rng(
        (int(device_seed) ^ _SEED_AGE, int(noise_seed), _SEED_AGE))
    z = rng.standard_normal(4)
    drift = spec.drift_per_decade * (1.0 + spec.rate_spread * z[0])
    gamma = abs(spec.gamma_per_decade * (1.0 + spec.rate_spread * z[1]))
    read = abs(spec.read_sigma_per_decade * (1.0 + spec.rate_spread * z[2]))
    imprint = abs(spec.imprint_per_decade * (1.0 + spec.rate_spread * z[3]))
    return float(drift), float(gamma), float(read), float(imprint)


def age_factors(chip, t_s: float, spec: AgingSpec | None = None
                ) -> tuple[float, float, float, float]:
    """(f_drift, f_gamma, d_read_sigma, d_imprint) at age t_s —
    multiplier, multiplier, additive µA, additive µA RMS.

    Exactly (1.0, 1.0, 0.0, 0.0) at t=0 — ``at_age(0)`` is the
    identity."""
    spec = spec or AgingSpec()
    dec = dev.retention_decades(float(t_s), spec.t0_s)
    drift, gamma, read, imprint = die_rates(
        chip.device_seed, chip.noise_seed, spec)
    return 1.0 + drift * dec, 1.0 + gamma * dec, read * dec, imprint * dec


def at_age(chip, t_s: float, spec: AgingSpec | None = None):
    """``chip`` (a birth-state ChipInstance) after ``t_s`` field seconds.

    Returns a new frozen instance — a new identity, so identity-keyed
    jit caches (featurize/round builders) key the aged die separately
    from its birth state, exactly like a different chip.  Raises on an
    already-aged input: ages are absolute (from programming), never
    compounded, so there is one well-defined die per (seed, t)."""
    if getattr(chip, "age_s", 0.0) != 0.0:
        raise ValueError(
            f"at_age expects the birth (age-0) instance; this die is "
            f"already at age {chip.age_s:g}s — keep the birth instance "
            f"and call birth.at_age(t) with absolute t")
    t_s = float(t_s)
    if t_s == 0.0:
        return chip
    f_drift, f_gamma, d_read, d_imprint = age_factors(chip, t_s, spec)
    return dataclasses.replace(
        chip,
        f_i_lo=chip.f_i_lo * f_drift,
        f_delta_i=chip.f_delta_i * f_drift,
        f_gamma=chip.f_gamma * f_gamma,
        read_sigma=chip.read_sigma + d_read,
        imprint=chip.imprint + d_imprint,
        age_s=t_s)
