"""Per-instance recalibration (paper §III-B1, applied per chip).

An uncalibrated deployment ships every die with the *golden* serving
transform: µ' compensated against the golden chip's closed-form offsets
and ε standardized by the nominal Fig. 9 constants (10.1, 0.993).  On a
real instance both are wrong — its devices were drawn differently, its
corner shifts the sum statistics, and drift moves them with
temperature.  Calibration is the paper's own two-step measurement,
executed on the instance's digital twin:

  1. **Sum-statistics measurement** — re-estimate (sum_mean, sum_std)
     from N reads across a cell block (core/clt_grng.calibrate), the
     Fig. 9 procedure.  The serving config swaps in the measured
     constants.
  2. **Offset re-compensation** — re-measure the per-cell mean offset
     Δε with N samples and fold it into µ' (core/offset.compensate_mu
     with ``exact=False`` — the paper's 54 + 458·N pJ, 12.8 + 0.64·N µs
     procedure, costed via core/energy.offset_compensation_cost).

Conductance programming error applies to whatever is *written*: the
compensated µ' and σ pass through ``instance.program_weights`` after
the digital transform, calibrated or not — calibration cannot fix write
noise, which bounds how much it recovers (visible in the hw_variation
benchmark).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import clt_grng as g
from repro.core import energy
from repro.core import quant as q
from repro.core.offset import compensate_mu
from repro.core.sampling import BayesHeadConfig
from repro.hw.instance import ChipInstance


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    chip_id: int
    nominal_sum_mean: float
    nominal_sum_std: float
    measured_sum_mean: float
    measured_sum_std: float
    residual_eps_uncal: float     # |E[ε]| under nominal constants+offsets
    residual_eps_cal: float       # |E[ε]| after per-chip recalibration
    n_samples: int
    energy_J: float               # §III-B1 measurement cost
    time_s: float


def measured_grng(icfg: g.GRNGConfig, n_cells: int = 2048,
                  n_samples: int = 128) -> g.GRNGConfig:
    """The calibrated serving view: physical params + measured constants.

    Computed eagerly (not via the jitted ``clt_grng.calibrate``): every
    chip instance is a distinct static config, and a fleet sweep would
    otherwise recompile per chip.
    """
    raw = g.raw_sums(icfg, n_cells, 1, n_samples)
    return dataclasses.replace(icfg, sum_mean=float(raw.mean()),
                               sum_std=float(raw.std()))


def calibration_report(instance: ChipInstance, base: g.GRNGConfig,
                       n_samples: int = 64, probe: int = 64) -> CalibrationReport:
    """Measure one chip against golden; cost from the paper's model.

    ``probe``: edge of the cell block used for the residual-offset
    probes (a [probe, probe] corner of the array).
    """
    icfg = instance.grng(base)
    ccfg = measured_grng(icfg, n_samples=n_samples)
    # Residual mean offset of ε̂ after compensation, per deployment mode:
    # uncal subtracts the GOLDEN chip's offsets under nominal constants;
    # cal subtracts the measured offsets under measured constants.
    eps_uncal = g.eps(icfg, probe, probe, 256)
    d_gold = g.cell_mean_offset(base, probe, probe)
    resid_uncal = float(jnp.abs((eps_uncal - d_gold[None]).mean()))
    eps_cal = g.eps(ccfg, probe, probe, 256)
    d_meas = g.estimate_mean_offset(ccfg, probe, probe, n_samples)
    resid_cal = float(jnp.abs((eps_cal - d_meas[None]).mean()))
    e_j, t_s = energy.offset_compensation_cost(n_samples)
    return CalibrationReport(
        chip_id=instance.chip_id,
        nominal_sum_mean=base.sum_mean, nominal_sum_std=base.sum_std,
        measured_sum_mean=ccfg.sum_mean, measured_sum_std=ccfg.sum_std,
        residual_eps_uncal=resid_uncal, residual_eps_cal=resid_cal,
        n_samples=n_samples, energy_J=e_j, time_s=t_s)


def prepare_instance_head(mu: jnp.ndarray, sigma: jnp.ndarray,
                          cfg: BayesHeadConfig,
                          instance: ChipInstance | None = None,
                          calibrated: bool = True,
                          n_offset_samples: int = 64,
                          hoist_tile_n: int | None = None
                          ) -> tuple[dict, BayesHeadConfig]:
    """Deploy (µ, σ) onto a chip instance.

    Returns (head, serving_cfg): the serving pytree whose stored values
    went through compensation → quantization → conductance programming
    noise, and the BayesHeadConfig whose ``grng`` is the instance's
    physical view (measured constants when ``calibrated``).  Drop-in for
    core/sampling: ``logit_samples(head, x, serving_cfg)`` and the
    engines' ``activation_basis``/``mix_samples`` fast path run
    unchanged on the degraded instance.

    ``instance=None`` reduces exactly to ``prepare_serving_head``.
    """
    if instance is None:
        from repro.core.sampling import prepare_serving_head
        return (prepare_serving_head(mu, sigma, cfg, hoist_tile_n),
                cfg)
    icfg = instance.grng(cfg.grng)
    if calibrated:
        scfg = measured_grng(icfg, n_samples=max(n_offset_samples, 64))
        mu_p = compensate_mu(mu, sigma, scfg, exact=False,
                             n_est=n_offset_samples)
    else:
        # Factory/golden transform: right math, wrong chip.
        scfg = icfg
        mu_p = compensate_mu(mu, sigma, cfg.grng, exact=True)
    if cfg.quant.enabled:
        mu_p, _ = q.quantize_mu(mu_p, cfg.quant)
        sigma, _ = q.quantize_sigma(sigma, cfg.quant)
    # Conductance programming error hits whatever is written.
    mu_p = instance.program_weights(mu_p, tag=0)
    sigma = instance.program_weights(sigma, tag=1)
    head = {
        "mu_prime": mu_p.astype(cfg.compute_dtype),
        "sigma": sigma.astype(cfg.compute_dtype),
    }
    serving_cfg = dataclasses.replace(cfg, grng=scfg)
    tile_n = (serving_cfg.hoist_tile_n if hoist_tile_n is None
              else hoist_tile_n)
    if cfg.hoist_basis and cfg.mode == "rank16":
        from repro.core.sampling import hoisted_sigma_basis
        head.update(hoisted_sigma_basis(sigma, scfg, cfg.compute_dtype,
                                        tile_n))
    return head, serving_cfg
