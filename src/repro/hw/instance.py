"""Sampled chip instances: frozen per-device parameters, serializable.

A ``ChipInstance`` is everything that distinguishes one physical die
from the golden model: the programming draw of its GRNG arrays (a
seed — the hash formulation stores per-device state for free), its
process corner, operating temperature, read-noise magnitude, per-column
ADC errors, and the conductance-programming error of everything written
to it.  Instances are drawn once from a ``VariationSpec`` population
with a NumPy PRNG key and are immutable afterwards — exactly the
"programmed once, never rewritten" contract of the paper's FeFETs,
extended to the whole die.

Serialization rides the repo's checkpoint layer (ckpt/): a fleet of
instances round-trips through ``save_instances``/``load_instances`` as
an ordinary checksummed pytree, so a benchmark can pin the exact chips
it measured.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.clt_grng import GRNGConfig
from repro.core.hashing import gaussianish, hash3
from repro.hw import device as dev

# Tag mixed into per-chip hash seeds so chip streams never collide with
# the golden chip's (seed 0xC1A0) or each other's.
_SEED_DEVICE = 0xD1E0
_SEED_NOISE = 0x0A15
_SEED_WEIGHT = 0x3E17
_SEED_IMPRINT = 0x16B1   # per-die aging-imprint walk (hw/aging.py)


@dataclasses.dataclass(frozen=True, eq=False)
class ChipInstance:
    """One die.  Scalars are the chip's frozen corner draw; ``adc_gain``
    / ``adc_offset`` are per-physical-column ([tile] = 64) arrays tiled
    over logical output columns by ``adc_columns``."""
    chip_id: int
    device_seed: int            # GRNG array programming draw
    noise_seed: int             # cycle-to-cycle read-noise stream
    weight_seed: int            # conductance programming-error draw
    f_i_lo: float = 1.0
    f_delta_i: float = 1.0
    f_gamma: float = 1.0
    temp_c: float = dev.T_NOMINAL_C
    tc_current: float = 0.0
    read_sigma: float = 0.0
    program_sigma: float = 0.0
    age_s: float = 0.0          # simulated seconds since programming
    imprint: float = 0.0        # accumulated Vth-walk RMS [µA] at age_s
    adc_gain: np.ndarray = dataclasses.field(
        default_factory=lambda: np.ones((64,), np.float32))
    adc_offset: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((64,), np.float32))

    # -- physical views --------------------------------------------------
    def grng(self, base: GRNGConfig, temp_c: float | None = None) -> GRNGConfig:
        """This chip's physical GRNG config (uncalibrated view: nominal
        standardization constants).  ``temp_c`` overrides the stored
        operating point — temperature sweeps re-use one instance."""
        t = self.temp_c if temp_c is None else temp_c
        return dev.degraded_grng(
            base, device_seed=self.device_seed, noise_seed=self.noise_seed,
            f_i_lo=self.f_i_lo, f_delta_i=self.f_delta_i,
            f_gamma=self.f_gamma,
            drift=dev.drift_factor(self.tc_current, t),
            read_sigma=self.read_sigma,
            imprint=self.imprint,
            # an un-aged die keeps the base seed: at imprint == 0 the
            # term is compiled out, and a dead per-die seed would break
            # GRNGConfig equality (jit cache keys, golden bit-identity)
            imprint_seed=(self.device_seed ^ _SEED_IMPRINT
                          if self.imprint else None))

    def program_weights(self, w: jnp.ndarray, tag: int = 0) -> jnp.ndarray:
        """Conductance programming error: w·(1 + σ_p·ν(k,n)).

        ν is hash-frozen per (cell, tag) — writing the same matrix to
        the same array twice lands on the same conductances; ``tag``
        distinguishes co-located arrays (µ vs σε subarray).
        """
        if self.program_sigma == 0.0:
            return w
        rows = jnp.arange(w.shape[0], dtype=jnp.uint32)[:, None]
        cols = jnp.arange(w.shape[1], dtype=jnp.uint32)[None, :]
        h = hash3(rows, cols, jnp.uint32(tag), self.weight_seed)
        return w * (1.0 + self.program_sigma * gaussianish(h)).astype(w.dtype)

    def at_age(self, t_s: float, spec=None) -> "ChipInstance":
        """This die after ``t_s`` simulated seconds in the field.

        Delegates to hw/aging.py: retention loss drifts the GRNG current
        params and read noise grows slowly, deterministically in
        (device_seed, t_s).  Only valid from the birth (age-0) instance
        so an age is always absolute, never compounded."""
        from repro.hw import aging
        return aging.at_age(self, t_s, spec)

    def adc_columns(self, n_cols: int) -> tuple[np.ndarray, np.ndarray]:
        """(gain [n_cols], offset [n_cols]): the 64 physical column
        front-ends tiled over logical output columns — column n of every
        tile row shares its ADC, matching the pitch-matched layout."""
        reps = -(-n_cols // self.adc_gain.shape[0])
        return (np.tile(self.adc_gain, reps)[:n_cols],
                np.tile(self.adc_offset, reps)[:n_cols])

    # -- serialization ---------------------------------------------------
    def to_tree(self) -> dict:
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = np.asarray(v)
        return out

    @classmethod
    def from_tree(cls, tree: dict) -> "ChipInstance":
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name not in tree:
                continue  # field added after the ckpt: dataclass default
            v = np.asarray(tree[f.name])
            if v.ndim == 0:
                v = v.item()
                if f.type in ("int",):
                    v = int(v)
            kw[f.name] = v
        return cls(**kw)


def golden_instance(base: GRNGConfig | None = None,
                    tile: int = 64) -> ChipInstance:
    """The characterized die itself, as a ChipInstance.

    Every nonideality is zeroed AND the hash seeds equal the golden
    config's, so the instance plumbing (``grng`` fold, ``adc_columns``,
    ``program_weights``, ``prepare_instance_head(calibrated=False)``)
    must reproduce the golden path bit-for-bit — the regression anchor
    benchmarks/hw_variation.py asserts before sweeping a fleet.  Note a
    severity-0 *sampled* instance is weaker: it has golden statistics
    but its own device draw (see VariationSpec.scaled).
    """
    base = base or GRNGConfig()
    return ChipInstance(
        chip_id=-1, device_seed=base.seed, noise_seed=base.noise_seed,
        weight_seed=_SEED_WEIGHT,
        adc_gain=np.ones((tile,), np.float32),
        adc_offset=np.zeros((tile,), np.float32))


def sample_instances(seed: int, n: int,
                     spec: dev.VariationSpec | None = None,
                     tile: int = 64) -> tuple[ChipInstance, ...]:
    """Draw ``n`` frozen chip instances from the population ``spec``."""
    spec = spec or dev.VariationSpec()
    rng = np.random.default_rng(seed)
    chips = []
    for i in range(n):
        sd = rng.integers(0, 2**31 - 1, size=3)
        chips.append(ChipInstance(
            chip_id=i,
            device_seed=int(sd[0]) ^ _SEED_DEVICE,
            noise_seed=int(sd[1]) ^ _SEED_NOISE,
            weight_seed=int(sd[2]) ^ _SEED_WEIGHT,
            f_i_lo=float(1.0 + spec.sigma_i_lo * rng.standard_normal()),
            f_delta_i=float(1.0 + spec.sigma_delta_i * rng.standard_normal()),
            f_gamma=float(abs(1.0 + spec.sigma_gamma * rng.standard_normal())),
            temp_c=float(spec.temp_mean_c
                         + spec.temp_spread_c * rng.standard_normal()),
            tc_current=spec.tc_current,
            read_sigma=float(abs(rng.normal(
                spec.read_sigma_mean,
                spec.read_sigma_mean * spec.read_sigma_spread))),
            program_sigma=spec.program_sigma,
            adc_gain=(1.0 + spec.adc_gain_sigma
                      * rng.standard_normal(tile)).astype(np.float32),
            adc_offset=(spec.adc_offset_sigma_lsb
                        * rng.standard_normal(tile)).astype(np.float32),
        ))
    return tuple(chips)


def save_instances(ckpt_dir, instances, step: int = 0):
    """Persist a fleet through the atomic checksummed checkpoint layer."""
    from repro.ckpt import save
    tree = {f"chip_{c.chip_id:04d}": c.to_tree() for c in instances}
    return save(ckpt_dir, step, tree)


def load_instances(ckpt_dir, step: int | None = None) -> tuple:
    from repro.ckpt import restore
    tree, _ = restore(ckpt_dir, step)
    return tuple(ChipInstance.from_tree(tree[k]) for k in sorted(tree))
