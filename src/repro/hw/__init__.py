"""repro.hw — FeFET digital twin: chip instances, tile compiler, calib.

The rest of the repo models the paper's *golden* chip.  This package
models the population a deployment actually ships:

  device.py    parameterized nonideality model (corner spread, drift,
               read noise, ADC/DAC errors, programming noise) and how
               each term folds into the core GRNG config
  instance.py  PRNG-keyed frozen chip instances, ckpt-serializable
  tilemap.py   tile compiler: bounded 64×64 grid, column splitting,
               pass multiplexing, Bayesian replication, shard-aware
               placement, utilization/area for the energy model
  calib.py     per-instance recalibration (measured sum stats + offset
               re-compensation) and the calibration report
  aging.py     time-evolving retention loss: ``chip.at_age(t)`` — drift
               arrives in the field instead of frozen at creation
  redeploy.py  act on obs/drift advisories: recalibrate the aged die,
               bump the calibration epoch, hot-swap a running engine

Entry points: ``sample_instances`` → ``prepare_instance_head`` →
serve/evaluate with the returned head + config (the serving engines'
rank-16 fast path runs unchanged);  ``compile_network`` →
``TileProgram.report()`` for deployed area/utilization/energy.
"""

from repro.hw.aging import AgingSpec, age_factors, at_age, die_rates
from repro.hw.calib import (CalibrationReport, calibration_report,
                            measured_grng, prepare_instance_head)
from repro.hw.device import (VariationSpec, degraded_grng, drift_factor,
                             retention_decades)
from repro.hw.instance import (ChipInstance, golden_instance,
                               load_instances, sample_instances,
                               save_instances)
from repro.hw.redeploy import (HealEvent, LifetimeConfig,
                               SelfHealingController, aged_belief_view,
                               recalibrate)
from repro.hw.tilemap import (Placement, TileGrid, TileProgram,
                              compile_layer, compile_network,
                              shard_column_partition)

__all__ = [
    "AgingSpec", "CalibrationReport", "ChipInstance", "HealEvent",
    "LifetimeConfig", "Placement", "SelfHealingController", "TileGrid",
    "TileProgram", "VariationSpec", "age_factors", "aged_belief_view",
    "at_age", "calibration_report", "compile_layer", "compile_network",
    "degraded_grng", "die_rates", "drift_factor", "golden_instance",
    "load_instances", "measured_grng", "prepare_instance_head",
    "recalibrate", "retention_decades", "sample_instances",
    "save_instances", "shard_column_partition",
]
