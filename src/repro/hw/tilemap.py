"""Tile compiler: map Bayesian / CIM layers onto a bounded tile grid.

The paper hand-maps one network (§V-B1: "24 Bayesian tiles + 1659
µ-only subarrays via im2col").  This module is the general version: a
chip exposes a finite ``TileGrid`` of 64×64 tiles; a network is a list
of layer shapes; the compiler splits every weight matrix into tile
blocks (column splitting along d_in — partial sums of the same output
column accumulate digitally across K-blocks), places the blocks onto
physical tiles, time-multiplexes in **passes** when the network needs
more tiles than the chip has, and replicates the Bayesian blocks into
left-over tiles of the last pass to raise sampling throughput.

Placement is **sharding-aware**: blocks are assigned a mesh shard by
output-column group, so every K-split of a column block lands on the
same shard and digital accumulation never crosses the 'model' axis —
the same divisibility discipline as sharding/specs.py, applied to
physical tiles.

The compiler reports utilization and active area for the analytic
energy model (core/energy.grid_inference_energy): padding waste inside
partially-filled tiles is real silicon that burns MVM energy, which is
exactly how deployed TOPS/W/mm² degrades relative to Table I.

Round-trip contract (tested): ``shard_weights`` cuts a dense matrix
into placed blocks, ``reconstruct`` reassembles it bit-exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import energy
from repro.core.energy import LayerShape


@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Physical tile resources of one chip."""
    rows: int = 8
    cols: int = 8
    tile: int = 64

    @property
    def n_tiles(self) -> int:
        return self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class Placement:
    """One [≤tile, ≤tile] weight block bound to a physical tile."""
    layer: str
    r0: int                 # weight-matrix row (d_in) origin
    c0: int                 # weight-matrix col (d_out) origin
    rows: int
    cols: int
    tile_idx: int           # physical tile
    pass_idx: int           # time-multiplex round
    shard: int = 0          # mesh shard owning this output-column group
    replica: int = 0        # >0: throughput replica of a Bayesian block


@dataclasses.dataclass(frozen=True, eq=False)
class TileProgram:
    grid: TileGrid
    layers: tuple            # (name, LayerShape) pairs, placement order
    placements: tuple        # Placement, ...
    n_shards: int = 1

    # -- queries ---------------------------------------------------------
    def layer_placements(self, name: str, replicas: bool = False):
        return tuple(p for p in self.placements
                     if p.layer == name and (replicas or p.replica == 0))

    @property
    def n_passes(self) -> int:
        return max(p.pass_idx for p in self.placements) + 1

    @property
    def physical_tiles_used(self) -> int:
        return len({p.tile_idx for p in self.placements})

    @property
    def utilization(self) -> float:
        """Mapped bitcells / allocated bitcells (padding waste included)."""
        active = sum(p.rows * p.cols for p in self.placements)
        return active / (len(self.placements) * self.grid.tile**2)

    def replication_factor(self, name: str) -> int:
        """1 + replicas per block: concurrent sample streams for layer."""
        base = self.layer_placements(name)
        if not base:
            return 0
        reps = self.layer_placements(name, replicas=True)
        return len(reps) // len(base)

    def layer_block_counts(self, replicas: bool = False) -> dict:
        """{layer name: placed blocks} in placement (= layer) order.

        The tilemap-true replacement for ``energy.tiles_for_layer``:
        every placed block burns a full physical-tile MVM regardless of
        how many cells it maps, so per-request energy accounting must
        charge PLACED blocks, not logical tiles (serving/metrics.py).
        Primary blocks only by default — replicas split the R samples
        across concurrent tiles at the same per-decision energy.
        """
        out = {name: 0 for name, _ in self.layers}
        for p in self.placements:
            if p.replica and not replicas:
                continue
            out[p.layer] += 1
        return out

    def layer_utilization(self, name: str) -> float:
        """Mapped / allocated bitcells for one layer's primary blocks."""
        ps = self.layer_placements(name)
        active = sum(p.rows * p.cols for p in ps)
        return active / (len(ps) * self.grid.tile**2)

    def det_bayes_blocks(self) -> tuple:
        """(deterministic blocks, Bayesian primary blocks) — aggregate
        placed counts the energy model consumes."""
        shapes = dict(self.layers)
        counts = self.layer_block_counts()
        det = sum(c for n, c in counts.items() if not shapes[n].bayesian)
        bayes = sum(c for n, c in counts.items() if shapes[n].bayesian)
        return det, bayes

    # -- weights ---------------------------------------------------------
    def shard_weights(self, name: str, w) -> dict:
        """Dense [d_in, d_out] -> {placement_key: [tile, tile] block}
        (zero-padded to the physical tile; primary blocks only)."""
        t = self.grid.tile
        w = np.asarray(w)
        out = {}
        for p in self.layer_placements(name):
            blk = np.zeros((t, t), w.dtype)
            blk[:p.rows, :p.cols] = w[p.r0:p.r0 + p.rows, p.c0:p.c0 + p.cols]
            out[(p.pass_idx, p.tile_idx)] = blk
        return out

    def reconstruct(self, name: str, shards: dict) -> np.ndarray:
        """Inverse of ``shard_weights`` — exact round trip."""
        ps = self.layer_placements(name)
        d_in = max(p.r0 + p.rows for p in ps)
        d_out = max(p.c0 + p.cols for p in ps)
        first = next(iter(shards.values()))
        w = np.zeros((d_in, d_out), first.dtype)
        for p in ps:
            blk = shards[(p.pass_idx, p.tile_idx)]
            w[p.r0:p.r0 + p.rows, p.c0:p.c0 + p.cols] = blk[:p.rows, :p.cols]
        return w

    # -- reporting -------------------------------------------------------
    def report(self, r_samples: int = energy.DEPLOY_R,
               batch: int = 1) -> dict:
        shapes = dict(self.layers)
        det, bayes = self.det_bayes_blocks()
        # replicas split the R samples across concurrent tiles: same
        # per-decision work, so energy counts primary blocks only
        bayes_passes = {p.pass_idx for p in self.placements
                        if not p.replica and shapes[p.layer].bayesian}
        bayes_names = [n for n, l in self.layers if l.bayesian]
        rep = min((self.replication_factor(n) for n in bayes_names),
                  default=0)
        r_latency = math.ceil(r_samples / rep) if rep > 1 else r_samples
        e = energy.grid_inference_energy(
            n_det_tiles=det, n_bayes_tiles=bayes, r_samples=r_samples,
            batch=batch, n_passes=self.n_passes,
            n_bayes_passes=len(bayes_passes),
            physical_tiles=self.physical_tiles_used,
            utilization=self.utilization, r_latency=r_latency)
        e.update(
            n_blocks=len(self.placements),
            n_passes=self.n_passes,
            n_shards=self.n_shards,
            physical_tiles=self.physical_tiles_used,
            grid_tiles=self.grid.n_tiles,
        )
        return e


def compile_layer(name: str, shape: LayerShape, grid: TileGrid,
                  seq0: int, n_shards: int = 1) -> tuple[list, int]:
    """Split one [d_in, d_out] layer into placed tile blocks.

    Column-major over output-column groups so K-splits of a column stay
    consecutive (and on one shard); returns (placements, next_seq).
    """
    t = grid.tile
    n_rb = math.ceil(shape.d_in / t)
    n_cb = math.ceil(shape.d_out / t)
    seq = seq0
    out = []
    for cb in range(n_cb):
        shard = (cb * n_shards) // n_cb
        c0 = cb * t
        cols = min(t, shape.d_out - c0)
        for rb in range(n_rb):
            r0 = rb * t
            out.append(Placement(
                layer=name, r0=r0, c0=c0,
                rows=min(t, shape.d_in - r0), cols=cols,
                tile_idx=seq % grid.n_tiles,
                pass_idx=seq // grid.n_tiles,
                shard=shard))
            seq += 1
    return out, seq


def compile_network(layers: Sequence, grid: TileGrid | None = None,
                    n_shards: int = 1, names: Sequence[str] | None = None,
                    replicate_bayesian: bool = True) -> TileProgram:
    """Place a whole network; time-multiplex when it exceeds the grid.

    layers: core.energy.LayerShape sequence (the same shapes the energy
    model and serving metrics consume).  Left-over tiles in the final
    pass replicate the Bayesian blocks (``replica > 0``) — extra
    concurrent sample streams at zero extra passes, reported via
    ``TileProgram.replication_factor``.
    """
    grid = grid or TileGrid()
    names = list(names or (f"layer{i}" for i in range(len(layers))))
    assert len(names) == len(set(names)), "layer names must be unique"
    placements: list[Placement] = []
    seq = 0
    for name, shape in zip(names, layers):
        ps, seq = compile_layer(name, shape, grid, seq, n_shards)
        placements.extend(ps)
    if replicate_bayesian:
        free = (-seq) % grid.n_tiles
        last_pass = (seq - 1) // grid.n_tiles
        bayes = [p for p, l in ((p, dict(zip(names, layers))[p.layer])
                                for p in placements) if l.bayesian]
        n_blocks = len(bayes)
        if n_blocks and free >= n_blocks:
            for rep in range(1, free // n_blocks + 1):
                for p in bayes:
                    placements.append(dataclasses.replace(
                        p, tile_idx=seq % grid.n_tiles,
                        pass_idx=last_pass, replica=rep))
                    seq += 1
    return TileProgram(grid=grid, layers=tuple(zip(names, layers)),
                       placements=tuple(placements), n_shards=n_shards)


def shard_column_partition(program: TileProgram, name: str) -> dict:
    """{shard: sorted output-column blocks} — the sharding-aware
    placement invariant: shards partition the output columns and every
    K-split of a column group lives on exactly one shard."""
    out: dict[int, set] = {}
    for p in program.layer_placements(name):
        out.setdefault(p.shard, set()).add(p.c0)
    return {s: sorted(v) for s, v in out.items()}
