"""Mesh-of-pools fleet serving: one SarServingEngine pool per device.

The single-pool engine already drives one device well — device-resident
escalation, ~0.05 host syncs/decision, a fused decision kernel.  This
module is the scale-out layer on top (ROADMAP item 1): ``N`` complete
engine pools tiled over a 1-D ``("pool",)`` mesh, a data-parallel
admission router in front, and ONE gang dispatch per fleet tick.

Architecture (each box is a full SarServingEngine):

    submit() ──▶ fleet backlog ──▶ least-loaded router
                                     │ (bounded per-pool queues:
                                     │  a saturated pool backpressures)
          ┌───────────┬──────────────┼──────────────┬───────────┐
          ▼           ▼              ▼              ▼
      ┌───────┐   ┌───────┐      ┌───────┐      ┌───────┐
      │pool 0 │   │pool 1 │      │pool 2 │      │pool 3 │   ("pool",)
      │ S slots│  │ S slots│     │ S slots│     │ S slots│    mesh axis
      └───┬───┘   └───┬───┘      └───┬───┘      └───┬───┘
          └───────────┴───── gang ───┴──────────────┘
                one shard_map'd round dispatch / tick
                (per-pool lax.while_loop, independent
                 trip counts, slot-local stats)
                          │
                          ▼
              one blocking host sync / tick:
              retire + refill every pool's slots

Why a *gang* dispatch: decisions/s on the single-pool engine is ~99.5%
host/dispatch overhead (wall 3958 vs model 890k decisions/s at the
bench workload), so running P pools as P independent dispatch loops
would pay that overhead P times.  Instead each fleet tick stacks the
per-pool (pool, stats, base, active) states inside ONE jitted call,
shard_maps the engine's own ``_build_multi_round`` body over the
``("pool",)`` mesh, and pulls all P pools' verdicts in one sync —
retirement drains at exactly the engine's existing host-sync points,
so fleet host_syncs/decision *improves* on the single-pool ~0.05 as P
grows.

Bit-identity: each shard runs the unmodified engine round body on one
complete pool (its own while_loop exit predicate, over only its own
slots — the same cond a standalone engine evaluates), and stream bases
are assigned by each pool engine's own decision counter at admission.
A pool inside the gang therefore produces bit-for-bit the verdicts of a
standalone engine fed the same admission sequence
(tests/test_spmd.py::test_fleet_gang_matches_standalone_pools).  An
idle pool in a gang tick runs one fully-masked round: zero stat/sample
deltas by construction (only its telemetry rounds/dispatch counters
tick, which is what executed).

Aggregation reuses the single-pool machinery unchanged: per-pool
``ServingMetrics`` (energy: Σ per-request ``request_energy`` — the
fleet summary is the exact sum of pool sums), per-pool device telemetry
merged with ``obs.telemetry.merge_snapshots``, and a shared
StageProfiler."""

from __future__ import annotations

import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import prof
from repro.obs.prof import NULL_PROFILER, StageProfiler
from repro.obs.slo import NULL_SLO, SloTracker
from repro.obs.telemetry import TelemetryConfig, merge_snapshots
from repro.obs.trace import NULL_TRACER
from repro.serving.engine import (Request, SarServingEngine,
                                  _build_multi_round)
from repro.serving.metrics import ServingMetrics
from repro.serving.triage import TriagePolicy

POOL_AXIS = "pool"


def make_pool_mesh(n_pools: int):
    """1-D ``("pool",)`` mesh over the first ``n_pools`` devices."""
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((n_pools,), (POOL_AXIS,))


@functools.lru_cache(maxsize=32)
def _sar_gang_fn(hcfg, policy: TriagePolicy, adaptive_mode: bool,
                 r_step: int, fused: bool, n_pools: int, mesh,
                 tcfg: TelemetryConfig | None = None):
    """jit (pools, stats, bases, actives[, telems]) -> per-pool results.

    ``pools``/``stats``(/``telems``) are tuples of P per-pool pytrees;
    ``bases``/``actives`` are [P, S] arrays.  The per-pool trees are
    stacked INSIDE the jitted graph (the stack is part of the compiled
    program — no extra host dispatches), shard_mapped over the
    ``("pool",)`` mesh where each shard runs the engine's un-jitted
    ``_build_multi_round`` body on its own pool, then sliced back out
    per pool.  Returns (stats_tuple, verdicts [P,S], fins tree-of-[P,·],
    rounds [P][, telems_tuple]) — ``rounds`` carries each pool's OWN
    while_loop trip count.

    Cached on the same frozen configs as ``_sar_round_fn`` plus the
    (hashable) mesh, so every fleet over the same mesh shares one
    executable per shape."""
    prof.count_build("sar_gang")
    core = _build_multi_round(
        hcfg=hcfg, policy=policy, adaptive_mode=adaptive_mode,
        r_step=r_step, fused=fused, constrain=lambda t: t, tcfg=tcfg,
        shard=None)
    from repro.launch.mesh import shard_map_compat
    spec = jax.sharding.PartitionSpec(POOL_AXIS)
    squeeze = functools.partial(jax.tree.map, lambda x: x[0])
    expand = functools.partial(jax.tree.map, lambda x: x[None])
    stack = lambda trees: jax.tree.map(                      # noqa: E731
        lambda *xs: jnp.stack(xs), *trees)

    def unstack(tree):
        return tuple(jax.tree.map(lambda x, _p=p: x[_p], tree)
                     for p in range(n_pools))

    if tcfg is None:
        def local(pool, stats, base, active):
            s, v, f, k = core(squeeze(pool), squeeze(stats),
                              squeeze(base), squeeze(active))
            return expand(s), v[None], expand(f), k[None]

        inner = shard_map_compat(local, mesh=mesh,
                                 in_specs=(spec,) * 4, out_specs=spec)

        def gang(pools, stats, bases, actives):
            s, v, f, k = inner(stack(pools), stack(stats), bases,
                               actives)
            return unstack(s), v, f, k

        return jax.jit(gang)

    def local_t(pool, stats, base, active, telem):
        s, v, f, k, t = core(squeeze(pool), squeeze(stats),
                             squeeze(base), squeeze(active),
                             squeeze(telem))
        return expand(s), v[None], expand(f), k[None], expand(t)

    inner = shard_map_compat(local_t, mesh=mesh,
                             in_specs=(spec,) * 5, out_specs=spec)

    def gang_t(pools, stats, bases, actives, telems):
        s, v, f, k, t = inner(stack(pools), stack(stats), bases,
                              actives, stack(telems))
        return unstack(s), v, f, k, unstack(t)

    return jax.jit(gang_t)


class SarServingFleet:
    """Data-parallel fleet of SAR serving pools behind one router.

    ``n_pools`` complete ``SarServingEngine``s (each ``slots_per_pool``
    slots), one per device of a 1-D ``("pool",)`` mesh.  ``gang=None``
    auto-enables the single-dispatch gang round when the process has at
    least ``n_pools`` devices and ``n_pools > 1``; ``gang=False`` (or
    too few devices) falls back to one dispatch per pool per tick —
    identical verdicts, more host syncs.

    Routing is *consistent least-loaded*: each backlog request goes to
    the pool with the smallest (in-flight + queued) load, ties broken
    by lowest pool id, so a given submission sequence always routes the
    same way.  Per-pool admission queues are bounded by ``queue_cap``
    (default: ``slots_per_pool``): a pool with zero free slots and a
    full queue is skipped — it *backpressures* instead of receiving
    blind round-robin traffic — and when every pool is saturated the
    remainder stays in the fleet backlog until a retirement frees
    capacity (``backlog_peak`` in the summary tracks the depth).

    ``head``/``hcfg``/``chip`` bind every pool to the same (possibly
    degraded) die, as in the single-pool engine."""

    def __init__(self, params, cfg, *, n_pools: int = 2,
                 slots_per_pool: int = 32,
                 policy: TriagePolicy = TriagePolicy(),
                 adaptive_mode: bool = True,
                 head: dict | None = None, hcfg=None, chip=None,
                 fused: bool = True,
                 telemetry: bool | TelemetryConfig = True,
                 layers=None, tile_program=None,
                 queue_cap: int | None = None,
                 gang: bool | None = None,
                 profiler: bool | StageProfiler = True,
                 tracer=None,
                 slo=True):
        if n_pools < 1:
            raise ValueError("n_pools must be >= 1")
        self.n_pools = n_pools
        self.slots_per_pool = slots_per_pool
        self.policy = policy
        self.queue_cap = slots_per_pool if queue_cap is None else queue_cap
        if self.queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")
        if profiler is True:
            profiler = StageProfiler()
        self.profiler: StageProfiler = profiler or NULL_PROFILER
        # One tracer stitches the whole fleet into a single timeline:
        # pid 0 = router (fleet_tick spans + request flow starts),
        # pid p+1 = pool p (its engine loop, gang-dispatch track, and
        # slot tracks).  One shared SloTracker receives every pool's
        # retirements plus the fleet-level router/queue/backpressure
        # samples — both are pure host bookkeeping (tests/test_slo.py).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if slo is True:
            slo = SloTracker()
        self.slo: SloTracker = slo or NULL_SLO
        if self.tracer.enabled:
            self.tracer.name_process(0, "router")
            self.tracer.name_thread(0, "fleet ticks", pid=0)
            for p in range(n_pools):
                self.tracer.name_process(p + 1, f"pool {p}")
                self.tracer.name_thread(0, "pool loop", pid=p + 1)
        self.engines = [
            SarServingEngine(
                params, cfg, n_slots=slots_per_pool, policy=policy,
                adaptive_mode=adaptive_mode,
                metrics=ServingMetrics(layers=layers,
                                       extra={"pool": p},
                                       tile_program=tile_program),
                head=head, hcfg=hcfg, chip=chip, fused=fused,
                telemetry=telemetry, profiler=profiler,
                tracer=self.tracer, slo=self.slo, trace_pid=p + 1)
            for p in range(n_pools)]
        e0 = self.engines[0]
        self.tcfg = e0.tcfg
        if gang is None:
            gang = n_pools > 1 and len(jax.devices()) >= n_pools
        self.mesh = None
        self._gang = None
        if gang:
            if len(jax.devices()) < n_pools:
                raise ValueError(
                    f"gang dispatch needs >= {n_pools} devices, have "
                    f"{len(jax.devices())} (XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N)")
            self.mesh = make_pool_mesh(n_pools)
            self._gang = _sar_gang_fn(
                e0.hcfg, policy, adaptive_mode, e0.r_step, fused,
                n_pools, self.mesh, self.tcfg)
        self.backlog: deque[Request] = deque()
        self.routes: dict[int, int] = {}          # rid -> pool id
        self.host_syncs = 0
        self.backlog_peak = 0
        self.wall_s = float("nan")
        # per-tick record for the mesh-latency model (see summary()):
        # {"wall_s", "trips": [P]} — trips is each pool's OWN while_loop
        # trip count this tick (0 = idle pool), the quantity that sets a
        # real mesh's per-tick critical path (slowest pool).
        self.tick_log: list[dict] = []

    # -- admission router ----------------------------------------------
    def submit(self, request: Request) -> None:
        if request.arrival_s == 0.0:
            request.arrival_s = time.time()
        if request.arrival_pc == 0.0:
            request.arrival_pc = time.perf_counter()
        self.backlog.append(request)
        self.backlog_peak = max(self.backlog_peak, len(self.backlog))

    def _pick_pool(self) -> int | None:
        """Least-loaded pool with queue headroom; None = all saturated."""
        best, best_load = None, None
        for p, eng in enumerate(self.engines):
            if len(eng.queue) >= self.queue_cap:
                continue                          # saturated: backpressure
            load = eng.n_active + len(eng.queue)
            if best_load is None or load < best_load:
                best, best_load = p, load
        return best

    def _route(self) -> None:
        had_work = bool(self.backlog)
        t0 = time.perf_counter()
        while self.backlog:
            p = self._pick_pool()
            if p is None:
                break                # every pool saturated — hold here
            req = self.backlog.popleft()
            self.routes[req.rid] = p
            self.engines[p].queue.append(req)
            if self.tracer.enabled:
                # open this request's flow on the router track; the
                # owning pool's slot span closes it at retirement
                self.tracer.flow_start(f"req {req.rid}", req.rid,
                                       tid=0, pid=0)
        if had_work:
            self.slo.observe_router(time.perf_counter() - t0)
        if self.backlog:
            # every pool's bounded queue is full: this tick backpressures
            self.slo.backpressure(len(self.backlog))
            if self.tracer.enabled:
                self.tracer.instant("backpressure", tid=0, pid=0,
                                    backlog=len(self.backlog))

    @property
    def pending(self) -> int:
        return len(self.backlog) + sum(len(e.queue) for e in self.engines)

    @property
    def n_active(self) -> int:
        return sum(e.n_active for e in self.engines)

    # -- dispatch -------------------------------------------------------
    def _dispatch_gang(self, actives: list[np.ndarray]) -> list[int]:
        """One shard_map'd round for ALL pools; one host sync."""
        template = next((e.pool for e in self.engines
                         if e.pool is not None), None)
        for eng in self.engines:
            eng.ensure_pool(like=template)
        pools = tuple(e.pool for e in self.engines)
        stats = tuple(e.stats for e in self.engines)
        bases = jnp.asarray(np.stack([e.base for e in self.engines]))
        acts = jnp.asarray(np.stack(actives))
        with self.profiler.span("dispatch"):
            if self.tcfg is None:
                stats_out, verdicts, fins, rounds = self._gang(
                    pools, stats, bases, acts)
            else:
                telems = tuple(e._telem for e in self.engines)
                stats_out, verdicts, fins, rounds, telems_out = \
                    self._gang(pools, stats, bases, acts, telems)
                for eng, t in zip(self.engines, telems_out):
                    eng._telem = t
        # ONE blocking pull for the whole fleet: every pool's verdicts,
        # finalized stats and trip counts arrive in a single sync.
        with self.profiler.span("triage_loop"):
            verdicts = np.asarray(verdicts)
            rounds = np.asarray(rounds)
            fins = {k: np.asarray(v) for k, v in fins.items()}
        self.host_syncs += 1
        t_verdict = time.perf_counter()
        with self.profiler.span("retirement"):
            for p, eng in enumerate(self.engines):
                eng.stats = stats_out[p]
                if actives[p].any():
                    fin_p = {k: v[p] for k, v in fins.items()}
                    spent = eng.r_step * int(rounds[p])
                    eng._retire_decided(actives[p], verdicts[p], fin_p,
                                        spent, verdict_s=t_verdict)
        return [int(r) for r in rounds]

    def _dispatch_sequential(self, actives: list[np.ndarray]) -> list[int]:
        """Fallback: one engine dispatch per active pool per tick."""
        trips = [0] * self.n_pools
        for p, (eng, active) in enumerate(zip(self.engines, actives)):
            if not active.any():
                continue
            with self.profiler.span("dispatch"):
                if eng.tcfg is None:
                    eng.stats, verdict, fin, rounds = eng._round(
                        eng.pool, eng.stats, jnp.asarray(eng.base),
                        jnp.asarray(active))
                else:
                    (eng.stats, verdict, fin, rounds,
                     eng._telem) = eng._round(
                        eng.pool, eng.stats, jnp.asarray(eng.base),
                        jnp.asarray(active), eng._telem)
            with self.profiler.span("triage_loop"):
                verdict = np.asarray(verdict)
                fin = {k: np.asarray(v) for k, v in fin.items()}
                spent = eng.r_step * int(rounds)
            self.host_syncs += 1
            eng.host_syncs += 1
            trips[p] = int(rounds)
            t_verdict = time.perf_counter()
            with self.profiler.span("retirement"):
                eng._retire_decided(active, verdict, fin, spent,
                                    verdict_s=t_verdict)
        return trips

    # -- main loop ------------------------------------------------------
    def start(self) -> None:
        """Reset per-pool stream bases.  ``run`` calls this; open-loop
        drivers (serving/load.py) call it once, then interleave
        ``submit`` with ``tick`` on their own clock."""
        for eng in self.engines:
            eng.start()

    def tick(self) -> bool:
        """One fleet tick: route the backlog, admit per pool, one gang
        (or sequential) dispatch, retire.  Returns False when no pool
        had active work (idle tick)."""
        t_tick = time.perf_counter()
        t_tr = self.tracer.now()
        with self.profiler.span("route"):
            self._route()
        for eng in self.engines:
            eng._admit()
        self.slo.sample_queues(
            [len(e.queue) for e in self.engines],
            [e.n_active for e in self.engines], len(self.backlog))
        actives = [eng.active_mask() for eng in self.engines]
        if not any(a.any() for a in actives):
            return False
        for eng, active in zip(self.engines, actives):
            eng._stamp_first_dispatch(active)
        t_disp = self.tracer.now()
        if self._gang is not None:
            trips = self._dispatch_gang(actives)
        else:
            trips = self._dispatch_sequential(actives)
        self.tick_log.append(
            {"wall_s": time.perf_counter() - t_tick, "trips": trips})
        if self.tracer.enabled:
            now = self.tracer.now()
            tick_no = len(self.tick_log) - 1
            # per-pool gang-dispatch tracks: one span per pool per tick
            # carrying that pool's OWN while_loop trip count
            for p in range(self.n_pools):
                if actives[p].any():
                    self.tracer.complete(
                        "gang_dispatch", t_disp, now - t_disp,
                        tid=0, pid=p + 1, tick=tick_no, trips=trips[p],
                        n_active=int(actives[p].sum()))
            self.tracer.complete(
                "fleet_tick", t_tr, now - t_tr, tid=0, pid=0,
                tick=tick_no, backlog=len(self.backlog),
                n_active=sum(int(a.sum()) for a in actives),
                max_trips=max(trips))
        return True

    def drain(self) -> dict:
        """Attach per-pool telemetry/perf and build the fleet summary
        (the shared SLO snapshot lands on the fleet summary only)."""
        for eng in self.engines:
            if eng.tcfg is not None:
                eng.metrics.attach_telemetry(eng.telemetry_snapshot())
            eng._attach_perf()
        return self.summary()

    def run(self, max_ticks: int = 100_000) -> dict:
        t0 = time.perf_counter()
        self.start()
        for _ in range(max_ticks):
            if not self.tick():
                if not self.backlog and not any(
                        e.queue for e in self.engines):
                    break
        self.wall_s = time.perf_counter() - t0
        return self.drain()

    # -- aggregation ----------------------------------------------------
    def summary(self) -> dict:
        """Fleet report: exact sums of the per-pool reports.

        ``energy_total_J`` is Σ over pools of Σ per-request
        ``request_energy`` (each pool's ``energy_total_J`` is already
        that sum, so the fleet total reconciles to the per-record sum —
        tests/test_fleet.py asserts it).  ``telemetry`` merges the
        per-pool device snapshots with ``merge_snapshots``; each
        request's counters live in exactly one pool's snapshot, so the
        merge never double-counts."""
        pool_summaries = [e.metrics.summary() for e in self.engines]
        decisions = sum(s["decisions"] for s in pool_summaries)
        requests = sum(s["requests"] for s in pool_summaries)
        wall = self.wall_s
        out = {
            "n_pools": self.n_pools,
            "slots_per_pool": self.slots_per_pool,
            "gang": self._gang is not None,
            "requests": requests,
            "decisions": decisions,
            "wall_s": wall,
            "decisions_per_s": (decisions / wall
                                if wall and wall > 0 else float("nan")),
            "host_syncs": self.host_syncs,
            "host_syncs_per_decision": (self.host_syncs / decisions
                                        if decisions else float("nan")),
            "backlog_peak": self.backlog_peak,
            "routed_per_pool": [
                sum(1 for p in self.routes.values() if p == q)
                for q in range(self.n_pools)],
            "ticks": len(self.tick_log),
            # raw per-tick record (one gang dispatch each): feeds the
            # mesh-latency model in benchmarks/fleet_bench.py, where a
            # real P-device mesh's tick critical path is its slowest
            # pool's trip count
            "tick_log": [dict(t) for t in self.tick_log],
        }
        if decisions:
            out["mean_samples_per_decision"] = sum(
                s["mean_samples_per_decision"] * s["decisions"]
                for s in pool_summaries if s["decisions"]) / decisions
            for frac in ("accept_fraction", "flag_fraction"):
                if requests and all(frac in s for s in pool_summaries):
                    out[frac] = sum(
                        s[frac] * s["requests"]
                        for s in pool_summaries if s["requests"]
                    ) / requests
        if all("energy_total_J" in s for s in pool_summaries):
            out["energy_total_J"] = float(sum(
                s["energy_total_J"] for s in pool_summaries
                if s["requests"]))
        snaps = [s.get("telemetry") for s in pool_summaries]
        snaps = [s for s in snaps if s is not None]
        if snaps:
            out["telemetry"] = merge_snapshots(snaps)
        out["pools"] = [
            {k: s.get(k) for k in
             ("pool", "requests", "decisions", "decisions_per_s",
              "mean_samples_per_decision", "energy_total_J",
              "accept_fraction", "flag_fraction")}
            for s in pool_summaries]
        snap = self.profiler.snapshot()
        if snap:
            out["stage_profile"] = snap
        slo_snap = self.slo.snapshot()
        if slo_snap:
            out["slo"] = slo_snap
            out["backpressure_ticks"] = self.slo.backpressure_ticks
        return out
