"""Adaptive-fidelity sampling: spend GRNG draws only where they matter.

Fixed R = 20 (the paper's deployment point) charges every input the
worst-case sampling cost; Bayes2IMC and FeBiM both identify exactly this
overhead as the barrier to in-memory BNN deployment.  This module
implements the alternative the rank-16 structure makes nearly free
(core/sampling.py): start each decision at a small R, maintain the
predictive statistics *incrementally*, and escalate in geometric rounds
only while the accept/flag decision is statistically ambiguous
(serving/triage.py).

Two properties keep this exact rather than approximate:

  * **Stream extension.**  Escalations draw samples at later ``sample0``
    offsets of the same free-running LFSR selection stream
    (lfsr.indexed_selections), so the union of all rounds is
    *identically* the prefix a single large draw would have produced —
    escalation extends, never redraws.  A request that escalates to
    R = 20 computes bit-for-bit the fixed-R=20 predictive distribution.
  * **Incremental sufficiency.**  predictive_stats needs only the
    arithmetic mean of per-sample softmax probabilities and the mean
    per-sample entropy; both are running sums.  ``finalize`` of the
    accumulated state equals core.uncertainty.predictive_stats of the
    concatenated samples (tested in tests/test_serving.py).

Standard errors: the MC noise of confidence is estimated from the
per-sample variance of the predicted class's probability; the noise of
mutual information from the per-sample entropy variance (the aleatoric
term — the dominant MC-variance contribution; the H(p̄) term's noise is
second-order in 1/n).  Both shrink as 1/√n, driving the sequential
test's ambiguity band to zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lfsr import indexed_selections
from repro.serving.triage import TriagePolicy

_EPS = 1e-12


def escalation_schedule(policy: TriagePolicy) -> tuple:
    """Round sizes (r_1, r_2, ...) summing to exactly r_max.

    Geometric with ratio ``r_growth`` starting at ``r_min`` — e.g. the
    defaults (4, 20, 2) give (4, 8, 8): a cheap first look, then two
    escalations for the ambiguous tail.  Used by the LM engine, whose
    pool escalates in lockstep per token; the SAR engine instead draws
    constant r_min-sized rounds so slots can sit at different depths
    (see SarServingEngine docstring).
    """
    rounds, total, step = [], 0, policy.r_min
    while total < policy.r_max:
        step = min(step, policy.r_max - total)
        rounds.append(step)
        total += step
        step *= policy.r_growth
    return tuple(rounds)


def init_stats(batch: int, n_classes: int) -> dict:
    """Zeroed running-sufficient-statistics for ``batch`` slots."""
    z = jnp.zeros
    return {
        "n": z((batch,), jnp.int32),
        "sum_p": z((batch, n_classes), jnp.float32),
        "sum_psq": z((batch, n_classes), jnp.float32),
        "sum_ent": z((batch,), jnp.float32),
        "sum_entsq": z((batch,), jnp.float32),
    }


def update_stats(stats: dict, logit_samples: jnp.ndarray,
                 mask=None) -> dict:
    """Fold [R, B, C] new logit samples into the running sums.

    ``mask`` [B] (optional): True for slots whose stats SHOULD advance;
    False rows keep their old sums (retired / inactive slots inside a
    fixed-shape pool round).
    """
    logp = jax.nn.log_softmax(logit_samples.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)                                     # [R, B, C]
    ent = -(p * logp).sum(-1)                             # [R, B]
    r = logit_samples.shape[0]
    upd = {
        "n": stats["n"] + r,
        "sum_p": stats["sum_p"] + p.sum(0),
        "sum_psq": stats["sum_psq"] + (p * p).sum(0),
        "sum_ent": stats["sum_ent"] + ent.sum(0),
        "sum_entsq": stats["sum_entsq"] + (ent * ent).sum(0),
    }
    if mask is None:
        return upd
    keep = jnp.asarray(mask)
    return jax.tree.map(
        lambda new, old: jnp.where(
            keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
        upd, stats)


def update_stats_streamed(stats: dict, abasis: dict, sel: jnp.ndarray,
                          hcfg, sample_idx=None, mask=None) -> dict:
    """Fold one round into the running sums WITHOUT materializing
    [R, B, N] — the pure-jnp twin of the fused decision kernel, built
    for chunk-hoisted bases (``activation_basis`` ``m_host``).

    Streams the basis column blocks twice, flash-attention style:
    pass 1 accumulates the online (max, sumexp) per (sample, slot);
    pass 2 normalizes each block against the finished logsumexp and
    accumulates the probability/entropy sums.  Peak device memory per
    step is one [R, B, tile_n] block — at vocab scale neither the 16×
    basis nor the logit-sample tensor ever exists on device.  Matches
    ``update_stats(stats, mix_samples(abasis, sel, ...), mask)`` to
    fp32 tolerance (reduction order differs); call outside jit for
    host-chunked bases.  ``hcfg``: the head's BayesHeadConfig.
    """
    from repro.core.sampling import _mix_block, _noise_key, basis_blocks
    grng = hcfg.grng
    key = _noise_key(sel, sample_idx) if grng.read_sigma else None
    y_mu, x_sigma = abasis["y_mu"], abasis["x_sigma"]
    x_sigsq = abasis.get("x_sigsq")
    r, b = sel.shape[0], y_mu.shape[0]

    def logits_block(m, c0, c1):
        return _mix_block(
            m, y_mu[:, c0:c1], x_sigma[:, c0:c1],
            None if x_sigsq is None else x_sigsq[:, c0:c1],
            sel, hcfg, key, col0=c0).astype(jnp.float32)

    mrun = jnp.full((r, b), -1.0e30, jnp.float32)
    lrun = jnp.zeros((r, b), jnp.float32)
    for m, c0, c1 in basis_blocks(abasis):
        logits = logits_block(m, c0, c1)
        mnew = jnp.maximum(mrun, logits.max(-1))
        lrun = (lrun * jnp.exp(mrun - mnew)
                + jnp.exp(logits - mnew[..., None]).sum(-1))
        mrun = mnew
    lse = mrun + jnp.log(lrun)                            # [R, B]

    p_parts, psq_parts = [], []
    ent = jnp.zeros((r, b), jnp.float32)
    for m, c0, c1 in basis_blocks(abasis):
        logp = logits_block(m, c0, c1) - lse[..., None]
        p = jnp.exp(logp)
        p_parts.append(p.sum(0))
        psq_parts.append((p * p).sum(0))
        ent = ent + -(p * logp).sum(-1)
    upd = {
        "n": stats["n"] + r,
        "sum_p": stats["sum_p"] + jnp.concatenate(p_parts, axis=-1),
        "sum_psq": stats["sum_psq"] + jnp.concatenate(psq_parts, axis=-1),
        "sum_ent": stats["sum_ent"] + ent.sum(0),
        "sum_entsq": stats["sum_entsq"] + (ent * ent).sum(0),
    }
    if mask is None:
        return upd
    keep = jnp.asarray(mask)
    return jax.tree.map(
        lambda new, old: jnp.where(
            keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
        upd, stats)


def finalize(stats: dict) -> dict:
    """Predictive quantities + MC standard errors from running sums.

    Matches core.uncertainty.predictive_stats on the same samples
    (probs / confidence / prediction / entropies / MI), adding
    ``confidence_se`` and ``mutual_information_se`` for the sequential
    test, and ``n`` (samples drawn so far).
    """
    n = jnp.maximum(stats["n"], 1).astype(jnp.float32)
    p_mean = stats["sum_p"] / n[:, None]                  # [B, C]
    pred = p_mean.argmax(-1)
    conf = p_mean.max(-1)
    logp_mean = jnp.log(jnp.maximum(p_mean, _EPS))
    pred_entropy = -(p_mean * logp_mean).sum(-1)
    exp_entropy = stats["sum_ent"] / n

    p_pred = jnp.take_along_axis(stats["sum_p"], pred[:, None], 1)[:, 0] / n
    psq_pred = jnp.take_along_axis(stats["sum_psq"], pred[:, None], 1)[:, 0] / n
    var_conf = jnp.maximum(psq_pred - p_pred**2, 0.0)
    var_ent = jnp.maximum(stats["sum_entsq"] / n - exp_entropy**2, 0.0)

    return {
        "probs": p_mean,
        "confidence": conf,
        "prediction": pred,
        "predictive_entropy": pred_entropy,
        "expected_entropy": exp_entropy,
        "mutual_information": pred_entropy - exp_entropy,
        "confidence_se": jnp.sqrt(var_conf / n),
        "mutual_information_se": jnp.sqrt(var_ent / n),
        "n": stats["n"],
    }


def stream_indices(base: jnp.ndarray, n_drawn: jnp.ndarray,
                   num: int) -> jnp.ndarray:
    """Absolute stream positions of the NEXT ``num`` samples. [num, B].

    Also the read-noise key for ``mix_samples`` on degraded chip
    instances — index-keyed noise keeps escalation rounds fresh and
    re-reads reproducible (repro/hw)."""
    return (base[None, :] + n_drawn[None, :]
            + jnp.arange(num, dtype=jnp.uint32)[:, None]).astype(jnp.uint32)


def stream_selections(grng_cfg, base: jnp.ndarray, n_drawn: jnp.ndarray,
                      num: int) -> jnp.ndarray:
    """Per-slot selection vectors for the NEXT ``num`` samples.

    base [B]: each slot's reserved region of the global selection stream
    (decision_id · r_max — see engine.py); n_drawn [B]: samples already
    consumed.  Returns [num, B, 16] — consecutive stream positions per
    slot, so escalation extends the exact stream a single large draw
    would read.
    """
    return indexed_selections(grng_cfg.lfsr_seed,
                              stream_indices(base, n_drawn, num))
