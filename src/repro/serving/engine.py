"""Continuous-batching serving engine with adaptive-fidelity slots.

The paper's deployment target is a latency/energy-constrained edge
engine; the ROADMAP's is a service under heavy traffic.  Both reduce to
the same scheduling problem: keep a fixed pool of decode slots full,
retire a request the moment its decision is made, and refill the slot
from the admission queue without stalling the others.  This module
implements that engine twice over one scheduler skeleton:

``SarServingEngine`` — the paper's workload.  A request is one aerial
image patch; its per-slot state is the rank-16 **activation basis**
(core/sampling.activation_basis): 16 basis products computed once at
admission, after which every escalation round costs only a [r,16]
mixing contraction.  Slots sit at *different* escalation depths — an
easy image retires after the first 4-sample round while its neighbor
escalates to 20 — which is where adaptive fidelity buys throughput.

``LMServingEngine`` — token streams.  Slots share a synchronized decode
clock (the KV cache layout has one scalar ``pos``); per-token head
sampling escalates in geometric rounds with early exit when every
active slot has decided.  Mid-stream admission is *exact* for RoPE
trunks: a new prompt is prefilled left-padded at the fixed admission
length, its cached K re-rotated by the pool-clock offset (RoPE scores
depend only on relative distance, so a uniform rotation re-bases the
stream), rolled into place, and masked via the per-slot ``start``
recorded by prefill (models/attention.py).  SSM slots are recurrent
state rows — the *scatter* is exact, but the admitted state carries a
documented approximation: prefill_ssm runs the left-pad prefix through
the recurrence (an exact path would re-run the bare prompt at
slot-local positions), so a fresh SSM slot starts pad-polluted.
Measured (test_serving.test_ssm_leftpad_admission_pollution_quantified):
~30% relative hidden error at admission for a short prompt behind a
long zero pad, decaying below 5% within 3 decode steps — the selective
state space forgets the pad like a short neutral context.  Trunks whose
positions cannot be re-based (learned absolute positions, e.g. whisper)
still serve correctly: admission simply waits for the pool to drain and
rebase to delta = 0, where left-padded prefill needs no re-basing.

Slot state lives in donated device buffers: admission scatters rows
into the pool pytree with ``.at[idx].set(..., mode='drop')`` (a fixed
out-of-range index parks unused admission rows), and every jitted pool
update donates its inputs, so the engine never holds two copies of a
KV cache.  All jitted shapes are fixed by (n_slots, prompt_len,
round sizes): the compile set is O(len(schedule)), not O(traffic).

Hot-path execution (this is the repo's hottest loop — see
kernels/decision_kernel.py):

  * **Device-resident escalation.**  Each dispatch runs a
    ``lax.while_loop`` of escalation rounds ON DEVICE — on-device
    ``triage.decide``, donated stats — and returns to the host only
    when some active slot has decided (so the scheduler can retire and
    refill it) or the R budget is exhausted.  The LM engine runs its
    whole geometric schedule per token in ONE dispatch
    (``lax.cond``-skipped rounds after every slot decides).  The old
    one-host-sync-per-4-samples pattern is gone; ``host_syncs`` counts
    the blocking device→host round trips that remain.

  * **Fused decision kernel** (``fused=True``, the default): each round
    folds samples into the running sufficient statistics via
    ``kernels.ops.decision_update`` — mixing, read-noise projection,
    online softmax over N, entropy, and active-slot masking all in
    VMEM; the [R, B, N] logit-sample tensor never materializes.
    ``fused=False`` keeps the pure-jnp ``mix_samples → update_stats``
    path (verdict-identical; tests/test_decision_kernel.py).

  * **Shared compile cache.**  The jitted pool functions are built by
    module-level ``lru_cache`` builders keyed on the (hashable, frozen)
    configs, so every engine instance with the same shapes and policy
    reuses the same compiled executables — constructing an engine per
    benchmark run or per chip instance no longer recompiles the world.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.sampling import (BayesHeadConfig, activation_basis,
                                 mix_samples)
from repro.obs import prof
from repro.obs.prof import NULL_PROFILER, StageProfiler
from repro.obs.telemetry import (TelemetryConfig, count_dispatch,
                                 init_telemetry, record_decisions,
                                 record_round)
from repro.obs.telemetry import snapshot as telemetry_snapshot
from repro.obs.slo import NULL_SLO, SloTracker
from repro.obs.trace import NULL_TRACER
from repro.serving import adaptive, triage
from repro.serving.metrics import RequestRecord, ServingMetrics
from repro.serving.triage import ACCEPT, ESCALATE, FLAG, TriagePolicy


@dataclasses.dataclass
class Request:
    """One unit of admission: an image (SAR) or a prompt (LM).

    ``arrival_s`` is a wall-clock timestamp (when the request entered
    the system); ``arrival_pc`` is the monotonic ``perf_counter`` twin
    stamped at ``submit`` and used for latency intervals, so a wall
    clock stepping backwards can never produce negative latencies."""
    rid: int
    payload: Any                      # [H,W,1] image | [L] token ids
    arrival_s: float = 0.0
    max_new_tokens: int = 8           # LM only
    meta: dict = dataclasses.field(default_factory=dict)
    arrival_pc: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    admit_s: float = 0.0              # perf_counter stamp at admission
    first_dispatch_s: float = 0.0     # first dispatch covering this slot
    n_samples: int = 0                # accumulated over the request
    n_decisions: int = 0              # tokens decided (LM) / 1 (SAR)


# ----------------------------------------------------------------------
# process-wide jitted pool functions (shared across engine instances)
# ----------------------------------------------------------------------
def _constrainer(slot_axis: str | None):
    if slot_axis is None:
        return lambda tree: tree
    from jax.sharding import PartitionSpec as P

    def constrain(tree):
        return jax.tree.map(
            lambda leaf: jax.lax.with_sharding_constraint(
                leaf, P(slot_axis, *(None,) * (leaf.ndim - 1))),
            tree)

    return constrain


@functools.lru_cache(maxsize=None)
def _scatter_fn(slot_axis: str | None):
    prof.count_build("scatter")
    constrain = _constrainer(slot_axis)

    def scatter(pool, rows, idx):
        return constrain(jax.tree.map(
            lambda p, r: p.at[idx].set(r, mode="drop"), pool, rows))

    return jax.jit(scatter, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _stats_reset_fn():
    prof.count_build("stats_reset")

    def stats_reset(stats, idx):
        return jax.tree.map(
            lambda s: s.at[idx].set(0, mode="drop"), stats)

    return jax.jit(stats_reset, donate_argnums=(0,))


@functools.lru_cache(maxsize=64)
def _sar_featurize_fn(cfg, hcfg: BayesHeadConfig, chip,
                      slot_axis: str | None):
    """jit (params, head, images) -> activation-basis rows.

    Cached on the frozen configs + the chip instance's identity
    (ChipInstance is ``eq=False`` — a given die's nonideal trunk
    constants are baked into one executable, reused by every engine
    bound to that die).  Bounded: a fleet sweep over many chips evicts
    least-recently-used entries instead of pinning every die's
    executable (live engines keep their own reference)."""
    prof.count_build("sar_featurize")
    from repro.models.sar_cnn import features
    constrain = _constrainer(slot_axis)

    def featurize(params, head, images):
        feats = features(params, images, cfg, chip=chip)
        return constrain(activation_basis(head, feats, hcfg))

    return jax.jit(featurize)


def _one_round(pool, stats, base, active, *, hcfg: BayesHeadConfig,
               policy: TriagePolicy, adaptive_mode: bool, r_step: int,
               fused: bool, constrain, tcfg: TelemetryConfig | None = None,
               telem=None, shard=None):
    """One escalation round: draw r_step per active slot, fold into the
    running stats (fused kernel or jnp), finalize, decide.

    ``shard`` is an optional ``(mesh, axis_name)``: the fused kernel
    then runs shard_map-native over the slot axis (its own Pallas grid
    per device, slot-local stats, global-row hash keys — bit-identical
    to the unsharded kernel).

    With ``tcfg``/``telem`` set, the round also folds the device-resident
    telemetry pytree (round counters + GRNG probe moments) — pure extra
    arithmetic on arrays already in the graph, never a sync."""
    grng = hcfg.grng
    sel = adaptive.stream_selections(grng, base, stats["n"], r_step)
    idx = adaptive.stream_indices(base, stats["n"], r_step)
    if fused:
        from repro.kernels.ops import decision_update
        stats = decision_update(stats, pool, sel, grng,
                                sample_idx=idx, mask=active, shard=shard)
    else:
        samples = mix_samples(pool, sel, hcfg, sample_idx=idx)
        stats = adaptive.update_stats(stats, samples, mask=active)
    stats = constrain(stats)
    fin = adaptive.finalize(stats)
    if adaptive_mode:
        verdict = triage.decide(fin, policy,
                                final=fin["n"] >= policy.r_max)
    else:
        verdict = triage.fixed_r_decide(fin, policy)
    if telem is not None:
        telem = record_round(telem, tcfg, grng, sel, idx, active)
    return stats, verdict, fin, telem


def _build_multi_round(*, hcfg: BayesHeadConfig, policy: TriagePolicy,
                       adaptive_mode: bool, r_step: int, fused: bool,
                       constrain, tcfg: TelemetryConfig | None = None,
                       shard=None):
    """Un-jitted device-resident escalation loop — the shared core of
    ``_sar_round_fn`` (per-engine dispatch) and the fleet gang round
    (serving/fleet.py shard_maps it over the pool axis).

    Returns (pool, stats, base, active) -> (stats, verdict, fin, rounds)
    — or the telemetry-carrying variant when ``tcfg`` is set (telem
    rides the while_loop carry; decisions recorded once after the loop,
    which only exits when a verdict leaves ESCALATE or the pool idles).
    """
    kw = dict(hcfg=hcfg, policy=policy, adaptive_mode=adaptive_mode,
              r_step=r_step, fused=fused, constrain=constrain,
              shard=shard)

    if tcfg is None:
        def multi_round(pool, stats, base, active):
            stats, verdict, fin, _ = _one_round(pool, stats, base,
                                                active, **kw)

            def cond(state):
                _, v, _f, _k = state
                return jnp.any(active) & ~jnp.any(active
                                                  & (v != ESCALATE))

            def body(state):
                s, _v, _f, k = state
                s, v, f, _ = _one_round(pool, s, base, active, **kw)
                return (s, v, f, k + jnp.int32(1))

            return lax.while_loop(cond, body,
                                  (stats, verdict, fin, jnp.int32(1)))

        return multi_round

    kw_t = dict(kw, tcfg=tcfg)

    def multi_round_t(pool, stats, base, active, telem):
        stats, verdict, fin, telem = _one_round(pool, stats, base,
                                                active, telem=telem,
                                                **kw_t)

        def cond(state):
            _, v, _f, _k, _t = state
            return jnp.any(active) & ~jnp.any(active & (v != ESCALATE))

        def body(state):
            s, _v, _f, k, t = state
            s, v, f, t = _one_round(pool, s, base, active, telem=t,
                                    **kw_t)
            return (s, v, f, k + jnp.int32(1), t)

        stats, verdict, fin, rounds, telem = lax.while_loop(
            cond, body, (stats, verdict, fin, jnp.int32(1), telem))
        decided = active & (verdict != ESCALATE)
        telem = record_decisions(telem, tcfg, fin, verdict, decided)
        telem = count_dispatch(telem)
        return stats, verdict, fin, rounds, telem

    return multi_round_t


@functools.lru_cache(maxsize=128)
def _sar_round_fn(hcfg: BayesHeadConfig, policy: TriagePolicy,
                  adaptive_mode: bool, r_step: int, fused: bool,
                  slot_axis: str | None,
                  tcfg: TelemetryConfig | None = None,
                  mesh=None):
    """jit (pool, stats, base, active) -> (stats, verdict, fin, rounds).

    Device-resident escalation: a ``lax.while_loop`` keeps drawing
    r_step-sample rounds for the active slots while EVERY one of them
    is still in the sequential test's ambiguity band; it exits the
    moment any slot's verdict leaves ESCALATE (that slot must retire —
    a host decision) or the budget forces a decision.  ``rounds`` is
    the number of rounds executed this dispatch (every active slot drew
    ``r_step · rounds`` samples).

    With both ``slot_axis`` and ``mesh`` set (a hashable
    jax.sharding.Mesh — engines capture the ambient one at
    construction), the fused kernel inside every round runs
    shard_map-native over the slot axis: one Pallas grid per device on
    its local slots, slot-local statistics, no collectives in the
    round's data path.  The only cross-shard coordination left is the
    while_loop exit predicate (one bool per shard per round) — required
    because retirement is a global host decision.  Without a mesh the
    old behavior stands: XLA partitions the interpret-mode lowering
    under ``with_sharding_constraint``.

    With ``tcfg`` set the signature becomes
    (pool, stats, base, active, telem) -> (..., rounds, telem): the
    telemetry pytree rides the while_loop carry and is donated back,
    so enabling it changes neither dispatch count nor sync count."""
    prof.count_build("sar_round")
    constrain = _constrainer(slot_axis)
    shard = (mesh, slot_axis) if (mesh is not None
                                  and slot_axis is not None) else None
    fn = _build_multi_round(
        hcfg=hcfg, policy=policy, adaptive_mode=adaptive_mode,
        r_step=r_step, fused=fused, constrain=constrain, tcfg=tcfg,
        shard=shard)
    if tcfg is None:
        return jax.jit(fn, donate_argnums=(1,))
    return jax.jit(fn, donate_argnums=(1, 4))


@functools.lru_cache(maxsize=128)
def _lm_token_fn(hcfg: BayesHeadConfig, policy: TriagePolicy,
                 adaptive_mode: bool, schedule: tuple, fused: bool,
                 n_slots: int, n_classes: int,
                 tcfg: TelemetryConfig | None = None,
                 slot_axis: str | None = None, mesh=None):
    """jit (abasis, base, active) -> (verdict, fin, spent).

    One whole token decision on device: zeroed stats, then the full
    geometric escalation schedule unrolled with ``lax.cond``-skipped
    rounds once every active slot has decided — stats advance only for
    active & undecided slots, exactly the old per-round host loop but
    in a single dispatch.

    With ``slot_axis``+``mesh`` set (and ``n_slots`` divisible over the
    axis) the fused kernel runs shard_map-native over the slot/batch
    dimension — the mission rollout threads its fleet×episodes batch
    axis here so die-group episodes shard like serving pools do.

    With ``tcfg`` set the signature becomes
    (abasis, base, active, telem) -> (..., spent, telem): telemetry
    rides the ``lax.cond`` state (it skips with the round), and every
    active slot's token verdict is final at schedule end (triage forces
    a decision at r_max), so decisions are recorded once on ``active``."""
    prof.count_build("lm_token")
    grng = hcfg.grng
    shard = None
    if mesh is not None and slot_axis is not None:
        size = dict(mesh.shape).get(slot_axis, 0)
        if size > 0 and n_slots % size == 0:
            shard = (mesh, slot_axis)
    identity = lambda st: st                                 # noqa: E731

    def token_decision(abasis, base, active, telem=None):
        stats = adaptive.init_stats(n_slots, n_classes)
        fin = adaptive.finalize(stats)
        verdict = jnp.full((n_slots,), ESCALATE, jnp.int32)
        spent = jnp.zeros((n_slots,), jnp.int32)
        # None is a valid (empty) pytree leaf-set: when telemetry is
        # off the carry element costs nothing and the graph is the old
        # one.
        state = (stats, active, spent, verdict, fin, telem)

        for r_k in schedule:
            def run_round(st, _r=r_k):
                stats, undec, spent, _v, _f, telem = st
                upd = active & undec
                sel = adaptive.stream_selections(grng, base,
                                                 stats["n"], _r)
                idx = adaptive.stream_indices(base, stats["n"], _r)
                if fused:
                    from repro.kernels.ops import decision_update
                    stats = decision_update(stats, abasis, sel, grng,
                                            sample_idx=idx, mask=upd,
                                            shard=shard)
                else:
                    samples = mix_samples(abasis, sel, hcfg,
                                          sample_idx=idx)
                    stats = adaptive.update_stats(stats, samples,
                                                  mask=upd)
                fin = adaptive.finalize(stats)
                if adaptive_mode:
                    verdict = triage.decide(
                        fin, policy, final=fin["n"] >= policy.r_max)
                else:
                    verdict = triage.fixed_r_decide(fin, policy)
                spent = spent + jnp.where(upd, _r, 0).astype(spent.dtype)
                undec = undec & (verdict == ESCALATE)
                if telem is not None:
                    telem = record_round(telem, tcfg, grng, sel, idx,
                                         upd)
                return (stats, undec, spent, verdict, fin, telem)

            state = lax.cond(jnp.any(state[1]), run_round, identity,
                             state)
        _, _, spent, verdict, fin, telem = state
        if telem is None:
            return verdict, fin, spent
        telem = record_decisions(telem, tcfg, fin, verdict, active)
        telem = count_dispatch(telem)
        return verdict, fin, spent, telem

    # no donation: the basis is consumed, not aliased into any output,
    # and this function also runs inside the mission episode jit where
    # donation of a captured carry would warn.
    return jax.jit(token_decision)


class _EngineBase:
    """Queue + slot bookkeeping shared by both engines."""

    def __init__(self, n_slots: int, policy: TriagePolicy,
                 metrics: ServingMetrics | None,
                 telemetry: bool | TelemetryConfig = True,
                 tracer=None,
                 profiler: bool | StageProfiler = True,
                 slo=True,
                 trace_pid: int = 0):
        self.n_slots = n_slots
        self.policy = policy
        self.queue: deque[Request] = deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.free: list[int] = list(range(n_slots))
        self.metrics = metrics or ServingMetrics()
        self._decision_counter = 0
        # Blocking device→host round trips on the decision path (one per
        # round dispatch: the verdict/fin pull).  serving_bench reports
        # host_syncs / decisions — the tentpole metric of the
        # device-resident escalation loop.
        self.host_syncs = 0
        # Device-resident telemetry (obs/telemetry): rides the jitted
        # round dispatches and is pulled only in telemetry_snapshot().
        if telemetry is True:
            telemetry = TelemetryConfig()
        self.tcfg: TelemetryConfig | None = telemetry or None
        self._telem = (init_telemetry(self.tcfg, policy.r_max)
                       if self.tcfg else None)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # Host-side stage latency histograms (obs/prof).  perf_counter
        # spans around the loop phases — never touches device state.
        if profiler is True:
            profiler = StageProfiler()
        self.profiler: StageProfiler = profiler or NULL_PROFILER
        # Host-side SLO lifecycle tracking (obs/slo): retired records
        # stream into time-to-verdict histograms.  True for a fresh
        # tracker this engine owns (and attaches to its summary), an
        # existing SloTracker to share one fleet-wide (the owner then
        # attaches it), False/None to disable.  Pure host bookkeeping
        # at the existing sync points: no graph change, no extra syncs.
        if slo is True:
            slo = SloTracker()
            self._own_slo = True
        else:
            self._own_slo = False
        self.slo: SloTracker = slo or NULL_SLO
        # Trace process id: 0 standalone; the fleet assigns pid p+1 so
        # every pool lands on its own named process track in ONE trace.
        self.trace_pid = int(trace_pid)
        for i in range(n_slots):
            self.tracer.name_thread(i + 1, f"slot {i}",
                                    pid=self.trace_pid)

    def submit(self, request: Request) -> None:
        if request.arrival_s == 0.0:
            request.arrival_s = time.time()
        if request.arrival_pc == 0.0:
            request.arrival_pc = time.perf_counter()
        self.queue.append(request)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free)

    @property
    def pending(self) -> int:
        """Requests admitted to the queue but not yet slotted."""
        return len(self.queue)

    def _next_bases(self, count: int) -> np.ndarray:
        """Reserve fresh selection-stream regions: each decision owns
        [id·r_max, (id+1)·r_max) of the global stream."""
        ids = np.arange(self._decision_counter,
                        self._decision_counter + count, dtype=np.uint32)
        self._decision_counter += count
        return ids * np.uint32(self.policy.r_max)

    def _retire(self, slot_idx: int, verdict: int, fin: dict,
                extra_samples: int,
                verdict_s: float = float("nan")) -> None:
        slot = self.slots[slot_idx]
        req = slot.req
        now = time.perf_counter()
        self.metrics.mark(now)
        rec = RequestRecord(
            rid=req.rid, verdict=int(verdict),
            n_samples=slot.n_samples + extra_samples,
            n_decisions=max(slot.n_decisions, 1),
            arrival_s=req.arrival_s, admit_s=slot.admit_s, done_s=now,
            prediction=int(fin["prediction"][slot_idx]),
            confidence=float(fin["confidence"][slot_idx]),
            mutual_information=float(fin["mutual_information"][slot_idx]),
            arrival_pc=req.arrival_pc,
            first_dispatch_s=(slot.first_dispatch_s or float("nan")),
            verdict_s=verdict_s,
        )
        self.metrics.record(rec)
        self.slo.observe(rec)
        if self.tracer.enabled:
            start = slot.admit_s - self.tracer.t0
            self.tracer.complete(
                f"req {req.rid}", start, now - slot.admit_s,
                tid=slot_idx + 1, pid=self.trace_pid,
                verdict=int(verdict),
                n_samples=slot.n_samples + extra_samples,
                n_decisions=max(slot.n_decisions, 1))
            # Close this request's Perfetto flow on the slot span —
            # a fleet's router opened it when the request was routed.
            self.tracer.flow_end(f"req {req.rid}", req.rid, start,
                                 tid=slot_idx + 1, pid=self.trace_pid)
        slot.req = None
        slot.n_samples = slot.n_decisions = 0
        slot.first_dispatch_s = 0.0
        self.free.append(slot_idx)

    def telemetry_snapshot(self) -> dict | None:
        """Host snapshot of the device-resident telemetry (one sync)."""
        if self.tcfg is None or self._telem is None:
            return None
        return telemetry_snapshot(self._telem, self.tcfg)

    def _attach_perf(self) -> None:
        """Attach the stage-profile snapshot + process compile counters
        to the run summary (surfaced as ``stage_profile`` /
        ``compile_counters`` keys; obs.registry picks both up)."""
        snap = self.profiler.snapshot()
        self.metrics.attach_profile(snap or None, prof.compile_counters())
        if self._own_slo:
            self.metrics.attach_slo(self.slo.snapshot())

    def _stamp_first_dispatch(self, active) -> None:
        """Host-side lifecycle stamp: the first dispatch that covers a
        slot.  Cheap clock arithmetic before the (already-pending)
        device round — no sync, no graph change."""
        now = time.perf_counter()
        for i in np.nonzero(active)[0]:
            if self.slots[i].first_dispatch_s == 0.0:
                self.slots[i].first_dispatch_s = now


# ----------------------------------------------------------------------
# SAR image-stream engine
# ----------------------------------------------------------------------
class SarServingEngine(_EngineBase):
    """Adaptive-fidelity victim/no-victim triage over an image stream.

    adaptive=False reproduces the paper's fixed-R dataflow inside the
    same scheduler (one r_max-sample round, decide immediately) so the
    bench compares policies, not implementations.

    Escalation here is CONSTANT-STEP (r_min samples per tick), not the
    geometric ``escalation_schedule`` the LM engine uses: slots sit at
    different escalation depths inside one fixed-shape pool round, so
    every tick must draw the same per-slot count.  ``policy.r_growth``
    therefore has no effect on this engine.  Consecutive rounds execute
    device-resident (``_sar_round_fn``): the host is re-entered only to
    retire decided slots and refill them from the queue.
    """

    def __init__(self, params, cfg, *, n_slots: int = 32,
                 policy: TriagePolicy = TriagePolicy(),
                 adaptive_mode: bool = True, metrics: ServingMetrics = None,
                 head: dict | None = None,
                 hcfg: BayesHeadConfig | None = None,
                 chip=None, slot_axis: str | None = None,
                 mesh=None,
                 fused: bool = True,
                 telemetry: bool | TelemetryConfig = True,
                 tracer=None,
                 profiler: bool | StageProfiler = True,
                 slo=True,
                 trace_pid: int = 0):
        """``head``/``hcfg``: pre-deployed serving head + its config —
        the repro/hw chip-instance path (hw.calib.prepare_instance_head
        returns both; the rank-16 fast path below runs unchanged on the
        degraded instance).  Default: golden-chip head from ``params``.

        ``profiler``: host-side per-stage latency histograms
        (obs/prof.StageProfiler) over admission / featurize / dispatch /
        triage_loop / retirement — True for a fresh profiler, an
        existing StageProfiler to share one across engines, False to
        disable.  Pure host clock arithmetic: no syncs, no graph change.

        ``chip`` (a hw.ChipInstance): run the deterministic conv trunk
        on that die's nonideal CIM arrays too (models/sar_cnn.features
        with per-column ADC gain/offset + programming error) — together
        with a ``prepare_instance_head`` head this makes EVERY serving
        decision flow through the same nonideal device model.

        ``slot_axis``: mesh axis name to shard the slot (pool batch)
        dimension over — construct and run the engine inside
        ``mesh_context`` and admission scatters stay slot-local while
        every pool round executes data-parallel over the slots.
        ``mesh``: the jax.sharding.Mesh carrying ``slot_axis`` (default:
        captured from the ambient mesh context at construction).  When
        the mesh is known and ``n_slots`` divides over the axis, the
        fused kernel runs shard_map-native per shard
        (kernels/decision_kernel.decision_stats_sharded) instead of
        relying on XLA to partition the interpret-mode lowering —
        verdicts are bit-identical either way (tests/test_spmd.py).

        ``fused``: fold escalation rounds through the fused Pallas
        decision kernel (kernels/decision_kernel.py) instead of the
        materializing ``mix_samples → update_stats`` path.  Verdicts
        are identical; the fused path never holds [R, B, N].

        ``telemetry``: device-resident counters/histograms/GRNG probe
        moments (obs/telemetry) riding the round dispatches — True for
        the default TelemetryConfig, a TelemetryConfig to customize,
        False to compile the exact pre-telemetry graph.  ``tracer``: an
        obs.trace.Tracer collecting per-request/per-dispatch spans.
        Neither adds host syncs or changes verdicts (tests/test_obs.py).
        ``slo``: host-side time-to-verdict tracking (obs/slo) — True
        for an owned tracker, a shared SloTracker (fleet), or False;
        like the profiler it is free at the decision level
        (tests/test_slo.py).
        """
        super().__init__(n_slots, policy, metrics, telemetry, tracer,
                         profiler, slo, trace_pid)
        from repro.core.bayes_layer import to_serving
        self.cfg = cfg
        self.adaptive_mode = adaptive_mode
        self.fused = fused
        self.hcfg = hcfg or BayesHeadConfig(
            num_samples=policy.r_max, mode="rank16", grng=cfg.grng,
            compute_dtype=jnp.float32, hoist_basis=True)
        if head is None:
            head = to_serving(params["head"], self.hcfg)
        self.r_step = policy.r_min if adaptive_mode else policy.r_max
        self._params = params
        self._head = head

        feat = _sar_featurize_fn(cfg, self.hcfg, chip, slot_axis)
        self._featurize_jit = feat
        self._featurize = lambda imgs: feat(self._params, self._head,
                                            imgs)
        self._scatter = _scatter_fn(slot_axis)
        self._stats_reset = _stats_reset_fn()
        self._mesh = self._resolve_mesh(mesh, slot_axis, n_slots)
        self._round = _sar_round_fn(self.hcfg, policy, adaptive_mode,
                                    self.r_step, fused, slot_axis,
                                    self.tcfg, mesh=self._mesh)
        self._chip = chip
        self._slot_axis = slot_axis
        self.pool = None
        self.stats = None
        self.base = None

    @staticmethod
    def _resolve_mesh(mesh, slot_axis: str | None, n_slots: int):
        """The mesh the shard_map-native round runs over, or None.

        Captures the ambient mesh when ``slot_axis`` is set but no mesh
        was passed; drops back to None (= XLA-partitioned lowering)
        when the axis is absent from the mesh or n_slots doesn't divide
        over it."""
        if slot_axis is None:
            return None
        if mesh is None:
            from repro.launch.mesh import abstract_mesh_or
            mesh = abstract_mesh_or(None)
        if mesh is None:
            return None
        size = dict(mesh.shape).get(slot_axis, 0)
        if size <= 0 or n_slots % size:
            return None
        return mesh

    # -- lifetime -------------------------------------------------------
    def swap_head(self, head: dict, hcfg: BayesHeadConfig) -> None:
        """Hot-swap a (re)deployed head into the RUNNING engine.

        hw/redeploy.py's self-healing loop calls this between run
        segments: after a recalibration (or an age advance of the
        served view) the new head + config replace the old ones and
        only the head-dependent builders (featurize, round) are
        re-resolved.  Those builders are module-level lru caches, so a
        previously-seen (hcfg, chip) pair is a cache HIT, and the
        epoch-free executables (scatter, stats reset, other engines')
        are untouched — ``BayesHeadConfig.calib_epoch`` keys fresh
        calibrations apart without invalidating anything else.

        Requires a quiescent pool: in-flight slots hold activations
        featurized under the old head, so swap between segments after
        ``run()`` drains the queue.  Queue contents, metrics, telemetry
        and the decision-stream counter all survive the swap.
        """
        if self.n_active:
            raise RuntimeError(
                f"swap_head with {self.n_active} in-flight slots — "
                f"drain the pool (run()) and swap between segments")
        self.hcfg = hcfg
        self._head = head
        feat = _sar_featurize_fn(self.cfg, hcfg, self._chip,
                                 self._slot_axis)
        self._featurize_jit = feat
        self._featurize = lambda imgs: feat(self._params, self._head,
                                            imgs)
        self._round = _sar_round_fn(hcfg, self.policy, self.adaptive_mode,
                                    self.r_step, self.fused,
                                    self._slot_axis, self.tcfg,
                                    mesh=self._mesh)

    # -- admission ------------------------------------------------------
    def _admit(self) -> None:
        take = min(len(self.free), len(self.queue))
        if take == 0:
            return
        with self.profiler.span("admission"):
            reqs = [self.queue.popleft() for _ in range(take)]
            imgs = np.stack([np.asarray(r.payload) for r in reqs])
            if take < self.n_slots:                   # fixed-shape batch
                pad = np.repeat(imgs[-1:], self.n_slots - take, axis=0)
                imgs = np.concatenate([imgs, pad], axis=0)
            with self.tracer.span("featurize", pid=self.trace_pid,
                                  n_admitted=take), \
                    self.profiler.span("featurize"):
                rows = self._featurize(jnp.asarray(imgs))
            idx = np.full((self.n_slots,), self.n_slots, np.int32)  # drop
            now = time.perf_counter()
            bases = self._next_bases(take)
            for j, req in enumerate(reqs):
                s = self.free.pop()
                idx[j] = s
                self.slots[s].req = req
                self.slots[s].admit_s = now
                self.base[s] = bases[j]
            idxj = jnp.asarray(idx)
            self.ensure_pool(like=rows)
            self.pool = self._scatter(self.pool, rows, idxj)
            self.stats = self._stats_reset(self.stats, idxj)
            self.metrics.mark(now)

    def ensure_pool(self, like: dict | None = None) -> None:
        """Materialize the (pool, stats) device state without waiting
        for the first admission.  ``like`` is an activation-basis pytree
        with leading dim ``n_slots`` (another engine's pool works) —
        the fleet gang stacks every pool engine's state into one
        dispatch, so an idle pool must still hold real zero buffers."""
        if self.pool is not None:
            return
        if like is None:
            raise ValueError("ensure_pool needs a template basis pytree")
        self.pool = jax.tree.map(jnp.zeros_like, like)
        self.stats = adaptive.init_stats(self.n_slots,
                                         like["y_mu"].shape[-1])

    def active_mask(self) -> np.ndarray:
        """[n_slots] bool — which slots hold an in-flight request."""
        return np.array([s.req is not None for s in self.slots])

    def _retire_decided(self, active, verdict, fin, spent: int,
                        verdict_s: float = float("nan")) -> int:
        """Post-dispatch draining shared with the fleet: charge samples
        to every active slot, retire those whose verdict left ESCALATE.
        ``verdict_s`` is the perf_counter stamp of the host sync that
        pulled these verdicts.  Returns the number retired."""
        retired = 0
        for i in np.nonzero(active)[0]:
            self.slots[i].n_samples += spent
            if verdict[i] != ESCALATE:
                self.slots[i].n_decisions = 1
                # n_samples already accumulated; fin["n"] agrees
                self._retire(i, verdict[i], fin, extra_samples=0,
                             verdict_s=verdict_s)
                retired += 1
        return retired

    # -- main loop ------------------------------------------------------
    def start(self) -> None:
        """Reset the per-run selection-stream bases.  ``run`` calls
        this; open-loop drivers (serving/load.py) call it once, then
        interleave ``submit`` with ``step`` on their own clock."""
        self.base = np.zeros((self.n_slots,), np.uint32)

    def step(self) -> bool:
        """One scheduler tick: admit from the queue, dispatch the
        device-resident escalation round, retire decided slots.
        Returns False when nothing was active (idle tick)."""
        self._admit()
        if self.n_active == 0:
            return False
        active = self.active_mask()
        self._stamp_first_dispatch(active)
        t_disp = self.tracer.now()
        with self.profiler.span("dispatch"):
            if self.tcfg is None:
                self.stats, verdict, fin, rounds = self._round(
                    self.pool, self.stats, jnp.asarray(self.base),
                    jnp.asarray(active))
            else:
                (self.stats, verdict, fin, rounds,
                 self._telem) = self._round(
                    self.pool, self.stats, jnp.asarray(self.base),
                    jnp.asarray(active), self._telem)
        # ONE blocking host↔device round trip per dispatch — the
        # while_loop above already ran every all-escalate round.
        # The triage_loop span measures exactly that pull: the host
        # waiting on the device-resident escalation.
        with self.profiler.span("triage_loop"):
            verdict = np.asarray(verdict)
            fin = {k: np.asarray(v) for k, v in fin.items()}
            spent = self.r_step * int(rounds)
        self.host_syncs += 1
        t_verdict = time.perf_counter()
        if self.tracer.enabled:
            self.tracer.complete(
                "sar_rounds", t_disp, self.tracer.now() - t_disp,
                pid=self.trace_pid,
                rounds=int(rounds), n_active=int(active.sum()),
                samples_per_slot=spent)
        with self.profiler.span("retirement"):
            self._retire_decided(active, verdict, fin, spent,
                                 verdict_s=t_verdict)
        return True

    def drain(self) -> dict:
        """Attach telemetry/perf/SLO snapshots and build the summary."""
        if self.tcfg is not None:
            self.metrics.attach_telemetry(self.telemetry_snapshot())
        self._attach_perf()
        return self.metrics.summary()

    def run(self, max_ticks: int = 100_000) -> dict:
        self.start()
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return self.drain()

    # -- compiled-cost capture (profiling path only) --------------------
    def compiled_cost_records(self) -> list[dict]:
        """obs/prof.compiled_cost records for this engine's hot jitted
        functions at the LIVE deployed shapes: the device-resident
        round fn and the featurize fn.  AOT-compiles fresh executables
        (AOT does not share the jit call cache) — call after ``run()``
        from a profiling/bench path, never inside the serving loop."""
        if self.pool is None:
            raise RuntimeError(
                "compiled_cost_records needs live pool shapes: run the "
                "engine (or admit once) first")
        sds = lambda t: jax.tree.map(                        # noqa: E731
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        args = [sds(self.pool), sds(self.stats),
                jax.ShapeDtypeStruct((self.n_slots,), jnp.uint32),
                jax.ShapeDtypeStruct((self.n_slots,), jnp.bool_)]
        if self.tcfg is not None:
            args.append(sds(self._telem))
        recs = [prof.compiled_cost("sar_round", self._round, *args)]
        img = jax.ShapeDtypeStruct(
            (self.n_slots, self.cfg.image_size, self.cfg.image_size, 1),
            jnp.float32)
        recs.append(prof.compiled_cost(
            "sar_featurize", self._featurize_jit, sds(self._params),
            sds(self._head), img))
        return recs


# ----------------------------------------------------------------------
# LM token-stream engine
# ----------------------------------------------------------------------
def _rotate_k(k, delta, theta):
    """Re-base cached RoPE'd keys by ``delta`` positions: rotations about
    a fixed plane compose additively, so R_Δ(R_i·k) = R_{i+Δ}·k."""
    from repro.models.blocks import apply_rope
    lead = k.shape[:-3]                       # [..., Sc, H, dh]
    flat = k.reshape((-1,) + k.shape[-3:])
    pos = jnp.full((flat.shape[0], flat.shape[1]), delta, jnp.int32)
    return apply_rope(flat, pos, theta).reshape(k.shape)


class LMServingEngine(_EngineBase):
    """Continuous-batching LM decode with adaptive per-token fidelity.

    Each tick decides ONE token for every active slot in a single
    device dispatch (``_lm_token_fn``): the geometric escalation
    schedule runs on device with per-round early exit, and the host
    sees only the final (verdict, fin, spent) — one sync per token
    instead of one per escalation round.
    """

    def __init__(self, params, cfg, *, n_slots: int = 4,
                 prompt_len: int = 16, cache_len: int = 64,
                 policy: TriagePolicy = TriagePolicy(),
                 adaptive_mode: bool = True,
                 metrics: ServingMetrics = None, extras: dict | None = None,
                 fused: bool = True,
                 telemetry: bool | TelemetryConfig = True,
                 tracer=None,
                 profiler: bool | StageProfiler = True,
                 slo=True):
        super().__init__(n_slots, policy, metrics, telemetry, tracer,
                         profiler, slo)
        from repro.models.registry import get_api
        from repro.models.transformer import _head_serving
        assert cfg.bayesian_head, "adaptive serving needs the Bayesian head"
        if cfg.swa_window is not None and cache_len > cfg.swa_window:
            # Rolling (circular) SWA caches break two admission
            # invariants: the roll+rerotate alignment assumes a linear
            # layout, and decode_attention's per-slot 'start' mask is
            # only defined for linear caches.  Refuse loudly rather
            # than serve silently-wrong attention.
            raise ValueError(
                f"cache_len={cache_len} exceeds swa_window="
                f"{cfg.swa_window}: the rolling-cache decode path does "
                "not support continuous-batching admission; use "
                f"cache_len <= {cfg.swa_window} or a non-SWA arch")
        self.cfg = cfg
        self.adaptive_mode = adaptive_mode
        self.fused = fused
        self.prompt_len = prompt_len
        self.cache_len = cache_len
        # Mid-stream (delta > 0) admission re-bases cached keys by a
        # uniform RoPE rotation — only exact for rotary trunks without
        # learned absolute positions.  Other trunks still get continuous
        # batching, but admission waits for the pool to drain and
        # rebase (delta == 0), where left-padded prefill is exact.
        self.midstream_ok = bool(cfg.use_rope) and not cfg.learned_pos
        api = get_api(cfg)
        self.hcfg = BayesHeadConfig(
            num_samples=policy.r_max, mode="rank16", grng=cfg.grng,
            compute_dtype=cfg.dtype, hoist_basis=False)
        head = _head_serving(params, cfg)
        extras = extras or {}
        self.schedule = (adaptive.escalation_schedule(policy)
                         if adaptive_mode else (policy.r_max,))

        self._prefill = jax.jit(
            lambda tokens, lengths: api.prefill(
                params, tokens, cfg, cache_len=cache_len,
                prompt_lengths=lengths, **extras))

        def align_scatter(pool, new, idx, delta):
            """Roll+rerotate admission rows into the pool timeline."""
            out = {}
            for key, leaf in pool.items():
                nw = new[key]
                if key == "pos":
                    out[key] = leaf
                elif key == "start":
                    out[key] = leaf.at[idx].set(nw + delta, mode="drop")
                elif key in ("k", "v"):
                    rolled = jnp.roll(nw, delta, axis=2)
                    if key == "k" and cfg.use_rope:
                        rolled = _rotate_k(rolled, delta, cfg.rope_theta)
                    out[key] = leaf.at[:, idx].set(rolled, mode="drop")
                else:                       # xk/xv/ssm/conv: slot-local
                    out[key] = leaf.at[:, idx].set(nw, mode="drop")
            return out

        self._align_scatter = jax.jit(align_scatter, donate_argnums=(0,))

        self._decode_hidden = jax.jit(
            lambda cache, token: api.decode_hidden(params, cache, token,
                                                   cfg),
            donate_argnums=(0,))
        self._basis = jax.jit(
            lambda h: activation_basis(head, h.astype(jnp.float32),
                                       self.hcfg))
        self._scatter_hidden = jax.jit(
            lambda pool, rows, idx: pool.at[idx].set(
                rows.astype(pool.dtype), mode="drop"),
            donate_argnums=(0,))

        self._token_decision = _lm_token_fn(
            self.hcfg, policy, adaptive_mode, self.schedule, fused,
            n_slots, cfg.vocab_padded, self.tcfg)
        self.cache = None
        self.token = None
        self.hidden = None
        self.base = None
        self.vocab_padded = cfg.vocab_padded

    # -- admission ------------------------------------------------------
    def _pad_prompt(self, tokens: np.ndarray) -> tuple[np.ndarray, int]:
        tokens = np.asarray(tokens, np.int32)[-self.prompt_len:]
        length = tokens.shape[0]
        if length < self.prompt_len:
            tokens = np.concatenate(
                [np.zeros((self.prompt_len - length,), np.int32), tokens])
        return tokens, length

    def _admit(self) -> None:
        if not self.queue:
            return
        pos = int(self.cache["pos"]) if self.cache is not None else \
            self.prompt_len
        # FIFO admission with a PER-REQUEST capacity bound: a request
        # admitted at clock ``pos`` writes cache entries up to
        # pos + max_new_tokens - 1.  Stop at the first request that
        # would overflow (it waits for the pool to drain and rebase).
        if self.prompt_len + self.queue[0].max_new_tokens > self.cache_len:
            bad = self.queue[0]
            raise ValueError(
                f"request {bad.rid}: max_new_tokens={bad.max_new_tokens} "
                f"cannot fit even a fresh pool (prompt_len="
                f"{self.prompt_len}, cache_len={self.cache_len})")
        if self.cache is not None and pos > self.prompt_len \
                and not self.midstream_ok:
            return          # non-re-basable trunk: wait for pool rebase
        reqs = []
        while (self.queue and len(reqs) < len(self.free)
               and pos + self.queue[0].max_new_tokens <= self.cache_len):
            reqs.append(self.queue.popleft())
        take = len(reqs)
        if take == 0:
            return
        with self.profiler.span("admission"):
            toks = np.zeros((self.n_slots, self.prompt_len), np.int32)
            lens = np.full((self.n_slots,), self.prompt_len, np.int32)
            for j, r in enumerate(reqs):
                toks[j], lens[j] = self._pad_prompt(r.payload)
            # prefill is the LM engine's featurize: payload -> per-slot
            # device state.
            with self.tracer.span("prefill", n_admitted=take), \
                    self.profiler.span("featurize"):
                new_cache, last_h = self._prefill(jnp.asarray(toks),
                                                  jnp.asarray(lens))
            now = time.perf_counter()
            idx = np.full((self.n_slots,), self.n_slots, np.int32)
            for j, req in enumerate(reqs):
                s = self.free.pop()
                idx[j] = s
                self.slots[s].req = req
                self.slots[s].admit_s = now
            idxj = jnp.asarray(idx)
            if self.cache is None:
                self.cache = new_cache
                self.hidden = jnp.zeros((self.n_slots, last_h.shape[-1]),
                                        last_h.dtype)
            else:
                delta = pos - self.prompt_len
                self.cache = self._align_scatter(self.cache, new_cache,
                                                 idxj, jnp.int32(delta))
            # the prefill hidden decides each admitted slot's FIRST token
            # — no re-feed of the last prompt token into decode.
            self.hidden = self._scatter_hidden(self.hidden, last_h, idxj)
            self.metrics.mark(now)

    # -- main loop ------------------------------------------------------
    def run(self, max_ticks: int = 10_000) -> dict:
        """Tick = decide (head-sample self.hidden) → commit/retire →
        decode committed tokens into the next hidden.  The first
        decision of every request comes from its PREFILL hidden, so
        each prompt token enters the KV cache exactly once."""
        self.base = np.zeros((self.n_slots,), np.uint32)
        tick = 0
        while tick < max_ticks:
            tick += 1
            self._admit()
            if self.n_active == 0:
                if not self.queue:
                    break
                self.cache = None                      # rebase the pool
                continue
            active = np.array([s.req is not None for s in self.slots])
            self._stamp_first_dispatch(active)
            # one token decision for every active slot, ONE dispatch:
            # the whole escalation schedule runs device-resident.
            t_disp = self.tracer.now()
            with self.profiler.span("dispatch"):
                abasis = self._basis(self.hidden)
                self.base = self._next_bases(self.n_slots)
                if self.tcfg is None:
                    verdict, fin, spent = self._token_decision(
                        abasis, jnp.asarray(self.base),
                        jnp.asarray(active))
                else:
                    verdict, fin, spent, self._telem = \
                        self._token_decision(
                            abasis, jnp.asarray(self.base),
                            jnp.asarray(active), self._telem)
            # blocking pull of the token's escalation outcome — the
            # whole on-device schedule shows up as this host wait.
            with self.profiler.span("triage_loop"):
                verdict = np.asarray(verdict)
                spent = np.asarray(spent)
                fin = {k: np.asarray(v) for k, v in fin.items()}
            self.host_syncs += 1
            t_verdict = time.perf_counter()
            if self.tracer.enabled:
                self.tracer.complete(
                    "lm_token", t_disp, self.tracer.now() - t_disp,
                    n_active=int(active.sum()),
                    samples=int(spent[active].sum()))
            self.token = jnp.asarray(
                fin["prediction"].astype(np.int32)[:, None])
            with self.profiler.span("retirement"):
                for i in np.nonzero(active)[0]:
                    slot = self.slots[i]
                    slot.n_samples += int(spent[i])
                    slot.n_decisions += 1
                    done = slot.n_decisions >= slot.req.max_new_tokens
                    if verdict[i] == FLAG or (verdict[i] == ACCEPT
                                              and done):
                        self._retire(i, verdict[i], fin, extra_samples=0,
                                     verdict_s=t_verdict)
            if self.n_active == 0 and not self.queue:
                break                       # nothing left to decode for
            # advance the pool clock: committed tokens -> next hidden
            with self.profiler.span("dispatch"):
                self.hidden, self.cache = self._decode_hidden(self.cache,
                                                              self.token)
        if self.tcfg is not None:
            self.metrics.attach_telemetry(self.telemetry_snapshot())
        self._attach_perf()
        return self.metrics.summary()

    # -- compiled-cost capture (profiling path only) --------------------
    def compiled_cost_records(self) -> list[dict]:
        """obs/prof.compiled_cost record for the per-token decision fn
        at the live hidden/basis shapes (AOT; profiling path only)."""
        if self.hidden is None:
            raise RuntimeError(
                "compiled_cost_records needs live shapes: run the "
                "engine (or admit once) first")
        sds = lambda t: jax.tree.map(                        # noqa: E731
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        abasis = jax.eval_shape(self._basis, sds(self.hidden))
        args = [abasis,
                jax.ShapeDtypeStruct((self.n_slots,), jnp.uint32),
                jax.ShapeDtypeStruct((self.n_slots,), jnp.bool_)]
        if self.tcfg is not None:
            args.append(sds(self._telem))
        return [prof.compiled_cost("lm_token", self._token_decision,
                                   *args)]
