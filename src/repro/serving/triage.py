"""Confidence triage: the paper's Fig. 1 three-way decision as a policy.

The deployment story of the paper is an aerial platform that must
decide, per detection, whether to (a) trust the result and move on,
(b) spend more compute (here: more CLT-GRNG samples; on the drone: a
costly descend-and-verify maneuver), or (c) hand the case to a human /
high-fidelity verifier.  We parameterize that as a three-way verdict
over the running predictive statistics (serving/adaptive.py):

  ACCEPT    confidence ≥ τ_conf  and  mutual information ≤ τ_mi,
            certain at the current sample count,
  FLAG      confidently *outside* the accept region — epistemic
            uncertainty τ_mi exceeded or confidence unreachable,
  ESCALATE  the accept/flag boundary is within ±z·SE of the estimate:
            draw more samples (sequential-test stopping rule).

Escalation is only available while n < r_max; at the sample budget the
verdict collapses to accept/flag on the point estimates — exactly what
a fixed-R=20 system would have decided, so adaptive fidelity changes
*cost*, never the asymptotic decision rule.

All functions are pure jnp over [B]-shaped stats — jit/vmap friendly,
usable inside the engine's round function.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

ACCEPT, ESCALATE, FLAG = 0, 1, 2
VERDICT_NAMES = {ACCEPT: "accept", ESCALATE: "escalate", FLAG: "flag"}


@dataclasses.dataclass(frozen=True)
class TriagePolicy:
    """Thresholds for the three-way decision (paper Fig. 1).

    conf_threshold / mi_threshold define the accept region; ``z`` is the
    width (in standard errors of the MC estimate) of the ambiguity band
    that triggers escalation; r_min/r_max/r_growth define the
    escalation schedule (adaptive.escalation_schedule).
    """
    conf_threshold: float = 0.8
    mi_threshold: float = 0.5
    z: float = 1.0
    r_min: int = 4
    r_max: int = 20
    r_growth: int = 2

    def __post_init__(self):
        if self.r_min < 1:
            raise ValueError(f"r_min must be >= 1, got {self.r_min}")
        if self.r_max < self.r_min:
            raise ValueError(
                f"r_max ({self.r_max}) must be >= r_min ({self.r_min})")
        if self.r_growth < 1:
            raise ValueError(f"r_growth must be >= 1, got {self.r_growth}")


def decide(stats: dict, policy: TriagePolicy, *, final) -> jnp.ndarray:
    """Three-way verdict [B] from running stats (adaptive.finalize).

    ``final`` (bool or [B] bool): sample budget exhausted — no more
    escalation available; decide on point estimates.
    """
    conf = stats["confidence"]
    mi = stats["mutual_information"]
    conf_se = policy.z * stats["confidence_se"]
    mi_se = policy.z * stats["mutual_information_se"]
    tau_c, tau_mi = policy.conf_threshold, policy.mi_threshold

    in_accept = (conf >= tau_c) & (mi <= tau_mi)
    accept_certain = (conf - conf_se >= tau_c) & (mi + mi_se <= tau_mi)
    flag_certain = (conf + conf_se < tau_c) | (mi - mi_se > tau_mi)

    final = jnp.broadcast_to(jnp.asarray(final), conf.shape)
    verdict = jnp.full(conf.shape, ESCALATE, jnp.int32)
    verdict = jnp.where(accept_certain, ACCEPT, verdict)
    verdict = jnp.where(flag_certain, FLAG, verdict)
    # Budget exhausted: collapse the ambiguous band onto point estimates.
    forced = jnp.where(in_accept, ACCEPT, FLAG)
    return jnp.where(final & (verdict == ESCALATE), forced, verdict)


def fixed_r_decide(stats: dict, policy: TriagePolicy) -> jnp.ndarray:
    """The non-adaptive baseline: accept/flag on point estimates only —
    what the paper's fixed R = 20 dataflow computes.  Used by the
    serving bench to match flagged fractions across modes."""
    in_accept = ((stats["confidence"] >= policy.conf_threshold)
                 & (stats["mutual_information"] <= policy.mi_threshold))
    return jnp.where(in_accept, ACCEPT, FLAG).astype(jnp.int32)
