"""Per-request serving metrics + analytic energy accounting.

Each retired request carries: queueing and service latency, samples
actually drawn for its decision(s), and the triage verdict.  The
summary reports throughput (decisions/s), latency percentiles, the
adaptive-fidelity headline (mean samples per decision), and — wired to
the paper's component energy model (core/energy.py) — the analytic
energy per decision the measured sample counts imply on the FeFET
engine, in aJ for the GRNG share and pJ end-to-end.

The energy model is the hardware's, not the TPU's: a Bayesian layer
costs one µ-subarray MVM plus ``n_samples`` σε-subarray re-reads per
tile (§IV), each GRNG sample 640 aJ.  Adaptive fidelity therefore
translates *directly* into σε-MVM and GRNG energy: the bench reports
fixed-R vs adaptive-R energy from the same accounting.

Tile accounting is **tilemap-true** when a compiled ``TileProgram``
(hw/tilemap.py) is supplied: per-request energy charges the compiler's
PLACED block counts — padding waste, column splits, and pass
multiplexing included — instead of the logical ``tiles_for_layer``
ceiling math, and the summary carries the deployed area, utilization,
and effective TOPS/W/mm².  The reconciliation invariant (tested): the
sum of per-request energies equals ``energy.grid_inference_energy`` of
the same placed counts evaluated at the batch's total sample spend.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import energy
from repro.serving.triage import VERDICT_NAMES


@dataclasses.dataclass
class RequestRecord:
    """One retired request.

    Clocks: the engines stamp ``admit_s``/``done_s`` from
    ``time.perf_counter`` and record the monotonic arrival twin in
    ``arrival_pc`` — latency intervals are then immune to wall-clock
    steps.  ``arrival_s`` stays wall-clock (it is semantically "when
    did this arrive").  Records built with only the ``*_s`` trio (older
    tests, hand-made records) keep working: the properties fall back to
    ``arrival_s`` when ``arrival_pc`` is NaN, in which case all three
    fields must share one clock as before.
    """
    rid: int
    verdict: int                 # triage.ACCEPT or triage.FLAG
    n_samples: int               # GRNG samples spent on this decision
    n_decisions: int             # 1 for SAR; generated tokens for LM
    arrival_s: float
    admit_s: float
    done_s: float
    prediction: int = -1
    confidence: float = float("nan")
    mutual_information: float = float("nan")
    arrival_pc: float = float("nan")
    # Remaining lifecycle stamps (perf_counter clock, NaN when the
    # engine predates them or the request never dispatched): first time
    # a dispatch covered this request's slot, and the instant the host
    # pulled its verdict (done_s is the later retirement bookkeeping).
    first_dispatch_s: float = float("nan")
    verdict_s: float = float("nan")

    @property
    def _arrival(self) -> float:
        return (self.arrival_pc if math.isfinite(self.arrival_pc)
                else self.arrival_s)

    @property
    def queue_latency_s(self) -> float:
        return self.admit_s - self._arrival

    @property
    def service_latency_s(self) -> float:
        return self.done_s - self.admit_s

    @property
    def latency_s(self) -> float:
        return self.done_s - self._arrival

    @property
    def dispatch_wait_s(self) -> float:
        """Admit → first dispatch that covered this slot."""
        return self.first_dispatch_s - self.admit_s

    @property
    def verdict_latency_s(self) -> float:
        """Time-to-verdict: arrival → the host sync that pulled the
        verdict (NaN without the stamp — callers fall back to
        ``latency_s``, which additionally includes retirement)."""
        return self.verdict_s - self._arrival


@dataclasses.dataclass(frozen=True)
class DecisionCost:
    """Frozen per-config decision cost coefficients — THE energy/latency
    numbers of one triage decision on a given layer stack + placement.

    Every consumer that charges a decision — the serving summaries
    below AND the mission simulator's per-drone battery ledger
    (repro/mission/rollout.py) — derives its numbers from one instance
    of this struct, so the two accountings reconcile by construction
    (tested in tests/test_mission.py) instead of by copy-pasted
    constants.  Frozen + scalar fields: hashable, so jitted episode
    builders can key their compile cache on it.

    Affine model (exactly ``decision_energy``/``decision_latency``):
        E(n) = e_fixed_J + n · e_per_sample_J
        T(n) = t_fixed_s + n · t_per_sample_s
    """
    e_fixed_J: float          # one MVM sweep over every placed block
    e_per_sample_J: float     # σε re-read of the Bayesian blocks
    grng_cells_per_sample: float
    t_fixed_s: float          # serial layer walk at n = 0
    t_per_sample_s: float     # per-sample σε latency share

    def decision_energy_J(self, n_samples):
        return self.e_fixed_J + n_samples * self.e_per_sample_J

    def decision_latency_s(self, n_samples):
        return self.t_fixed_s + n_samples * self.t_per_sample_s

    def grng_energy_aJ(self, n_samples):
        return (self.grng_cells_per_sample * n_samples
                * energy.GRNG_ENERGY_PER_SAMPLE * 1e18)


def decision_cost(layers, tile_program=None,
                  terms: dict | None = None) -> DecisionCost:
    """Build the frozen per-decision cost struct for a layer stack.

    Energy coefficients come from ``energy_terms`` (tilemap-true placed
    blocks when ``tile_program`` is given); latency coefficients from
    the §V-A serial layer walk (``decision_latency``'s math, factored
    into its affine form)."""
    t = terms if terms is not None else energy_terms(layers, tile_program)
    n_bayes = sum(1 for l in layers if l.bayesian)
    return DecisionCost(
        e_fixed_J=t["e_fixed"], e_per_sample_J=t["e_per_sample"],
        grng_cells_per_sample=t["cells_per_sample"],
        t_fixed_s=len(layers) * energy.MVM_LATENCY,
        t_per_sample_s=n_bayes * energy.MVM_LATENCY)


def decision_latency(n_samples: float, layers) -> float:
    """Analytic per-decision latency on the FeFET engine (§V-A): one
    MVM per deterministic layer, (1 + n_samples) serial σε re-reads for
    a Bayesian layer (tiles within a layer are parallel).  This is the
    paper's own FPS math (72.2 FPS at R=20) evaluated at the measured
    mean sample count — the deployment-side meaning of adaptive R."""
    t = 0.0
    for l in layers:
        t += ((1 + n_samples) if l.bayesian else 1) * energy.MVM_LATENCY
    return t


def placed_decision_latency(n_samples: float, layers, tile_program,
                            replicated: bool = False) -> float:
    """Tilemap-aware per-decision latency: the paper's per-layer serial
    model evaluated on the COMPILED placement (ROADMAP reconciliation).

    The two models disagreed in both directions: the §V-A math assumes
    every layer's tiles fire concurrently in one configuration, while
    the tile compiler's pass count ignores inter-layer data dependence
    (a pass mixes blocks of several layers that cannot actually run
    together).  The reconciled model keeps the dependence-respecting
    serial walk over layers but charges each layer the number of
    DISTINCT PASSES its primary blocks were placed into — a
    time-multiplexed layer must reconfigure the grid that many times
    per MVM, so

        t = Σ_layers  span(layer) · (1 + R if bayesian else 1) · t_MVM
            ≥  decision_latency(...)                    (span ≥ 1)

    which is the property tests/test_tilemap_properties.py pins.

    ``replicated=True`` additionally credits Bayesian replication
    (compile_network packs replica blocks into free tiles): R samples
    stream through ``rep`` concurrent block sets, so the σε term drops
    to ceil(R / rep).  That OPTIMISTIC bound can undercut the logical
    model — report it, never assert it.
    """
    shapes = dict(tile_program.layers)
    if [tuple(dataclasses.astuple(s)) for s in shapes.values()] != \
            [tuple(dataclasses.astuple(s)) for s in layers]:
        raise ValueError(
            "tile_program was compiled for a different layer stack")
    t = 0.0
    for name, shape in tile_program.layers:
        span = len({p.pass_idx
                    for p in tile_program.layer_placements(name)})
        if shape.bayesian:
            r_eff = n_samples
            if replicated:
                rep = tile_program.replication_factor(name)
                if rep > 1:
                    r_eff = math.ceil(n_samples / rep)
            t += span * (1 + r_eff) * energy.MVM_LATENCY
        else:
            t += span * energy.MVM_LATENCY
    return t


def energy_terms(layers, tile_program=None) -> dict:
    """Per-decision/per-sample energy coefficients for a layer stack.

    Returns {e_fixed: J per decision (one MVM per det/Bayes-µ block),
    e_per_sample: J per GRNG sample (σε re-read), cells_per_sample:
    GRNG draws per sample}.  With ``tile_program`` (hw/tilemap.py) the
    block counts are the compiler's PLACED blocks; otherwise the
    logical ``tiles_for_layer`` fallback (pre-compiler behaviour, and
    exactly equal whenever the grid tile matches ``energy.TILE_DIM``
    with no packing).  Every placed block is priced at the paper's
    physical 64×64 tile — MVM energy, GRNG cells, and area all use the
    same TILE_* constants, so the accounting stays internally
    consistent (and reconciles with ``energy.grid_inference_energy``)
    even on grids whose logical tile edge is smaller.
    """
    if tile_program is not None:
        shapes = [s for _, s in tile_program.layers]
        if [tuple(dataclasses.astuple(s)) for s in shapes] != \
                [tuple(dataclasses.astuple(s)) for s in layers]:
            raise ValueError(
                "tile_program was compiled for a different layer stack")
        counts = list(tile_program.layer_block_counts().values())
    else:
        counts = [energy.tiles_for_layer(l) for l in layers]
    e_fixed = e_per_sample = cells = 0.0
    for l, nt in zip(layers, counts):
        e_fixed += nt * energy.TILE_MVM_ENERGY
        if l.bayesian:
            e_per_sample += nt * energy.SIGMA_MVM_ENERGY
            cells += nt * energy.TILE_DIM**2
    return {"e_fixed": e_fixed, "e_per_sample": e_per_sample,
            "cells_per_sample": cells}


def decision_energy(n_samples: float, layers, tile_program=None,
                    terms: dict | None = None) -> dict:
    """Analytic per-decision energy for ``n_samples`` drawn samples.

    layers: list of core.energy.LayerShape — the deterministic trunk
    plus the Bayesian head(s); ``tile_program``: the compiled placement
    for tilemap-true block counts; ``terms``: precomputed
    ``energy_terms`` to skip the placement walk.  Returns joules plus
    the GRNG share in aJ (the paper's headline unit).
    """
    # energy.inference_energy expects an integer-ish R; evaluate the
    # Bayesian terms at the *measured mean* sample count instead.
    # Routed through the frozen DecisionCost struct so any other
    # consumer of the same struct (the mission battery ledger) charges
    # provably identical numbers.
    cost = decision_cost(layers, tile_program, terms=terms)
    return {
        "energy_J": cost.decision_energy_J(n_samples),
        "energy_sigma_J": n_samples * cost.e_per_sample_J,
        "grng_energy_aJ": cost.grng_energy_aJ(n_samples),
        "grng_samples": cost.grng_cells_per_sample * n_samples,
    }


def request_energy(rec: RequestRecord, layers, tile_program=None,
                   terms: dict | None = None) -> float:
    """Total energy (J) one retired request spent on the engine: one
    fixed MVM sweep per decision plus its measured GRNG sample spend.
    ``terms``: precomputed ``energy_terms`` (batch summaries pass it so
    the placement walk happens once, not per record)."""
    t = terms if terms is not None else energy_terms(layers, tile_program)
    return (max(rec.n_decisions, 1) * t["e_fixed"]
            + rec.n_samples * t["e_per_sample"])


class ServingMetrics:
    """Aggregates RequestRecords into the serving report."""

    def __init__(self, layers=None, extra: dict | None = None,
                 tile_program=None):
        self.records: list[RequestRecord] = []
        self.layers = layers          # energy.LayerShape list or None
        # hw/tilemap.TileProgram compiled for ``layers``: switches the
        # energy accounting from logical tiles to placed blocks and adds
        # deployed area/utilization to the summary.
        self.tile_program = tile_program
        # Run-level metadata merged verbatim into the summary — the
        # chip-instance serving mode records the chip id/seeds,
        # calibration state, and the tile compiler's area/utilization
        # here so a fleet sweep can attribute results to hardware.
        self.extra = dict(extra or {})
        self.wall_start: float | None = None
        self.wall_end: float | None = None
        # obs/telemetry snapshot attached by the engine at drain time;
        # surfaced under summary()["telemetry"].
        self.telemetry: dict | None = None
        # obs/prof stage-profile snapshot + compile counters, attached
        # at drain time; surfaced under summary()["stage_profile"] /
        # ["compile_counters"] (obs.registry.serving_registry exports
        # both automatically).
        self.stage_profile: dict | None = None
        self.compile_counters: dict | None = None
        # obs/slo tracker snapshot, attached at drain time; surfaced
        # under summary()["slo"].
        self.slo: dict | None = None

    def record(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def attach_telemetry(self, snapshot: dict | None) -> None:
        self.telemetry = snapshot

    def attach_profile(self, stage_profile: dict | None,
                       compile_counters: dict | None = None) -> None:
        self.stage_profile = stage_profile
        self.compile_counters = compile_counters

    def attach_slo(self, snapshot: dict | None) -> None:
        self.slo = snapshot or None

    def mark(self, t: float) -> None:
        if self.wall_start is None:
            self.wall_start = t
        self.wall_end = t

    def summary(self) -> dict:
        if not self.records:
            # Same schema as the populated case so consumers (CLI,
            # benches) never KeyError on an empty run.
            nan = float("nan")
            out = {"requests": 0, "decisions": 0, "wall_s": nan,
                   "decisions_per_s": nan, "mean_samples_per_decision": nan,
                   "p50_latency_s": nan, "p95_latency_s": nan,
                   "p99_latency_s": nan, "mean_service_s": nan,
                   "mean_queue_wait_s": nan, "queue_wait_total_s": nan,
                   "service_total_s": nan, "queue_wait_share": nan,
                   "accept_fraction": nan, "flag_fraction": nan}
            if self.layers is not None:
                out.update(energy_per_decision_pJ=nan,
                           grng_energy_per_decision_aJ=nan,
                           energy_total_J=nan,
                           energy_saving_vs_R20=nan, model_latency_s=nan,
                           model_decisions_per_s=nan)
                if self.tile_program is not None:
                    out.update(placed_latency_s=nan,
                               placed_decisions_per_s=nan,
                               placed_latency_replicated_s=nan)
            out.update(self._tile_summary())
            if self.telemetry is not None:
                out["telemetry"] = self.telemetry
            out.update(self._perf_summary())
            out.update(self.extra)
            return out
        n_dec = sum(r.n_decisions for r in self.records)
        samples = np.array([r.n_samples / max(r.n_decisions, 1)
                            for r in self.records], np.float64)
        lat = np.array([r.latency_s for r in self.records], np.float64)
        service = np.array([r.service_latency_s for r in self.records])
        queue = np.array([r.queue_latency_s for r in self.records],
                         np.float64)
        verdicts = np.array([r.verdict for r in self.records])
        wall = ((self.wall_end - self.wall_start)
                if self.wall_start is not None else float("nan"))
        # Per record, latency ≡ queue_wait + service exactly (shared
        # arithmetic on the same stamps) — so the totals below
        # reconcile against the wall span by construction; the
        # queue-wait share says where a run's time actually went.
        q_tot, s_tot = float(queue.sum()), float(service.sum())
        out = {
            "requests": len(self.records),
            "decisions": n_dec,
            "wall_s": wall,
            "decisions_per_s": n_dec / wall if wall and wall > 0 else
            float("nan"),
            "mean_samples_per_decision": float(samples.mean()),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "mean_service_s": float(service.mean()),
            "mean_queue_wait_s": float(queue.mean()),
            "queue_wait_total_s": q_tot,
            "service_total_s": s_tot,
            "queue_wait_share": q_tot / (q_tot + s_tot)
                                if (q_tot + s_tot) > 0 else 0.0,
        }
        for code, name in VERDICT_NAMES.items():
            if name != "escalate":
                out[f"{name}_fraction"] = float((verdicts == code).mean())
        if self.layers is not None:
            n_bar = float(samples.mean())
            terms = energy_terms(self.layers, self.tile_program)
            e = decision_energy(n_bar, self.layers, terms=terms)
            e20 = decision_energy(energy.DEPLOY_R, self.layers,
                                  terms=terms)
            out["energy_per_decision_pJ"] = e["energy_J"] * 1e12
            out["grng_energy_per_decision_aJ"] = e["grng_energy_aJ"]
            out["energy_total_J"] = sum(
                request_energy(r, self.layers, terms=terms)
                for r in self.records)
            out["energy_saving_vs_R20"] = (
                e20["energy_J"] / max(e["energy_J"], 1e-30))
            # Per-layer serial latency (§V-A FPS math), plus — when a
            # placement is known — the tilemap-reconciled model: pass
            # spans serialize (placed ≥ logical, property-tested) and
            # the replication-credited optimistic bound.
            lat = decision_latency(n_bar, self.layers)
            out["model_latency_s"] = lat
            out["model_decisions_per_s"] = 1.0 / lat
            if self.tile_program is not None:
                placed = placed_decision_latency(n_bar, self.layers,
                                                 self.tile_program)
                out["placed_latency_s"] = placed
                out["placed_decisions_per_s"] = 1.0 / placed
                out["placed_latency_replicated_s"] = \
                    placed_decision_latency(n_bar, self.layers,
                                            self.tile_program,
                                            replicated=True)
        out.update(self._tile_summary())
        if self.telemetry is not None:
            out["telemetry"] = self.telemetry
        out.update(self._perf_summary())
        out.update(self.extra)
        return out

    def _perf_summary(self) -> dict:
        out = {}
        if self.stage_profile is not None:
            out["stage_profile"] = self.stage_profile
        if self.compile_counters is not None:
            out["compile_counters"] = self.compile_counters
        if self.slo is not None:
            out["slo"] = self.slo
        return out

    def _tile_summary(self) -> dict:
        if self.tile_program is None:
            return {}
        p = self.tile_program
        return {
            "tile_area_mm2": p.physical_tiles_used * energy.TILE_AREA_MM2,
            "tile_utilization": p.utilization,
            "tile_passes": p.n_passes,
            "tops_w_mm2_effective": (energy.efficiency_density()
                                     * p.utilization),
        }
