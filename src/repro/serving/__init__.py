"""Adaptive-fidelity Bayesian serving subsystem.

Modules:
  engine    continuous-batching scheduler (slots, admission, retirement)
  adaptive  incremental predictive stats + sequential escalation
  triage    the paper Fig. 1 accept / escalate / flag policy
  metrics   per-request latency, samples/decision, energy accounting
  fleet     mesh-of-pools scale-out: one engine pool per device, a
            least-loaded admission router with backpressure, and one
            shard_map'd gang round dispatch per fleet tick
  load      closed-loop load harness: seeded Poisson/burst/ramp
            arrival schedules driving an engine or fleet OPEN-LOOP
            (arrivals don't wait for the system), for latency-vs-
            offered-load curves and SLO measurement

The escalation math leans on the rank-16 structure of the shared
selection lines (core/sampling.py): per-slot activation bases make
additional samples nearly free, and ``sample0`` stream offsets make
escalation an exact extension of the fixed-R draw.
"""

from repro.serving.adaptive import (escalation_schedule, finalize,
                                    init_stats, stream_indices,
                                    stream_selections, update_stats,
                                    update_stats_streamed)
from repro.serving.engine import (LMServingEngine, Request,
                                  SarServingEngine)
from repro.serving.fleet import SarServingFleet, make_pool_mesh
from repro.serving.load import ArrivalSpec, run_open_loop
from repro.serving.metrics import (DecisionCost, RequestRecord,
                                   ServingMetrics, decision_cost,
                                   decision_energy, decision_latency,
                                   energy_terms, request_energy)
from repro.serving.triage import (ACCEPT, ESCALATE, FLAG, TriagePolicy,
                                  decide, fixed_r_decide)

__all__ = [
    "ACCEPT", "ArrivalSpec", "DecisionCost", "ESCALATE", "FLAG",
    "LMServingEngine", "Request", "RequestRecord", "SarServingEngine",
    "SarServingFleet", "ServingMetrics", "TriagePolicy", "decide",
    "decision_cost", "decision_energy", "decision_latency",
    "energy_terms", "escalation_schedule", "finalize", "fixed_r_decide",
    "init_stats", "make_pool_mesh", "request_energy", "run_open_loop",
    "stream_indices", "stream_selections", "update_stats",
    "update_stats_streamed",
]
