"""Closed-loop load harness: seeded arrival processes driving the
serving stack open-loop.

The engines were always fed everything-at-once, so queue wait measured
burst absorption, never a *traffic* regime.  This module generates
deterministic, seeded arrival-time sequences — steady Poisson, bursty,
and ramped offered load — and drives a :class:`SarServingEngine` or
:class:`SarServingFleet` open-loop: each request is submitted when its
arrival time comes due on the real clock while the engine keeps
ticking, so admission-queue wait, backpressure, and time-to-verdict
under a given offered load are all real measured quantities.

Open-loop means arrivals do NOT wait for the system (the standard load
-testing discipline): under overload the queue grows and latency
explodes, which is exactly the knee `benchmarks/slo_bench.py` charts.

Spec strings (CLI ``--arrival``):

- ``poisson:RATE`` — iid exponential gaps at RATE req/s.
- ``burst:RATE[:FACTOR]`` — same mean RATE, but alternating groups of
  16 requests arrive with gaps compressed by FACTOR (default 10) and
  stretched in the lull groups so the overall mean rate is preserved.
- ``ramp:LO:HI`` — rate ramps linearly LO → HI req/s over the stream.

All draws come from ``np.random.default_rng(seed)``: the same spec +
seed + n is the same arrival sequence on any machine.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Sequence

import numpy as np

BURST_GROUP = 16  # requests per burst/lull alternation in burst mode


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """One arrival process: ``kind`` in {poisson, burst, ramp}."""

    kind: str
    rate: float                  # mean req/s (poisson/burst); LO (ramp)
    rate_hi: float = 0.0         # HI rate (ramp only)
    burst_factor: float = 10.0   # gap compression inside bursts

    @classmethod
    def parse(cls, spec: str) -> "ArrivalSpec":
        parts = [p for p in str(spec).split(":") if p]
        kind = parts[0].lower()
        if kind == "poisson":
            return cls(kind="poisson", rate=float(parts[1]))
        if kind == "burst":
            factor = float(parts[2]) if len(parts) > 2 else 10.0
            return cls(kind="burst", rate=float(parts[1]),
                       burst_factor=factor)
        if kind == "ramp":
            return cls(kind="ramp", rate=float(parts[1]),
                       rate_hi=float(parts[2]))
        raise ValueError(
            f"unknown arrival spec {spec!r} — want poisson:RATE, "
            f"burst:RATE[:FACTOR], or ramp:LO:HI")

    @property
    def mean_rate(self) -> float:
        """Realized overall rate (requests / total span).  For a ramp
        the stream spends 1/rate_i per request, so the effective rate
        is the LOG-mean (hi-lo)/ln(hi/lo), not the arithmetic mean."""
        if self.kind == "ramp":
            lo, hi = self.rate, self.rate_hi
            if lo <= 0 or hi <= 0 or lo == hi:
                return lo
            return (hi - lo) / math.log(hi / lo)
        return self.rate

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self) | {"mean_rate": self.mean_rate}

    def offsets(self, n: int, seed: int = 0) -> np.ndarray:
        """[n] arrival offsets in seconds from the stream start
        (ascending, first arrival at its own first gap)."""
        if n <= 0:
            return np.zeros((0,), np.float64)
        rng = np.random.default_rng(seed)
        if self.kind == "poisson":
            gaps = rng.exponential(1.0 / self.rate, size=n)
        elif self.kind == "burst":
            gaps = rng.exponential(1.0 / self.rate, size=n)
            group = (np.arange(n) // BURST_GROUP) % 2
            f = self.burst_factor
            # burst groups compress gaps by f; lull groups stretch by
            # (2 - 1/f) so the mean gap — and the offered load — is
            # unchanged: (1/f + (2 - 1/f)) / 2 == 1.
            gaps = np.where(group == 0, gaps / f, gaps * (2.0 - 1.0 / f))
        elif self.kind == "ramp":
            t = np.arange(n) / max(n - 1, 1)
            rate = self.rate + (self.rate_hi - self.rate) * t
            gaps = rng.exponential(1.0, size=n) / rate
        else:  # pragma: no cover - parse() rejects unknown kinds
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        return np.cumsum(gaps)


def run_open_loop(target, requests: Sequence, offsets,
                  *, speed: float = 1.0,
                  max_wall_s: float = 600.0) -> dict:
    """Drive an engine or fleet open-loop and return its summary.

    ``target`` is a :class:`SarServingEngine` or
    :class:`SarServingFleet` (anything with ``start``/``submit``/
    ``pending``/``n_active``/``drain`` and a per-tick ``step``/``tick``
    method).  Request ``i`` is submitted when ``offsets[i] / speed``
    seconds of real time have elapsed; between arrivals the target
    keeps ticking so in-flight work drains.  Arrival stamps are taken
    at actual submission time — queue wait is measured, not simulated.

    ``speed`` > 1 compresses the arrival schedule (same sequence,
    proportionally higher offered load); ``max_wall_s`` bounds a run
    whose offered load the system cannot drain.
    """
    offsets = np.asarray(offsets, np.float64) / float(speed)
    n = len(requests)
    if n != len(offsets):
        raise ValueError(f"{n} requests vs {len(offsets)} offsets")
    step = getattr(target, "step", None) or target.tick
    target.start()
    i = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < n and offsets[i] <= now:
            req = requests[i]
            req.arrival_s = time.time()
            req.arrival_pc = time.perf_counter()
            target.submit(req)
            i += 1
        worked = step()
        if i >= n and not worked and target.pending == 0 \
                and target.n_active == 0:
            break
        if now > max_wall_s:
            break
        if not worked and i < n:
            # idle until the next arrival comes due
            wait = offsets[i] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 0.05))
    wall = time.perf_counter() - t0
    if hasattr(target, "wall_s"):
        target.wall_s = wall
    out = target.drain()
    out["offered"] = {
        "requests": n, "submitted": i,
        "offered_rps": n / offsets[-1] if n and offsets[-1] > 0
                       else float("nan"),
        "harness_wall_s": wall,
    }
    return out
