"""SSM language models: mamba2-130m (pure SSM) and zamba2-2.7b (hybrid).

zamba2 style: a stack of mamba2 blocks with ONE shared transformer block
(attention + MLP, parameters reused at every application site) applied
after every ``hybrid_attn_every`` mamba layers.  Each application site
keeps its own KV cache; the shared block consumes concat(h, h0) through
an input projection (the zamba "global skip" to the embeddings).

Both models end in the paper's Bayesian head — the CLT-GRNG technique is
head-level and attaches to attention-free trunks unchanged
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bayes_layer
from repro.models import blocks
from repro.models.mamba2 import (init_mamba_stack, mamba_block_decode,
                                 mamba_block_full, mamba_dims)
from repro.models.transformer import (ModelConfig, _block_decode, _block_full,
                                      _maybe_remat, _wsc, apply_bayes_head,
                                      head_logits_train)


def init_ssm_lm(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 6)
    params: dict = {
        "embed": blocks.embed_init(keys[0], cfg.vocab_padded, cfg.d_model),
        "mamba": init_mamba_stack(keys[1], cfg, cfg.n_layers),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.hybrid_attn_every:
        from repro.models.transformer import _init_block_stack
        shared = _init_block_stack(keys[2], cfg, 1)
        shared = jax.tree.map(lambda x: x[0], shared)      # drop stack dim
        params["shared_attn"] = shared
        params["shared_w_in"] = blocks.dense_init(
            keys[3], 2 * cfg.d_model, cfg.d_model)
        params["shared_w_out"] = blocks.dense_init(
            keys[4], cfg.d_model, cfg.d_model)
    if cfg.bayesian_head:
        params["head"] = bayes_layer.init(keys[5], cfg.head_bayes_cfg())
    else:
        params["head"] = {"w": blocks.dense_init(
            keys[5], cfg.d_model, cfg.vocab_padded)}
    return params


def _shared_block_full(h, h0, params, cfg: ModelConfig, positions):
    u = jnp.concatenate([h, h0], axis=-1) @ params["shared_w_in"].astype(h.dtype)
    u, _, kv, _ = _block_full(u, params["shared_attn"], cfg, positions,
                              causal=True)
    return h + u @ params["shared_w_out"].astype(h.dtype), kv


def _shared_block_decode(h, h0, params, cfg: ModelConfig, ck, cv, pos,
                         start=None):
    u = jnp.concatenate([h, h0], axis=-1) @ params["shared_w_in"].astype(h.dtype)
    u, ck, cv = _block_decode(u, params["shared_attn"], cfg, ck, cv, pos,
                              rolling=False, start=start)
    return h + u @ params["shared_w_out"].astype(h.dtype), ck, cv


def trunk_forward_ssm(params, tokens, cfg: ModelConfig,
                      collect_cache: bool = False):
    """-> (hidden [B,S,D], aux 0, caches dict|None)."""
    b, s = tokens.shape
    h = _wsc(params["embed"].astype(cfg.dtype)[tokens], cfg, None, None)
    h0 = h
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def mamba_body(h, lp):
        out, (st, cst) = mamba_block_full(h, lp, cfg)
        return _wsc(h + out, cfg, None, None), ((st, cst) if collect_cache else None)

    mamba_body_r = _maybe_remat(mamba_body, cfg)
    caches: dict | None = {} if collect_cache else None

    if cfg.hybrid_attn_every:
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]), params["mamba"])

        def group_fn(h, gp):
            h, states = lax.scan(mamba_body_r, h, gp)
            h, kv = _shared_block_full(h, h0, params, cfg, positions)
            return h, (states, kv if collect_cache else None)

        h, (states, kvs) = lax.scan(group_fn, h, grouped)
        if collect_cache:
            st, cst = states
            caches["ssm"] = st.reshape(-1, *st.shape[2:])
            caches["conv"] = cst.reshape(-1, *cst.shape[2:])
            caches["k"], caches["v"] = kvs          # [n_sites, B, S, Hkv, dh]
    else:
        h, states = lax.scan(mamba_body_r, h, params["mamba"])
        if collect_cache:
            caches["ssm"], caches["conv"] = states

    h = blocks.rms_norm(h, params["final_norm"])
    return h, jnp.zeros((), jnp.float32), caches


def train_loss_ssm(params, batch, cfg: ModelConfig, step=0):
    h, _, _ = trunk_forward_ssm(params, batch["tokens"], cfg)
    logits, kl = head_logits_train(params["head"], h, cfg, step)
    from repro.models.transformer import _model_ax
    logits = _wsc(logits, cfg, None, _model_ax(cfg, cfg.vocab_padded))
    ce = blocks.causal_cross_entropy(logits, batch["labels"], cfg.vocab)
    n_tokens = batch["tokens"].shape[0] * batch["tokens"].shape[1]
    return ce + cfg.kl_weight * kl / n_tokens, {"ce": ce, "kl": kl,
                                                "aux": jnp.zeros(())}


def prefill_ssm(params, tokens, cfg: ModelConfig, *, cache_len: int,
                prompt_lengths=None):
    """Returns (cache, last hidden [B, D]).  SSM state is O(1) in length;
    only the hybrid's shared-attn sites carry KV caches.

    ``prompt_lengths`` (continuous-batching admission): recorded as the
    per-slot ``start`` for the hybrid's attention sites.  The recurrent
    state itself absorbs left-pad tokens — a documented approximation
    (pad prefix ≈ a short neutral context), unlike the exact RoPE
    transformer path.  An exact path would re-run the bare prompt at
    slot-local positions (decode-stepping from a zeroed state).
    Quantified in tests/test_serving.py::
    test_ssm_leftpad_admission_pollution_quantified: ~30% relative
    hidden error at admission for a 4-token prompt behind 28 pad
    tokens, <5% within 3 decode steps (the selection gates decay the
    pad contribution geometrically).
    """
    b, s = tokens.shape
    h, _, caches = trunk_forward_ssm(params, tokens, cfg, collect_cache=True)
    cache = {"ssm": caches["ssm"], "conv": caches["conv"],
             "pos": jnp.int32(s)}
    if prompt_lengths is not None:
        cache["start"] = (s - prompt_lengths).astype(jnp.int32)
    if "k" in caches:
        sc = cache_len
        k, v = caches["k"], caches["v"]
        if s >= sc:
            k, v = k[:, :, s - sc:], v[:, :, s - sc:]
        else:
            pad = sc - s
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["k"], cache["v"] = k, v
    return cache, h[:, -1]


def decode_hidden_ssm(params, cache, token, cfg: ModelConfig):
    """Trunk-only decode step (no head): (last hidden [B, D], cache).

    SSM state is strictly slot-local, so the serving engine's mid-batch
    admission is exact here by construction: scattering a freshly
    prefilled state row into a pool slot carries everything the
    recurrence needs.
    """
    pos = cache["pos"]
    start = cache.get("start")
    h = params["embed"].astype(cfg.dtype)[token]             # [B, 1, D]
    h0 = h

    def mamba_body(h, xs):
        lp, st, cst = xs
        out, (st, cst) = mamba_block_decode(h, lp, cfg, st, cst)
        return h + out, (st, cst)

    if cfg.hybrid_attn_every:
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]), params["mamba"])
        ssm_g = cache["ssm"].reshape(n_groups, every, *cache["ssm"].shape[1:])
        conv_g = cache["conv"].reshape(n_groups, every, *cache["conv"].shape[1:])

        def group_fn(h, xs):
            gp, st, cst, ck, cv = xs
            h, (st, cst) = lax.scan(mamba_body, h, (gp, st, cst))
            h, ck, cv = _shared_block_decode(h, h0, params, cfg, ck, cv, pos,
                                             start=start)
            return h, (st, cst, ck, cv)

        h, (st, cst, ck, cv) = lax.scan(
            group_fn, h, (grouped, ssm_g, conv_g, cache["k"], cache["v"]))
        new_cache = dict(cache, ssm=st.reshape(-1, *st.shape[2:]),
                         conv=cst.reshape(-1, *cst.shape[2:]),
                         k=ck, v=cv, pos=pos + 1)
    else:
        h, (st, cst) = lax.scan(mamba_body, h,
                                (params["mamba"], cache["ssm"], cache["conv"]))
        new_cache = dict(cache, ssm=st, conv=cst, pos=pos + 1)

    h = blocks.rms_norm(h, params["final_norm"])
    return h[:, 0], new_cache


def decode_step_ssm(params, cache, token, cfg: ModelConfig):
    """One decode step: O(1) state updates per mamba layer."""
    pos = cache["pos"]
    x, new_cache = decode_hidden_ssm(params, cache, token, cfg)
    return apply_bayes_head(params, x, cfg, pos), new_cache
