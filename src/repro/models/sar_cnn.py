"""SAR detection model (paper §V-B): conv backbone + Bayesian last layer.

Stands in for YOLO26n at the assignment's scale: a small conv net whose
*final projection is the paper's Bayesian weight-decomposition layer*
(convert-only-the-last-layer, §V-B1), trained with Bayes-by-backprop on
the synthetic SARD task, served through the CLT-GRNG sampling modes.

Deterministic layers optionally execute through the CIM numeric path —
im2col + 8-bit weights + 64-deep 6-bit-ADC chunked matmul (core/cim.py),
exactly the paper's µ-only-subarray mapping ("1659 µ-only subarrays …
via im2col").  This is the configuration used to validate that CIM
quantization costs ~no accuracy (Table II "This*" rows).

Chip-instance execution (repro/hw): pass a ``hw.ChipInstance`` as
``chip`` to ``features``/``logit_samples_serve`` and every conv-as-
matmul layer runs through the NONIDEAL CIM kernel instead
(kernels/ops.cim_matmul_nonideal): 8-bit IDAC inputs and 8-bit weights,
conductance programming error on the written weight matrix
(``instance.program_weights``, one tag per conv array), and that die's
per-column ADC gain/offset front-end.  A zero-variation instance
(gain = 1, offset = 0, program_sigma = 0) is bit-identical to the ideal
chunked-ADC KERNEL pipeline (quantize → ``ops.cim_matmul``) — the
trunk-side acceptance criterion, enforced in
tests/test_hw_conformance.py.  It is close to but NOT bit-identical to
the pure-jnp ``cfg.cim_execution`` trunk (core/cim.cim_matmul): that
path calibrates the ADC full-scale from the full-batch partial-sum RMS
while the kernel wrapper samples 16 rows, and blocked-dot vs einsum
float ordering differs — calibration-level deltas, also bounded in the
conformance suite.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import bayes_layer
from repro.core.bayes_layer import BayesDenseConfig
from repro.core.cim import cim_matmul
from repro.core.clt_grng import GRNGConfig
from repro.core.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class SarCnnConfig:
    image_size: int = 32
    channels: tuple = (16, 32, 64)
    kernel: int = 3
    n_classes: int = 2
    bayesian_head: bool = True
    sigma_init: float = 0.05
    prior_sigma: float = 0.1
    kl_weight: float = 1e-4
    cim_execution: bool = False          # run convs through the CIM path
    quant: QuantConfig = dataclasses.field(
        default_factory=lambda: QuantConfig(enabled=True))
    grng: GRNGConfig = dataclasses.field(default_factory=GRNGConfig)

    def head_cfg(self) -> BayesDenseConfig:
        return BayesDenseConfig(
            d_in=self.channels[-1], d_out=self.n_classes,
            sigma_init=self.sigma_init, prior_sigma=self.prior_sigma,
            grng=self.grng)


def init_sar_cnn(key, cfg: SarCnnConfig) -> dict:
    params: dict = {"convs": []}
    c_in = 1
    keys = jax.random.split(key, len(cfg.channels) + 1)
    for i, c_out in enumerate(cfg.channels):
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.kernel**2 * c_in, jnp.float32))
        params["convs"].append({
            "w": jax.random.normal(
                keys[i], (cfg.kernel, cfg.kernel, c_in, c_out)) * scale,
            "b": jnp.zeros((c_out,)),
        })
        c_in = c_out
    if cfg.bayesian_head:
        params["head"] = bayes_layer.init(keys[-1], cfg.head_cfg())
    else:
        params["head"] = {"w": jax.random.normal(
            keys[-1], (cfg.channels[-1], cfg.n_classes)) * 0.05,
            "b": jnp.zeros((cfg.n_classes,))}
    return params


def _im2col(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """[B,H,W,C] -> patches [B, Ho, Wo, k*k*C] (the paper's CIM mapping)."""
    b, h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    idx_h = jnp.arange(ho) * stride
    idx_w = jnp.arange(wo) * stride
    patches = []
    for dy in range(k):
        for dx in range(k):
            patches.append(x[:, idx_h[:, None] + dy, idx_w[None, :] + dx, :])
    return jnp.concatenate(patches, axis=-1)


# program_weights tag space: the Bayesian head's µ/σε subarrays own
# tags 0/1 (hw/calib.py); conv-trunk arrays start here so co-located
# writes never share a programming-noise draw.
_TRUNK_TAG0 = 16


def _conv(x, w, b, cfg: SarCnnConfig, stride: int = 2, chip=None,
          layer_idx: int = 0):
    k = w.shape[0]
    if chip is not None:
        # This die's µ-only subarrays: quantize to the stored precision,
        # apply conductance programming error to the WRITTEN matrix,
        # then run the chunked-ADC kernel through the chip's per-column
        # gain/offset front-end.
        from repro.core import quant as q
        from repro.kernels import ops
        cols = _im2col(x, k, stride)                    # [B,Ho,Wo,k²C]
        bsz, ho, wo, d = cols.shape
        wmat = w.reshape(-1, w.shape[-1])               # [k²C, Cout]
        xq, _ = q.quantize_input(cols.reshape(-1, d), cfg.quant)
        wq, _ = q.quantize_mu(wmat, cfg.quant)
        wq = chip.program_weights(wq, tag=_TRUNK_TAG0 + layer_idx)
        pad = (-d) % cfg.quant.chunk                    # tile depth align
        xq = jnp.pad(xq, ((0, 0), (0, pad)))
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
        gain, off = chip.adc_columns(w.shape[-1])
        y = ops.cim_matmul_nonideal(xq, wq, cfg.quant,
                                    jnp.asarray(gain), jnp.asarray(off))
        y = y.reshape(bsz, ho, wo, -1)
    elif cfg.cim_execution:
        cols = _im2col(x, k, stride)                    # [B,Ho,Wo,k²C]
        bsz, ho, wo, d = cols.shape
        wmat = w.reshape(-1, w.shape[-1])               # [k²C, Cout]
        pad = (-d) % cfg.quant.chunk                    # tile depth align
        cols2 = jnp.pad(cols.reshape(-1, d), ((0, 0), (0, pad)))
        wmat2 = jnp.pad(wmat, ((0, pad), (0, 0)))
        y = cim_matmul(cols2, wmat2, cfg.quant)
        y = y.reshape(bsz, ho, wo, -1)
    else:
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


def features(params, images, cfg: SarCnnConfig, chip=None) -> jnp.ndarray:
    """Conv trunk -> GAP features [B, C].

    ``chip`` (a hw.ChipInstance): execute every conv on that die's
    nonideal CIM arrays — quantized weights with programming error, per-
    column ADC gain/offset.  Overrides ``cfg.cim_execution`` (a physical
    chip has no float conv units).
    """
    h = images
    for i, layer in enumerate(params["convs"]):
        h = _conv(h, layer["w"], layer["b"], cfg, chip=chip, layer_idx=i)
    return h.mean(axis=(1, 2))                          # GAP -> [B, C]


def logits_train(params, images, cfg: SarCnnConfig, step):
    feats = features(params, images, cfg)
    if cfg.bayesian_head:
        w = bayes_layer.sample_weights_at(params["head"], cfg.head_cfg(), step)
        kl = bayes_layer.kl_divergence(params["head"], cfg.head_cfg())
        return feats @ w, kl
    return feats @ params["head"]["w"] + params["head"]["b"], jnp.zeros(())


def train_loss(params, batch, cfg: SarCnnConfig, step):
    logits, kl = logits_train(params, batch["images"], cfg, step)
    labels = batch["labels"]
    ce = -jnp.take_along_axis(jax.nn.log_softmax(logits), labels[:, None],
                              axis=1).mean()
    return ce + cfg.kl_weight * kl / batch["images"].shape[0], {
        "ce": ce, "kl": kl,
        "acc": (logits.argmax(-1) == labels).mean()}


def logit_samples_serve(params, images, cfg: SarCnnConfig, num_samples: int,
                        mode: str = "rank16", sample0: int = 0, chip=None):
    """MC logit samples through the CLT-GRNG serving path. [R, B, C].

    ``chip`` routes the conv trunk through that die's nonideal CIM
    arrays (see ``features``).  The head here stays the golden factory
    transform — deploy the head onto the same die with
    ``hw.calib.prepare_instance_head`` and sample via core/sampling for
    the fully-nonideal path (what serve_sar --chip-instance does).
    """
    from repro.core.sampling import BayesHeadConfig, logit_samples
    from repro.core.bayes_layer import sigma_of, to_serving
    feats = features(params, images, cfg, chip=chip)
    if not cfg.bayesian_head:
        logits = feats @ params["head"]["w"] + params["head"]["b"]
        return logits[None]
    hcfg = BayesHeadConfig(num_samples=num_samples, mode=mode, grng=cfg.grng,
                           compute_dtype=jnp.float32)
    head = to_serving(params["head"], hcfg)
    return logit_samples(head, feats, hcfg, sample0=sample0)


def logit_samples_ideal(params, images, cfg: SarCnnConfig, num_samples: int,
                        key) -> jnp.ndarray:
    """Ideal-Gaussian ablation (paper's 'BNN' rows): w = µ + σ·N(0,1)."""
    from repro.core.bayes_layer import sigma_of
    feats = features(params, images, cfg)
    mu, sigma = params["head"]["mu"], sigma_of(params["head"])
    eps = jax.random.normal(key, (num_samples,) + mu.shape)
    w = mu[None] + sigma[None] * eps
    return jnp.einsum("bd,rdc->rbc", feats, w)
