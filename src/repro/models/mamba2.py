"""Mamba2 (state-space duality, SSD) — attention-free trunk.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060):
within a chunk the quadratic dual form runs on the MXU; across chunks a
cheap [B, H, P, N] state is carried by lax.scan.  Decode is a single
O(1) recurrent state update — which is why mamba2/zamba2 are the archs
assigned to the 500k-token long-context cell.

Layer = in_proj → causal depthwise conv (shift-add form) → SSD →
gated RMSNorm → out_proj, mirroring the reference mamba2 block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import blocks


def mamba_dims(cfg) -> dict:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    d_proj = 2 * d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + nheads
    return dict(d_inner=d_inner, nheads=nheads, conv_dim=conv_dim,
                d_proj=d_proj, d_state=cfg.ssm_state, ngroups=cfg.ssm_ngroups,
                headdim=cfg.ssm_headdim, d_conv=cfg.ssm_conv)


def init_mamba_stack(key, cfg, l: int) -> dict:
    dims = mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    dt = jnp.float32
    return {
        "in_proj": jax.vmap(lambda k: blocks.dense_init(k, d, dims["d_proj"], dt))(
            jax.random.split(ks[0], l)),
        "conv_w": (jax.random.normal(ks[1], (l, dims["conv_dim"], dims["d_conv"]))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((l, dims["conv_dim"]), dt),
        "dt_bias": jnp.zeros((l, dims["nheads"]), dt),
        "A_log": jnp.zeros((l, dims["nheads"]), dt),       # A = -exp(0) = -1
        "D": jnp.ones((l, dims["nheads"]), dt),
        "norm": jnp.ones((l, dims["d_inner"]), dt),
        "out_proj": jax.vmap(
            lambda k: blocks.dense_init(k, dims["d_inner"], d, dt))(
            jax.random.split(ks[2], l)),
    }


def _causal_conv_full(x, w, b):
    """Depthwise causal conv as shift-adds. x: [B,S,C], w: [C,K]."""
    k = w.shape[-1]
    y = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        y = y + xi * w[:, i].astype(x.dtype)
    return y + b.astype(x.dtype)


def _segsum_exp(a):
    """L[i,j] = exp(Σ_{j<t<=i} a_t) for i>=j else 0. a: [..., Q]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # [..., Q, Q]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, state0=None):
    """Chunked SSD scan.

    x:  [B, S, H, P]   inputs (pre dt-scaling)
    dt: [B, S, H]      positive step sizes
    a:  [H]            negative decay rates
    b_mat, c_mat: [B, S, G, N]
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, "sequence must be chunk-aligned"
    nc = s // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, g, n)
    cc = c_mat.reshape(bsz, nc, chunk, g, n)
    # move chunk axis first for scan
    xc = jnp.moveaxis(xc, 1, 0)
    dtc = jnp.moveaxis(dtc, 1, 0)
    bc = jnp.moveaxis(bc, 1, 0)
    cc = jnp.moveaxis(cc, 1, 0)

    def chunk_step(state, inp):
        xq, dtq, bq, cq = inp                      # [B,Q,H,P] [B,Q,H] [B,Q,G,N]
        adt = dtq * a[None, None, :]               # [B,Q,H]
        adt_t = jnp.moveaxis(adt, -1, 1)           # [B,H,Q]
        cum = jnp.cumsum(adt_t, axis=-1)           # [B,H,Q]
        lmat = _segsum_exp(adt_t)                  # [B,H,Q,Q]
        bq_h = jnp.repeat(bq, rep, axis=2)         # [B,Q,H,N]
        cq_h = jnp.repeat(cq, rep, axis=2)
        xdt = xq * dtq[..., None]                  # [B,Q,H,P]

        scores = jnp.einsum("bzhn,bshn->bhzs", cq_h, bq_h)  # [B,H,Q,Q]
        y_diag = jnp.einsum("bhzs,bshp->bzhp", scores * lmat, xdt)

        decay_out = jnp.exp(cum)                   # [B,H,Q]
        y_off = jnp.einsum("bzhn,bhpn,bhz->bzhp", cq_h, state, decay_out)

        decay_st = jnp.exp(cum[..., -1:] - cum)    # [B,H,Q]
        new_contrib = jnp.einsum("bshn,bhs,bshp->bhpn", bq_h, decay_st, xdt)
        state = state * jnp.exp(cum[..., -1])[..., None, None] + new_contrib
        return state, y_diag + y_off

    if state0 is None:
        state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    state, ys = lax.scan(chunk_step, state0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, state


def mamba_block_full(h, lp, cfg, state0=None, conv_state0=None):
    """Full-sequence mamba2 block. Returns (h, (ssm_state, conv_state))."""
    dims = mamba_dims(cfg)
    bsz, s, _ = h.shape
    d_in, nh, hd = dims["d_inner"], dims["nheads"], dims["headdim"]
    g, n = dims["ngroups"], dims["d_state"]

    zxbcdt = h @ lp["in_proj"].astype(h.dtype)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_in, d_in + dims["conv_dim"]], axis=-1)
    # conv (with optional carried state: prepend, conv, strip)
    if conv_state0 is not None:
        xbc_ext = jnp.concatenate(
            [conv_state0.astype(xbc.dtype).transpose(0, 2, 1), xbc], axis=1)
        y = _causal_conv_full(xbc_ext, lp["conv_w"], lp["conv_b"])
        xbc_conv = y[:, conv_state0.shape[2]:]
    else:
        xbc_conv = _causal_conv_full(xbc, lp["conv_w"], lp["conv_b"])
    xbc_conv = jax.nn.silu(xbc_conv)
    x, b_mat, c_mat = jnp.split(xbc_conv, [d_in, d_in + g * n], axis=-1)

    x = x.reshape(bsz, s, nh, hd).astype(jnp.float32)
    b_mat = b_mat.reshape(bsz, s, g, n).astype(jnp.float32)
    c_mat = c_mat.reshape(bsz, s, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None])
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))

    y, state = ssd_chunked(x, dt, a, b_mat, c_mat, cfg.ssm_chunk, state0)
    y = y + x * lp["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, s, d_in).astype(h.dtype)
    y = blocks.rms_norm(y * jax.nn.silu(z), lp["norm"])
    out = y @ lp["out_proj"].astype(h.dtype)
    # conv state: last (K-1) raw xbc inputs, [B, conv_dim, K-1]
    new_conv_state = xbc[:, -(dims["d_conv"] - 1):].transpose(0, 2, 1)
    return out, (state, new_conv_state)


def mamba_block_decode(h, lp, cfg, ssm_state, conv_state):
    """Single-token mamba2 step. h: [B, 1, D]. O(1) state update."""
    dims = mamba_dims(cfg)
    bsz = h.shape[0]
    d_in, nh, hd = dims["d_inner"], dims["nheads"], dims["headdim"]
    g, n, k = dims["ngroups"], dims["d_state"], dims["d_conv"]

    zxbcdt = (h[:, 0] @ lp["in_proj"].astype(h.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + dims["conv_dim"]], axis=-1)

    # conv: state holds last K-1 inputs [B, conv_dim, K-1]
    w = lp["conv_w"].astype(jnp.float32)                   # [conv_dim, K]
    hist = conv_state.astype(jnp.float32)
    xbc32 = xbc.astype(jnp.float32)
    y = (hist * w[None, :, :k - 1]).sum(-1) + xbc32 * w[None, :, k - 1]
    y = jax.nn.silu(y + lp["conv_b"].astype(jnp.float32)[None])
    new_conv_state = jnp.concatenate([hist[:, :, 1:], xbc32[:, :, None]],
                                     axis=-1)

    x, b_mat, c_mat = jnp.split(y, [d_in, d_in + g * n], axis=-1)
    x = x.reshape(bsz, nh, hd)
    b_mat = jnp.repeat(b_mat.reshape(bsz, g, n), nh // g, axis=1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, g, n), nh // g, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None])
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))

    decay = jnp.exp(dt * a[None])                          # [B, H]
    ssm_state = (ssm_state * decay[..., None, None]
                 + jnp.einsum("bhp,bhn,bh->bhpn", x, b_mat, dt))
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, c_mat)
    y = y + x * lp["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_in).astype(h.dtype)
    y = blocks.rms_norm(y * jax.nn.silu(z), lp["norm"])
    out = (y @ lp["out_proj"].astype(h.dtype))[:, None]
    return out, (ssm_state, new_conv_state)
