"""Attention: GQA + RoPE + qk-norm + sliding window, memory-efficient.

Training/prefill use a flash-style chunked attention (online softmax,
lax.scan over KV chunks inside lax.map over Q chunks) so that 32k-token
prefill never materializes an S×S score matrix — required for the
dry-run memory analysis to be honest at seq 32768.

Decode uses a direct cache read (scores are [B, H, 1, S] — small).  The
sliding-window (SWA) decode path supports a *rolling* cache of size
``window`` so mixtral's long_500k cell runs with O(window) state.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Repeat KV heads to the full query head count (Megatron-style GQA:
    replicating KV across the group lets the head dim shard cleanly on
    the 'model' axis — per-device bytes are identical to replication,
    but score/prob tensors become head-sharded instead of replicated)."""
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // hkv, axis=2)


def chunked_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                      q_offset=0, kv_valid_len=None, kv_start=None,
                      chunk_q: int = 512, chunk_kv: int = 1024):
    """Flash-style attention. q: [B,Sq,H,dh]; k,v: [B,Sk,H,dh] (callers
    expand GQA KV heads via ``expand_kv`` so the head dim stays intact —
    and 'model'-sharded — through every intermediate).

    q_offset: global position of q[0] (for prefill continuation).
    kv_valid_len: number of valid kv entries (None = Sk).
    kv_start: optional [B] int32 — first valid kv position per batch row
    (left-padded prompts in a continuous-batching pool; earlier
    positions are masked out so pad tokens never leak into attention).
    Returns [B, Sq, H, dh] in q.dtype; accumulation in f32.
    """
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    assert hkv == hq, "expand KV heads before calling (expand_kv)"
    scale = 1.0 / math.sqrt(dh)
    chunk_q = min(chunk_q, sq)
    chunk_kv = min(chunk_kv, sk)

    pad_q = (-sq) % chunk_q
    pad_k = (-sk) % chunk_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = qp.shape[1] // chunk_q, kp.shape[1] // chunk_kv
    valid = sk if kv_valid_len is None else kv_valid_len

    # [nq, B, Qc, H, dh] — q chunks as the mapped axis.
    qs = qp.reshape(b, nq, chunk_q, hq, dh).transpose(1, 0, 2, 3, 4)
    ks = kp.reshape(b, nk, chunk_kv, hq, dh).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, nk, chunk_kv, hq, dh).transpose(1, 0, 2, 3, 4)

    def one_q_chunk(args):
        qc, iq = args                                  # [B,Qc,H,dh]
        q_pos = (q_offset + iq * chunk_q
                 + jnp.arange(chunk_q))                # [Qc]
        qcf = qc.astype(jnp.float32) * scale

        def kv_step(carry, blk):
            m, l, acc = carry
            k_blk, v_blk, ik = blk
            kv_pos = ik * chunk_kv + jnp.arange(chunk_kv)   # [Kc]
            s = jnp.einsum("bqhd,bkhd->bhqk", qcf,
                           k_blk.astype(jnp.float32))        # [B,H,Qc,Kc]
            mask = kv_pos[None, :] < valid                   # padding
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
            mask = mask[None, None]                          # [1,1,Qc,Kc]
            if kv_start is not None:
                bmask = kv_pos[None, :] >= kv_start[:, None]  # [B,Kc]
                mask = mask & bmask[:, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))                # [B,H,Qc]
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bhqk,bkhd->bhqd", p,
                                    v_blk.astype(jnp.float32)))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, hq, chunk_q, dh), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)         # [B,H,Qc,dh]
        return out.transpose(0, 2, 1, 3)                     # [B,Qc,H,dh]

    # Flash semantics in the backward too: recompute scores/probs per
    # q-chunk instead of saving [nq, nk, B, H, Qc, Kc] f32 residuals.
    one_q_chunk = jax.checkpoint(
        one_q_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    outs = lax.map(one_q_chunk, (qs, jnp.arange(nq)))        # [nq,B,Qc,H,dh]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * chunk_q, hq, dh)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q, cache_k, cache_v, pos, *, window: int | None = None,
                     rolling: bool = False, start=None):
    """Single-token attention against a KV cache.

    q: [B, 1, Hq, dh]; cache_k/v: [B, Sc, Hkv, dh]; pos: scalar int32 —
    number of tokens already in the cache (the new token's position,
    already inserted).  With ``rolling`` the cache is a circular buffer
    of size Sc=window.  ``start`` is an optional [B] int32 of first
    valid cache positions — slots admitted mid-stream by the serving
    engine carry left-padded prompts whose pad region must stay masked.

    (§Perf I5 post-mortem: an S-minor cache layout + separate self-token
    score column measured WORSE under the CPU SPMD partitioner — concat
    on the sharded S axis forces resharding, and carry-threading the
    cache copies it wholesale per layer.  Reverted; see EXPERIMENTS.md.)
    """
    b, _, hq, dh = q.shape
    _, sc, hkv, _ = cache_k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qf = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, cache_k.astype(jnp.float32))
    idx = jnp.arange(sc)
    if rolling:
        # Slot s holds global position p = pos - ((pos - s) mod Sc); valid
        # if 0 <= p <= pos and within the window.
        p = pos - ((pos - idx) % sc)
        mask = (p >= 0) & (p <= pos)
        if window is not None:
            mask = mask & (p > pos - window)
    else:
        mask = idx <= pos
        if window is not None:
            mask = mask & (idx > pos - window)
    mask = mask[None, None, None]                      # [1,1,1,Sc]
    if start is not None and not rolling:
        mask = mask & (idx[None, :] >= start[:, None])[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def cache_update(cache_k, cache_v, k_new, v_new, pos, *, rolling: bool = False):
    """Insert [B, 1, Hkv, dh] entries at position ``pos`` (rolling: pos % Sc)."""
    sc = cache_k.shape[1]
    slot = (pos % sc) if rolling else pos
    ck = lax.dynamic_update_slice(cache_k, k_new, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache_v, v_new, (0, slot, 0, 0))
    return ck, cv
