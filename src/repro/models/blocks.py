"""Shared model building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays; every block exposes
``init_*`` and a functional apply.  Layer stacks are `lax.scan`-stacked
(leading L dim on every leaf) for compile-time sanity at 94-layer ×
512-device scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _rms_stats(x, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return jax.lax.rsqrt(var + eps)


@jax.custom_vjp
def _rms_norm_core(x, scale, eps):
    inv = _rms_stats(x, eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def _rms_fwd(x, scale, eps):
    inv = _rms_stats(x, eps)
    y = x * inv.astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, inv, scale, eps)


def _rms_bwd(res, g):
    # §Perf I2c: autodiff of an f32-upcast norm emits f32 [B,S,D]
    # cotangents that flow up the residual chain and turn every backward
    # all-reduce f32 (2× wire).  This custom backward keeps all [B,S,D]
    # tensors in x.dtype; only [B,S,1] reductions run f32.
    x, inv, scale, eps = res
    inv_x = inv.astype(x.dtype)
    sc = scale.astype(x.dtype)
    d = x.shape[-1]
    proj = jnp.sum((g * sc * x).astype(jnp.float32), axis=-1,
                   keepdims=True)                       # [B,S,1] f32
    coef = (inv ** 3 * proj / d).astype(x.dtype)        # [B,S,1]
    dx = g * sc * inv_x - x * coef
    dscale = jnp.sum((g * x * inv_x).astype(jnp.float32),
                     axis=tuple(range(g.ndim - 1))).astype(scale.dtype)
    return dx, dscale, None


_rms_norm_core.defvjp(_rms_fwd, _rms_bwd)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """Statistics in f32, application AND backward in x.dtype.

    §Perf I2c: upcasting the whole tensor creates [B,S,D]-f32 consumers
    (and f32 cotangents) that XLA's partitioner sinks into adjacent
    collectives.  Both directions stay in x.dtype here; only [B,S,1]
    reductions are f32.
    """
    return _rms_norm_core(x, scale, eps)


def init_rms_norm(dim: int) -> jnp.ndarray:
    return jnp.ones((dim,), jnp.float32)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    """Moments in f32, application in x.dtype (see rms_norm note)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return ((x - mean.astype(x.dtype)) * inv.astype(x.dtype)
            * scale.astype(x.dtype) + bias.astype(x.dtype))


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


def swiglu(x: jnp.ndarray, wi: jnp.ndarray, wg: jnp.ndarray,
           wo: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: silu(x·wg) ⊙ (x·wi) · wo."""
    h = jax.nn.silu(x @ wg) * (x @ wi)
    return h @ wo


def gelu_mlp(x: jnp.ndarray, wi: jnp.ndarray, bi, wo: jnp.ndarray, bo):
    """GELU MLP with biases (whisper-style)."""
    h = jax.nn.gelu(x @ wi + bi, approximate=True)
    return h @ wo + bo


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [B, S, H, dh]; positions: [B, S] (or [S]) int32.

    Trig tables in f32, rotation applied in x.dtype (see rms_norm note —
    an f32 rotation would drag the K all-gathers up to f32).
    """
    freqs = rope_frequencies(x.shape[-1], theta)            # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, dh/2]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)     # [B, S, 1, dh/2]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def causal_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                         vocab_real: int) -> jnp.ndarray:
    """Mean next-token CE; logits [B, S, Vp] (padded vocab), labels [B, S].

    Padded vocab columns are masked to -inf so they never receive mass.
    """
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp > vocab_real:
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < vocab_real, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
