"""Family dispatch: one API surface over all model families.

Every family exposes: init(key, cfg) -> params, train_loss(params,
batch, cfg, step), prefill(params, tokens, cfg, cache_len, **extras),
decode_step(params, cache, token, cfg).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.models import ssm_lm, transformer
from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    decode_hidden: Callable = None   # trunk-only decode (serving engine)
    needs_frames: bool = False
    needs_images: bool = False


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family in ("ssm", "hybrid"):
        return ModelAPI(
            init=ssm_lm.init_ssm_lm,
            train_loss=ssm_lm.train_loss_ssm,
            prefill=ssm_lm.prefill_ssm,
            decode_step=ssm_lm.decode_step_ssm,
            decode_hidden=ssm_lm.decode_hidden_ssm,
        )
    return ModelAPI(
        init=transformer.init_transformer,
        train_loss=transformer.train_loss,
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        decode_hidden=transformer.decode_hidden,
        needs_frames=cfg.family == "audio",
        needs_images=cfg.family == "vlm",
    )
