"""Mixture-of-Experts layer: top-k routing with sort-based dispatch.

Capacity-bounded, drop-on-overflow dispatch implemented with argsort +
scatter (static shapes throughout — XLA/GSPMD friendly, no [T,E,C]
one-hot dispatch tensors).  Expert weights carry a leading E dim that is
expert-parallel-sharded on the 'model' mesh axis when E divides the axis
(qwen3-moe: 128 experts / 16 = 8 per device); otherwise tensor-parallel
inside each expert (mixtral: 8 experts, d_ff sharded).

Aux load-balancing loss follows Switch/Mixtral: E · Σ_e f_e · p_e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def init_moe(key, l: int, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(jnp.asarray(d_model, jnp.float32))
    scale_out = 1.0 / jnp.sqrt(jnp.asarray(d_ff, jnp.float32))
    return {
        "router": jax.random.normal(ks[0], (l, d_model, n_experts), dtype) * scale_in,
        "wi": jax.random.normal(ks[1], (l, n_experts, d_model, d_ff), dtype) * scale_in,
        "wg": jax.random.normal(ks[2], (l, n_experts, d_model, d_ff), dtype) * scale_in,
        "wo": jax.random.normal(ks[3], (l, n_experts, d_ff, d_model), dtype) * scale_out,
    }


def moe_apply(x: jnp.ndarray, router: jnp.ndarray, wi: jnp.ndarray,
              wg: jnp.ndarray, wo: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25,
              ep_axis: str | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    Dispatch: flatten (token, choice) pairs, argsort by expert id,
    compute each pair's slot within its expert's capacity-padded buffer,
    scatter, run batched expert matmuls [E,C,D]×[E,D,F], gather back.
    Overflow pairs land in a trash slot and contribute zero.
    """
    b, s, d = x.shape
    e = router.shape[-1]
    t = b * s
    xf = x.reshape(t, d)

    gates = jax.nn.softmax(
        (xf.astype(jnp.float32) @ router.astype(jnp.float32)), axis=-1)  # [T,E]
    weights, expert_idx = jax.lax.top_k(gates, top_k)                    # [T,k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Aux load-balancing loss (computed before any dropping).
    frac_tokens = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (t * top_k))
    frac_probs = gates.mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    capacity = int(max(top_k, capacity_factor * t * top_k / e))

    sel = expert_idx.reshape(-1)                       # [S_all = T*k]
    order = jnp.argsort(sel)                           # stable
    sel_sorted = sel[order]
    token_sorted = order // top_k
    # Position of each pair within its expert's run.
    run_start = jnp.searchsorted(sel_sorted, jnp.arange(e), side="left")
    pos_in_run = jnp.arange(t * top_k) - run_start[sel_sorted]
    keep = pos_in_run < capacity
    slot = jnp.where(keep, sel_sorted * capacity + pos_in_run,
                     e * capacity)                     # trash slot

    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[token_sorted])
    xe = buf[: e * capacity].reshape(e, capacity, d)
    if ep_axis:  # expert-parallel dispatch boundary (GSPMD all-to-all)
        from jax.sharding import PartitionSpec as P
        xe = jax.lax.with_sharding_constraint(xe, P(ep_axis, None, None))

    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
         * jnp.einsum("ecd,edf->ecf", xe, wi))
    ye = jnp.einsum("ecf,efd->ecd", h, wo)             # [E,C,D]
    if ep_axis:
        from jax.sharding import PartitionSpec as P
        ye = jax.lax.with_sharding_constraint(ye, P(ep_axis, None, None))

    yf = ye.reshape(e * capacity, d)
    y_pairs = jnp.where(keep[:, None], yf[jnp.minimum(slot, e * capacity - 1)],
                        0.0)                           # [T*k, D] sorted order
    w_pairs = weights.reshape(-1)[order]
    contrib = y_pairs * w_pairs[:, None].astype(y_pairs.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_sorted].add(contrib)
    return y.reshape(b, s, d), aux


# ----------------------------------------------------------------------
# Manual (shard_map) dispatch — §Perf I1
# ----------------------------------------------------------------------
# The GSPMD path above lets XLA partition a *global* argsort and
# global-capacity buffers — at 1M tokens × 94 layers that lowers to
# thousands of seconds of collectives (see EXPERIMENTS.md baseline).
# But MoE routing is embarrassingly parallel over the batch: activations
# are sharded over the DP axes and REPLICATED over 'model', while
# experts are sharded over 'model'.  So every device can route its local
# tokens to its local experts with ZERO dispatch communication; the only
# collective left is the same psum a dense TP MLP needs, plus the
# explicit FSDP all-gather of the expert weights.


def _dispatch_local(xf, expert_idx, weights, e0: int, e_loc: int,
                    capacity: int):
    """Local-token → local-expert dispatch (no collectives).

    xf: [T, D]; expert_idx/weights: [T, k] global expert ids + gates.
    Selects pairs with e0 <= id < e0+e_loc, packs them into
    [e_loc, capacity, D].  Returns (xe, slot, keep, token_sorted,
    w_sorted) for the combine step.
    """
    t, d = xf.shape
    k = expert_idx.shape[1]
    sel = expert_idx.reshape(-1) - e0                  # [T*k]
    mine = (sel >= 0) & (sel < e_loc)
    sel_c = jnp.where(mine, sel, e_loc)                # foreign -> sentinel
    order = jnp.argsort(sel_c)
    # §Perf I1b: sorted order puts LOCAL experts first — only the head of
    # the sorted pair list can land in the capacity buffers.  Slicing to
    # 2·e_loc·capacity statically shrinks every [T·k, D] dispatch gather
    # ~(E/e_loc)/2× (6.4× for qwen3-moe EP=16).  The 2× slack absorbs
    # early-expert overflow; beyond that, pairs drop exactly as capacity
    # overflow does.  TP-F (e_loc=E) keeps the full list.
    q = min(t * k, 2 * e_loc * capacity)
    order_q = order[:q]
    sel_sorted = sel_c[order_q]
    token_sorted = order_q // k
    run_start = jnp.searchsorted(sel_sorted, jnp.arange(e_loc), side="left")
    pos_in_run = jnp.arange(q) - run_start[jnp.minimum(sel_sorted,
                                                       e_loc - 1)]
    keep = (sel_sorted < e_loc) & (pos_in_run < capacity)
    slot = jnp.where(keep, sel_sorted * capacity + pos_in_run,
                     e_loc * capacity)
    buf = jnp.zeros((e_loc * capacity + 1, d), xf.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xf[token_sorted], 0))
    xe = buf[: e_loc * capacity].reshape(e_loc, capacity, d)
    w_sorted = weights.reshape(-1)[order_q].astype(xf.dtype)
    return xe, slot, keep, token_sorted, w_sorted


def make_sharded_moe(mesh, *, top_k: int, capacity_factor: float,
                     n_experts: int, dp_axes: tuple):
    """Returns moe(x, router, wi, wg, wo) -> (y, aux) using manual
    collectives.  Expert placement follows sharding/specs.py: experts on
    'model' when divisible (EP), else d_ff on 'model' (TP-F)."""
    from jax.sharding import PartitionSpec as P

    model_size = mesh.shape["model"]
    ep = n_experts % model_size == 0
    dp = tuple(dp_axes)

    def body(x_loc, router, wi_loc, wg_loc, wo_loc):
        # local shapes: x [B_loc, S, D]; router [D, E] replicated;
        # EP:  wi [E_loc, D/fsdp, F]  TP-F: wi [E, D/fsdp, F_loc]
        b_loc, s, d = x_loc.shape
        wi_f = lax.all_gather(wi_loc, "data", axis=1, tiled=True)
        wg_f = lax.all_gather(wg_loc, "data", axis=1, tiled=True)
        wo_f = lax.all_gather(wo_loc, "data", axis=2, tiled=True)
        e = router.shape[-1]
        e_loc = wi_f.shape[0]
        e0 = (lax.axis_index("model") * e_loc) if ep else 0

        t_loc = b_loc * s
        xf = x_loc.reshape(t_loc, d)
        gates = jax.nn.softmax(
            xf.astype(jnp.float32) @ router.astype(jnp.float32), axis=-1)
        weights, expert_idx = lax.top_k(gates, top_k)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

        frac_tokens = jnp.zeros((e,), jnp.float32).at[
            expert_idx.reshape(-1)].add(1.0 / (t_loc * top_k))
        aux = e * jnp.sum(frac_tokens * gates.mean(0))
        for ax in dp:
            aux = lax.pmean(aux, ax)

        capacity = int(max(top_k, capacity_factor * t_loc * top_k / e))
        xe, slot, keep, token_sorted, w_sorted = _dispatch_local(
            xf, expert_idx, weights, e0, e_loc, capacity)

        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg_f))
             * jnp.einsum("ecd,edf->ecf", xe, wi_f))
        ye = jnp.einsum("ecf,efd->ecd", h, wo_f)
        yf = ye.reshape(e_loc * capacity, d)
        y_pairs = jnp.where(keep[:, None],
                            yf[jnp.minimum(slot, e_loc * capacity - 1)], 0.0)
        contrib = y_pairs * w_sorted[:, None].astype(y_pairs.dtype)
        y = jnp.zeros((t_loc, d), x_loc.dtype).at[token_sorted].add(contrib)
        # EP: each model shard produced its experts' share; TP-F: each
        # shard produced a partial over F.  Both finish with one psum.
        y = lax.psum(y, "model")
        return y.reshape(b_loc, s, d), aux

    if ep:
        wi_spec = P("model", "data", None)
        wo_spec = P("model", None, "data")
    else:
        wi_spec = P(None, "data", "model")
        wo_spec = P(None, "model", "data")

    from repro.launch.mesh import shard_map_compat
    smapped = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), wi_spec, wi_spec,
                  wo_spec),
        out_specs=(P(dp, None, None), P()),
    )

    def moe(x, router, wi, wg, wo):
        return smapped(x, router, wi, wg, wo)

    return moe
